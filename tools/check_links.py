#!/usr/bin/env python3
"""Fail on broken intra-repo markdown links (the CI docs gate).

Scans every tracked ``*.md`` file for inline links/images
(``[text](target)``), ignores external schemes and pure anchors, strips
``#fragment`` suffixes, and checks the target exists relative to the
linking file (or the repo root for ``/``-prefixed targets).

Usage:  python tools/check_links.py  [paths...]
Exit status 1 lists every broken link as file:line.
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# inline links and images; [text](target "title") tolerated
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def md_files(args: list[str]) -> list[Path]:
    if args:
        # relative arguments are taken relative to the caller's CWD
        return [Path(a).resolve() for a in args]
    out = subprocess.run(["git", "ls-files", "*.md", "**/*.md"],
                         capture_output=True, text=True, cwd=REPO)
    return [REPO / line for line in out.stdout.splitlines() if line]


def display(path: Path) -> str:
    try:
        return str(path.relative_to(REPO))
    except ValueError:
        return str(path)


def check_file(path: Path) -> list[str]:
    errors = []
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            if target.startswith("/"):
                resolved = REPO / target.lstrip("/")
            else:
                resolved = (path.parent / target).resolve()
            if not resolved.exists():
                errors.append(f"{display(path)}:{lineno}: "
                              f"broken link -> {m.group(1)}")
    return errors


def main() -> int:
    files = md_files(sys.argv[1:])
    errors = []
    for f in files:
        if f.exists():
            errors.extend(check_file(f))
    if errors:
        print(f"{len(errors)} broken intra-repo link(s):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"checked {len(files)} markdown file(s): all intra-repo links OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
