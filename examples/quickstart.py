"""Quickstart: train a continuous-time digital twin of the HP memristor
in ~30 s on CPU, then deploy it onto simulated analogue memristor arrays.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.analogue import AnalogueSpec
from repro.core.backends import AnalogueBackend
from repro.train import recipes


def main():
    print("=== training neural-ODE digital twin of the HP memristor ===")
    twin, params, loss = recipes.train_hp_twin(pretrain_steps=300,
                                               train_steps=400)
    print(f"final training loss (L1): {loss:.5f}")

    print("\n=== evaluation across stimulation waveforms (paper Fig. 3f/j) ===")
    for wf in ["sine", "triangular", "rectangular", "modulated_sine"]:
        m = recipes.eval_hp_twin(twin, params, wf)
        print(f"  {wf:>15s}:  MRE {m['mre']:.3f}   DTW/pt {m['dtw']:.4f}")

    print("\n=== analogue deployment (6-bit, 4.36% programming noise) ===")
    spec = AnalogueSpec(prog_noise=0.0436, read_noise=0.02)
    a_twin = twin.with_backend(
        AnalogueBackend(spec=spec, prog_key=jax.random.PRNGKey(0),
                        read_key=jax.random.PRNGKey(1)))
    m = recipes.eval_hp_twin(twin, params, "sine")
    pred = a_twin.simulate(params, jnp.array([m["true"][0]]), m["ts"])[:, 0]
    from repro.core.losses import mre
    print(f"  analogue twin MRE vs ground truth: "
          f"{float(mre(pred, m['true'])):.3f}")

    from repro.core import energy
    row = energy.hp_projection()[-1]
    print("\n=== projected gains at hidden 64 (paper Fig. 3k,l) ===")
    print(f"  speed vs NODE-on-GPU:  x{row['node_gpu_speed_gain']:.1f} "
          f"(paper: 4.2)")
    print(f"  energy vs NODE-on-GPU: x{row['node_gpu_energy_gain']:.1f} "
          f"(paper: 41.4)")


if __name__ == "__main__":
    main()
