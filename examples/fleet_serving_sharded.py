"""Sharded fleet serving, end to end: checkpoint -> mesh -> 1024 twins.

The production deployment story in one script (the Lorenz96 scenario,
paper Fig. 4 scaled out):

  1. obtain trained twin weights (a quick derivative-matching fit here;
     any ``train_l96_twin`` result drops in) and persist them with
     ``checkpoint.save_twin`` — the hand-off from training to serving;
  2. build the twin mesh over every visible device and stream request
     batches through ``serve_fleet``: weights are replicated once, the
     fleet axis (per-asset initial conditions) is sharded with
     ``shard_map``, each device rolls out its slice through the
     fused-Pallas (or digital) backend;
  3. verify the sharded trajectories match a plain single-device
     ``TwinFleet`` rollout (<= 1e-5) — sharding changes placement, not
     numerics.

On this host the mesh may be a single device (the sharded path
degenerates to the same program); on a pod the same script scales the
fleet linearly across chips.

Run:  PYTHONPATH=src python examples/fleet_serving_sharded.py [--smoke]
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core.twin import TwinFleet
from repro.launch.fleet_serving import serve_fleet
from repro.launch.mesh import make_twin_mesh, twin_shard_count
from repro.train import checkpoint as ckpt_lib
from repro.train import recipes, trainer
from repro.train.optimizer import adam

PARITY_TOL = 1e-5


def quick_train(fleet, steps: int):
    """Derivative-matching fit on the paper's Lorenz96 data — cheap but
    real trained weights (the full recipe is ``recipes.train_l96_twin``)."""
    params = fleet.twin.init(jax.random.PRNGKey(7))
    if steps <= 0:
        return params
    ts, ys, split = recipes.l96_data()
    params, hist = trainer.pretrain_derivatives(
        fleet.twin.field, params, ts[:split], ys[:split],
        optimizer=adam(3e-3), num_steps=steps)
    print(f"  trained {steps} derivative-matching steps "
          f"(loss {float(hist[-1]):.4f})")
    return params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (small fleet, no training)")
    ap.add_argument("--fleet", type=int, default=0,
                    help="override fleet size (default 1024; smoke 64)")
    args = ap.parse_args(argv)

    n = args.fleet or (64 if args.smoke else 1024)
    horizon = 50 if args.smoke else 200
    train_steps = 0 if args.smoke else 500

    print("== 1. train + checkpoint (the training->serving hand-off) ==")
    fleet = recipes.make_l96_fleet()            # fused-Pallas backend
    params = quick_train(fleet, train_steps)
    ckpt_dir = tempfile.mkdtemp(prefix="l96_fleet_ckpt_")
    ckpt_lib.save_twin(ckpt_dir, params)
    print(f"  weights -> {ckpt_dir}")

    print("\n== 2. serve the fleet over the twin mesh ==")
    mesh = make_twin_mesh()
    ts = recipes.l96_fleet_ts(horizon=horizon)
    requests = list(recipes.l96_fleet_requests(fleet_size=n, num_batches=2))
    print(f"  {twin_shard_count(mesh)} device(s); {len(requests)} request "
          f"batches x {n} assets x {horizon} RK4 steps")

    trajs, t0 = [], time.perf_counter()
    for i, traj in enumerate(serve_fleet(ckpt_dir, fleet, ts, requests,
                                         mesh=mesh)):
        trajs.append(jax.block_until_ready(traj))
        print(f"  batch {i}: {tuple(traj.shape)}")
    dt_s = time.perf_counter() - t0
    print(f"  served in {dt_s:.2f}s "
          f"({len(requests) * n * horizon / dt_s:,.0f} twin-steps/s)")

    print("\n== 3. sharded == single-device parity ==")
    single = jax.jit(lambda p, y: fleet.simulate(p, y, ts))
    ref = jax.block_until_ready(single(params, requests[0]))
    gap = float(jnp.abs(trajs[0] - ref).max())
    print(f"  max|sharded - single-device| = {gap:.2e}  "
          f"(tolerance {PARITY_TOL:.0e})")
    assert gap <= PARITY_TOL, gap
    digital = TwinFleet(fleet.twin.with_backend("digital"))
    dref = digital.simulate(params, requests[0][:32], ts)
    dgap = float(jnp.abs(trajs[0][:32] - dref).max())
    print(f"  max|fused - digital| (32 assets) = {dgap:.2e}  "
          f"(solver-precision cross-check)")
    print("OK")
    return trajs


if __name__ == "__main__":
    main()
