"""Fleet-of-twins serving: one trained model, N physical assets, one
device program per rollout — on every execution backend.

Production digital-twin deployments serve many asset instances of the
same model class (Hartmann 2023; Fuller et al. 2019): each asset has its
own sensed initial condition and its own stimulus parameters, but the
trained weights are shared.  ``TwinFleet`` batches all of that:

  * digital / analogue backends vmap N rollouts into one XLA program;
  * the fused-Pallas backend tiles the fleet across the kernel grid —
    every tile reuses the VMEM-resident weights (the crossbar analogy).

Run:  PYTHONPATH=src python examples/twin_fleet_serving.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core.analogue import AnalogueSpec
from repro.core.backends import AnalogueBackend, FusedPallasBackend
from repro.core.twin import TwinFleet
from repro.train import recipes

FLEET_SIZE = 64
HORIZON = 200          # RK4 steps per rollout


def sine_family(t, theta):
    """Per-asset stimulus: theta = (amp, freq) sensed at the asset."""
    amp, freq = theta[0], theta[1]
    return amp * jnp.sin(2.0 * jnp.pi * freq * t)


def main():
    print("== train once (shared weights for the whole fleet) ==")
    twin, params, loss = recipes.train_hp_twin(pretrain_steps=200,
                                               train_steps=300)
    print(f"  final training loss {loss:.5f}")

    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    ts = jnp.linspace(0.0, HORIZON * 1e-3, HORIZON + 1)
    y0s = 0.1 + 0.2 * jax.random.uniform(k1, (FLEET_SIZE, 1))
    thetas = jnp.stack([
        1.0 + jax.random.uniform(k2, (FLEET_SIZE,)),          # amp in [1,2)
        1.0 + 2.0 * jax.random.uniform(k3, (FLEET_SIZE,)),    # freq in [1,3)
    ], axis=-1)

    fleet = TwinFleet(twin, drive_family=sine_family)
    backends = {
        "digital": None,
        "fused_pallas": FusedPallasBackend(batch_tile=min(64, FLEET_SIZE)),
        "analogue": AnalogueBackend(spec=AnalogueSpec(prog_noise=0.0),
                                    prog_key=jax.random.PRNGKey(7)),
    }

    print(f"\n== serve {FLEET_SIZE} assets x {HORIZON} RK4 steps ==")
    ref = None
    for name, backend in backends.items():
        fl = fleet if backend is None else fleet.with_backend(backend)
        fn = jax.jit(lambda p, y, th, fl=fl: fl.simulate(p, y, ts, th))
        out = jax.block_until_ready(fn(params, y0s, thetas))   # compile
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(params, y0s, thetas))
        dt_s = time.perf_counter() - t0
        steps_per_s = FLEET_SIZE * HORIZON / dt_s
        if ref is None:
            ref = out
            agree = 0.0
        else:
            agree = float(jnp.abs(out - ref).max())
        print(f"  {name:13s} {dt_s*1e3:8.2f} ms/rollout  "
              f"{steps_per_s:12.0f} twin-steps/s  "
              f"max|Δ| vs digital {agree:.2e}")
    print("\n  (fused/digital agree to solver precision; the analogue gap "
          "is 6-bit quantisation, the paper's deployment cost)")


if __name__ == "__main__":
    main()
