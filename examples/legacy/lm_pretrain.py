"""End-to-end LM pretraining driver: train a ~100M-parameter decoder for
a few hundred steps on the synthetic Markov stream, with checkpointing
and resume — the CPU-scale twin of the multi-pod ``train_4k`` cell.

Also demonstrates the paper's technique inside the LM stack: pass
``--ode-depth 4`` to execute the residual stack as a weight-tied neural
ODE (continuous depth, RK4).

Run:  PYTHONPATH=src python examples/legacy/lm_pretrain.py [--steps 300]
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ode-depth", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    from repro.launch.legacy.train import main as train_main

    argv = ["--arch", "qwen3-1.7b", "--smoke",
            "--d-model", "256", "--layers", "4", "--vocab", "4096",
            "--steps", str(args.steps), "--batch", "8", "--seq", "128",
            "--lr", "3e-3", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "100", "--log-every", "25"]
    if args.ode_depth:
        # continuous-depth execution: swap the config before the driver
        import repro.launch.legacy.train as t

        orig = t.build_config

        def build(a):
            return dataclasses.replace(orig(a), ode_depth=args.ode_depth)

        t.build_config = build
        print(f"(continuous-depth mode: RK4 x{args.ode_depth} over the "
              f"weight-tied stack — the paper's Eq. 8/9 equivalence)")

    losses = train_main(argv)
    assert losses[-1] < losses[0], "loss must decrease"
    print("\nLM pretraining e2e complete — the same train_step lowers "
          "onto the 16x16 / 2x16x16 production meshes in the dry-run.")


if __name__ == "__main__":
    main()
