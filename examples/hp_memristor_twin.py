"""End-to-end driver: digital twin of the HP memristor (paper Fig. 3).

Trains the neural-ODE twin AND the recurrent-ResNet digital baseline on
the sine drive, evaluates both across the paper's four stimulation
waveforms, deploys the twin on simulated analogue crossbars, and prints
the projected speed/energy table.

Run:  PYTHONPATH=src python examples/hp_memristor_twin.py [--fast]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import energy
from repro.core.analogue import AnalogueSpec
from repro.core.backends import AnalogueBackend
from repro.core.losses import mre
from repro.train import recipes

WAVEFORMS = ["sine", "triangular", "rectangular", "modulated_sine"]


def main(fast: bool = False):
    scale = 0.25 if fast else 1.0
    print("== training neural-ODE twin (adjoint, RK4, L1 — paper Methods) ==")
    twin, params, node_loss = recipes.train_hp_twin(
        pretrain_steps=int(400 * scale), train_steps=int(600 * scale))
    print(f"NODE final loss {node_loss:.5f}")

    print("== training recurrent-ResNet baseline (paper Eq. 8) ==")
    resnet, rparams, res_loss = recipes.train_hp_resnet(
        train_steps=int(800 * scale))
    print(f"ResNet final loss {res_loss:.5f}")

    print("\n== Fig. 3j: modelling error across stimulation waveforms ==")
    node_m, res_m = [], []
    for wf in WAVEFORMS:
        mn = recipes.eval_hp_twin(twin, params, wf)
        mr = recipes.eval_hp_resnet(resnet, rparams, wf)
        node_m.append(mn["mre"])
        res_m.append(mr["mre"])
        print(f"  {wf:>15s}:  NODE MRE {mn['mre']:.3f} DTW/pt {mn['dtw']:.4f}"
              f"  |  ResNet MRE {mr['mre']:.3f} DTW/pt {mr['dtw']:.4f}")
    print(f"  mean MRE: NODE {sum(node_m)/4:.3f} vs ResNet {sum(res_m)/4:.3f}"
          f"   (paper: 0.17 vs 0.61)")

    print("\n== analogue deployment (paper device statistics) ==")
    m = recipes.eval_hp_twin(twin, params, "sine")
    for pn, rn in [(0.0, 0.0), (0.0436, 0.0), (0.0436, 0.02)]:
        spec = AnalogueSpec(prog_noise=pn, read_noise=rn)
        at = twin.with_backend(
            AnalogueBackend(spec=spec, prog_key=jax.random.PRNGKey(0),
                            read_key=jax.random.PRNGKey(1)))
        pred = at.simulate(params, jnp.array([m["true"][0]]), m["ts"])[:, 0]
        print(f"  prog {pn*100:4.1f}%  read {rn*100:3.1f}%:  "
              f"MRE vs truth {float(mre(pred, m['true'])):.4f}")

    print("\n== Fig. 3k,l: projected speed/energy scalability ==")
    for row in energy.hp_projection():
        print(f"  hidden {row['hidden']:4d}: analogue {row['analogue_time_us']:6.1f} us"
              f" {row['analogue_energy_uj']:7.2f} uJ | NODE-GPU x{row['node_gpu_speed_gain']:.1f}"
              f" speed x{row['node_gpu_energy_gain']:.1f} energy"
              f" | ResNet-GPU x{row['resnet_gpu_energy_gain']:.1f} energy")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    main(**vars(ap.parse_args()))
