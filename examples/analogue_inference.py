"""Analogue-crossbar execution deep-dive: run a trained twin through the
simulated memristor arrays under device non-idealities, and through the
fused Pallas kernel path (the TPU adaptation of in-memory computing) —
all reached through the pluggable ``twin.with_backend(...)`` layer.

Run:  PYTHONPATH=src python examples/analogue_inference.py
"""
import jax
import jax.numpy as jnp

from repro.core.analogue import (AnalogueSpec, program_mlp,
                                 analogue_mlp_apply, programming_error,
                                 program_tensor)
from repro.core.backends import AnalogueBackend, FusedPallasBackend
from repro.core.losses import mre
from repro.kernels import ops
from repro.train import recipes


def main():
    twin, params, _ = recipes.train_hp_twin(pretrain_steps=200,
                                            train_steps=300)
    m = recipes.eval_hp_twin(twin, params, "sine")
    ts, true = m["ts"], m["true"]
    y0 = jnp.array([true[0]])

    print("== device-statistics sweep (paper Fig. 2h-k constraints) ==")
    for levels, pn in [(256, 0.0), (64, 0.0), (64, 0.0436), (16, 0.0436)]:
        spec = AnalogueSpec(levels=levels, prog_noise=pn)
        at = twin.with_backend(
            AnalogueBackend(spec=spec, prog_key=jax.random.PRNGKey(0)))
        pred = at.simulate(params, y0, ts)[:, 0]
        print(f"  {levels:3d} levels, prog noise {pn*100:4.1f}%:  "
              f"MRE vs truth {float(mre(pred, true)):.4f}")

    print("\n== backend matrix: one set of weights, three substrates ==")
    for name, v in recipes.hp_backend_matrix(twin, params).items():
        print(f"  {name:13s} MRE vs truth {v:.4f}")

    print("\n== programming-error statistics (paper Fig. 3e: ~2.2%) ==")
    spec = AnalogueSpec(prog_noise=0.0436)
    errs = []
    for i, layer in enumerate(params):
        prog = program_tensor(jax.random.PRNGKey(i), layer["w"], spec)
        pe = programming_error(prog, layer["w"], spec)
        errs.append(float(pe.mean()))
        print(f"  layer {i}: mean relative programming error "
              f"{float(pe.mean())*100:.2f}% of range")
    print(f"  average: {sum(errs)/len(errs)*100:.2f}%  (paper: 2.2%)")

    print("\n== fused weights-stationary kernel vs step-by-step solver ==")
    traj_kernel = twin.with_backend(
        FusedPallasBackend(batch_tile=1)).simulate(params, y0, ts)
    traj_solver = twin.simulate(params, y0, ts)
    err = float(jnp.abs(traj_kernel - traj_solver).max())
    print(f"  kernel-vs-odeint max abs deviation: {err:.2e}")

    print("\n== quantised-storage crossbar read (uint8 levels, fused dequant) ==")
    spec = AnalogueSpec()
    w = params[1]["w"]
    gpq, gmq, scale = ops.quantize_to_levels(w, spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, w.shape[0]))
    y_q = ops.crossbar_vmm_quantized(x, gpq, gmq, spec, scale)
    rel = float(jnp.linalg.norm(y_q - x @ w) / jnp.linalg.norm(x @ w))
    print(f"  6-bit differential storage vs fp32 matmul rel-err: {rel:.4f}")


if __name__ == "__main__":
    main()
