"""End-to-end driver: Lorenz96 multivariate-time-series twin (paper Fig. 4).

Trains the autonomous neural-ODE twin on the first 1800 points
(interpolation window), extrapolates the remaining 600, compares against
LSTM/GRU/RNN forecasters, and runs the analogue noise-robustness grid
(Fig. 4j).

Run:  PYTHONPATH=src python examples/lorenz96_twin.py [--fast] [--no-baselines]
"""
import argparse

from repro.core import energy
from repro.train import recipes


def main(fast: bool = False, no_baselines: bool = False):
    data = recipes.l96_data()
    info = recipes.l96_lyapunov_info()
    print(f"Lorenz96 n=6 F=8: MLE {info['mle']:.2f}, "
          f"Lyapunov time {info['lyapunov_time']:.2f} time units")

    scale = 0.2 if fast else 1.0
    print("\n== training neural-ODE twin (soft-DTW/L1, adjoint, RK4) ==")
    twin, params = recipes.train_l96_twin(
        pretrain_steps=int(5000 * scale),
        train_steps=((60, int(600 * scale), 1e-3),
                     (200, int(600 * scale), 4e-4)),
        data=data)
    m = recipes.eval_l96_twin(twin, params, data=data)
    print(f"NODE: interp L1 {m['interp_l1']:.3f}  extrap L1 "
          f"{m['extrap_l1']:.3f}   (paper: 0.512 / 0.321)")

    if not no_baselines:
        print("\n== Fig. 4g: recurrent baselines ==")
        for cell in ["lstm", "gru", "rnn"]:
            b = recipes.eval_l96_baseline(
                cell, train_steps=int(2500 * scale), data=data)
            print(f"  {cell:>5s}: interp L1 {b['interp_l1']:.3f}  "
                  f"extrap L1 {b['extrap_l1']:.3f}")

    print("\n== Fig. 4j: analogue noise robustness (extrapolation L1) ==")
    grid = recipes.noise_robustness_grid(
        twin, params, read_noises=[0.0, 0.02], prog_noises=[0.0, 0.01],
        data=data, repeats=1 if fast else 3)
    for row in grid:
        print(f"  prog {row['prog_noise']*100:4.1f}%  "
              f"read {row['read_noise']*100:3.1f}%:  "
              f"extrap L1 {row['extrap_l1']:.3f}")

    print("\n== Fig. 4h,i: projected execution time / energy ==")
    for row in energy.lorenz96_projection():
        print(f"  hidden {row['hidden']:4d}: analogue {row['analogue_time_us']:5.1f} us |"
              f" NODE x{row['node_gpu_speed_gain']:4.1f}/x{row['node_gpu_energy_gain']:5.0f}"
              f" LSTM x{row['lstm_gpu_speed_gain']:4.1f}/x{row['lstm_gpu_energy_gain']:5.0f}"
              f" GRU x{row['gru_gpu_speed_gain']:4.1f}/x{row['gru_gpu_energy_gain']:5.0f}"
              f" RNN x{row['rnn_gpu_speed_gain']:4.1f}/x{row['rnn_gpu_energy_gain']:5.0f}"
              f"  (speed/energy)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--no-baselines", action="store_true")
    args = ap.parse_args()
    main(fast=args.fast, no_baselines=args.no_baselines)
