"""The paper's own HP-memristor twin configuration (Methods)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class HPTwinConfig:
    state_dim: int = 1
    drive_dim: int = 1
    hidden: int = 14              # the 2x14 / 14x14 / 14x1 crossbars
    n_hidden_layers: int = 2
    num_points: int = 500
    dt: float = 1e-3
    method: str = "rk4"
    gradient: str = "adjoint"
    train_waveform: str = "sine"
    eval_waveforms: tuple = ("sine", "triangular", "rectangular",
                             "modulated_sine")
    loss: str = "l1"


CONFIG = HPTwinConfig()
