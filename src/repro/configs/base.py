"""ArchConfig — the single schema driving the whole model zoo, plus the
input-shape suite every architecture is exercised against."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.models.mamba import MambaConfig
from repro.models.moe import MoEConfig
from repro.models.xlstm import XLSTMConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense|moe|hybrid|ssm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 10000.0
    use_rope: bool = True
    qk_norm: bool = False
    qkv_bias: bool = False
    mlp_type: str = "swiglu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # attention variant
    attn: str = "gqa"              # gqa | mla
    mla_kv_lora: int = 512
    mla_q_lora: int = 0
    mla_rope_dim: int = 64
    # memory-bounded (flash) attention tuning
    flash_threshold: int = 1024
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 512
    attn_causal_skip: bool = False
    attn_score_dtype: str = "float32"
    kv_cache_quant: bool = False
    # sharding profile: 'auto' (divisibility rules) or 'no_attn_tp'
    # (replicate attention weights over the model axis, FSDP/DP-only —
    # the right call when heads don't divide the TP axis)
    shard_profile: str = "auto"
    # MoE placement
    moe: Optional[MoEConfig] = None
    moe_every: int = 1             # MoE on layer idx where idx % every == off
    moe_offset: int = 0
    first_k_dense: int = 0
    d_ff_dense: int = 0            # dense-layer FFN width (0 -> d_ff)
    # block pattern
    pattern: str = "dense"         # dense | jamba | xlstm
    jamba_period: int = 8
    jamba_attn_pos: int = 3
    mamba: Optional[MambaConfig] = None
    xlstm_period: int = 6          # sLSTM at the last slot of each period
    # paper technique (continuous-depth execution of the residual stack)
    ode_depth: int = 0             # >0: RK4 steps per weight-tied block
    # capability flags
    sub_quadratic: bool = False    # can run the 500k-context decode cell
    remat: str = "full"            # full | dots | none

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def d_ff_dense_(self) -> int:
        return self.d_ff_dense or self.d_ff

    def xlstm_cfg(self) -> XLSTMConfig:
        return XLSTMConfig(d_model=self.d_model, n_heads=self.n_heads)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def runnable_shapes(cfg: ArchConfig) -> list[str]:
    """long_500k needs sub-quadratic attention (skip policy in DESIGN.md)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (used for roofline MODEL_FLOPS)."""
    d, hd = cfg.d_model, cfg.hd
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    total = emb + d  # final norm

    def attn_params():
        if cfg.attn == "mla":
            p = d * cfg.mla_kv_lora                      # w_dkv
            p += cfg.mla_kv_lora * cfg.n_heads * hd * 2  # w_uk, w_uv
            p += d * cfg.mla_rope_dim                    # w_kr
            p += cfg.n_heads * hd * d                    # wo
            if cfg.mla_q_lora:
                p += d * cfg.mla_q_lora + cfg.mla_q_lora * cfg.n_heads * (
                    hd + cfg.mla_rope_dim)
            else:
                p += d * cfg.n_heads * (hd + cfg.mla_rope_dim)
            return p
        return d * hd * (cfg.n_heads + 2 * cfg.n_kv) + cfg.n_heads * hd * d

    def mlp_params(ff):
        mats = 3 if cfg.mlp_type == "swiglu" else 2
        return mats * d * ff

    def moe_params():
        m = cfg.moe
        p = d * m.n_experts
        p += m.n_experts * mlp_params(m.d_ff) // 1
        if m.n_shared:
            p += mlp_params(m.n_shared * m.d_ff)
        return p

    def mamba_params():
        mc = cfg.mamba
        di, n, r = mc.d_inner, mc.d_state, mc.dt_rank_
        return (d * 2 * di + mc.d_conv * di + di * (r + 2 * n) + r * di
                + di * n + 2 * di + di * d)

    def xlstm_m():
        xc = cfg.xlstm_cfg()
        di = xc.d_inner
        return d * 2 * di + xc.d_conv * di + 3 * di * di + 2 * di * \
            cfg.n_heads + di * d + di

    def xlstm_s():
        xc = cfg.xlstm_cfg()
        df = int(xc.s_proj_factor * d)
        return d * 4 * d + cfg.n_heads * (d // cfg.n_heads) * 4 * (
            d // cfg.n_heads) + 3 * d * df // 1 + 2 * d * df - 2 * d * df \
            + d * df * 3

    for i in range(cfg.n_layers):
        total += 2 * d  # norms
        if cfg.pattern == "dense":
            total += attn_params()
            if cfg.moe is not None and i >= cfg.first_k_dense and \
                    (i - cfg.moe_offset) % cfg.moe_every == 0:
                total += moe_params()
            else:
                total += mlp_params(cfg.d_ff_dense_)
        elif cfg.pattern == "jamba":
            pos = i % cfg.jamba_period
            total += attn_params() if pos == cfg.jamba_attn_pos \
                else mamba_params()
            if i % 2 == 1 and cfg.moe is not None:
                total += moe_params()
            else:
                total += mlp_params(cfg.d_ff)
        elif cfg.pattern == "xlstm":
            pos = i % cfg.xlstm_period
            total += xlstm_s() if pos == cfg.xlstm_period - 1 else xlstm_m()
    return int(total)


def active_param_count(cfg: ArchConfig) -> int:
    """Activated parameters per token (MoE: top-k + shared only)."""
    if cfg.moe is None:
        return param_count(cfg)
    m = cfg.moe
    full = param_count(cfg)
    mats = 3 if cfg.mlp_type == "swiglu" else 2

    def n_moe_layers():
        if cfg.pattern == "jamba":
            return sum(1 for i in range(cfg.n_layers) if i % 2 == 1)
        return sum(1 for i in range(cfg.n_layers)
                   if i >= cfg.first_k_dense and
                   (i - cfg.moe_offset) % cfg.moe_every == 0)

    inactive = n_moe_layers() * (m.n_experts - m.top_k) * mats * \
        cfg.d_model * m.d_ff
    return int(full - inactive)
