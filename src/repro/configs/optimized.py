"""Hillclimbed config variants (§Perf in EXPERIMENTS.md).

``get_optimized(name)`` = the paper-faithful CONFIG plus the measured
beyond-baseline optimisations.  Baseline artifacts stay reproducible from
the unmodified configs (snapshot: runs/dryrun_baseline/).
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config

# applied to every arch (measured on the three hillclimb cells, then
# rolled out — each is semantics-preserving up to bf16 score rounding)
GLOBAL = dict(
    attn_causal_skip=True,        # banded kv loop: ~2x fewer score tiles
    attn_score_dtype="bfloat16",  # halves the dominant score-tile traffic
    attn_q_chunk=1024,            # nq<=32 => static banding even at 32k
    attn_kv_chunk=1024,
)

PER_ARCH = {
    # heads % 16 != 0: pad heads to the next multiple of the TP axis.
    # Zero-padded wo rows keep the function identical to the unpadded
    # model; +33%/+20% attention FLOPs but clean Megatron TP instead of
    # 16x attention replication (measured in §Perf iterations 1->2).
    "musicgen-medium": dict(n_heads=32, n_kv=32),
    "qwen1.5-32b": dict(n_heads=48, n_kv=48, kv_cache_quant=True),
    # 4 heads on a 16-way axis: replicate attention-ish mixer weights,
    # shard the wide projected dims instead (rules do this natively)
    "xlstm-125m": dict(),
    # jamba: bf16 scan tree (in-chunk contraction is already structural)
    "jamba-v0.1-52b": dict(),
}


def get_optimized(name: str):
    cfg = get_config(name)
    over = dict(GLOBAL)
    over.update(PER_ARCH.get(name, {}))
    # NODE/MLA absorbed flash uses the same knobs; mamba scan dtype rides
    # on the MambaConfig
    if cfg.mamba is not None:
        over["mamba"] = dataclasses.replace(cfg.mamba,
                                            scan_dtype="bfloat16")
    return dataclasses.replace(cfg, **over)
