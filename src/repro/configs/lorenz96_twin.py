"""The paper's own Lorenz96 twin configuration (Methods), plus the
fleet-serving scale-up scenario built on it (Fig. 4 / ROADMAP north
star): many assets sharing one trained twin, sharded over a device mesh
by :mod:`repro.launch.fleet_serving`."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Lorenz96TwinConfig:
    state_dim: int = 6
    forcing: float = 8.0
    hidden: int = 64              # three-layer net, 64 per hidden layer
    n_hidden_layers: int = 2
    num_points: int = 2400
    train_points: int = 1800      # interpolation window
    dt: float = 0.0025            # total span ~13 Lyapunov times
    method: str = "rk4"
    gradient: str = "adjoint"
    loss: str = "l1+softdtw"
    noise_regulariser: float = 0.02


CONFIG = Lorenz96TwinConfig()


@dataclasses.dataclass(frozen=True)
class Lorenz96FleetConfig:
    """Fleet serving: N independent Lorenz96 assets, one trained twin.

    The model sizes mirror :class:`Lorenz96TwinConfig` (weights from a
    training run drop straight in via ``train.checkpoint.save_twin`` /
    ``load_twin``); the serving knobs size the request stream and the
    per-device execution tile.
    """
    state_dim: int = 6
    hidden: int = 64
    n_hidden_layers: int = 2
    dt: float = 0.0025            # same grid the twin was trained on
    fleet_size: int = 1024        # assets per request batch
    horizon: int = 200            # RK4 steps per request
    y0_spread: float = 0.5        # stddev of sensed initial conditions
                                  # (the training data is normalised)
    backend: str = "fused_pallas"
    batch_tile: int = 64          # fused-kernel grid tile per device


FLEET = Lorenz96FleetConfig()
