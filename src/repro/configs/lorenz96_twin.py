"""The paper's own Lorenz96 twin configuration (Methods)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Lorenz96TwinConfig:
    state_dim: int = 6
    forcing: float = 8.0
    hidden: int = 64              # three-layer net, 64 per hidden layer
    n_hidden_layers: int = 2
    num_points: int = 2400
    train_points: int = 1800      # interpolation window
    dt: float = 0.0025            # total span ~13 Lyapunov times
    method: str = "rk4"
    gradient: str = "adjoint"
    loss: str = "l1+softdtw"
    noise_regulariser: float = 0.02


CONFIG = Lorenz96TwinConfig()
