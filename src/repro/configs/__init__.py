"""Architecture registry: ``get_config(name)`` / ``get_smoke(name)``.

Twin configs (the paper's workloads, used by serving and recipes) live
at the top level — ``repro.configs.hp_twin`` and
``repro.configs.lorenz96_twin`` (which also defines the fleet-serving
scenario).  The seed-era LM architectures are quarantined under
``repro.configs.lm`` — only the roofline dry-run and the model-zoo tests
touch them, and only via this registry.
"""
from repro.configs.base import (SHAPES, ArchConfig, ShapeConfig,
                                active_param_count, param_count,
                                runnable_shapes)

_MODULES = {
    "deepseek-v2-lite-16b": "lm.deepseek_v2_lite_16b",
    "deepseek-v2-236b": "lm.deepseek_v2_236b",
    "jamba-v0.1-52b": "lm.jamba_v0_1_52b",
    "llama3-8b": "lm.llama3_8b",
    "internlm2-20b": "lm.internlm2_20b",
    "qwen3-1.7b": "lm.qwen3_1_7b",
    "qwen1.5-32b": "lm.qwen1_5_32b",
    "musicgen-medium": "lm.musicgen_medium",
    "xlstm-125m": "lm.xlstm_125m",
    "chameleon-34b": "lm.chameleon_34b",
}

ARCH_NAMES = list(_MODULES)


def _module(name: str):
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE
