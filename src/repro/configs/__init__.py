"""Architecture registry: ``get_config(name)`` / ``get_smoke(name)``."""
from repro.configs.base import (SHAPES, ArchConfig, ShapeConfig,
                                active_param_count, param_count,
                                runnable_shapes)

_MODULES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "llama3-8b": "llama3_8b",
    "internlm2-20b": "internlm2_20b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen1.5-32b": "qwen1_5_32b",
    "musicgen-medium": "musicgen_medium",
    "xlstm-125m": "xlstm_125m",
    "chameleon-34b": "chameleon_34b",
}

ARCH_NAMES = list(_MODULES)


def _module(name: str):
    import importlib
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE
