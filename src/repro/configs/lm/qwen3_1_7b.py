"""Qwen3 1.7B [hf:Qwen/Qwen3-1.7B] — qk-norm, GQA, tied embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv=8, d_ff=6144,
    vocab=151936, head_dim=128, rope_theta=1000000.0,
    qk_norm=True, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="qwen3-1.7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
    vocab=512, head_dim=16, qk_norm=True, tie_embeddings=True,
    dtype="float32", remat="none",
)
