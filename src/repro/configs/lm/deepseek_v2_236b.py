"""DeepSeek-V2 236B [arXiv:2405.04434; hf] — MLA (q-LoRA 1536) + 160-expert MoE."""
from repro.configs.base import ArchConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv=128, d_ff=1536,
    vocab=102400, head_dim=128, attn="mla",
    mla_kv_lora=512, mla_q_lora=1536, mla_rope_dim=64,
    moe=MoEConfig(n_experts=160, top_k=6, d_ff=1536, n_shared=2),
    first_k_dense=1, d_ff_dense=12288,
)

SMOKE = ArchConfig(
    name="deepseek-v2-236b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=48,
    vocab=512, head_dim=32, attn="mla",
    mla_kv_lora=32, mla_q_lora=48, mla_rope_dim=16,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=48, n_shared=1,
                  capacity_factor=4.0),
    first_k_dense=1, d_ff_dense=128, dtype="float32", remat="none",
)
