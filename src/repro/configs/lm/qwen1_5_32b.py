"""Qwen1.5 32B [hf:Qwen/Qwen1.5-32B] — QKV bias, MHA (kv = heads)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv=40, d_ff=27392,
    vocab=152064, head_dim=128, qkv_bias=True,
)

SMOKE = ArchConfig(
    name="qwen1.5-32b-smoke", family="dense",
    n_layers=2, d_model=80, n_heads=5, n_kv=5, d_ff=160,
    vocab=512, head_dim=16, qkv_bias=True,
    dtype="float32", remat="none",
)
