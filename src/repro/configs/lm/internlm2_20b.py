"""InternLM2 20B [arXiv:2403.17297; hf] — dense GQA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv=8, d_ff=16384,
    vocab=92544, head_dim=128, rope_theta=1000000.0,
)

SMOKE = ArchConfig(
    name="internlm2-20b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv=2, d_ff=160,
    vocab=512, head_dim=16, rope_theta=1000000.0,
    dtype="float32", remat="none",
)
