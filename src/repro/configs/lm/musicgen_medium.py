"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec
tokens.  The EnCodec frontend is a STUB per the assignment: inputs are
precomputed codec tokens (vocab 2048) from the synthetic pipeline."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv=24, d_ff=6144,
    vocab=2048, head_dim=64, mlp_type="gelu",
)

SMOKE = ArchConfig(
    name="musicgen-medium-smoke", family="audio",
    n_layers=2, d_model=48, n_heads=3, n_kv=3, d_ff=96,
    vocab=256, head_dim=16, mlp_type="gelu",
    dtype="float32", remat="none",
)
