"""Chameleon 34B [arXiv:2405.09818] — early-fusion VLM backbone.  The VQ
image tokenizer is a STUB per the assignment: image patches arrive as
precomputed VQ tokens inside the shared 65536 vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv=8, d_ff=22016,
    vocab=65536, head_dim=128, qk_norm=True,
)

SMOKE = ArchConfig(
    name="chameleon-34b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
    vocab=512, head_dim=16, qk_norm=True,
    dtype="float32", remat="none",
)
