"""Jamba v0.1 52B [arXiv:2403.19887; hf] — Mamba+attn 1:7, 16-expert MoE."""
from repro.configs.base import ArchConfig
from repro.models.mamba import MambaConfig
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=65536, head_dim=128, use_rope=False,
    pattern="jamba", jamba_period=8, jamba_attn_pos=3,
    mamba=MambaConfig(d_model=4096, d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, norm_topk=True),
    sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv=2, d_ff=128,
    vocab=512, head_dim=16, use_rope=False,
    pattern="jamba", jamba_period=8, jamba_attn_pos=3,
    mamba=MambaConfig(d_model=64, d_state=4, d_conv=4, expand=2, chunk=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=128, norm_topk=True,
                  capacity_factor=4.0),
    sub_quadratic=True, dtype="float32", remat="none",
)
