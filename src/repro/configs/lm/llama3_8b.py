"""Llama-3 8B [arXiv:2407.21783] — dense GQA, 128k vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
    vocab=128256, head_dim=128, rope_theta=500000.0,
)

SMOKE = ArchConfig(
    name="llama3-8b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
    vocab=512, head_dim=16, rope_theta=500000.0,
    dtype="float32", remat="none",
)
