"""Quarantined seed-era LM architecture configs.

The twin workload (HP memristor + Lorenz96 fleets — the paper and the
serving pipeline) never imports these; they exist solely for the LM
roofline dry-run (:mod:`repro.launch.dryrun`), the model-zoo smoke tests
and the sharding-rule tests.  Reach them through the registry
(``repro.configs.get_config``/``get_smoke``), not by direct import.
"""
