"""xLSTM 125M [arXiv:2405.04517] — sLSTM + mLSTM blocks (1 sLSTM per 6)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv=4, d_ff=0,
    vocab=50304, pattern="xlstm", xlstm_period=6,
    sub_quadratic=True,
)

SMOKE = ArchConfig(
    name="xlstm-125m-smoke", family="ssm",
    n_layers=6, d_model=64, n_heads=2, n_kv=2, d_ff=0,
    vocab=512, pattern="xlstm", xlstm_period=6,
    sub_quadratic=True, dtype="float32", remat="none",
)
