# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Active kernels: fused_ode_mlp (+ _bwd), fused_analogue, crossbar_vmm,
# softdtw, noise (counter-derived streams), all fronted by ops.py with
# jnp oracles in ref.py.  LM-era kernels that are not part of the
# neural-ODE twin stack live in legacy/ (technique references only).
