"""Counter-derived Gaussian noise for in-kernel analogue read modelling.

The analogue substrate re-samples multiplicative read noise on every
crossbar evaluation.  Inside a Pallas kernel we cannot thread a
``jax.random`` key through the RK4 loop (keys don't live in VMEM refs and
splitting is not Mosaic-lowerable), so the kernels derive noise from a
*counter*: every (seed, salt, element) triple is hashed independently to
a normal sample.  Properties the kernels rely on:

* deterministic — same seed => bitwise-identical noise, so a noisy
  analogue rollout is exactly replayable (and its tests are exact);
* stateless — sample (step t, eval s, layer l, element ij) is a pure
  function of its coordinates; the reverse-sweep or a resumed chunk
  regenerates the same stream without carrying RNG state;
* portable — integer mixing + Box-Muller only, identical results under
  the Pallas interpreter (CPU/GPU hosts) and the compiled TPU lowering
  (unlike ``pltpu.prng_random_bits``, which has no interpreter analogue).

The mixer is the splitmix32 finaliser — full avalanche, 4 int ops — and
uniforms come from the standard exponent-trick bitcast
(``(bits >> 9) | 0x3f800000`` is a float in [1, 2)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_U32 = jnp.uint32


def splitmix32(x: jax.Array) -> jax.Array:
    """Splitmix32 finaliser: uint32 -> well-mixed uint32 (full avalanche)."""
    x = jnp.asarray(x, _U32)
    x = (x ^ (x >> 16)) * _U32(0x7FEB352D)
    x = (x ^ (x >> 15)) * _U32(0x846CA68B)
    return x ^ (x >> 16)


def _bits_to_unit(bits: jax.Array) -> jax.Array:
    """uint32 -> float32 uniform in (0, 1] (never 0, safe under log)."""
    f = jax.lax.bitcast_convert_type((bits >> 9) | _U32(0x3F800000),
                                     jnp.float32)
    return jnp.float32(2.0) - f          # [1,2) -> (0,1]


def counter_uniform_at(seed, salt, idx: jax.Array) -> jax.Array:
    """Uniform (0, 1] float32 samples indexed by explicit element ids.

    Unlike :func:`counter_normal` (which derives ids from the *local*
    block shape), ``idx`` carries caller-chosen — typically global —
    element coordinates, so a blocked kernel and an unblocked jnp
    computation draw bitwise-identical samples for the same logical
    element.  This is the primitive behind the device-fault masks
    (:mod:`repro.core.faults`): a stuck cell is a property of the
    physical array, not of the tile decomposition reading it.
    """
    idx = jnp.asarray(idx, _U32)
    base = splitmix32(jnp.asarray(seed, _U32) * _U32(0x9E3779B9)
                      + splitmix32(jnp.asarray(salt, _U32)))
    return _bits_to_unit(splitmix32(base ^ idx))


def global_cell_index(shape: tuple[int, int], row0, col0, ncols) -> jax.Array:
    """Global flat ids for a 2-D block at offset (row0, col0) of a
    logically (nrows, ncols) array — the id each element would get from
    ``arange(nrows * ncols).reshape(nrows, ncols)``.  ``row0``/``col0``
    may be traced (grid-derived); built from per-axis broadcasted iotas
    (TPU Mosaic has no 1-D iota)."""
    rr = jax.lax.broadcasted_iota(_U32, shape, 0) + jnp.asarray(row0, _U32)
    cc = jax.lax.broadcasted_iota(_U32, shape, 1) + jnp.asarray(col0, _U32)
    return rr * jnp.asarray(ncols, _U32) + cc


#: Offset separating a stuck-cell decision draw from its polarity draw
#: (see :func:`stuck_cell_masks`; the salt space itself is allocated by
#: :mod:`repro.core.faults`).
POLARITY_SALT_OFFSET = 0x0080_0000


def stuck_cell_masks(seed, salt, shape: tuple[int, int], rate: float,
                     on_frac: float = 0.5, *, row0=0, col0=0, ncols=None):
    """(is_stuck, stuck_on) boolean fields for one device array.

    Pure function of (seed, salt, global cell coordinates): a blocked
    kernel evaluating a (row0, col0)-offset tile of a logically
    (?, ncols) array and an unblocked jnp caller (``row0=col0=0``,
    ``ncols=shape[1]``) see bitwise-identical masks — a stuck cell is a
    property of the physical array, not of the tile decomposition
    reading it.  ``rate``/``on_frac`` must be static (they parameterise
    the comparison, not the stream).
    """
    idx = global_cell_index(shape, row0, col0,
                            shape[1] if ncols is None else ncols)
    is_stuck = counter_uniform_at(seed, salt, idx) < jnp.float32(rate)
    stuck_on = (counter_uniform_at(seed, salt + POLARITY_SALT_OFFSET, idx)
                < jnp.float32(on_frac))
    return is_stuck, stuck_on


def counter_normal(seed, salt, shape: tuple[int, ...]) -> jax.Array:
    """Standard-normal float32 samples indexed purely by coordinates.

    ``seed``/``salt`` are python ints or scalar integer arrays (traced is
    fine); ``shape`` must be static.  Each element's sample is
    ``BoxMuller(hash(seed, salt, flat_index))`` — decorrelated across
    elements, salts and seeds by the splitmix32 avalanche.
    """
    # Flat element index from per-axis broadcasted iotas — TPU Mosaic has
    # no 1-D iota, so build the index at the target rank directly (works
    # identically in the interpreter).
    idx = jnp.zeros(shape, _U32)
    stride = 1
    for axis in range(len(shape) - 1, -1, -1):
        idx = idx + jax.lax.broadcasted_iota(_U32, shape, axis) * _U32(stride)
        stride *= int(shape[axis])
    base = splitmix32(jnp.asarray(seed, _U32) * _U32(0x9E3779B9)
                      + splitmix32(jnp.asarray(salt, _U32)))
    h1 = splitmix32(base ^ idx)
    h2 = splitmix32(h1 ^ _U32(0x85EBCA6B))
    u1 = _bits_to_unit(h1)
    u2 = _bits_to_unit(h2)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(2.0 * 3.14159265358979) * u2)
