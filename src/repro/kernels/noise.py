"""Counter-derived Gaussian noise for in-kernel analogue read modelling.

The analogue substrate re-samples multiplicative read noise on every
crossbar evaluation.  Inside a Pallas kernel we cannot thread a
``jax.random`` key through the RK4 loop (keys don't live in VMEM refs and
splitting is not Mosaic-lowerable), so the kernels derive noise from a
*counter*: every (seed, salt, element) triple is hashed independently to
a normal sample.  Properties the kernels rely on:

* deterministic — same seed => bitwise-identical noise, so a noisy
  analogue rollout is exactly replayable (and its tests are exact);
* stateless — sample (step t, eval s, layer l, element ij) is a pure
  function of its coordinates; the reverse-sweep or a resumed chunk
  regenerates the same stream without carrying RNG state;
* portable — integer mixing + Box-Muller only, identical results under
  the Pallas interpreter (CPU/GPU hosts) and the compiled TPU lowering
  (unlike ``pltpu.prng_random_bits``, which has no interpreter analogue).

The mixer is the splitmix32 finaliser — full avalanche, 4 int ops — and
uniforms come from the standard exponent-trick bitcast
(``(bits >> 9) | 0x3f800000`` is a float in [1, 2)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_U32 = jnp.uint32


def splitmix32(x: jax.Array) -> jax.Array:
    """Splitmix32 finaliser: uint32 -> well-mixed uint32 (full avalanche)."""
    x = jnp.asarray(x, _U32)
    x = (x ^ (x >> 16)) * _U32(0x7FEB352D)
    x = (x ^ (x >> 15)) * _U32(0x846CA68B)
    return x ^ (x >> 16)


def _bits_to_unit(bits: jax.Array) -> jax.Array:
    """uint32 -> float32 uniform in (0, 1] (never 0, safe under log)."""
    f = jax.lax.bitcast_convert_type((bits >> 9) | _U32(0x3F800000),
                                     jnp.float32)
    return jnp.float32(2.0) - f          # [1,2) -> (0,1]


def counter_normal(seed, salt, shape: tuple[int, ...]) -> jax.Array:
    """Standard-normal float32 samples indexed purely by coordinates.

    ``seed``/``salt`` are python ints or scalar integer arrays (traced is
    fine); ``shape`` must be static.  Each element's sample is
    ``BoxMuller(hash(seed, salt, flat_index))`` — decorrelated across
    elements, salts and seeds by the splitmix32 avalanche.
    """
    # Flat element index from per-axis broadcasted iotas — TPU Mosaic has
    # no 1-D iota, so build the index at the target rank directly (works
    # identically in the interpreter).
    idx = jnp.zeros(shape, _U32)
    stride = 1
    for axis in range(len(shape) - 1, -1, -1):
        idx = idx + jax.lax.broadcasted_iota(_U32, shape, axis) * _U32(stride)
        stride *= int(shape[axis])
    base = splitmix32(jnp.asarray(seed, _U32) * _U32(0x9E3779B9)
                      + splitmix32(jnp.asarray(salt, _U32)))
    h1 = splitmix32(base ^ idx)
    h2 = splitmix32(h1 ^ _U32(0x85EBCA6B))
    u1 = _bits_to_unit(h1)
    u2 = _bits_to_unit(h2)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos(jnp.float32(2.0 * 3.14159265358979) * u2)
