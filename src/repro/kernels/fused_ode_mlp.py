"""Weights-stationary fused neural-ODE solve — the paper's in-memory insight
on TPU.

The analogue system's whole advantage is that weights never move: they sit
in the crossbar while the state circulates through the closed loop.  The
TPU transposition: pin the MLP weights in VMEM once and run the ENTIRE RK4
trajectory (T steps x 4 f-evals) inside a single ``pallas_call`` —
activations live in VREGs/VMEM, the only HBM traffic is y0/drive in and
the trajectory out.  A step-by-step XLA implementation would re-read the
weights from HBM every f-eval and write every intermediate state back; at
the paper's sizes that makes the solve HBM-latency-bound.

Grid: (batch tiles, time chunks); weights broadcast to every cell.  Time
is the minor grid dimension, so all chunks of one batch tile run back to
back and the integration state is carried across chunks in a VMEM scratch
buffer (re-seeded from ``y0`` whenever a new batch tile starts).
Block layout per (i, j) cell:
  y0       (bt, D)            per-tile, same block for every chunk
  u_chunks (1, 2C+1, Du)      chunk j's drive half-steps, broadcast
           — or, for per-twin drives (fleet serving), (1, 2C+1, bt, Du)
           per-tile slices of a (n_chunks, 2C+1, B, Du) stimulus tensor
  w_i/b_i  (full)             broadcast — the "crossbar residency"
  out      (C, bt, D)         chunk j's slab of the trajectory
  carry    (bt, D)            VMEM scratch, persistent across the grid

VMEM per cell ~= weights + C*bt*D*4 (out slab) + (2C+1)*Du*4 (drive
slab) + carry + activations; the horizon T no longer has to fit — only
one chunk does.  ``time_chunk=None`` auto-picks the largest C within
``vmem_budget_bytes``, so weights stay resident while arbitrarily long
horizons stream chunk-by-chunk through HBM.  A ``ValueError`` is now
raised only when the weights plus a single step genuinely cannot fit.

This module is the forward; :mod:`repro.kernels.fused_ode_mlp_bwd`
walks the same grid in reverse (chunk-boundary checkpoints = trajectory
rows, recompute-in-VMEM replay) to make the rollout differentiable on
the same substrate.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_VMEM_BUDGET = 14 * 1024 * 1024   # ~16 MB/core minus headroom


def _default_interpret() -> bool:
    """Compiled lowering on TPU, interpreter everywhere else — so CPU/GPU
    hosts validate the kernel while TPU runs never silently benchmark the
    interpreter."""
    return jax.default_backend() != "tpu"


class ChunkPlan(NamedTuple):
    """How a T-step horizon is streamed through VMEM."""
    time_chunk: int          # C — RK4 steps resident per grid cell
    num_chunks: int          # ceil(T / C)
    vmem_bytes: int          # estimated per-cell VMEM footprint


def plan_time_chunk(T: int, bt: int, D: int, du: int, per_tile_drive: bool,
                    weights: Sequence[jax.Array], biases: Sequence[jax.Array],
                    vmem_budget_bytes: int,
                    time_chunk: int | None = None) -> ChunkPlan:
    """Pick the largest time chunk C whose per-cell working set fits the
    VMEM budget (or honour an explicit ``time_chunk`` override).

    Per-cell bytes: weights + biases (resident), the (C, bt, D) output
    slab, the (2C+1, u_width) drive slab, the (bt, D) carry, and a slack
    term for RK4 activations (k1..k4, the widest matmul operand).
    """
    u_width = max(du, 1) * (bt if per_tile_drive else 1)
    wbytes = sum(4 * w.size for w in weights) + sum(4 * b.size for b in biases)
    act = 4 * bt * max(du + D, max(w.shape[1] for w in weights)) * 6
    fixed = wbytes + act + 4 * bt * D            # + carry
    per_step = 4 * bt * D + 8 * u_width          # out row + two u rows
    if time_chunk is not None:
        C = max(1, min(int(time_chunk), T))
    else:
        avail = vmem_budget_bytes - fixed - 4 * u_width   # the +1 u row
        C = int(avail // per_step)
        if C < 1:
            raise ValueError(
                f"fused kernel weights + one RK4 step need "
                f"~{(fixed + per_step + 4 * u_width) / 2 ** 20:.1f} MiB VMEM "
                f"(budget {vmem_budget_bytes / 2 ** 20:.1f}); shrink "
                f"batch_tile or the MLP")
        C = min(C, T)
    need = fixed + 4 * C * bt * D + 4 * (2 * C + 1) * u_width
    if need > vmem_budget_bytes:
        # only reachable with an explicit (oversized) time_chunk — fail
        # with a clear message instead of an opaque Mosaic allocation
        # error at lowering time
        raise ValueError(
            f"time_chunk={C} needs ~{need / 2 ** 20:.1f} MiB VMEM "
            f"(budget {vmem_budget_bytes / 2 ** 20:.1f}); shrink "
            f"time_chunk or batch_tile")
    return ChunkPlan(C, -(-T // C), need)


def make_rk4_step(num_layers: int, dt: float, drive_dim: int, bt: int,
                  per_tile_drive: bool):
    """One in-kernel RK4 step ``step(y, u0, um, u1, ws, bs) -> y_next``.

    SHARED between the forward kernel and the backward kernel's
    checkpoint replay + step VJP (:mod:`repro.kernels.fused_ode_mlp_bwd`)
    — the recompute must be bit-identical to the forward, so there is
    exactly one definition of the step."""

    def mlp(x, ws, bs):
        for i in range(num_layers):
            x = jnp.dot(x, ws[i], preferred_element_type=jnp.float32)
            x = x + bs[i][None, :]
            if i < num_layers - 1:
                x = jnp.maximum(x, 0.0)
        return x

    def f(u_row, y, ws, bs):
        if drive_dim > 0:
            # u_row: (drive_dim,) broadcast, or (bt, drive_dim) per-twin
            u = (u_row if per_tile_drive
                 else jnp.broadcast_to(u_row, (bt, drive_dim)))
            inp = jnp.concatenate([u, y], axis=-1)
        else:
            inp = y
        return mlp(inp, ws, bs)

    def step(y, u0, um, u1, ws, bs):
        k1 = f(u0, y, ws, bs)
        k2 = f(um, y + (dt / 2) * k1, ws, bs)
        k3 = f(um, y + (dt / 2) * k2, ws, bs)
        k4 = f(u1, y + dt * k3, ws, bs)
        return y + (dt / 6) * (k1 + 2 * k2 + 2 * k3 + k4)

    return step


def pad_fleet_to_tile(y0s: jax.Array, uh: jax.Array, batch_tile: int):
    """Pad the fleet axis up to a multiple of the batch tile.

    Padded rows replicate the last twin (in-distribution values, no NaN
    risk) and per-twin drive slabs (``uh.ndim == 3``) are replicated
    alongside; the caller slices the result back to the real fleet.
    Returns ``(y0s_padded, uh_padded, bt, B)`` with ``B`` the original
    fleet size.  One extra tile instead of the old largest-divisor
    search that degenerated to bt=1 for prime fleet sizes.
    """
    B = y0s.shape[0]
    bt = min(batch_tile, B)
    pad = (-B) % bt
    if pad:
        y0s = jnp.concatenate(
            [y0s, jnp.broadcast_to(y0s[-1:], (pad,) + y0s.shape[1:])])
        if uh.ndim == 3:
            uh = jnp.concatenate(
                [uh, jnp.broadcast_to(uh[-1:], (pad,) + uh.shape[1:])])
    return y0s, uh, bt, B


def _make_kernel(num_layers: int, C: int, dt: float, drive_dim: int,
                 bt: int, per_tile_drive: bool = False):
    step = make_rk4_step(num_layers, dt, drive_dim, bt, per_tile_drive)

    def kernel(*refs):
        y0_ref = refs[0]
        u_ref = refs[1]
        w_refs = refs[2:2 + num_layers]
        b_refs = refs[2 + num_layers:2 + 2 * num_layers]
        out_ref = refs[2 + 2 * num_layers]
        carry_ref = refs[3 + 2 * num_layers]

        # First chunk of a batch tile: seed the carried state from y0.
        @pl.when(pl.program_id(1) == 0)
        def _():
            carry_ref[...] = y0_ref[...]

        # Load weights ONCE per cell — they stay register/VMEM-resident
        # for the whole chunk (the crossbar analogy).
        ws = [w_ref[...] for w_ref in w_refs]
        bs = [b_ref[...] for b_ref in b_refs]

        def body(t, y):
            y = step(y, u_ref[0, 2 * t], u_ref[0, 2 * t + 1],
                     u_ref[0, 2 * t + 2], ws, bs)
            out_ref[t] = y
            return y

        y = lax.fori_loop(0, C, body, carry_ref[...])
        carry_ref[...] = y

    return kernel


def _chunk_drive(u: jax.Array, C: int, num_chunks: int) -> jax.Array:
    """Re-slab a time-major drive (2T+1, ...) into per-chunk overlapping
    windows (num_chunks, 2C+1, ...).  Consecutive RK4 chunks share their
    boundary half-step sample, and the tail is edge-padded so a partial
    final chunk integrates on a frozen drive (those steps are sliced off
    the trajectory before returning)."""
    pad = 2 * (num_chunks * C) + 1 - u.shape[0]
    if pad:
        u = jnp.pad(u, ((0, pad),) + ((0, 0),) * (u.ndim - 1), mode="edge")
    idx = (jnp.arange(num_chunks) * 2 * C)[:, None] + jnp.arange(2 * C + 1)
    return u[idx]


def fused_node_rollout(
    y0: jax.Array,                    # (B, D) f32
    u_half: jax.Array,                # (2T+1, Du) shared or (B, 2T+1, Du)
    weights: Sequence[jax.Array],
    biases: Sequence[jax.Array],
    dt: float,
    *,
    batch_tile: int = 64,
    time_chunk: int | None = None,
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
) -> jax.Array:
    """Full-trajectory RK4 solve; returns (T+1, B, D).  See module doc.

    ``u_half`` is the drive sampled at RK4 half-steps: (2T+1, Du) shared
    by the whole batch, or (B, 2T+1, Du) with one stimulus per batch
    element (fleet serving); Du may be 0 (autonomous).  ``time_chunk``
    bounds how many RK4 steps stay VMEM-resident per grid cell (None =
    auto-pick the largest chunk fitting ``vmem_budget_bytes``), so the
    horizon T is unbounded.  ``interpret=None`` auto-detects: compiled on
    TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = _default_interpret()
    B, D = y0.shape
    per_tile_drive = u_half.ndim == 3
    if per_tile_drive and u_half.shape[0] != B:
        raise ValueError(
            f"per-twin drive batch {u_half.shape[0]} != y0 batch {B}")
    if per_tile_drive and u_half.shape[-1] == 0:
        per_tile_drive, u_half = False, u_half[0]
    T = (u_half.shape[1 if per_tile_drive else 0] - 1) // 2
    du = u_half.shape[-1]
    L = len(weights)
    bt = min(batch_tile, B)
    if B % bt:
        raise ValueError(f"batch {B} not divisible by tile {bt}")

    plan = plan_time_chunk(T, bt, D, du, per_tile_drive, weights, biases,
                           vmem_budget_bytes, time_chunk)
    C, NC = plan.time_chunk, plan.num_chunks

    kernel = _make_kernel(L, C, float(dt), du, bt, per_tile_drive)

    grid = (B // bt, NC)                 # time minor: chunks run in order
    if per_tile_drive:
        # time-major so the kernel's u_ref[0, 2t] indexing holds
        u_tm = jnp.transpose(u_half, (1, 0, 2))          # (2T+1, B, du)
        u_in = _chunk_drive(u_tm, C, NC)                 # (NC, 2C+1, B, du)
        u_spec = pl.BlockSpec((1, 2 * C + 1, bt, du),
                              lambda i, j: (j, 0, i, 0))
    else:
        u_tm = u_half if du > 0 else jnp.zeros((2 * T + 1, 1), y0.dtype)
        u_in = _chunk_drive(u_tm, C, NC)                 # (NC, 2C+1, du')
        u_spec = pl.BlockSpec((1, 2 * C + 1, max(du, 1)),
                              lambda i, j: (j, 0, 0))
    in_specs = [
        pl.BlockSpec((bt, D), lambda i, j: (i, 0)),      # y0
        u_spec,                                          # u_chunks
    ]
    for w in weights:
        in_specs.append(pl.BlockSpec(w.shape, lambda i, j: (0, 0)))
    for b in biases:
        in_specs.append(pl.BlockSpec(b.shape, lambda i, j: (0,)))
    out_spec = pl.BlockSpec((C, bt, D), lambda i, j: (j, i, 0))

    steps = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((NC * C, B, D), y0.dtype),
        scratch_shapes=[pltpu.VMEM((bt, D), jnp.float32)],
        interpret=interpret,
    )(y0, u_in, *weights, *biases)
    # Row k of ``steps`` is y after step k; prepend y0, drop the padded
    # tail of a partial final chunk.
    return jnp.concatenate([y0[None], steps[:T]], axis=0)
