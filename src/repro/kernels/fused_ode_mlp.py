"""Weights-stationary fused neural-ODE solve — the paper's in-memory insight
on TPU.

The analogue system's whole advantage is that weights never move: they sit
in the crossbar while the state circulates through the closed loop.  The
TPU transposition: pin the MLP weights in VMEM once and run the ENTIRE RK4
trajectory (T steps x 4 f-evals) inside a single ``pallas_call`` —
activations live in VREGs/VMEM, the only HBM traffic is y0/drive in and
the trajectory out.  A step-by-step XLA implementation would re-read the
weights from HBM every f-eval and write every intermediate state back; at
the paper's sizes that makes the solve HBM-latency-bound.

Grid: one cell per batch tile (weights broadcast to every cell).
Block layout:
  y0      (bt, D)          per-tile
  u_half  (2T+1, Du)       full, broadcast  (drive at half-steps for RK4)
          — or, for per-twin drives (fleet serving), (2T+1, bt, Du)
          per-tile slices of a (2T+1, B, Du) stimulus tensor
  w_i/b_i (full)           broadcast — the "crossbar residency"
  out     (T+1, bt, D)     per-tile trajectory

VMEM budget per cell ~= (T+1)*bt*D*4  +  sum(w)  +  (2T+1)*Du*4 bytes;
the wrapper asserts it fits the ~16 MB/core budget before lowering.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _default_interpret() -> bool:
    """Compiled lowering on TPU, interpreter everywhere else — so CPU/GPU
    hosts validate the kernel while TPU runs never silently benchmark the
    interpreter."""
    return jax.default_backend() != "tpu"


def _make_kernel(num_layers: int, T: int, dt: float, drive_dim: int,
                 bt: int, per_tile_drive: bool = False):
    def kernel(*refs):
        y0_ref = refs[0]
        u_ref = refs[1]
        w_refs = refs[2:2 + num_layers]
        b_refs = refs[2 + num_layers:2 + 2 * num_layers]
        out_ref = refs[2 + 2 * num_layers]

        # Load weights ONCE — they stay register/VMEM-resident for the
        # whole trajectory (the crossbar analogy).
        ws = [w_ref[...] for w_ref in w_refs]
        bs = [b_ref[...] for b_ref in b_refs]

        def mlp(x):
            for i in range(num_layers):
                x = jnp.dot(x, ws[i], preferred_element_type=jnp.float32)
                x = x + bs[i][None, :]
                if i < num_layers - 1:
                    x = jnp.maximum(x, 0.0)
            return x

        def f(u_row, y):
            if drive_dim > 0:
                # u_row: (drive_dim,) broadcast, or (bt, drive_dim) per-twin
                u = (u_row if per_tile_drive
                     else jnp.broadcast_to(u_row, (bt, drive_dim)))
                inp = jnp.concatenate([u, y], axis=-1)
            else:
                inp = y
            return mlp(inp)

        y = y0_ref[...]
        out_ref[0] = y

        def body(t, y):
            u0 = u_ref[2 * t]
            um = u_ref[2 * t + 1]
            u1 = u_ref[2 * t + 2]
            k1 = f(u0, y)
            k2 = f(um, y + (dt / 2) * k1)
            k3 = f(um, y + (dt / 2) * k2)
            k4 = f(u1, y + dt * k3)
            y = y + (dt / 6) * (k1 + 2 * k2 + 2 * k3 + k4)
            out_ref[t + 1] = y
            return y

        lax.fori_loop(0, T, body, y)

    return kernel


def fused_node_rollout(
    y0: jax.Array,                    # (B, D) f32
    u_half: jax.Array,                # (2T+1, Du) shared or (B, 2T+1, Du)
    weights: Sequence[jax.Array],
    biases: Sequence[jax.Array],
    dt: float,
    *,
    batch_tile: int = 64,
    interpret: bool | None = None,
    vmem_budget_bytes: int = 14 * 1024 * 1024,
) -> jax.Array:
    """Full-trajectory RK4 solve; returns (T+1, B, D).  See module doc.

    ``u_half`` is the drive sampled at RK4 half-steps: (2T+1, Du) shared
    by the whole batch, or (B, 2T+1, Du) with one stimulus per batch
    element (fleet serving); Du may be 0 (autonomous).  ``interpret=None``
    auto-detects: compiled on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = _default_interpret()
    B, D = y0.shape
    per_tile_drive = u_half.ndim == 3
    if per_tile_drive and u_half.shape[0] != B:
        raise ValueError(
            f"per-twin drive batch {u_half.shape[0]} != y0 batch {B}")
    if per_tile_drive and u_half.shape[-1] == 0:
        per_tile_drive, u_half = False, u_half[0]
    T = (u_half.shape[1 if per_tile_drive else 0] - 1) // 2
    du = u_half.shape[-1]
    L = len(weights)
    bt = min(batch_tile, B)
    if B % bt:
        raise ValueError(f"batch {B} not divisible by tile {bt}")

    wbytes = sum(4 * w.size for w in weights) + sum(4 * b.size for b in biases)
    traj_bytes = 4 * (T + 1) * bt * D
    u_bytes = 4 * (2 * T + 1) * max(du, 1) * (bt if per_tile_drive else 1)
    need = wbytes + traj_bytes + u_bytes + 4 * bt * max(
        du + D, max(w.shape[1] for w in weights))
    if need > vmem_budget_bytes:
        raise ValueError(
            f"fused trajectory needs ~{need/2**20:.1f} MiB VMEM "
            f"(budget {vmem_budget_bytes/2**20:.1f}); shrink batch_tile or T")

    kernel = _make_kernel(L, T, float(dt), du, bt, per_tile_drive)

    grid = (B // bt,)
    if per_tile_drive:
        # time-major so the kernel's leading-axis u_ref[2t] indexing holds
        u_in = jnp.transpose(u_half, (1, 0, 2))           # (2T+1, B, du)
        u_spec = pl.BlockSpec((2 * T + 1, bt, du), lambda i: (0, i, 0))
    else:
        u_in = u_half if du > 0 else jnp.zeros((2 * T + 1, 1), y0.dtype)
        u_spec = pl.BlockSpec((2 * T + 1, max(du, 1)), lambda i: (0, 0))
    in_specs = [
        pl.BlockSpec((bt, D), lambda i: (i, 0)),          # y0
        u_spec,                                           # u_half
    ]
    for w in weights:
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
    for b in biases:
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
    out_spec = pl.BlockSpec((T + 1, bt, D), lambda i: (0, i, 0))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((T + 1, B, D), y0.dtype),
        interpret=interpret,
    )(y0, u_in, *weights, *biases)
