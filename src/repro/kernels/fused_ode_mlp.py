"""Weights-stationary fused neural-ODE solve — the paper's in-memory insight
on TPU.

The analogue system's whole advantage is that weights never move: they sit
in the crossbar while the state circulates through the closed loop.  The
TPU transposition: pin the MLP weights in VMEM once and run the ENTIRE RK4
trajectory (T steps x 4 f-evals) inside a single ``pallas_call`` —
activations live in VREGs/VMEM, the only HBM traffic is y0/drive in and
the trajectory out.  A step-by-step XLA implementation would re-read the
weights from HBM every f-eval and write every intermediate state back; at
the paper's sizes that makes the solve HBM-latency-bound.

Grid: (batch tiles, time chunks); weights broadcast to every cell.  Time
is the minor grid dimension, so all chunks of one batch tile run back to
back and the integration state is carried across chunks in a VMEM scratch
buffer (re-seeded from ``y0`` whenever a new batch tile starts).
Block layout per (i, j) cell:
  y0       (bt, D)            per-tile, same block for every chunk
  u_chunks (1, 2C+1, Du)      chunk j's drive half-steps, broadcast
           — or, for per-twin drives (fleet serving), (1, 2C+1, bt, Du)
           per-tile slices of a (n_chunks, 2C+1, B, Du) stimulus tensor
  w_i/b_i  (full)             broadcast — the "crossbar residency"
  out      (C, bt, D)         chunk j's slab of the trajectory
  carry    (bt, D)            VMEM scratch, persistent across the grid

VMEM per cell ~= weights + C*bt*D (out slab) + (2C+1)*Du (drive slab)
+ carry + activations, each term sized by its policy dtype; the horizon
T no longer has to fit — only one chunk does.  ``time_chunk=None``
auto-picks the largest C within ``vmem_budget_bytes``, so weights stay
resident while arbitrarily long horizons stream chunk-by-chunk through
HBM.  A ``ValueError`` is now raised only when the weights plus a
single step genuinely cannot fit.

Mixed precision: the ``precision`` policy decides the byte width of
everything that streams through VMEM/HBM.  ``"bf16_f32acc"`` (the TPU
default) stores weights, drive slabs and trajectory slabs in bfloat16
— halving HBM traffic and roughly doubling the resident time chunk —
while every ``jnp.dot`` accumulates at float32 on the MXU and the RK4
state carry stays float32 in VMEM scratch.  ``"bf16"`` additionally
carries the state at bfloat16 (the fully reduced substrate, mirroring
the analogue crossbar's precision tolerance); ``"f32"`` is the exact
float32 path.  In the bf16 policies the carried state is rounded to
the storage dtype once per chunk boundary, so the chunk-start states
the backward pass replays from (the stored trajectory rows) are
bit-identical to the states the forward actually continued from.

This module is the forward; :mod:`repro.kernels.fused_ode_mlp_bwd`
walks the same grid in reverse (chunk-boundary checkpoints = trajectory
rows, recompute-in-VMEM replay) to make the rollout differentiable on
the same substrate.

Resuming mid-trajectory (the streaming-serving contract, enforced by
``tests/test_streaming.py``): because the carried state is rounded
through the storage dtype at every chunk boundary AND the y0 seed takes
the same ``.astype(store).astype(carry)`` path, any stored trajectory
row ``traj[k]`` is the exact value the kernel continued integrating
from — so ``fused_node_rollout(traj[k], drive_window(u_half, k, T-k),
...)`` reproduces rows ``k..T`` of the uninterrupted solve
bit-identically under "f32" (the seed round-trip is a no-op) and under
pure "bf16" (rows are stored at the carry dtype).  Under
"bf16_f32acc" the intra-chunk carry is f32 but rows are stored bf16,
so resuming at a non-chunk-boundary step re-rounds the seed once:
parity within one storage-dtype rounding of the carried state.  The
drive must be re-sampled on the canonical global half-step grid
(:func:`repro.kernels.ops.half_step_times`) — re-deriving it with
``linspace`` over the sub-window perturbs t by ~1 ulp and breaks
bitwise parity.
"""
from __future__ import annotations

import functools
import os
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_VMEM_BUDGET = 14 * 1024 * 1024   # ~16 MB/core minus headroom

#: Supported precision policies (see the module docstring's error model).
PRECISIONS = ("f32", "bf16", "bf16_f32acc")


def _default_interpret() -> bool:
    """Compiled lowering on TPU, interpreter everywhere else — so CPU/GPU
    hosts validate the kernel while TPU runs never silently benchmark the
    interpreter.  ``REPRO_FORCE_INTERPRET=1`` (or ``0``) pins the mode
    regardless of the detected accelerator, so CI and local debugging can
    force the interpreter (or a compiled lowering) without monkeypatching;
    an empty/unset variable keeps the auto-detect."""
    env = os.environ.get("REPRO_FORCE_INTERPRET", "").strip().lower()
    if env:
        truthy = {"1", "true", "yes", "on"}
        falsy = {"0", "false", "no", "off"}
        if env not in truthy | falsy:
            raise ValueError(
                f"REPRO_FORCE_INTERPRET={env!r} not understood; use one "
                f"of {sorted(truthy)} / {sorted(falsy)} (or unset it for "
                f"accelerator auto-detect)")
        return env in truthy
    return jax.default_backend() != "tpu"


def default_precision() -> str:
    """``"bf16_f32acc"`` on TPU (MXU-native bf16, f32 accumulation),
    ``"f32"`` everywhere else — CPU/GPU hosts validate exact numerics."""
    return "bf16_f32acc" if jax.default_backend() == "tpu" else "f32"


def resolve_precision(precision: str | None) -> str:
    """Accept a policy name or None (auto: :func:`default_precision`)."""
    if precision is None:
        return default_precision()
    if precision not in PRECISIONS:
        raise ValueError(
            f"unknown precision {precision!r}; have {list(PRECISIONS)}")
    return precision


def precision_dtypes(precision: str):
    """``(store, compute, acc, carry)`` dtypes of a resolved policy.

    store   — weights/biases, drive slabs, trajectory slabs (HBM + the
              VMEM-resident operand blocks);
    compute — matmul operand dtype fed to the MXU;
    acc     — ``preferred_element_type`` of every in-kernel ``jnp.dot``;
    carry   — the RK4 integration state in VMEM scratch.
    """
    if precision == "f32":
        return (jnp.float32,) * 4
    if precision == "bf16":
        return (jnp.bfloat16,) * 4
    if precision == "bf16_f32acc":
        return jnp.bfloat16, jnp.bfloat16, jnp.float32, jnp.float32
    raise ValueError(
        f"unknown precision {precision!r}; have {list(PRECISIONS)}")


def _require_float(name: str, x: jax.Array, precision: str) -> None:
    """Clear dtype gate: a non-floating input would otherwise reach the
    kernel and die with an opaque Mosaic lowering error."""
    if not jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        raise ValueError(
            f"fused_node_rollout: {name} has non-floating dtype "
            f"{jnp.asarray(x).dtype}; the precision={precision!r} policy "
            f"stores {jnp.dtype(precision_dtypes(precision)[0]).name} — "
            f"cast {name} to a floating dtype first")


class ChunkPlan(NamedTuple):
    """How a T-step horizon is streamed through VMEM."""
    time_chunk: int          # C — RK4 steps resident per grid cell
    num_chunks: int          # ceil(T / C)
    vmem_bytes: int          # estimated per-cell VMEM footprint


def _rk4_activation_bytes(bt: int, D: int, du: int,
                          weights: Sequence[jax.Array],
                          acc_itemsize: int) -> int:
    """VMEM slack for the live RK4 temporaries of one step.

    Derived from what one ``make_rk4_step`` invocation actually keeps
    alive at its peak, all at the accumulation dtype:

      6 · (bt, D)          y, k1..k4 and the perturbed state y + c·k_i
                           (the final combination holds all four k's plus
                           y at once — six state-width buffers)
      (bt, in_l + out_l)   the widest adjacent (input, output) activation
                           pair of the MLP — at any moment one layer's
                           input and its dot output coexist; the first
                           layer's input width already includes du + D
                           through w_0.shape[0]

    i.e. ``act = acc_itemsize · bt · (6·D + max_l(in_l + out_l))``.  This
    replaces the old ``4 · bt · max(du + D, max width) · 6`` magic
    constant, which over-counted narrow-state MLPs ~3x and under-counted
    none of the tested shapes.
    """
    del du  # already folded into w_0.shape[0] by the caller's MLP sizes
    widest_pair = max(w.shape[0] + w.shape[1] for w in weights)
    return acc_itemsize * bt * (6 * D + widest_pair)


def plan_time_chunk(T: int, bt: int, D: int, du: int, per_tile_drive: bool,
                    weights: Sequence[jax.Array], biases: Sequence[jax.Array],
                    vmem_budget_bytes: int,
                    time_chunk: int | None = None,
                    precision: str = "f32") -> ChunkPlan:
    """Pick the largest time chunk C whose per-cell working set fits the
    VMEM budget (or honour an explicit ``time_chunk`` override).

    Per-cell bytes, each term sized by the ``precision`` policy's dtypes
    (``sb``/``ab``/``cb`` = storage/accumulation/carry itemsize):

      sb · (Σ w.size + Σ b.size)     weights + biases (resident)
      sb · C·bt·D                    the (C, bt, D) output slab
      sb · (2C+1)·u_width            the drive slab (u_width = Du, or
                                     bt·Du per-twin)
      cb · bt·D                      the carry scratch
      ab · bt · (6·D + max(in+out))  RK4 activation slack (see
                                     :func:`_rk4_activation_bytes`)

    bf16 storage halves every per-step term, so the planned chunk is
    ~2x the f32 one at a fixed budget (the weights-must-fit threshold
    moves by the same factor).
    """
    store, _, acc, carry = precision_dtypes(resolve_precision(precision))
    sb = jnp.dtype(store).itemsize
    ab = jnp.dtype(acc).itemsize
    cb = jnp.dtype(carry).itemsize
    u_width = max(du, 1) * (bt if per_tile_drive else 1)
    wbytes = (sum(sb * w.size for w in weights)
              + sum(sb * b.size for b in biases))
    act = _rk4_activation_bytes(bt, D, du, weights, ab)
    fixed = wbytes + act + cb * bt * D           # + carry
    per_step = sb * bt * D + 2 * sb * u_width    # out row + two u rows
    if time_chunk is not None:
        C = max(1, min(int(time_chunk), T))
    else:
        avail = vmem_budget_bytes - fixed - sb * u_width   # the +1 u row
        C = int(avail // per_step)
        if C < 1:
            raise ValueError(
                f"fused kernel weights + one RK4 step need "
                f"~{(fixed + per_step + sb * u_width) / 2 ** 20:.1f} MiB VMEM "
                f"(budget {vmem_budget_bytes / 2 ** 20:.1f}); shrink "
                f"batch_tile or the MLP")
        C = min(C, T)
    need = fixed + sb * C * bt * D + sb * (2 * C + 1) * u_width
    if need > vmem_budget_bytes:
        # only reachable with an explicit (oversized) time_chunk — fail
        # with a clear message instead of an opaque Mosaic allocation
        # error at lowering time
        raise ValueError(
            f"time_chunk={C} needs ~{need / 2 ** 20:.1f} MiB VMEM "
            f"(budget {vmem_budget_bytes / 2 ** 20:.1f}); shrink "
            f"time_chunk or batch_tile")
    return ChunkPlan(C, -(-T // C), need)


def make_rk4_step(num_layers: int, dt: float, drive_dim: int, bt: int,
                  per_tile_drive: bool, precision: str = "f32"):
    """One in-kernel RK4 step ``step(y, u0, um, u1, ws, bs) -> y_next``.

    SHARED between the forward kernel and the backward kernel's
    checkpoint replay + step VJP (:mod:`repro.kernels.fused_ode_mlp_bwd`)
    — the recompute must be bit-identical to the forward, so there is
    exactly one definition of the step.

    Under a bf16 ``precision`` policy the matmul operands are cast to
    the compute dtype (MXU-native bf16) and every ``jnp.dot`` names the
    policy's accumulation dtype via ``preferred_element_type``; the
    surrounding RK4 arithmetic runs at the carry dtype (f32 for
    ``"bf16_f32acc"``), so only the MXU operands are reduced."""
    _, compute, acc, carry = precision_dtypes(resolve_precision(precision))

    def mlp(x, ws, bs):
        for i in range(num_layers):
            x = jnp.dot(x.astype(compute), ws[i],
                        preferred_element_type=acc)
            x = x + bs[i][None, :].astype(acc)
            if i < num_layers - 1:
                x = jnp.maximum(x, 0.0)
        return x.astype(carry)

    def f(u_row, y, ws, bs):
        if drive_dim > 0:
            # u_row: (drive_dim,) broadcast, or (bt, drive_dim) per-twin
            u = (u_row if per_tile_drive
                 else jnp.broadcast_to(u_row, (bt, drive_dim)))
            inp = jnp.concatenate([u.astype(carry), y], axis=-1)
        else:
            inp = y
        return mlp(inp, ws, bs)

    def step(y, u0, um, u1, ws, bs):
        k1 = f(u0, y, ws, bs)
        k2 = f(um, y + (dt / 2) * k1, ws, bs)
        k3 = f(um, y + (dt / 2) * k2, ws, bs)
        k4 = f(u1, y + dt * k3, ws, bs)
        return y + (dt / 6) * (k1 + 2 * k2 + 2 * k3 + k4)

    return step


def pad_fleet_to_tile(y0s: jax.Array, uh: jax.Array, batch_tile: int):
    """Pad the fleet axis up to a multiple of the batch tile.

    Padded rows replicate the last twin (in-distribution values, no NaN
    risk) and per-twin drive slabs (``uh.ndim == 3``) are replicated
    alongside; the caller slices the result back to the real fleet.
    Returns ``(y0s_padded, uh_padded, bt, B)`` with ``B`` the original
    fleet size.  One extra tile instead of the old largest-divisor
    search that degenerated to bt=1 for prime fleet sizes.
    """
    B = y0s.shape[0]
    bt = min(batch_tile, B)
    pad = (-B) % bt
    if pad:
        y0s = jnp.concatenate(
            [y0s, jnp.broadcast_to(y0s[-1:], (pad,) + y0s.shape[1:])])
        if uh.ndim == 3:
            uh = jnp.concatenate(
                [uh, jnp.broadcast_to(uh[-1:], (pad,) + uh.shape[1:])])
    return y0s, uh, bt, B


def drive_window(u_half: jax.Array, start_step: int,
                 num_steps: int) -> jax.Array:
    """Slice a pre-sampled half-step drive to a resume window.

    ``u_half`` is the full-horizon drive on the RK4 half-step grid —
    (2T+1, Du) shared or (B, 2T+1, Du) per-twin; the window covering
    global steps ``[start_step, start_step + num_steps)`` is rows
    ``[2*start_step, 2*(start_step + num_steps)]`` inclusive (adjacent
    windows share their boundary sample, exactly like the kernel's own
    chunked drive slabs).  Handing this window to
    ``fused_node_rollout`` together with trajectory row ``start_step``
    as ``y0`` continues the solve bit-identically (see module doc).
    """
    axis = 1 if u_half.ndim == 3 else 0
    lo, hi = 2 * start_step, 2 * (start_step + num_steps) + 1
    if not (0 <= lo < hi <= u_half.shape[axis]):
        raise ValueError(
            f"drive_window: steps [{start_step}, {start_step + num_steps})"
            f" fall outside the (2T+1)={u_half.shape[axis]} half-step grid")
    return u_half[:, lo:hi] if axis == 1 else u_half[lo:hi]


def _make_kernel(num_layers: int, C: int, dt: float, drive_dim: int,
                 bt: int, per_tile_drive: bool = False,
                 precision: str = "f32"):
    store, _, _, carry = precision_dtypes(resolve_precision(precision))
    step = make_rk4_step(num_layers, dt, drive_dim, bt, per_tile_drive,
                         precision)

    def kernel(*refs):
        y0_ref = refs[0]
        u_ref = refs[1]
        w_refs = refs[2:2 + num_layers]
        b_refs = refs[2 + num_layers:2 + 2 * num_layers]
        out_ref = refs[2 + 2 * num_layers]
        carry_ref = refs[3 + 2 * num_layers]

        # First chunk of a batch tile: seed the carried state from y0,
        # rounded through the storage dtype so the seed equals trajectory
        # row 0 exactly (what the backward pass replays chunk 0 from).
        @pl.when(pl.program_id(1) == 0)
        def _():
            carry_ref[...] = y0_ref[...].astype(store).astype(carry)

        # Load weights ONCE per cell — they stay register/VMEM-resident
        # for the whole chunk (the crossbar analogy).
        ws = [w_ref[...] for w_ref in w_refs]
        bs = [b_ref[...] for b_ref in b_refs]

        def body(t, y):
            y = step(y, u_ref[0, 2 * t], u_ref[0, 2 * t + 1],
                     u_ref[0, 2 * t + 2], ws, bs)
            out_ref[t] = y.astype(store)
            return y

        y = lax.fori_loop(0, C, body, carry_ref[...])
        # Round the chunk-boundary carry through the storage dtype: the
        # next chunk then continues from the exact value the trajectory
        # row stores, keeping forward and checkpoint-replay bit-identical
        # under reduced-precision storage (no-op for f32).
        carry_ref[...] = y.astype(store).astype(carry)

    return kernel


def _chunk_drive(u: jax.Array, C: int, num_chunks: int) -> jax.Array:
    """Re-slab a time-major drive (2T+1, ...) into per-chunk overlapping
    windows (num_chunks, 2C+1, ...).  Consecutive RK4 chunks share their
    boundary half-step sample, and the tail is edge-padded so a partial
    final chunk integrates on a frozen drive (those steps are sliced off
    the trajectory before returning)."""
    pad = 2 * (num_chunks * C) + 1 - u.shape[0]
    if pad:
        u = jnp.pad(u, ((0, pad),) + ((0, 0),) * (u.ndim - 1), mode="edge")
    idx = (jnp.arange(num_chunks) * 2 * C)[:, None] + jnp.arange(2 * C + 1)
    return u[idx]


def fused_node_rollout(
    y0: jax.Array,                    # (B, D) float
    u_half: jax.Array,                # (2T+1, Du) shared or (B, 2T+1, Du)
    weights: Sequence[jax.Array],
    biases: Sequence[jax.Array],
    dt: float,
    *,
    batch_tile: int = 64,
    time_chunk: int | None = None,
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
    precision: str | None = None,
) -> jax.Array:
    """Full-trajectory RK4 solve; returns (T+1, B, D) at the policy's
    storage dtype.  See module doc.

    ``u_half`` is the drive sampled at RK4 half-steps: (2T+1, Du) shared
    by the whole batch, or (B, 2T+1, Du) with one stimulus per batch
    element (fleet serving); Du may be 0 (autonomous).  ``time_chunk``
    bounds how many RK4 steps stay VMEM-resident per grid cell (None =
    auto-pick the largest chunk fitting ``vmem_budget_bytes``), so the
    horizon T is unbounded.  ``interpret=None`` auto-detects: compiled on
    TPU, interpreter elsewhere (``REPRO_FORCE_INTERPRET`` overrides).
    ``precision`` picks the mixed-precision policy ("f32" | "bf16" |
    "bf16_f32acc"; ``None`` = auto — bf16_f32acc on TPU, f32 elsewhere):
    floating inputs are cast to the policy dtypes here, non-floating
    inputs raise a named ``ValueError`` instead of an opaque Mosaic
    lowering error.
    """
    if interpret is None:
        interpret = _default_interpret()
    precision = resolve_precision(precision)
    store, _, _, carry = precision_dtypes(precision)
    _require_float("y0", y0, precision)
    _require_float("u_half", u_half, precision)
    for li, (w, b) in enumerate(zip(weights, biases)):
        _require_float(f"weights[{li}]", w, precision)
        _require_float(f"biases[{li}]", b, precision)
    weights = [w.astype(store) for w in weights]
    biases = [b.astype(store) for b in biases]
    u_half = u_half.astype(store)
    y0 = y0.astype(jnp.float32)       # the seed block; rounded in-kernel
    B, D = y0.shape
    per_tile_drive = u_half.ndim == 3
    if per_tile_drive and u_half.shape[0] != B:
        raise ValueError(
            f"per-twin drive batch {u_half.shape[0]} != y0 batch {B}")
    if per_tile_drive and u_half.shape[-1] == 0:
        per_tile_drive, u_half = False, u_half[0]
    T = (u_half.shape[1 if per_tile_drive else 0] - 1) // 2
    du = u_half.shape[-1]
    L = len(weights)
    bt = min(batch_tile, B)
    if B % bt:
        raise ValueError(f"batch {B} not divisible by tile {bt}")

    plan = plan_time_chunk(T, bt, D, du, per_tile_drive, weights, biases,
                           vmem_budget_bytes, time_chunk,
                           precision=precision)
    C, NC = plan.time_chunk, plan.num_chunks

    kernel = _make_kernel(L, C, float(dt), du, bt, per_tile_drive,
                          precision)

    grid = (B // bt, NC)                 # time minor: chunks run in order
    if per_tile_drive:
        # time-major so the kernel's u_ref[0, 2t] indexing holds
        u_tm = jnp.transpose(u_half, (1, 0, 2))          # (2T+1, B, du)
        u_in = _chunk_drive(u_tm, C, NC)                 # (NC, 2C+1, B, du)
        u_spec = pl.BlockSpec((1, 2 * C + 1, bt, du),
                              lambda i, j: (j, 0, i, 0))
    else:
        u_tm = u_half if du > 0 else jnp.zeros((2 * T + 1, 1), store)
        u_in = _chunk_drive(u_tm, C, NC)                 # (NC, 2C+1, du')
        u_spec = pl.BlockSpec((1, 2 * C + 1, max(du, 1)),
                              lambda i, j: (j, 0, 0))
    in_specs = [
        pl.BlockSpec((bt, D), lambda i, j: (i, 0)),      # y0
        u_spec,                                          # u_chunks
    ]
    for w in weights:
        in_specs.append(pl.BlockSpec(w.shape, lambda i, j: (0, 0)))
    for b in biases:
        in_specs.append(pl.BlockSpec(b.shape, lambda i, j: (0,)))
    out_spec = pl.BlockSpec((C, bt, D), lambda i, j: (j, i, 0))

    steps = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((NC * C, B, D), store),
        scratch_shapes=[pltpu.VMEM((bt, D), carry)],
        interpret=interpret,
    )(y0, u_in, *weights, *biases)
    # Row k of ``steps`` is y after step k; prepend y0, drop the padded
    # tail of a partial final chunk.
    return jnp.concatenate([y0[None].astype(store), steps[:T]], axis=0)
