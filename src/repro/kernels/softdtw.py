"""Anti-diagonal wavefront soft-DTW kernels (forward AND backward).

Forward: the DP recurrence
R[i,j] = D[i,j] + softmin(R[i-1,j], R[i,j-1], R[i-1,j-1])
serialises along both axes but is embarrassingly parallel along each
anti-diagonal — an exact match for the VPU's lane-parallel vector ops.
The cost matrix is pre-laid-out in diagonal-major order (n+m-1, n) so each
wavefront step is one contiguous VMEM row read; the two carried diagonals
live in VMEM scratch that persists across the sequential k-chunk grid
dimension (the chunking keeps arbitrarily long series within VMEM).
``return_r=True`` additionally emits the full accumulated-cost matrix R
in the same diagonal layout — the residual the backward pass needs.

Backward: the gradient of soft-DTW w.r.t. the cost matrix is the
E-matrix of Cuturi & Blondel 2017 (Alg. 2), computed by the CLOSED-FORM
reverse DP

    E[i,j] = E[i+1,j]   * exp((R[i+1,j]   - R[i,j] - D[i+1,j])  / gamma)
           + E[i,j+1]   * exp((R[i,j+1]   - R[i,j] - D[i,j+1])  / gamma)
           + E[i+1,j+1] * exp((R[i+1,j+1] - R[i,j] - D[i+1,j+1])/ gamma)

seeded with E[n-1,m-1] = 1 and swept over anti-diagonals in REVERSE
order — the same wavefront schedule as the forward, so it runs as a
second Pallas kernel (``softdtw_bwd_pallas``) with the carried E/R/D
diagonals in VMEM scratch.  No autodiff of the sequential DP is
involved anywhere.

Grid: (batch, num_k_chunks); the backward's chunk grid dimension is
index-mapped in reverse.

Mixed precision: the cost matrix ``dd`` — the only O(n·m) input — may
arrive in bfloat16 (the ``"bf16"``/``"bf16_f32acc"`` policies halve its
VMEM/HBM traffic); each wavefront row is upcast once on read and the
R/E/D diagonal carries, the accumulated answer and the emitted R and E
matrices ALWAYS stay float32 — the sequential DP recurrences are where
reduced precision would compound.  The BIG padding sentinel is detected
with a half-BIG threshold because bf16 rounds ``1e10`` slightly DOWN
(an exact ``>= BIG`` compare would mistake padded cells for real ones).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.losses import BIG

# padding-sentinel threshold: robust to BIG's bf16 rounding (see module
# docstring); real costs are pairwise distances, orders of magnitude
# below BIG/2
BIG_CUT = BIG * 0.5


def _kernel(dd_ref, *refs, n: int, m: int, chunk: int, nkc: int,
            gamma: float, hard: bool, with_r: bool):
    if with_r:
        out_ref, r_dd_ref = refs[0], refs[1]
        scratch = refs[2:]
    else:
        out_ref = refs[0]
        scratch = refs[1:]
    rp_ref, rp2_ref, ans_ref = scratch
    kc = pl.program_id(1)

    @pl.when(kc == 0)
    def _init():
        rp_ref[...] = jnp.full_like(rp_ref, BIG)
        rp2_ref[...] = jnp.full_like(rp2_ref, BIG)
        ans_ref[...] = jnp.zeros_like(ans_ref)

    def minop(a, b, c):
        if hard:
            return jnp.minimum(jnp.minimum(a, b), c)
        s = jnp.stack([a, b, c], axis=0)
        return -gamma * jax.nn.logsumexp(-s / gamma, axis=0)

    big_head = jnp.full((1,), BIG, dtype=jnp.float32)

    def body(r, _):
        k = kc * chunk + r
        d_k = dd_ref[0, r].astype(jnp.float32)   # bf16 slab upcast once
        rp = rp_ref[...]
        rp2 = rp2_ref[...]
        up = rp
        left = jnp.concatenate([big_head, rp[:-1]])
        diag = jnp.concatenate([big_head, rp2[:-1]])
        best = minop(up, left, diag)
        invalid = d_k >= BIG_CUT
        r_k = d_k + jnp.where(invalid, 0.0, best)
        r_k = jnp.where(k == 0, d_k, r_k)          # (0,0) has no predecessor
        r_k = jnp.where(invalid, BIG, r_k)
        rp2_ref[...] = rp
        rp_ref[...] = r_k
        if with_r:
            r_dd_ref[0, r] = r_k
        ans_ref[0] = jnp.where(k == n + m - 2, r_k[n - 1], ans_ref[0])
        return 0

    lax.fori_loop(0, chunk, body, 0)

    @pl.when(kc == nkc - 1)
    def _finish():
        out_ref[0] = ans_ref[0]


def softdtw_pallas(
    dd: jax.Array,           # (B, KD_pad, n) diagonal-major costs, BIG-padded
    n: int, m: int,
    *,
    gamma: float = 1.0,
    hard: bool = False,
    chunk: int = 256,
    interpret: bool = True,
    return_r: bool = False,
):
    """Batched accumulated (soft-)DTW from diagonal-layout costs -> (B,).

    ``dd`` may be float32 or bfloat16 (the reduced-precision policies
    stream the cost slab at half width); the DP carries and the output
    are always float32.  ``return_r=True`` also returns the
    accumulated-cost matrix R (float32) in the same (B, KD_pad, n)
    diagonal layout — the backward pass's residual.
    """
    B, kd_pad, n_ = dd.shape
    assert n_ == n and kd_pad % chunk == 0
    nkc = kd_pad // chunk
    kernel = functools.partial(_kernel, n=n, m=m, chunk=chunk, nkc=nkc,
                               gamma=float(gamma), hard=hard,
                               with_r=return_r)
    out_shape = [jax.ShapeDtypeStruct((B,), jnp.float32)]
    out_specs = [pl.BlockSpec((1,), lambda b, kc: (b,))]
    if return_r:
        out_shape.append(jax.ShapeDtypeStruct((B, kd_pad, n), jnp.float32))
        out_specs.append(pl.BlockSpec((1, chunk, n), lambda b, kc: (b, kc, 0)))
    outs = pl.pallas_call(
        kernel,
        grid=(B, nkc),
        in_specs=[pl.BlockSpec((1, chunk, n), lambda b, kc: (b, kc, 0))],
        out_specs=out_specs if return_r else out_specs[0],
        out_shape=out_shape if return_r else out_shape[0],
        scratch_shapes=[pltpu.VMEM((n,), jnp.float32),
                        pltpu.VMEM((n,), jnp.float32),
                        pltpu.VMEM((1,), jnp.float32)],
        interpret=interpret,
    )(dd)
    return outs


def _bwd_kernel(dd_ref, rd_ref, e_dd_ref, e1_ref, e2_ref, r1_ref, r2_ref,
                d1_ref, d2_ref, *, n: int, m: int, chunk: int, nkc: int,
                gamma: float):
    """Reverse anti-diagonal sweep computing the E-matrix.

    Diagonal layout: layout[k, i] holds cell (i, k-i).  The children of
    cell (i, j) on diag k sit at layout[k+1, i+1] ((i+1, j)),
    layout[k+1, i] ((i, j+1)) and layout[k+2, i+1] ((i+1, j+1)) — so the
    sweep carries the two PREVIOUSLY processed (later) diagonals of E, R
    and D in VMEM scratch, exactly mirroring the forward's carry but
    walking k downwards (the chunk grid dimension is index-mapped in
    reverse)."""
    kc_rev = pl.program_id(1)
    inv_g = 1.0 / gamma
    # one-hot of row n-1 (1-D iota is not lowerable on TPU)
    seed_row = jnp.concatenate([jnp.zeros((n - 1,), jnp.float32),
                                jnp.ones((1,), jnp.float32)])

    @pl.when(kc_rev == 0)
    def _init():
        e1_ref[...] = jnp.zeros_like(e1_ref)
        e2_ref[...] = jnp.zeros_like(e2_ref)
        r1_ref[...] = jnp.full_like(r1_ref, BIG)
        r2_ref[...] = jnp.full_like(r2_ref, BIG)
        d1_ref[...] = jnp.full_like(d1_ref, BIG)
        d2_ref[...] = jnp.full_like(d2_ref, BIG)

    def shift(x, pad):
        """layout row index i -> i+1 (children live one row down)."""
        return jnp.concatenate([x[1:], jnp.full((1,), pad, x.dtype)])

    def body(s, _):
        r = chunk - 1 - s
        k = (nkc - 1 - kc_rev) * chunk + r
        d_k = dd_ref[0, r].astype(jnp.float32)   # bf16 slab upcast once
        r_k = rd_ref[0, r]
        e1, e2 = e1_ref[...], e2_ref[...]
        r1, r2 = r1_ref[...], r2_ref[...]
        d1, d2 = d1_ref[...], d2_ref[...]

        def term(ev, rv, dv):
            w = jnp.exp((rv - r_k - dv) * inv_g)
            return jnp.where(dv < BIG_CUT, ev * w, 0.0)

        e_k = (term(shift(e1, 0.0), shift(r1, BIG), shift(d1, BIG))  # down
               + term(e1, r1, d1)                                    # right
               + term(shift(e2, 0.0), shift(r2, BIG), shift(d2, BIG)))  # diag
        e_k = jnp.where(d_k < BIG_CUT, e_k, 0.0)
        # seed: dF/dR[n-1,m-1] = 1 (F = R[n-1,m-1])
        e_k = e_k + jnp.where(k == n + m - 2, seed_row, 0.0)
        e2_ref[...] = e1
        e1_ref[...] = e_k
        r2_ref[...] = r1
        r1_ref[...] = r_k
        d2_ref[...] = d1
        d1_ref[...] = d_k
        e_dd_ref[0, r] = e_k
        return 0

    lax.fori_loop(0, chunk, body, 0)


def softdtw_bwd_pallas(
    dd: jax.Array,           # (B, KD_pad, n) diagonal-major costs
    rd: jax.Array,           # (B, KD_pad, n) diagonal-major R (from forward)
    n: int, m: int,
    *,
    gamma: float = 1.0,
    chunk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """E-matrix (dSDTW/dD) in diagonal layout, (B, KD_pad, n) float32.

    ``dd`` may be bfloat16 (matching the forward's reduced-precision
    cost slab); ``rd`` is the forward's float32 R and the E/R/D diagonal
    carries stay float32."""
    B, kd_pad, n_ = dd.shape
    assert n_ == n and kd_pad % chunk == 0 and rd.shape == dd.shape
    nkc = kd_pad // chunk
    kernel = functools.partial(_bwd_kernel, n=n, m=m, chunk=chunk, nkc=nkc,
                               gamma=float(gamma))
    rev = lambda b, kc: (b, nkc - 1 - kc, 0)
    return pl.pallas_call(
        kernel,
        grid=(B, nkc),
        in_specs=[pl.BlockSpec((1, chunk, n), rev),
                  pl.BlockSpec((1, chunk, n), rev)],
        out_specs=pl.BlockSpec((1, chunk, n), rev),
        out_shape=jax.ShapeDtypeStruct((B, kd_pad, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n,), jnp.float32)] * 6,
        interpret=interpret,
    )(dd, rd)
