"""Anti-diagonal wavefront soft-DTW kernel.

The DP recurrence R[i,j] = D[i,j] + softmin(R[i-1,j], R[i,j-1], R[i-1,j-1])
serialises along both axes but is embarrassingly parallel along each
anti-diagonal — an exact match for the VPU's lane-parallel vector ops.
The cost matrix is pre-laid-out in diagonal-major order (n+m-1, n) so each
wavefront step is one contiguous VMEM row read; the two carried diagonals
live in VMEM scratch that persists across the sequential k-chunk grid
dimension (the chunking keeps arbitrarily long series within VMEM).

Grid: (batch, num_k_chunks); scratch: r_prev, r_prev2 (n,), ans (1,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.losses import BIG


def _kernel(dd_ref, out_ref, rp_ref, rp2_ref, ans_ref, *, n: int, m: int,
            chunk: int, nkc: int, gamma: float, hard: bool):
    kc = pl.program_id(1)

    @pl.when(kc == 0)
    def _init():
        rp_ref[...] = jnp.full_like(rp_ref, BIG)
        rp2_ref[...] = jnp.full_like(rp2_ref, BIG)
        ans_ref[...] = jnp.zeros_like(ans_ref)

    def minop(a, b, c):
        if hard:
            return jnp.minimum(jnp.minimum(a, b), c)
        s = jnp.stack([a, b, c], axis=0)
        return -gamma * jax.nn.logsumexp(-s / gamma, axis=0)

    big_head = jnp.full((1,), BIG, dtype=jnp.float32)

    def body(r, _):
        k = kc * chunk + r
        d_k = dd_ref[0, r]
        rp = rp_ref[...]
        rp2 = rp2_ref[...]
        up = rp
        left = jnp.concatenate([big_head, rp[:-1]])
        diag = jnp.concatenate([big_head, rp2[:-1]])
        best = minop(up, left, diag)
        invalid = d_k >= BIG
        r_k = d_k + jnp.where(invalid, 0.0, best)
        r_k = jnp.where(k == 0, d_k, r_k)          # (0,0) has no predecessor
        r_k = jnp.where(invalid, BIG, r_k)
        rp2_ref[...] = rp
        rp_ref[...] = r_k
        ans_ref[0] = jnp.where(k == n + m - 2, r_k[n - 1], ans_ref[0])
        return 0

    lax.fori_loop(0, chunk, body, 0)

    @pl.when(kc == nkc - 1)
    def _finish():
        out_ref[0] = ans_ref[0]


def softdtw_pallas(
    dd: jax.Array,           # (B, KD_pad, n) diagonal-major costs, BIG-padded
    n: int, m: int,
    *,
    gamma: float = 1.0,
    hard: bool = False,
    chunk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Batched accumulated (soft-)DTW from diagonal-layout costs -> (B,)."""
    B, kd_pad, n_ = dd.shape
    assert n_ == n and kd_pad % chunk == 0
    nkc = kd_pad // chunk
    kernel = functools.partial(_kernel, n=n, m=m, chunk=chunk, nkc=nkc,
                               gamma=float(gamma), hard=hard)
    return pl.pallas_call(
        kernel,
        grid=(B, nkc),
        in_specs=[pl.BlockSpec((1, chunk, n), lambda b, kc: (b, kc, 0))],
        out_specs=pl.BlockSpec((1,), lambda b, kc: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n,), jnp.float32),
                        pltpu.VMEM((n,), jnp.float32),
                        pltpu.VMEM((1,), jnp.float32)],
        interpret=interpret,
    )(dd)
