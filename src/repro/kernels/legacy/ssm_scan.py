"""State-resident selective-SSM scan — the paper's in-memory-computing
insight applied to Mamba's recurrence.

The XLA chunked scan materialises the (B, chunk, d_inner, N) decay/input
tensors in HBM at every associative-scan level (~d_inner*N = 128k f32 per
token); this kernel keeps the SSM state h (d_tile, N) resident in VMEM
across the whole sequence and builds da/dbx on the fly in registers — HBM
traffic collapses to exactly the functional inputs/outputs:

    reads  : dt, x (S, d_tile), B, C (S, N), A (d_tile, N)
    writes : y (S, d_tile), final state (d_tile, N)

i.e. ~(2*d+2N) floats/token instead of ~14*d*N — the same
"weights/state stationary, operands flow" structure as the memristive
crossbar loop (DESIGN.md §2).

Grid: (batch, d_inner / d_tile); sequential ``fori_loop`` over S inside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, y_ref, hout_ref, h_scr,
            *, seq_len: int):
    a = a_ref[...]                                    # (dtile, N)
    h_scr[...] = jnp.zeros_like(h_scr)

    def body(t, _):
        dt_t = dt_ref[0, t]                           # (dtile,)
        b_t = b_ref[0, t]                             # (N,)
        c_t = c_ref[0, t]                             # (N,)
        x_t = x_ref[0, t]                             # (dtile,)
        da = jnp.exp(dt_t[:, None] * a)               # (dtile, N)
        dbx = (dt_t * x_t)[:, None] * b_t[None, :]
        h = da * h_scr[...] + dbx
        h_scr[...] = h
        y_ref[0, t] = jnp.sum(h * c_t[None, :], axis=1)
        return 0

    lax.fori_loop(0, seq_len, body, 0)
    hout_ref[0] = h_scr[...]


def ssm_scan(dt: jax.Array, b: jax.Array, c: jax.Array, x: jax.Array,
             a: jax.Array, *, d_tile: int = 512,
             interpret: bool = True):
    """Selective scan: h_t = exp(dt*A)h_{t-1} + dt*B*x; y_t = <h_t, C>.

    dt, x: (BATCH, S, DI) f32; b, c: (BATCH, S, N) f32; a: (DI, N) f32.
    Returns (y (BATCH, S, DI) f32, h_final (BATCH, DI, N) f32).
    """
    bsz, s, di = dt.shape
    n = b.shape[-1]
    d_tile = min(d_tile, di)
    assert di % d_tile == 0
    grid = (bsz, di // d_tile)

    kernel = functools.partial(_kernel, seq_len=s)
    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, d_tile), lambda i, j: (i, 0, j)),   # dt
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),        # B
            pl.BlockSpec((1, s, n), lambda i, j: (i, 0, 0)),        # C
            pl.BlockSpec((1, s, d_tile), lambda i, j: (i, 0, j)),   # x
            pl.BlockSpec((d_tile, n), lambda i, j: (j, 0)),         # A
        ],
        out_specs=[
            pl.BlockSpec((1, s, d_tile), lambda i, j: (i, 0, j)),   # y
            pl.BlockSpec((1, d_tile, n), lambda i, j: (i, j, 0)),   # h_out
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), jnp.float32),
            jax.ShapeDtypeStruct((bsz, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d_tile, n), jnp.float32)],
        interpret=interpret,
    )(dt, b, c, x, a)
    return y, h_final


def ssm_scan_ref(dt, b, c, x, a):
    """Pure-jnp oracle (sequential lax.scan)."""
    def one(dt_g, b_g, c_g, x_g):
        def step(h, inp):
            dt_t, b_t, c_t, x_t = inp
            da = jnp.exp(dt_t[:, None] * a)
            dbx = (dt_t * x_t)[:, None] * b_t[None, :]
            h = da * h + dbx
            return h, jnp.sum(h * c_t[None, :], axis=1)

        h0 = jnp.zeros((dt_g.shape[-1], a.shape[-1]), jnp.float32)
        h, ys = lax.scan(step, h0, (dt_g, b_g, c_g, x_g))
        return ys, h

    return jax.vmap(one)(dt, b, c, x)
