"""Fused causal flash attention — the VMEM-residency fix for the memory
term that dominates every attention cell in §Roofline.

The pure-XLA flash schedule (models/flash.py) re-materialises the
(B,H,qc,kc) score tile and rewrites the (B,H,qc,dv) accumulator in HBM on
every kv step.  Here the accumulator/max/denominator live in VMEM scratch
across the sequential kv grid dimension and scores never leave VMEM —
per-layer HBM traffic collapses to Q/K/V in + O out, the same
state-resident structure as kernels/legacy/ssm_scan.py (and the paper's
crossbar loop).

Grid: (batch, q_heads, nq, nk) with nk innermost (sequential, scratch
carries); GQA handled by indexing the kv head as h // group in the K/V
BlockSpecs.  Causal banding: fully-masked tiles are skipped with
``@pl.when`` (no MXU work, no DMA use of the loaded tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, nk: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal banding: skip tiles strictly above the diagonal
    @pl.when(ki * bk <= qi * bq + bq - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, dv)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _epilogue():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           scale: float | None = None,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = True) -> jax.Array:
    """Causal GQA flash attention.

    q: (B, H, S, d); k, v: (B, Hkv, S, d) with Hkv | H.
    Returns (B, H, S, dv) in q.dtype.
    """
    b, h, s, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]
    group = h // hkv
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0
    nq, nk = s // bq, s // bk
    scale = scale if scale is not None else d ** -0.5

    kernel = functools.partial(_kernel, bq=bq, bk=bk, nk=nk,
                               scale=float(scale))
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=group:
                         (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, dv),
                         lambda bi, hi, qi, ki, g=group:
                         (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v)


def flash_attention_pallas_ref(q, k, v, *, scale: float | None = None):
    """Oracle: dense causal softmax attention."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale if scale is not None else d ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    sgrid = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       kk.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    sgrid = jnp.where(mask[None, None], sgrid, NEG_INF)
    p = jax.nn.softmax(sgrid, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def hbm_traffic_bytes(b, h, hkv, s, d, dv, dtype_bytes=2) -> dict:
    """The kernel's DMA contract (used for the §Perf projection)."""
    q_io = b * h * s * d * dtype_bytes
    kv_io = 2 * b * hkv * s * d * dtype_bytes
    # k/v re-read once per q block row is avoided by the sequential nk
    # dim revisiting the same block; worst case: nq re-reads
    o_io = b * h * s * dv * dtype_bytes
    return {"q": q_io, "kv": kv_io, "out": o_io,
            "total": q_io + kv_io + o_io}
