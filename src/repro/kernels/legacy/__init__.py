"""LEGACY kernels — not part of the neural-ODE twin stack.

``flash_attention`` and ``ssm_scan`` are LM-era state-resident kernels
kept as technique references (online-softmax streaming, chunked
state-space scan).  Nothing in the twin/fleet/analogue pipeline imports
them; their parity tests live in ``tests/test_legacy_kernels.py``.  New
work belongs in the active kernels one package up
(``fused_ode_mlp``, ``fused_analogue``, ``crossbar_vmm``, ``softdtw``).
"""
