"""Public jit'd wrappers around the Pallas kernels.

Each op pairs a TPU-target kernel (validated in interpret mode on CPU)
with its pure-jnp oracle in :mod:`repro.kernels.ref`.  Gradient support:
both hot-path ops are differentiable on the kernel substrate itself —
the fused neural-ODE rollout through a reverse-time checkpoint/replay
Pallas kernel (:mod:`repro.kernels.fused_ode_mlp_bwd`), and soft-DTW
through the closed-form E-matrix reverse DP as a second wavefront
kernel (no autodiff of the reference DP anywhere).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.analogue import AnalogueSpec
from repro.core.losses import BIG, _pairwise_dist
from repro.kernels import ref
from repro.kernels.crossbar_vmm import crossbar_matmul as _crossbar_pallas
from repro.kernels.fused_analogue import (
    fused_analogue_rollout as _fused_analogue)
from repro.kernels.fused_ode_mlp import (DEFAULT_VMEM_BUDGET,
                                         _require_float, drive_window,
                                         fused_node_rollout as _fused_pallas,
                                         precision_dtypes,
                                         resolve_precision)
from repro.kernels.fused_ode_mlp_bwd import fused_node_rollout_vjp
from repro.kernels.softdtw import (softdtw_bwd_pallas as _softdtw_bwd_pallas,
                                   softdtw_pallas as _softdtw_pallas)


# ---------------------------------------------------------------------------
# Fused neural-ODE rollout
# ---------------------------------------------------------------------------

def fused_node_rollout(params: Sequence[dict], y0: jax.Array,
                       u_half: jax.Array, dt: float,
                       *, batch_tile: int = 64,
                       time_chunk: int | None = None,
                       interpret: bool | None = None,
                       vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
                       gradient: str = "fused_vjp",
                       precision: str | None = None,
                       ) -> jax.Array:
    """Solve the twin's neural ODE with the weights-stationary kernel.

    The whole RK4 trajectory runs inside one ``pallas_call`` with the MLP
    weights pinned in VMEM (grid layout and VMEM model:
    ``docs/kernels.md``).  Requires a uniform time grid (``dt`` and the
    step count are kernel compile-time constants).

    Args:
      params: the core MLP param list ``[{'w','b'}, ...]``.
      y0: (B, D) initial conditions — one row per fleet member.
      u_half: drive sampled at RK4 half-steps (:func:`half_step_drive`) —
        (2T+1, Du) shared across the batch, (B, 2T+1, Du) per-twin
        (fleet serving), or (2T+1, 0) when autonomous.
      dt: RK4 step size (uniform).
      batch_tile: fleet members per grid cell; B must divide by it
        (``FusedPallasBackend`` pads the fleet up to a tile multiple).
      time_chunk: RK4 steps resident in VMEM per grid cell.  ``None``
        auto-picks the largest chunk whose working set fits
        ``vmem_budget_bytes`` (see ``fused_ode_mlp.plan_time_chunk``), so
        the horizon T is unbounded; an explicit value is validated
        against the same budget.
      interpret: ``None`` auto-detects the accelerator (compiled on TPU,
        interpreter on CPU/GPU hosts); pass True/False to force.
      vmem_budget_bytes: the planner's per-cell VMEM budget.  If the
        weights plus a single RK4 step cannot fit, a ``ValueError`` is
        raised at planning time ("shrink batch_tile or the MLP").
      gradient: ``"fused_vjp"`` (default) makes the rollout
        differentiable in ``params`` and ``y0`` through the reverse-time
        checkpoint/replay kernel (:mod:`repro.kernels.fused_ode_mlp_bwd`)
        — the drive is data and gets a zero cotangent; ``"stopgrad"``
        detaches the solve (inference-only serving).
      precision: mixed-precision policy — ``"f32"`` | ``"bf16"`` |
        ``"bf16_f32acc"``, or ``None`` for the platform default
        (bf16_f32acc on TPU, f32 elsewhere).  The bf16 policies store
        weights, drive and trajectory slabs at half width while matmuls
        accumulate at f32 (``bf16_f32acc``) and gradient accumulators
        always stay f32; the error model is documented in
        ``docs/kernels.md``.  Non-floating inputs raise a ``ValueError``
        naming the offending input.

    Returns:
      The (T+1, B, D) trajectory (y0 prepended), at the policy's
      storage dtype.
    """
    precision = resolve_precision(precision)
    named = [("y0", y0), ("u_half", u_half)]
    named += [(f"params[{i}]['w']", p["w"]) for i, p in enumerate(params)]
    named += [(f"params[{i}]['b']", p["b"]) for i, p in enumerate(params)]
    for name, x in named:      # fail HERE with the dict-level input name,
        _require_float(name, x, precision)  # not inside the kernel wrapper
    # hand the kernels f32 master copies; the precision policy decides
    # (inside the kernel wrappers) what is rounded to storage width, so
    # cotangents come back at f32 regardless of the substrate dtype
    weights = [p["w"].astype(jnp.float32) for p in params]
    biases = [p["b"].astype(jnp.float32) for p in params]
    y0 = y0.astype(jnp.float32)
    u_half = u_half.astype(jnp.float32)
    if gradient == "fused_vjp":
        return fused_node_rollout_vjp(y0, u_half, weights, biases,
                                      float(dt), batch_tile, time_chunk,
                                      interpret, vmem_budget_bytes,
                                      precision)
    if gradient == "stopgrad":
        out = _fused_pallas(lax.stop_gradient(y0),
                            lax.stop_gradient(u_half),
                            [lax.stop_gradient(w) for w in weights],
                            [lax.stop_gradient(b) for b in biases],
                            float(dt),
                            batch_tile=batch_tile, time_chunk=time_chunk,
                            interpret=interpret,
                            vmem_budget_bytes=vmem_budget_bytes,
                            precision=precision)
        return lax.stop_gradient(out)
    raise ValueError(
        f"unknown gradient mode {gradient!r}; have 'fused_vjp', 'stopgrad'")


def fused_node_rollout_ref(params, y0, u_half, dt):
    weights = [p["w"].astype(jnp.float32) for p in params]
    biases = [p["b"].astype(jnp.float32) for p in params]
    return ref.fused_node_rollout_ref(y0.astype(jnp.float32),
                                      u_half.astype(jnp.float32),
                                      weights, biases, float(dt))


def half_step_drive(drive, ts: jax.Array) -> jax.Array:
    """Sample a continuous drive u(t) at the RK4 half-step grid (2T+1, 1)."""
    t0, t1 = ts[0], ts[-1]
    T = ts.shape[0] - 1
    th = jnp.linspace(t0, t1, 2 * T + 1)
    u = jax.vmap(drive)(th)
    return u[:, None] if u.ndim == 1 else u


# ---------------------------------------------------------------------------
# Canonical global time grids (the streaming-resume determinism contract)
# ---------------------------------------------------------------------------
#
# A rollout resumed at global step k is only bit-identical to the
# uninterrupted one if every time value it sees is BYTE-identical to the
# value the uninterrupted rollout saw.  Re-deriving a sub-window with
# ``linspace(t_k, t_T, ...)`` perturbs interior points by ~1 ulp (f32
# endpoints, divided differently), which is enough to move every drive
# sample and break parity.  These helpers are the single source of truth:
# each grid point is an exact float64 function of (t0, dt, global index),
# rounded to float32 once — so any window of any split reproduces the
# same bytes.  ``start_step`` may be an int or an (N,) array of per-twin
# offsets (rows of the result are then per-twin windows).

def window_times(t0: float, dt: float, num_steps: int,
                 start_step=0) -> jax.Array:
    """The (num_steps+1,) f32 time grid t_i = t0 + dt*(start_step + i),
    computed in float64; (N, num_steps+1) for an (N,) ``start_step``."""
    start = np.asarray(start_step, dtype=np.int64)
    idx = start[..., None] + np.arange(num_steps + 1, dtype=np.int64)
    t = np.float64(t0) + np.float64(dt) * idx
    return jnp.asarray(t.astype(np.float32))


def half_step_times(t0: float, dt: float, num_steps: int,
                    start_step=0) -> jax.Array:
    """The (2*num_steps+1,) f32 RK4 half-step grid
    t_j = t0 + (dt/2)*(2*start_step + j), computed in float64;
    (N, 2*num_steps+1) for an (N,) ``start_step``."""
    start = np.asarray(start_step, dtype=np.int64)
    idx = 2 * start[..., None] + np.arange(2 * num_steps + 1, dtype=np.int64)
    t = np.float64(t0) + 0.5 * np.float64(dt) * idx
    return jnp.asarray(t.astype(np.float32))


def sample_drive_window(drive, t0: float, dt: float, num_steps: int,
                        start_step=0) -> jax.Array:
    """Sample u(t) on the canonical half-step window: (2T'+1, Du) for a
    scalar ``start_step``, (N, 2T'+1, Du) per-twin for an (N,) one."""
    th = half_step_times(t0, dt, num_steps, start_step)
    u = jax.vmap(drive)(th) if th.ndim == 1 else jax.vmap(jax.vmap(drive))(th)
    return u[..., None] if u.ndim == th.ndim else u


# ---------------------------------------------------------------------------
# Crossbar VMM
# ---------------------------------------------------------------------------

def _require_2d_float(op: str, name: str, x: jax.Array) -> None:
    x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"{op}: {name} must be 2-D, got shape {x.shape}")
    if not jnp.issubdtype(x.dtype, jnp.floating):
        raise ValueError(
            f"{op}: {name} has non-floating dtype {x.dtype}; cast it to "
            f"a floating dtype first")


def _fault_kernel_kwargs(fault: dict | None, spec: AnalogueSpec,
                         layer: int) -> dict:
    """Translate a ``FaultModel.kernel_args()`` dict into the static
    scalars of :func:`repro.kernels.crossbar_vmm.crossbar_matmul`: the
    per-(layer, pair) stuck salts of the core convention, plus the drift
    snapshot factor — a single VMM has a fixed read count, so the
    power-law decay collapses to one static multiplier here (only the
    fused rollout kernel advances it live)."""
    if not fault:
        return {}
    drift = 1.0
    if fault.get("drift_nu", 0.0) > 0.0:
        drift = (1.0 + fault.get("drift_n0", 0)
                 / fault["drift_tau"]) ** (-fault["drift_nu"])
    base = fault.get("salt_base", 0)
    return {
        "stuck_rate": fault.get("stuck_rate", 0.0),
        "stuck_on_frac": fault.get("stuck_on_frac", 0.5),
        "fault_seed": fault.get("fault_seed", 0),
        "fault_salts": (base + 2 * layer, base + 2 * layer + 1),
        "drift": drift,
        "g_max": spec.g_max,
    }


def crossbar_vmm(prog: dict, x: jax.Array, spec: AnalogueSpec,
                 *, interpret: bool | None = None,
                 read_noise: float | None = None,
                 noise_seed: int = 0,
                 fault: dict | None = None,
                 layer: int = 0) -> jax.Array:
    """Analogue crossbar read through the fused kernel (float mode).

    ``interpret=None`` auto-detects (compiled on TPU, interpreter
    elsewhere; ``REPRO_FORCE_INTERPRET`` pins the mode).  ``read_noise``
    overrides ``spec.read_noise`` (None = take the spec's value) with
    the deterministic counter-derived stream keyed on ``noise_seed``.
    ``fault`` (a ``FaultModel.kernel_args()`` dict) injects stuck cells
    and a drift snapshot in-kernel at the device array addressed by
    ``layer`` — bitwise the program-time masks of
    :mod:`repro.core.faults`, at zero extra HBM traffic.
    """
    _require_2d_float("crossbar_vmm", "x", x)
    _require_2d_float("crossbar_vmm", "prog['gp']", prog["gp"])
    _require_2d_float("crossbar_vmm", "prog['gm']", prog["gm"])
    sigma = spec.read_noise if read_noise is None else read_noise
    # scale is traced (programming may run under jit), so the rescale —
    # and therefore the clamp, which acts in post-scale units — happens
    # outside the kernel here; the fused rollout kernel, whose scales
    # ride in as an operand, clamps in-kernel.
    y = _crossbar_pallas(
        x, prog["gp"], prog["gm"],
        inv_scale=1.0, g_step=None, clamp=None,
        read_noise=float(sigma), noise_seed=noise_seed,
        g_min=spec.g_min, interpret=interpret,
        **_fault_kernel_kwargs(fault, spec, layer)) / prog["scale"]
    if spec.v_clamp is not None:
        y = jnp.clip(y, -spec.v_clamp, spec.v_clamp)
    return y


def crossbar_vmm_quantized(x: jax.Array, gp_idx: jax.Array,
                           gm_idx: jax.Array, spec: AnalogueSpec,
                           scale: jax.Array | float,
                           *, interpret: bool | None = None,
                           read_noise: float | None = None,
                           noise_seed: int = 0,
                           fault: dict | None = None,
                           layer: int = 0) -> jax.Array:
    """Quantised-storage read: uint8 level indices, dequant fused in-kernel.

    Same interpret auto-detect, noise and fault contract as
    ``crossbar_vmm``; noisy or faulty reads reconstruct the absolute
    conductances from ``spec.g_min`` in-kernel (the differential offsets
    only cancel clean, and stuck cells pin to absolute G_on/G_off).
    """
    _require_2d_float("crossbar_vmm_quantized", "x", x)
    for name, idx in (("gp_idx", gp_idx), ("gm_idx", gm_idx)):
        idx = jnp.asarray(idx)
        if idx.ndim != 2 or idx.dtype != jnp.uint8:
            raise ValueError(
                f"crossbar_vmm_quantized: {name} must be 2-D uint8 level "
                f"indices, got shape {idx.shape} dtype {idx.dtype}")
    sigma = spec.read_noise if read_noise is None else read_noise
    g_step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    y = _crossbar_pallas(x, gp_idx, gm_idx, inv_scale=1.0,
                         g_step=float(g_step), clamp=None,
                         read_noise=float(sigma), noise_seed=noise_seed,
                         g_min=spec.g_min, interpret=interpret,
                         **_fault_kernel_kwargs(fault, spec, layer)) / scale
    if spec.v_clamp is not None:
        y = jnp.clip(y, -spec.v_clamp, spec.v_clamp)
    return y


def fused_analogue_rollout(staged: dict, y0: jax.Array, u_half: jax.Array,
                           dt: float, *, batch_tile: int = 64,
                           time_chunk: int | None = None,
                           interpret: bool | None = None,
                           vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
                           read_noise: float = 0.0,
                           noise_seed: int = 0,
                           step_offset: int = 0) -> jax.Array:
    """Whole-trajectory analogue RK4 solve on the fused crossbar kernel.

    ``staged`` is the deployment dict built by
    ``FusedAnalogueBackend.program`` (or assembled by hand):

      gps, gms — per-layer (K_l+1, N_l) conductance pairs, float32 or
                 uint8 level indices (bias folded as the last row);
      scales   — (L,) per-tensor programming scales;
      g_step   — dequant step for uint8 storage (None = float);
      g_min    — conductance floor (needed for noisy quantised reads);
      g_max    — conductance ceiling (needed for stuck-cell injection);
      v_clamp  — optional peripheral output clamp;
      fault    — optional ``FaultModel.kernel_args()`` dict: stuck cells
                 and live read-disturb drift injected in-kernel (see
                 :mod:`repro.core.faults`).

    The solve is inference-only (the analogue substrate does not
    backpropagate — train digitally, deploy analogue): all inputs are
    detached and the trajectory returns with zero cotangent.  See
    :mod:`repro.kernels.fused_analogue` for the kernel itself and the
    deterministic read-noise stream; ``step_offset`` (the global step
    index of ``y0``) makes a resumed noisy/drifting rollout replay the
    uninterrupted rollout's noise salts and drift exponents.
    """
    _require_2d_float("fused_analogue_rollout", "y0", y0)
    if not jnp.issubdtype(jnp.asarray(u_half).dtype, jnp.floating):
        raise ValueError(
            f"fused_analogue_rollout: u_half has non-floating dtype "
            f"{jnp.asarray(u_half).dtype}; cast it to a floating dtype")
    out = _fused_analogue(
        [lax.stop_gradient(g) for g in staged["gps"]],
        [lax.stop_gradient(g) for g in staged["gms"]],
        lax.stop_gradient(jnp.asarray(staged["scales"])),
        lax.stop_gradient(y0), lax.stop_gradient(u_half), float(dt),
        g_step=staged.get("g_step"), g_min=staged.get("g_min", 0.0),
        g_max=staged.get("g_max", 0.0), fault=staged.get("fault"),
        v_clamp=staged.get("v_clamp"), read_noise=float(read_noise),
        noise_seed=int(noise_seed), step_offset=int(step_offset),
        batch_tile=batch_tile,
        time_chunk=time_chunk, interpret=interpret,
        vmem_budget_bytes=vmem_budget_bytes)
    return lax.stop_gradient(out)


def quantize_to_levels(w: jax.Array, spec: AnalogueSpec):
    """Map weights to (gp_idx, gm_idx, scale) uint8 level tensors."""
    from repro.core.analogue import conductance_pair
    gp, gm, scale = conductance_pair(w, spec)
    step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    to_idx = lambda g: jnp.clip(jnp.round((g - spec.g_min) / step),
                                0, spec.levels - 1).astype(jnp.uint8)
    return to_idx(gp), to_idx(gm), scale


# ---------------------------------------------------------------------------
# soft-DTW (kernel forward, kernelised E-matrix backward)
# ---------------------------------------------------------------------------

def _diag_layout_batch(D: jax.Array, chunk: int) -> jax.Array:
    dd = jax.vmap(ref.diag_layout)(D)
    kd = dd.shape[1]
    pad = (-kd) % chunk
    if pad:
        dd = jnp.pad(dd, ((0, 0), (0, pad), (0, 0)), constant_values=BIG)
    return dd


def _undiag_batch(e_dd: jax.Array, n: int, m: int) -> jax.Array:
    """Inverse of ``ref.diag_layout``: (B, KD_pad, n) -> (B, n, m)."""
    rows = jnp.arange(n)[:, None]
    cols = jnp.arange(m)[None, :]
    return e_dd[:, rows + cols, rows]


def _sdtw_chunk(n: int, m: int) -> int:
    return min(256, n + m - 1)


def _sdtw_cost_slab(x, y, chunk, precision):
    """Diagonal-layout cost slab at the policy's storage dtype (bf16
    halves the only O(n·m) operand; carries/outputs stay f32)."""
    D = jax.vmap(_pairwise_dist)(x, y)
    store = precision_dtypes(resolve_precision(precision))[0]
    return _diag_layout_batch(D, chunk).astype(store)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def soft_dtw(x: jax.Array, y: jax.Array, gamma: float = 1.0,
             interpret: bool = True,
             precision: str | None = None) -> jax.Array:
    """Batched soft-DTW((B,n,d),(B,m,d)) -> (B,) via the wavefront kernel.

    ``precision``: ``"f32"`` | ``"bf16"`` | ``"bf16_f32acc"`` (``None``
    = platform default).  Under the bf16 policies the cost matrix
    streams through the kernel at bfloat16 while the R/E diagonal
    carries and the answer stay float32 (see ``docs/kernels.md``).
    """
    n, m = x.shape[1], y.shape[1]
    chunk = _sdtw_chunk(n, m)
    dd = _sdtw_cost_slab(x, y, chunk, precision)
    return _softdtw_pallas(dd, n, m, gamma=gamma, hard=False, chunk=chunk,
                           interpret=interpret)


def _sdtw_fwd(x, y, gamma, interpret, precision):
    n, m = x.shape[1], y.shape[1]
    chunk = _sdtw_chunk(n, m)
    dd = _sdtw_cost_slab(x, y, chunk, precision)
    ans, rd = _softdtw_pallas(dd, n, m, gamma=gamma, hard=False, chunk=chunk,
                              interpret=interpret, return_r=True)
    # residuals: only R must come from the forward kernel; the cost slab
    # is cheaply re-derived from (x, y) in the backward
    return ans, (x, y, rd)


def _sdtw_bwd(gamma, interpret, precision, res, g):
    # Closed-form E-matrix reverse DP as a second wavefront kernel
    # (kernels/softdtw.py) — dSDTW/dD = E, then an elementwise pullback
    # through the |x_i - y_j| cost.  The old autodiff-of-the-reference-DP
    # path (O(n·m) sequential tape) is gone.
    x, y, rd = res
    n, m = x.shape[1], y.shape[1]
    chunk = _sdtw_chunk(n, m)
    store = precision_dtypes(resolve_precision(precision))[0]
    D, dist_vjp = jax.vjp(lambda a, b: jax.vmap(_pairwise_dist)(a, b), x, y)
    dd = _diag_layout_batch(D, chunk).astype(store)
    e_dd = _softdtw_bwd_pallas(dd, rd, n, m, gamma=gamma, chunk=chunk,
                               interpret=interpret)
    dD = g[:, None, None] * _undiag_batch(e_dd, n, m)
    gx, gy = dist_vjp(dD.astype(D.dtype))
    return gx, gy


soft_dtw.defvjp(_sdtw_fwd, _sdtw_bwd)


def dtw_distance(x: jax.Array, y: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """Batched hard-DTW metric via the same wavefront kernel."""
    D = jax.vmap(_pairwise_dist)(x, y)
    n, m = D.shape[1], D.shape[2]
    chunk = min(256, n + m - 1)
    dd = _diag_layout_batch(D, chunk)
    return _softdtw_pallas(dd, n, m, gamma=1.0, hard=True, chunk=chunk,
                           interpret=interpret)
