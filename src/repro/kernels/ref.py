"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.losses import BIG, _dtw_scan, _hardmin, _softmin


# ---------------------------------------------------------------------------
# fused ODE-MLP trajectory solve
# ---------------------------------------------------------------------------

def mlp_fwd(weights: list[jax.Array], biases: list[jax.Array],
            x: jax.Array) -> jax.Array:
    for i, (w, b) in enumerate(zip(weights, biases)):
        x = x @ w + b
        if i < len(weights) - 1:
            x = jnp.maximum(x, 0.0)
    return x


def fused_node_rollout_ref(y0: jax.Array, u_half: jax.Array,
                           weights: list[jax.Array], biases: list[jax.Array],
                           dt: float) -> jax.Array:
    """RK4 rollout of dy/dt = MLP([u(t), y]) (drive optional).

    y0: (B, D); u_half: drive sampled at half-steps, (2T+1, Du) shared or
    (B, 2T+1, Du) per-sample (Du may be 0); returns (T+1, B, D).
    """
    B = y0.shape[0]
    per_sample = u_half.ndim == 3
    if per_sample:
        u_half = jnp.transpose(u_half, (1, 0, 2))   # time-major (2T+1, B, Du)
    T = (u_half.shape[0] - 1) // 2
    du = u_half.shape[-1]

    def f(u, y):
        if du > 0:
            if not per_sample:
                u = jnp.broadcast_to(u[None, :], (B, du))
            inp = jnp.concatenate([u, y], axis=-1)
        else:
            inp = y
        return mlp_fwd(weights, biases, inp)

    def step(y, t):
        u0 = u_half[2 * t]
        um = u_half[2 * t + 1]
        u1 = u_half[2 * t + 2]
        k1 = f(u0, y)
        k2 = f(um, y + dt / 2 * k1)
        k3 = f(um, y + dt / 2 * k2)
        k4 = f(u1, y + dt * k3)
        y = y + dt / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
        return y, y

    _, ys = lax.scan(step, y0, jnp.arange(T))
    return jnp.concatenate([y0[None], ys], axis=0)


# ---------------------------------------------------------------------------
# crossbar differential-pair VMM
# ---------------------------------------------------------------------------

def crossbar_matmul_ref(x: jax.Array, gp: jax.Array, gm: jax.Array,
                        inv_scale: float, clamp: float | None) -> jax.Array:
    """y = x @ (gp - gm) / scale, clamped (float-programmed arrays)."""
    y = (x.astype(jnp.float32) @
         (gp.astype(jnp.float32) - gm.astype(jnp.float32))) * inv_scale
    if clamp is not None:
        y = jnp.clip(y, -clamp, clamp)
    return y


def crossbar_matmul_q_ref(x: jax.Array, gp_idx: jax.Array, gm_idx: jax.Array,
                          g_step: float, inv_scale: float,
                          clamp: float | None) -> jax.Array:
    """Quantised-storage variant: uint8 level indices dequantised on the fly.

    gp - gm = (idx_p - idx_m) * g_step  (G_min offsets cancel in the pair).
    """
    g = (gp_idx.astype(jnp.float32) - gm_idx.astype(jnp.float32)) * g_step
    y = (x.astype(jnp.float32) @ g) * inv_scale
    if clamp is not None:
        y = jnp.clip(y, -clamp, clamp)
    return y


# ---------------------------------------------------------------------------
# soft-DTW wavefront DP
# ---------------------------------------------------------------------------

def diag_layout(D: jax.Array) -> jax.Array:
    """(n, m) cost matrix -> (n+m-1, n) anti-diagonal layout, BIG-padded."""
    n, m = D.shape
    rows = jnp.arange(n)
    ks = jnp.arange(n + m - 1)
    j = ks[:, None] - rows[None, :]
    valid = (j >= 0) & (j < m)
    return jnp.where(valid, D[rows[None, :], jnp.clip(j, 0, m - 1)], BIG)


def softdtw_ref(D: jax.Array, gamma: float, hard: bool = False) -> jax.Array:
    """Accumulated (soft-)DTW cost of a (n, m) distance matrix."""
    return _dtw_scan(D, gamma, _hardmin if hard else _softmin)


def softdtw_batch_ref(D: jax.Array, gamma: float,
                      hard: bool = False) -> jax.Array:
    return jax.vmap(lambda d: softdtw_ref(d, gamma, hard))(D)


def softdtw_grad_ref(D, gamma: float):
    """Closed-form E-matrix (dSDTW/dD) by the reverse DP of Cuturi &
    Blondel 2017, Alg. 2 — the numpy oracle for ``softdtw_bwd_pallas``.

    Pads R and D with +inf borders so every child weight
    exp((R_child - R - D_child) / gamma) vanishes outside the matrix.
    """
    import numpy as np
    D = np.asarray(D, dtype=np.float64)
    n, m = D.shape
    # forward DP in float64
    R = np.full((n, m), np.inf)
    for i in range(n):
        for j in range(m):
            if i == 0 and j == 0:
                R[i, j] = D[i, j]
                continue
            preds = []
            if i > 0:
                preds.append(R[i - 1, j])
            if j > 0:
                preds.append(R[i, j - 1])
            if i > 0 and j > 0:
                preds.append(R[i - 1, j - 1])
            p = np.asarray(preds)
            soft = -gamma * (np.log(np.sum(np.exp(-(p - p.min()) / gamma)))
                             - p.min() / gamma)
            R[i, j] = D[i, j] + soft
    E = np.zeros((n, m))
    E[n - 1, m - 1] = 1.0
    Rp = np.full((n + 1, m + 1), np.inf)
    Rp[:n, :m] = R
    Dp = np.full((n + 1, m + 1), np.inf)
    Dp[:n, :m] = D
    Ep = np.zeros((n + 1, m + 1))
    Ep[:n, :m] = E
    for k in range(n + m - 3, -1, -1):          # reverse anti-diagonals
        for i in range(max(0, k - m + 1), min(n, k + 1)):
            j = k - i
            if i == n - 1 and j == m - 1:
                continue
            acc = 0.0
            for (ci, cj) in ((i + 1, j), (i, j + 1), (i + 1, j + 1)):
                w = np.exp((Rp[ci, cj] - R[i, j] - Dp[ci, cj]) / gamma) \
                    if np.isfinite(Dp[ci, cj]) else 0.0
                acc += Ep[ci, cj] * w
            Ep[i, j] = acc
    return Ep[:n, :m]
