"""Weights-stationary fused *analogue* neural-ODE solve.

The jnp crossbar simulator (:mod:`repro.core.analogue`) pays a full XLA
dispatch per RK4 stage — 4 stages x 3 layers x 2 differential dots per
step — which makes the paper's centrepiece substrate the slowest backend
in the repo.  This kernel closes that gap by running the ENTIRE analogue
trajectory inside one ``pallas_call`` with the crossbar semantics traced
in-kernel, reusing the weights-stationary, time-chunked architecture of
:mod:`repro.kernels.fused_ode_mlp` (same grid, same carry scratch, same
chunked drive slabs):

* conductance residency — the per-layer differential pairs (G+, G-) are
  the kernel's stationary operands, float32 conductances or uint8 6-bit
  level indices with dequant fused into the MXU feed;
* differential-pair read — each layer evaluates
  ``(x_aug @ G+ - x_aug @ G-) / scale`` with the bias folded as the
  constant-1 row (the crossbar idiom), per-tensor ``scale`` arriving as
  a traced (L,) operand (scales are data: programming runs under jit);
* peripheral clamp — optional output voltage clamp per layer
  (``v_clamp``), applied after rescaling exactly like
  ``analogue_matmul``;
* deterministic read noise — ``read_noise > 0`` perturbs every
  conductance per evaluation from the counter-derived stream of
  :mod:`repro.kernels.noise`, salted by (global step, RK4 stage, layer,
  pair): the noisy rollout is bitwise-replayable from ``noise_seed``
  alone, with no RNG state carried across chunks.

Noise-free fast path: the pair is combined ONCE per grid cell into
effective weights ``W_l = (G+ - G-)[:K] / scale_l`` (uint8 indices
dequantised through ``g_step``), so the steady-state inner loop runs a
single dot per layer — the same arithmetic as the digital fused kernel,
matching the jnp simulator to float32 rounding.  With read noise the
pair must stay separate (the perturbation does not cancel) and each
evaluation re-noises the stationary conductances in VMEM.

The result is inference-only by construction — the analogue substrate
is not differentiable (the paper trains digitally, then deploys) — and
always float32: conductances are physical quantities, not policy-typed
tensors.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_ode_mlp import (DEFAULT_VMEM_BUDGET,
                                         _chunk_drive, _default_interpret,
                                         plan_time_chunk)
from repro.kernels.noise import counter_normal, stuck_cell_masks

#: Static fault parameters the kernel understands (subset optional);
#: produced by ``FaultModel.kernel_args()`` in :mod:`repro.core.faults`.
_FAULT_DEFAULTS = {
    "stuck_rate": 0.0, "stuck_on_frac": 0.5, "fault_seed": 0,
    "salt_base": 0, "drift_nu": 0.0, "drift_tau": 1.0, "drift_n0": 0,
}


def _make_kernel(num_layers: int, C: int, dt: float, drive_dim: int,
                 bt: int, per_tile_drive: bool, g_step: float | None,
                 g_min: float, g_max: float, v_clamp: float | None,
                 read_noise: float, noise_seed: int, stuck_rate: float,
                 stuck_on_frac: float, fault_seed: int, salt_base: int,
                 drift_nu: float, drift_tau: float, drift_n0: int,
                 step_offset: int = 0):
    stuck = stuck_rate > 0.0

    def apply_stuck(g, li, pair):
        # Stationary arrays are whole (unblocked), so local coordinates
        # ARE the global cell ids — the mask matches program-time baking
        # (core/faults.py) bitwise, derived from the counter stream with
        # zero extra HBM traffic.
        is_stuck, stuck_on = stuck_cell_masks(
            fault_seed, salt_base + 2 * li + pair, g.shape, stuck_rate,
            stuck_on_frac)
        val = jnp.where(stuck_on, jnp.float32(g_max), jnp.float32(g_min))
        return jnp.where(is_stuck, val, g)

    def kernel(*refs):
        y0_ref = refs[0]
        u_ref = refs[1]
        gp_refs = refs[2:2 + num_layers]
        gm_refs = refs[2 + num_layers:2 + 2 * num_layers]
        scale_ref = refs[2 + 2 * num_layers]
        out_ref = refs[3 + 2 * num_layers]
        carry_ref = refs[4 + 2 * num_layers]

        @pl.when(pl.program_id(1) == 0)
        def _():
            carry_ref[...] = y0_ref[...]

        inv_scales = [1.0 / scale_ref[li] for li in range(num_layers)]
        if read_noise > 0.0:
            # Stationary absolute conductances; re-noised per evaluation.
            if g_step is not None:
                gps = [g_min + r[...].astype(jnp.float32) * g_step
                       for r in gp_refs]
                gms = [g_min + r[...].astype(jnp.float32) * g_step
                       for r in gm_refs]
            else:
                gps = [r[...].astype(jnp.float32) for r in gp_refs]
                gms = [r[...].astype(jnp.float32) for r in gm_refs]
            if stuck:
                gps = [apply_stuck(g, li, 0) for li, g in enumerate(gps)]
                gms = [apply_stuck(g, li, 1) for li, g in enumerate(gms)]
        else:
            # Noise-free fast path: combine the pair once per cell.  The
            # G_min offsets cancel exactly (quantised) / by construction
            # (float), so the inner loop is a single dot per layer.
            # Stuck cells pin to ABSOLUTE conductances, so with faults
            # active the quantised pair must be reconstructed first.
            ws, bs = [], []
            for li in range(num_layers):
                gp_a = gp_refs[li][...].astype(jnp.float32)
                gm_a = gm_refs[li][...].astype(jnp.float32)
                if stuck:
                    if g_step is not None:
                        gp_a = g_min + gp_a * g_step
                        gm_a = g_min + gm_a * g_step
                    g = apply_stuck(gp_a, li, 0) - apply_stuck(gm_a, li, 1)
                else:
                    g = gp_a - gm_a
                    if g_step is not None:
                        g = g * g_step
                g = g * inv_scales[li]
                ws.append(g[:-1])        # (K, N) weight rows
                bs.append(g[-1])         # the constant-1 bias row
        salts_per_step = 4 * num_layers * 2     # stages x layers x pair
        # Hoisted out of the fori_loop body: program_id has no lowering
        # inside a captured loop jaxpr on the interpreter path.
        # ``step_offset`` shifts the GLOBAL step index: a rollout resumed
        # at step k with step_offset=k replays the same noise salts and
        # drift exponents the uninterrupted rollout would have used.
        chunk_step0 = step_offset + pl.program_id(1) * C

        def layer_out(x, li, salt, dfac):
            """One crossbar read: differential dot, rescale, clamp."""
            if read_noise > 0.0:
                shape = gps[li].shape
                ep = counter_normal(noise_seed, salt, shape)
                em = counter_normal(noise_seed, salt + 1, shape)
                g = (gps[li] * (1.0 + read_noise * ep)
                     - gms[li] * (1.0 + read_noise * em))
                y = (jnp.dot(x, g[:-1], preferred_element_type=jnp.float32)
                     + g[-1][None, :]) * inv_scales[li]
            else:
                y = jnp.dot(x, ws[li],
                            preferred_element_type=jnp.float32) + bs[li]
            if dfac is not None:
                # drift scales every conductance of the pair, hence the
                # whole differential read (bias row included)
                y = y * dfac
            if v_clamp is not None:
                y = jnp.clip(y, -v_clamp, v_clamp)
            return y

        def f(u_row, y, eval_salt, dfac):
            if drive_dim > 0:
                u = (u_row if per_tile_drive
                     else jnp.broadcast_to(u_row, (bt, drive_dim)))
                x = jnp.concatenate([u.astype(jnp.float32), y], axis=-1)
            else:
                x = y
            for li in range(num_layers):
                x = layer_out(x, li, eval_salt + 2 * li, dfac)
                if li < num_layers - 1:
                    x = jnp.maximum(x, 0.0)
            return x

        def body(t, y):
            # Global step index -> unique salt block per (step, stage).
            step_salt = ((chunk_step0 + t) * salts_per_step
                         if read_noise > 0.0 else 0)
            if drift_nu > 0.0:
                # Live read-disturb relaxation: every RK4 step costs 4
                # reads of each array, so the decay exponent advances
                # with the GLOBAL step count — chunked rollouts drift
                # exactly like unchunked ones.  exp/log1p instead of a
                # float pow for a clean Mosaic lowering.
                n = jnp.asarray(drift_n0 + 4 * (chunk_step0 + t),
                                jnp.float32)
                dfac = jnp.exp(jnp.float32(-drift_nu)
                               * jnp.log1p(n / jnp.float32(drift_tau)))
            else:
                dfac = None
            k1 = f(u_ref[0, 2 * t], y, step_salt, dfac)
            k2 = f(u_ref[0, 2 * t + 1], y + (dt / 2) * k1,
                   step_salt + 2 * num_layers, dfac)
            k3 = f(u_ref[0, 2 * t + 1], y + (dt / 2) * k2,
                   step_salt + 4 * num_layers, dfac)
            k4 = f(u_ref[0, 2 * t + 2], y + dt * k3,
                   step_salt + 6 * num_layers, dfac)
            y = y + (dt / 6) * (k1 + 2 * k2 + 2 * k3 + k4)
            out_ref[t] = y
            return y

        carry_ref[...] = lax.fori_loop(0, C, body, carry_ref[...])

    return kernel


def fused_analogue_rollout(
    gps: Sequence[jax.Array],     # per layer (K_l + 1, N_l): conductances
    gms: Sequence[jax.Array],     # (f32) or uint8 level indices; bias row last
    scales: jax.Array,            # (L,) per-tensor programming scales
    y0: jax.Array,                # (B, D) float32
    u_half: jax.Array,            # (2T+1, Du) shared or (B, 2T+1, Du)
    dt: float,
    *,
    g_step: float | None = None,  # set => uint8 quantised storage
    g_min: float = 0.0,           # conductance floor (noisy quantised reads)
    g_max: float = 0.0,           # conductance ceiling (stuck overrides)
    v_clamp: float | None = None,
    read_noise: float = 0.0,
    noise_seed: int = 0,
    step_offset: int = 0,         # global step index of y0 (resume replay)
    fault: dict | None = None,    # FaultModel.kernel_args(); None = healthy
    batch_tile: int = 64,
    time_chunk: int | None = None,
    interpret: bool | None = None,
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET,
) -> jax.Array:
    """Full-trajectory analogue RK4 solve; returns (T+1, B, D) float32.

    Same contract as ``fused_node_rollout`` (uniform grid, half-step
    drive, batch tiling, VMEM-budgeted time chunking) with the crossbar
    read semantics of ``core.analogue.analogue_mlp_apply`` traced
    in-kernel.  See the module docstring for the noise model.

    ``fault`` (a ``FaultModel.kernel_args()`` dict of static scalars)
    injects device faults in-kernel: stuck cells pinned at their global
    coordinates (bitwise the program-time masks of
    :mod:`repro.core.faults`) and live read-disturb drift whose decay
    exponent advances with the global step count.

    ``step_offset`` declares the global RK4 step index of ``y0``: a
    rollout resumed mid-trajectory (streaming serving, see
    ``docs/serving.md``) passes the number of steps already served so
    the per-step noise salts and the drift exponent continue the SAME
    global streams an uninterrupted rollout would have used — with it,
    split-and-resume noisy rollouts are bitwise-identical to unsplit
    ones.  It is a compile-time constant (one compiled program per
    distinct offset); noise-free, drift-free solves ignore it.
    """
    if interpret is None:
        interpret = _default_interpret()
    if read_noise > 0.0 and g_step is not None and g_min <= 0.0:
        raise ValueError(
            "fused_analogue_rollout: noisy quantised reads need the "
            "absolute conductance floor — pass g_min > 0 (spec.g_min)")
    fa = dict(_FAULT_DEFAULTS, **(fault or {}))
    if set(fa) != set(_FAULT_DEFAULTS):
        raise ValueError(
            f"fused_analogue_rollout: unknown fault keys "
            f"{sorted(set(fa) - set(_FAULT_DEFAULTS))}; have "
            f"{sorted(_FAULT_DEFAULTS)}")
    if fa["stuck_rate"] > 0.0 and not g_max > g_min:
        raise ValueError(
            "fused_analogue_rollout: stuck-cell injection pins cells to "
            "the absolute G_on/G_off values — pass g_max > g_min "
            "(spec.g_max/spec.g_min)")
    y0 = y0.astype(jnp.float32)
    u_half = u_half.astype(jnp.float32)
    scales = jnp.asarray(scales, jnp.float32)
    gps = list(gps)
    gms = list(gms)
    L = len(gps)
    if scales.shape != (L,):
        raise ValueError(
            f"fused_analogue_rollout: scales must be ({L},), got "
            f"{scales.shape}")

    B, D = y0.shape
    per_tile_drive = u_half.ndim == 3
    if per_tile_drive and u_half.shape[0] != B:
        raise ValueError(
            f"per-twin drive batch {u_half.shape[0]} != y0 batch {B}")
    if per_tile_drive and u_half.shape[-1] == 0:
        per_tile_drive, u_half = False, u_half[0]
    T = (u_half.shape[1 if per_tile_drive else 0] - 1) // 2
    du = u_half.shape[-1]
    bt = min(batch_tile, B)
    if B % bt:
        raise ValueError(f"batch {B} not divisible by tile {bt}")

    # VMEM plan: the stationary operands are the TWO conductance arrays
    # per layer (the pair never combines in HBM), so size the plan on
    # both; activation slack is that of the effective (K, N) weights.
    plan = plan_time_chunk(T, bt, D, du, per_tile_drive,
                           [g.astype(jnp.float32) for g in gps + gms], [],
                           vmem_budget_bytes, time_chunk, precision="f32")
    C, NC = plan.time_chunk, plan.num_chunks

    kernel = _make_kernel(L, C, float(dt), du, bt, per_tile_drive,
                          None if g_step is None else float(g_step),
                          float(g_min), float(g_max), v_clamp,
                          float(read_noise), int(noise_seed),
                          float(fa["stuck_rate"]),
                          float(fa["stuck_on_frac"]),
                          int(fa["fault_seed"]), int(fa["salt_base"]),
                          float(fa["drift_nu"]), float(fa["drift_tau"]),
                          int(fa["drift_n0"]), int(step_offset))

    grid = (B // bt, NC)
    if per_tile_drive:
        u_tm = jnp.transpose(u_half, (1, 0, 2))          # (2T+1, B, du)
        u_in = _chunk_drive(u_tm, C, NC)                 # (NC, 2C+1, B, du)
        u_spec = pl.BlockSpec((1, 2 * C + 1, bt, du),
                              lambda i, j: (j, 0, i, 0))
    else:
        u_tm = u_half if du > 0 else jnp.zeros((2 * T + 1, 1), jnp.float32)
        u_in = _chunk_drive(u_tm, C, NC)                 # (NC, 2C+1, du')
        u_spec = pl.BlockSpec((1, 2 * C + 1, max(du, 1)),
                              lambda i, j: (j, 0, 0))
    in_specs = [
        pl.BlockSpec((bt, D), lambda i, j: (i, 0)),      # y0
        u_spec,                                          # u_chunks
    ]
    for g in gps + gms:
        in_specs.append(pl.BlockSpec(g.shape, lambda i, j: (0, 0)))
    in_specs.append(pl.BlockSpec(scales.shape, lambda i, j: (0,)))
    out_spec = pl.BlockSpec((C, bt, D), lambda i, j: (j, i, 0))

    steps = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((NC * C, B, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt, D), jnp.float32)],
        interpret=interpret,
    )(y0, u_in, *gps, *gms, scales)
    return jnp.concatenate([y0[None], steps[:T]], axis=0)
