"""Blocked differential-pair crossbar VMM kernel.

Simulates the analogue array read path as one fused TPU kernel:

    y = clip( (x @ (G+ - G-)) / scale, -v_clamp, +v_clamp )

Two storage modes:
  * float mode — conductances as float (carries programming noise);
  * quantised mode — uint8 level indices (the device's 6-bit states),
    dequantised on the fly inside the kernel ((idx_p - idx_m) * g_step —
    the G_min offsets cancel in the noise-free differential pair).  This
    is the memristive analogue of an int-quantised weight GEMM: 4x less
    weight traffic than f32, dequant fused into the MXU feed.

Optional per-read noise: ``read_noise`` > 0 perturbs each conductance
multiplicatively with a counter-derived Gaussian stream
(:mod:`repro.kernels.noise`) keyed on ``noise_seed`` and the element's
global (k, n) coordinates — deterministic, so the same seed reproduces
the same read bitwise.  In quantised mode the full conductances
``g_min + idx * g_step`` are reconstructed first, because the G_min
offsets only cancel when both halves of the pair are noise-free.

Classic (M/bm, N/bn, K/bk) blocked matmul: fp32 accumulator scratch in
VMEM, K as the innermost (sequential, revisiting) grid dim; the
differential subtraction, dequant, noise, rescale and clamp are all
fused so the pair never materialises in HBM.

Padding follows the masked-padding discipline of the fleet tiles
(:func:`pad_accumulator_neutral`): pad rows/columns must be
*accumulator-neutral*, i.e. contribute exactly zero partial sums in
every mode.  Zero-padding alone guarantees that for the noise-free
paths (0 - 0 = 0 in float mode, (0 - 0) * g_step = 0 in quantised
mode), but NOT for noisy quantised reads — a zero level index still
reconstructs to ``g_min`` and the pair's noise does not cancel — so the
kernel masks reconstructed conductances against the true (K, N) extent
before accumulating.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_ode_mlp import _default_interpret
from repro.kernels.noise import counter_normal, stuck_cell_masks


def pad_accumulator_neutral(x: jax.Array, mult: int, axis: int) -> jax.Array:
    """Pad ``axis`` up to a multiple of ``mult`` with accumulator-neutral
    values (zeros).

    This is the same discipline the fused fleet tiles use
    (``fused_ode_mlp.pad_fleet_to_tile``): padding must never change what
    the kernel accumulates for real elements.  For the crossbar operands
    zero *values* are neutral in both storage modes — float conductances
    pad as G+ = G- = 0, and uint8 level indices pad as idx_p = idx_m = 0
    whose dequant ``(0 - 0) * g_step`` is exactly 0.  Reads that
    reconstruct absolute conductances (the noisy quantised path) must
    additionally mask by the true extent; the kernel does.
    """
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _kernel(x_ref, gp_ref, gm_ref, o_ref, acc_ref, *, nk: int, bk: int,
            bn: int, K: int, N: int, g_step: float | None,
            g_min: float, g_max: float, inv_scale: float,
            clamp: float | None, read_noise: float, noise_seed: int,
            stuck_rate: float, stuck_on_frac: float, fault_seed: int,
            salt_p: int, salt_m: int, drift: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gp = gp_ref[...].astype(jnp.float32)
    gm = gm_ref[...].astype(jnp.float32)
    stuck = stuck_rate > 0.0
    if g_step is not None and (read_noise > 0.0 or stuck):
        # Quantised storage: reconstruct the absolute conductances —
        # G_min offsets cancel only in the clean (noise- and fault-free)
        # pair; stuck overrides and read noise both act on absolutes.
        gp = g_min + gp * g_step
        gm = g_min + gm * g_step
    if stuck:
        # Stuck cells pin to G_on/G_off at their GLOBAL coordinates —
        # bitwise the mask core/faults.py applies at program time, so
        # in-kernel injection (zero extra HBM traffic: the mask is
        # counter-derived, never materialised) matches a baked program.
        row0 = pl.program_id(2) * bk
        col0 = pl.program_id(1) * bn
        for arr, salt in ((0, salt_p), (1, salt_m)):
            is_stuck, stuck_on = stuck_cell_masks(
                fault_seed, salt, (bk, bn), stuck_rate, stuck_on_frac,
                row0=row0, col0=col0, ncols=N)
            val = jnp.where(stuck_on, jnp.float32(g_max), jnp.float32(g_min))
            if arr == 0:
                gp = jnp.where(is_stuck, val, gp)
            else:
                gm = jnp.where(is_stuck, val, gm)
    if read_noise > 0.0:
        # One salt per (k-tile, n-tile, pair): the element iota inside
        # counter_normal then decorrelates within the tile, so the full
        # (K, N) stream is deterministic in noise_seed alone.
        salt = (pl.program_id(2) * (2 * 65536)
                + pl.program_id(1) * 2)
        gp = gp * (1.0 + read_noise * counter_normal(
            noise_seed, salt, (bk, bn)))
        gm = gm * (1.0 + read_noise * counter_normal(
            noise_seed, salt + 1, (bk, bn)))
    if read_noise > 0.0 or stuck:
        # Masked-padding discipline: reconstructed pads sit at ~g_min
        # (and stuck overrides would pin pad cells to real conductances)
        # — zero everything past the true (K, N) extent so pads stay
        # accumulator-neutral.
        kk = pl.program_id(2) * bk + jax.lax.broadcasted_iota(
            jnp.int32, (bk, bn), 0)
        nn = pl.program_id(1) * bn + jax.lax.broadcasted_iota(
            jnp.int32, (bk, bn), 1)
        valid = (kk < K) & (nn < N)
        g = jnp.where(valid, gp - gm, 0.0)
    else:
        g = gp - gm
        if g_step is not None:      # quantised mode: dequant level indices
            g = g * g_step
    if drift != 1.0:
        # Read-disturb relaxation scales both halves of the pair equally,
        # so the differential scales by the same (static) factor.
        g = g * jnp.float32(drift)
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, g, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        y = acc_ref[...] * inv_scale
        if clamp is not None:
            y = jnp.clip(y, -clamp, clamp)
        o_ref[...] = y.astype(o_ref.dtype)


def crossbar_matmul(
    x: jax.Array,          # (M, K)
    gp: jax.Array,         # (K, N) float conductances or uint8 level indices
    gm: jax.Array,         # (K, N)
    *,
    inv_scale: float,
    g_step: float | None = None,   # set => quantised (uint8) mode
    clamp: float | None = None,
    read_noise: float = 0.0,
    noise_seed: int = 0,
    g_min: float = 0.0,            # needed for noisy quantised reconstruction
    g_max: float = 0.0,            # needed for stuck-cell overrides
    stuck_rate: float = 0.0,
    stuck_on_frac: float = 0.5,
    fault_seed: int = 0,
    fault_salts: tuple[int, int] = (0, 1),   # (G+ salt, G- salt)
    drift: float = 1.0,
    bm: int = 128, bk: int = 128, bn: int = 128,
    interpret: bool | None = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Fused differential-pair VMM.

    Pads every dim to its tile multiple (hardware 8x128 alignment) with
    accumulator-neutral values and slices the result back.
    ``interpret=None`` auto-detects the accelerator (compiled on TPU,
    interpreter elsewhere; ``REPRO_FORCE_INTERPRET`` pins the mode).
    ``read_noise`` > 0 applies the deterministic counter-derived read
    perturbation described in the module docstring.

    Device faults are injected in-kernel (counter-derived, zero extra
    HBM traffic — see :mod:`repro.core.faults` for the model and the
    salt convention): ``stuck_rate`` > 0 pins that fraction of cells to
    ``g_max``/``g_min`` at their global coordinates, bitwise-identical
    to program-time baking, and ``drift`` scales every conductance by a
    static read-disturb relaxation factor.
    """
    if interpret is None:
        interpret = _default_interpret()
    M, K = x.shape
    K2, N = gp.shape
    assert K == K2 and gm.shape == gp.shape
    if read_noise > 0.0 and g_step is not None and g_min <= 0.0:
        raise ValueError(
            "crossbar_matmul: noisy quantised reads need the absolute "
            "conductance floor — pass g_min > 0 (spec.g_min)")
    if stuck_rate > 0.0 and not g_max > g_min:
        raise ValueError(
            "crossbar_matmul: stuck-cell injection pins cells to the "
            "absolute G_on/G_off values — pass g_max > g_min "
            "(spec.g_max/spec.g_min)")

    bm = min(bm, max(8, M))
    bn = min(bn, max(128, 128))
    bk = min(bk, max(128, 128))
    xp = pad_accumulator_neutral(
        pad_accumulator_neutral(x, bm, 0), bk, 1)
    gpp = pad_accumulator_neutral(
        pad_accumulator_neutral(gp, bk, 0), bn, 1)
    gmp = pad_accumulator_neutral(
        pad_accumulator_neutral(gm, bk, 0), bn, 1)
    Mp, Kp = xp.shape
    _, Np = gpp.shape
    nk = Kp // bk

    kernel = functools.partial(_kernel, nk=nk, bk=bk, bn=bn, K=K, N=N,
                               g_step=g_step, g_min=float(g_min),
                               g_max=float(g_max),
                               inv_scale=float(inv_scale), clamp=clamp,
                               read_noise=float(read_noise),
                               noise_seed=int(noise_seed),
                               stuck_rate=float(stuck_rate),
                               stuck_on_frac=float(stuck_on_frac),
                               fault_seed=int(fault_seed),
                               salt_p=int(fault_salts[0]),
                               salt_m=int(fault_salts[1]),
                               drift=float(drift))
    out = pl.pallas_call(
        kernel,
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, gpp, gmp)
    return out[:M, :N]
