"""Blocked differential-pair crossbar VMM kernel.

Simulates the analogue array read path as one fused TPU kernel:

    y = clip( (x @ (G+ - G-)) / scale, -v_clamp, +v_clamp )

Two storage modes:
  * float mode — conductances as float (carries programming noise);
  * quantised mode — uint8 level indices (the device's 6-bit states),
    dequantised on the fly inside the kernel ((idx_p - idx_m) * g_step —
    the G_min offsets cancel in the differential pair).  This is the
    memristive analogue of an int-quantised weight GEMM: 4x less weight
    traffic than f32, dequant fused into the MXU feed.

Classic (M/bm, N/bn, K/bk) blocked matmul: fp32 accumulator scratch in
VMEM, K as the innermost (sequential, revisiting) grid dim; the
differential subtraction, dequant, rescale and clamp are all epilogue-
fused so the pair never materialises in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, gp_ref, gm_ref, o_ref, acc_ref, *, nk: int,
            g_step: float | None, inv_scale: float, clamp: float | None):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gp = gp_ref[...].astype(jnp.float32)
    gm = gm_ref[...].astype(jnp.float32)
    g = gp - gm
    if g_step is not None:          # quantised mode: dequant level indices
        g = g * g_step
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(x, g, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        y = acc_ref[...] * inv_scale
        if clamp is not None:
            y = jnp.clip(y, -clamp, clamp)
        o_ref[...] = y.astype(o_ref.dtype)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def crossbar_matmul(
    x: jax.Array,          # (M, K)
    gp: jax.Array,         # (K, N) float conductances or uint8 level indices
    gm: jax.Array,         # (K, N)
    *,
    inv_scale: float,
    g_step: float | None = None,   # set => quantised (uint8) mode
    clamp: float | None = None,
    bm: int = 128, bk: int = 128, bn: int = 128,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Fused differential-pair VMM.  Pads every dim to its tile multiple
    (hardware 8x128 alignment) and slices the result back."""
    M, K = x.shape
    K2, N = gp.shape
    assert K == K2 and gm.shape == gp.shape

    bm = min(bm, max(8, M))
    bn = min(bn, max(128, 128))
    bk = min(bk, max(128, 128))
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    gpp = _pad_to(_pad_to(gp, bk, 0), bn, 1)
    gmp = _pad_to(_pad_to(gm, bk, 0), bn, 1)
    Mp, Kp = xp.shape
    _, Np = gpp.shape
    nk = Kp // bk

    kernel = functools.partial(_kernel, nk=nk, g_step=g_step,
                               inv_scale=float(inv_scale), clamp=clamp)
    out = pl.pallas_call(
        kernel,
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, gpp, gmp)
    return out[:M, :N]
