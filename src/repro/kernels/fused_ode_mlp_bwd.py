"""Reverse-time fused neural-ODE solve — training on the serving substrate.

The forward kernel (:mod:`repro.kernels.fused_ode_mlp`) keeps the MLP
weights VMEM-resident for the whole RK4 trajectory.  This module gives
that rollout a custom VJP whose backward pass runs the SAME
weights-stationary discipline in reverse: a second Pallas kernel walks
the time-chunk grid dimension backwards, replays each chunk forward from
its chunk-boundary state (recompute-in-VMEM checkpointing — the
checkpoints are the chunk boundaries the forward already materialised as
trajectory rows), and accumulates ``(dL/dy0, dL/dW, dL/db)`` while the
weights and their gradient accumulators stay pinned in VMEM.

This is the discretise-then-optimise analogue of
:mod:`repro.core.adjoint`: instead of integrating a continuous adjoint
ODE step by step (one HBM round-trip per f-eval), the cotangent is
pulled back through the exact RK4 update whole-chunk-fused, so the
gradient matches backprop-through-the-unrolled-solver to float32
rounding.

Grid: (batch tiles, time chunks), time minor, chunks visited in REVERSE
order via the index maps.  Block layout per (i, j) cell (chunk
``jj = NC-1-j``):

  y_bound  (1, bt, D)        chunk jj's boundary state (traj row jj*C)
  u_chunks (1, 2C+1, Du)     chunk jj's drive half-steps (as forward)
  g        (C, bt, D)        cotangent slab for chunk jj's output rows
  w_l/b_l  (full)            broadcast — weights stay resident
  dy0      (bt, D)           per-tile block; last write (chunk 0) wins
  dw_l/db_l (full)           one block for the WHOLE grid — the VMEM
                             gradient accumulator (zeroed at the first
                             cell, accumulated in place, flushed once)
  a        (bt, D)  scratch  adjoint carried across chunks of one tile
  ys       (C, bt, D) scratch  replayed per-step states of the chunk

VMEM per cell ~= 3x weights (w, dw refs, dw loop carry) + TWO C-slabs
(replayed states + cotangents) + activation slack for the step VJP —
roughly twice the forward's footprint, so ``plan_bwd_time_chunk`` packs
a (usually smaller) chunk against the same budget.  The boundary states
are FREE residuals: the forward's output trajectory already contains
every chunk-start state as row ``jj*C``, so the VJP stores nothing
beyond what serving already returns.

Gradients are taken w.r.t. ``y0``, ``weights`` and ``biases``; the drive
``u_half`` is treated as data (zero cotangent) — it is a sampled input
signal, not a parameter.

Mixed precision mirrors the forward's ``precision`` policy: the
boundary states, drive slabs, cotangent slabs and weight operands
stream at the storage dtype (bf16 under the bf16 policies), the replay
and the adjoint run at the carry dtype, and the dW/db gradient
accumulators — both the in-loop carry and the constant-index-map VMEM
output blocks — ALWAYS stay float32, so reduced storage never costs
accumulation accuracy across T steps.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_ode_mlp import (DEFAULT_VMEM_BUDGET, ChunkPlan,
                                         _chunk_drive, _default_interpret,
                                         _rk4_activation_bytes,
                                         fused_node_rollout, make_rk4_step,
                                         precision_dtypes, resolve_precision)


def plan_bwd_time_chunk(T: int, bt: int, D: int, du: int,
                        per_tile_drive: bool,
                        weights: Sequence[jax.Array],
                        biases: Sequence[jax.Array],
                        vmem_budget_bytes: int,
                        time_chunk: int | None = None,
                        precision: str = "f32") -> ChunkPlan:
    """Backward-pass chunk planner: same contract as ``plan_time_chunk``
    but for the heavier reverse working set — weights appear three times
    (operands at the storage dtype, plus the gradient-accumulator refs
    and the fori_loop gradient carry, both ALWAYS f32), every chunk
    keeps TWO (C, bt, D) slabs resident (the replayed states at the
    carry dtype and the cotangents at the storage dtype), and the step
    VJP's activation slack is twice the forward's (primal residuals +
    cotangents live together)."""
    store, _, acc, carry = precision_dtypes(resolve_precision(precision))
    sb = jnp.dtype(store).itemsize
    ab = jnp.dtype(acc).itemsize
    cb = jnp.dtype(carry).itemsize
    u_width = max(du, 1) * (bt if per_tile_drive else 1)
    wsize = sum(w.size for w in weights) + sum(b.size for b in biases)
    # operands at storage width; dw refs + the dw loop carry stay f32
    wbytes = sb * wsize + 2 * 4 * wsize
    act = 2 * _rk4_activation_bytes(bt, D, du, weights, ab)
    # + boundary row (store), adjoint carry (f32), dy0 block (f32)
    fixed = wbytes + act + sb * bt * D + 2 * 4 * bt * D
    per_step = (cb + sb) * bt * D + 2 * sb * u_width  # ys + g + two u rows
    if time_chunk is not None:
        C = max(1, min(int(time_chunk), T))
    else:
        avail = vmem_budget_bytes - fixed - sb * u_width
        C = int(avail // per_step)
        if C < 1:
            raise ValueError(
                f"fused backward: weights + one reverse RK4 step need "
                f"~{(fixed + per_step + sb * u_width) / 2 ** 20:.1f} MiB VMEM "
                f"(budget {vmem_budget_bytes / 2 ** 20:.1f}); shrink "
                f"batch_tile or the MLP")
        C = min(C, T)
    need = fixed + (cb + sb) * C * bt * D + sb * (2 * C + 1) * u_width
    if need > vmem_budget_bytes:
        raise ValueError(
            f"backward time_chunk={C} needs ~{need / 2 ** 20:.1f} MiB VMEM "
            f"(budget {vmem_budget_bytes / 2 ** 20:.1f}); shrink "
            f"time_chunk or batch_tile")
    return ChunkPlan(C, -(-T // C), need)


def _make_bwd_kernel(num_layers: int, C: int, dt: float,
                     drive_dim: int, bt: int, per_tile_drive: bool,
                     precision: str = "f32"):
    L = num_layers
    _, _, _, carry_dt = precision_dtypes(resolve_precision(precision))
    # THE step of the forward kernel — shared so the checkpoint replay
    # recomputes bit-identical states and the VJP transposes the exact
    # update the forward applied (same precision policy included)
    rk4 = make_rk4_step(L, dt, drive_dim, bt, per_tile_drive, precision)

    def kernel(*refs):
        yb_ref, u_ref, g_ref = refs[0], refs[1], refs[2]
        w_refs = refs[3:3 + L]
        b_refs = refs[3 + L:3 + 2 * L]
        dy0_ref = refs[3 + 2 * L]
        dw_refs = refs[4 + 2 * L:4 + 3 * L]
        db_refs = refs[4 + 3 * L:4 + 4 * L]
        a_ref = refs[4 + 4 * L]
        ys_ref = refs[5 + 4 * L]

        i = pl.program_id(0)
        j = pl.program_id(1)       # j walks 0..NC-1; the chunk REVERSAL
        #                            lives in the BlockSpec index maps

        # First (reverse-)chunk of a batch tile: zero the adjoint carry.
        @pl.when(j == 0)
        def _():
            a_ref[...] = jnp.zeros_like(a_ref)

        # Very first grid cell: zero the in-VMEM gradient accumulators.
        @pl.when((i == 0) & (j == 0))
        def _():
            for r in dw_refs:
                r[...] = jnp.zeros_like(r)
            for r in db_refs:
                r[...] = jnp.zeros_like(r)

        ws = [w_ref[...] for w_ref in w_refs]
        bs = [b_ref[...] for b_ref in b_refs]

        # -- replay: recompute the chunk's per-step states into VMEM ----
        def fwd_body(t, y):
            ys_ref[t] = y
            return rk4(y, u_ref[0, 2 * t], u_ref[0, 2 * t + 1],
                       u_ref[0, 2 * t + 2], ws, bs)

        lax.fori_loop(0, C, fwd_body, yb_ref[0].astype(carry_dt))

        # -- reverse sweep: pull the cotangent back through each step ---
        # Per-step weight cotangents come back at the storage dtype (the
        # VJP transposes the bf16 operands); the ACCUMULATORS stay f32 —
        # both the fori_loop carry here and the dw_refs output blocks —
        # so T steps of bf16-rounded increments sum without drift.
        zeros_w = [jnp.zeros(w.shape, jnp.float32) for w in ws]
        zeros_b = [jnp.zeros(b.shape, jnp.float32) for b in bs]

        def bwd_body(r, carry):
            a, dws, dbs = carry
            t = C - 1 - r
            y_t = ys_ref[t]
            u0 = u_ref[0, 2 * t]
            um = u_ref[0, 2 * t + 1]
            u1 = u_ref[0, 2 * t + 2]
            # cotangent injected at this output row (adjoint stays at the
            # carry dtype — f32 unless the policy is pure bf16)
            a = a + g_ref[t].astype(a.dtype)
            _, vjp = jax.vjp(
                lambda y_, ws_, bs_: rk4(y_, u0, um, u1, ws_, bs_),
                y_t, ws, bs)
            a, dws_t, dbs_t = vjp(a)
            dws = [acc + d.astype(jnp.float32)
                   for acc, d in zip(dws, dws_t)]
            dbs = [acc + d.astype(jnp.float32)
                   for acc, d in zip(dbs, dbs_t)]
            return a, dws, dbs

        a, dws, dbs = lax.fori_loop(0, C, bwd_body,
                                    (a_ref[...], zeros_w, zeros_b))
        a_ref[...] = a
        # chunk 0 (the last j) leaves dL/dy0
        dy0_ref[...] = a.astype(jnp.float32)
        for ref, v in zip(dw_refs, dws):
            ref[...] += v
        for ref, v in zip(db_refs, dbs):
            ref[...] += v

    return kernel


def fused_node_rollout_bwd(
    y_bounds: jax.Array,              # (NC, B, D) chunk-boundary states
    u_half: jax.Array,                # (2T+1, Du) shared or (B, 2T+1, Du)
    weights: Sequence[jax.Array],
    biases: Sequence[jax.Array],
    g_steps: jax.Array,               # (T, B, D) cotangents for rows 1..T
    dt: float,
    *,
    batch_tile: int,
    time_chunk: int,                  # the C that produced y_bounds
    interpret: bool | None = None,
    precision: str = "f32",
) -> tuple:
    """Run the reverse-time kernel; returns ``(dy0, dweights, dbiases)``
    — always f32 (the gradient accumulators never leave full precision).

    ``y_bounds[jj]`` must be the state at the START of chunk jj (forward
    trajectory row ``jj*C``); ``g_steps`` are the cotangents of the
    forward's per-step outputs (trajectory rows 1..T — the y0 row's
    cotangent is added by the caller).  ``y_bounds``, ``u_half``,
    ``weights``/``biases`` and ``g_steps`` are expected at the policy's
    storage dtype (the caller casts).
    """
    if interpret is None:
        interpret = _default_interpret()
    precision = resolve_precision(precision)
    store, _, _, carry_dt = precision_dtypes(precision)
    NC, B, D = y_bounds.shape
    C = int(time_chunk)
    per_tile_drive = u_half.ndim == 3
    if per_tile_drive and u_half.shape[-1] == 0:
        per_tile_drive, u_half = False, u_half[0]
    T = g_steps.shape[0]
    du = u_half.shape[-1]
    L = len(weights)
    bt = min(batch_tile, B)
    if B % bt:
        raise ValueError(f"batch {B} not divisible by tile {bt}")

    # zero-pad the cotangents over the padded tail of a partial final
    # chunk: the replayed padded steps then contribute exactly nothing.
    pad = NC * C - T
    if pad:
        g_steps = jnp.pad(g_steps, ((0, pad), (0, 0), (0, 0)))

    kernel = _make_bwd_kernel(L, C, float(dt), du, bt, per_tile_drive,
                              precision)

    grid = (B // bt, NC)
    if per_tile_drive:
        u_tm = jnp.transpose(u_half, (1, 0, 2))          # (2T+1, B, du)
        u_in = _chunk_drive(u_tm, C, NC)                 # (NC, 2C+1, B, du)
        u_spec = pl.BlockSpec((1, 2 * C + 1, bt, du),
                              lambda i, j: (NC - 1 - j, 0, i, 0))
    else:
        u_tm = u_half if du > 0 else jnp.zeros((2 * T + 1, 1), store)
        u_in = _chunk_drive(u_tm, C, NC)                 # (NC, 2C+1, du')
        u_spec = pl.BlockSpec((1, 2 * C + 1, max(du, 1)),
                              lambda i, j: (NC - 1 - j, 0, 0))
    in_specs = [
        pl.BlockSpec((1, bt, D), lambda i, j: (NC - 1 - j, i, 0)),  # bounds
        u_spec,
        pl.BlockSpec((C, bt, D), lambda i, j: (NC - 1 - j, i, 0)),  # g
    ]
    for w in weights:
        in_specs.append(pl.BlockSpec(w.shape, lambda i, j: (0, 0)))
    for b in biases:
        in_specs.append(pl.BlockSpec(b.shape, lambda i, j: (0,)))

    out_shapes = ([jax.ShapeDtypeStruct((B, D), jnp.float32)]
                  + [jax.ShapeDtypeStruct(w.shape, jnp.float32)
                     for w in weights]
                  + [jax.ShapeDtypeStruct(b.shape, jnp.float32)
                     for b in biases])
    out_specs = ([pl.BlockSpec((bt, D), lambda i, j: (i, 0))]
                 + [pl.BlockSpec(w.shape, lambda i, j: (0, 0))
                    for w in weights]
                 + [pl.BlockSpec(b.shape, lambda i, j: (0,))
                    for b in biases])

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((bt, D), carry_dt),     # adjoint
                        pltpu.VMEM((C, bt, D), carry_dt)], # replayed ys
        interpret=interpret,
    )(y_bounds, u_in, g_steps, *weights, *biases)
    dy0, dws, dbs = outs[0], list(outs[1:1 + L]), list(outs[1 + L:])
    return dy0, dws, dbs


# ---------------------------------------------------------------------------
# The differentiable rollout: custom VJP over (y0, u_half, weights, biases)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def fused_node_rollout_vjp(y0, u_half, weights, biases, dt,
                           batch_tile=64, time_chunk=None, interpret=None,
                           vmem_budget_bytes=DEFAULT_VMEM_BUDGET,
                           precision=None):
    """:func:`fused_node_rollout` with gradients that never leave the
    fused substrate: forward AND backward are whole-chunk Pallas kernels,
    weights pinned in VMEM both ways.  Differentiable in ``y0``,
    ``weights`` and ``biases``; the drive gets a zero cotangent.

    ``precision`` is a nondiff static: the forward casts the operands to
    the policy's storage dtype internally, and the backward returns
    cotangents at the PRIMAL dtypes — so f32 params in, f32 grads out,
    with the f32 in-kernel accumulators never rounded on the way back.

    The primal body uses the same shared (backward-planned) time chunk
    as the VJP pair, so a plain call and the forward inside
    ``jax.grad`` are bitwise identical even under the bf16 policies
    (where chunk boundaries are rounding points).
    """
    return fused_node_rollout(y0, u_half, weights, biases, dt,
                              batch_tile=batch_tile,
                              time_chunk=_shared_chunk(
                                  y0, u_half, weights, biases, batch_tile,
                                  time_chunk, vmem_budget_bytes, precision),
                              interpret=interpret,
                              vmem_budget_bytes=vmem_budget_bytes,
                              precision=precision)


def _shared_chunk(y0, u_half, weights, biases, batch_tile, time_chunk,
                  vmem_budget_bytes, precision):
    """The time chunk BOTH passes of the VJP use: the backward planner's
    (heavier) auto-pick, or the explicit override.

    Sharing one C matters under the bf16 policies: the forward rounds
    its VMEM carry through the storage dtype exactly at chunk
    boundaries, so the chunk-start rows the backward replays from are
    bit-identical to the states the forward continued with ONLY when
    the two passes agree on where the boundaries are.  (Under f32 the
    carry is never rounded and the chunking is numerically free.)"""
    if time_chunk is not None:
        return time_chunk
    B, D = y0.shape
    T = (u_half.shape[1 if u_half.ndim == 3 else 0] - 1) // 2
    du = u_half.shape[-1]
    per_tile = u_half.ndim == 3 and du > 0
    plan = plan_bwd_time_chunk(T, min(batch_tile, B), D, du, per_tile,
                               weights, biases, vmem_budget_bytes, None,
                               precision=resolve_precision(precision))
    return plan.time_chunk


def _rollout_fwd(y0, u_half, weights, biases, dt, batch_tile, time_chunk,
                 interpret, vmem_budget_bytes, precision):
    traj = fused_node_rollout(y0, u_half, weights, biases, dt,
                              batch_tile=batch_tile,
                              time_chunk=_shared_chunk(
                                  y0, u_half, weights, biases, batch_tile,
                                  time_chunk, vmem_budget_bytes, precision),
                              interpret=interpret,
                              vmem_budget_bytes=vmem_budget_bytes,
                              precision=precision)
    # The trajectory IS the residual: every chunk-boundary state the
    # backward replays from is already a row of the primal output (at
    # the storage dtype — the forward rounds its chunk-boundary carry to
    # match, so the replay is still bit-identical), and checkpointing
    # costs zero extra memory traffic.  The empty y0-dtype marker lets
    # the backward return dL/dy0 at the primal dtype.
    return traj, (u_half, weights, biases, traj,
                  jnp.zeros((0,), y0.dtype))


def _rollout_bwd(dt, batch_tile, time_chunk, interpret, vmem_budget_bytes,
                 precision, res, g):
    u_half, weights, biases, traj, y0_marker = res
    precision = resolve_precision(precision)
    store, _, _, _ = precision_dtypes(precision)
    u_orig, w_orig, b_orig = u_half, weights, biases
    # the kernel consumes the storage-dtype operands the forward ran on
    weights = [w.astype(store) for w in weights]
    biases = [b.astype(store) for b in biases]
    u_half = u_half.astype(store)
    B, D = traj.shape[1], traj.shape[2]
    per_tile_drive = u_half.ndim == 3
    if per_tile_drive and u_half.shape[-1] == 0:
        per_tile_drive, u_half = False, u_half[0]
    T = (u_half.shape[1 if per_tile_drive else 0] - 1) // 2
    du = u_half.shape[-1]
    bt = min(batch_tile, B)
    plan = plan_bwd_time_chunk(T, bt, D, du, per_tile_drive, weights,
                               biases, vmem_budget_bytes, time_chunk,
                               precision=precision)
    C, NC = plan.time_chunk, plan.num_chunks
    y_bounds = traj[jnp.arange(NC) * C]              # chunk-start states
    # the y0 row's cotangent never enters the kernel — keep it f32; only
    # the per-step slab streams at storage width
    g0 = g[0].astype(jnp.float32)
    dy0, dws, dbs = fused_node_rollout_bwd(
        y_bounds, u_half, weights, biases, g[1:].astype(store), dt,
        batch_tile=batch_tile, time_chunk=C, interpret=interpret,
        precision=precision)
    dy0 = (dy0 + g0).astype(y0_marker.dtype)
    # cotangents must match the PRIMAL avals (f32 params stay f32)
    dws = [d.astype(w.dtype) for d, w in zip(dws, w_orig)]
    dbs = [d.astype(b.dtype) for d, b in zip(dbs, b_orig)]
    # drive is data, not a parameter — zero cotangent (see module doc)
    return dy0, jnp.zeros_like(u_orig), dws, dbs


fused_node_rollout_vjp.defvjp(_rollout_fwd, _rollout_bwd)
