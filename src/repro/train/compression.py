"""Gradient compression for the data-parallel reduction.

At 1000+ nodes the DP gradient all-reduce crosses DCI; int8 compression
with error feedback (1-bit-Adam-style residual accumulation) cuts that
traffic 4x with negligible quality loss.  Implemented as an *optimizer
transform* so the error-feedback buffers live in optimizer state and are
checkpointed/resharded for free:

    opt = compressed(adam(3e-4), bits=8)

The quantise->dequantise round trip happens *before* the (GSPMD-inserted)
mean over the data axis; XLA then reduces the small-dynamic-range values.
On real fleets the transport itself would move int8 — here the transform
preserves the numerics (quantisation error + feedback) so convergence
behaviour is faithfully testable, and the traffic saving is accounted in
the roofline's collective term when enabled.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer

_tree_map = jax.tree_util.tree_map


class CompressedState(NamedTuple):
    inner: object
    error: object          # error-feedback residuals (same tree as grads)


def _quantize_dequantize(g: jax.Array, bits: int):
    """Symmetric per-tensor int quantisation; returns (deq, residual)."""
    levels = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(g)) / levels + 1e-12
    q = jnp.clip(jnp.round(g / scale), -levels, levels)
    deq = q * scale
    return deq, g - deq


def topk_sparsify(g: jax.Array, frac: float):
    """Keep the largest-|.| fraction of entries (deep-gradient-compression
    style); returns (sparse, residual)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(g) >= thresh
    kept = jnp.where(mask, g, 0.0)
    return kept, g - kept


def compressed(inner: Optimizer, bits: int = 8,
               topk_frac: float | None = None) -> Optimizer:
    """Wrap an optimizer with compress(grad + error_feedback)."""

    def init(params):
        return CompressedState(
            inner=inner.init(params),
            error=_tree_map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params))

    def update(grads, state: CompressedState, params=None):
        def comp(g, e):
            g = g.astype(jnp.float32) + e
            if topk_frac is not None:
                return topk_sparsify(g, topk_frac)
            return _quantize_dequantize(g, bits)

        pairs = _tree_map(comp, grads, state.error)
        cgrads = _tree_map(lambda p: p[0], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        error = _tree_map(lambda p: p[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
        updates, inner_state = inner.update(cgrads, state.inner, params)
        return updates, CompressedState(inner=inner_state, error=error)

    return Optimizer(init, update)
