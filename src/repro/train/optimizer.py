"""From-scratch optimizers (no optax in the environment).

Minimal GradientTransformation-style API:

    opt = adam(1e-3)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Includes Adam/AdamW (the paper trains with Adam), global-norm clipping,
and warmup-cosine / constant schedules for the LM trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any
_tree_map = jax.tree_util.tree_map


class Optimizer(NamedTuple):
    init: Callable[[Pytree], Pytree]
    update: Callable[..., tuple]


class AdamState(NamedTuple):
    step: jax.Array
    mu: Pytree
    nu: Pytree


def global_norm(tree: Pytree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: Pytree, max_norm: float) -> Pytree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return _tree_map(lambda x: x * scale, tree)


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup_steps: int,
                           total_steps: int,
                           final_frac: float = 0.1) -> Callable:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) /
                        max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) *
                         0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def adam(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         grad_clip: Optional[float] = None,
         mu_dtype=None) -> Optimizer:
    """Adam / AdamW (decoupled weight decay) with optional global-norm clip."""
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        mu = _tree_map(lambda p: jnp.zeros_like(p, dtype=mu_dtype or p.dtype),
                       params)
        nu = _tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), mu, nu)

    def update(grads, state: AdamState, params=None):
        if grad_clip is not None:
            grads = clip_by_global_norm(grads, grad_clip)
        step = state.step + 1
        mu = _tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                       state.mu, grads)
        nu = _tree_map(
            lambda v, g: b2 * v + (1 - b2) *
            jnp.square(g.astype(jnp.float32)), state.nu, grads)
        lr_t = sched(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v / bc2
            u = -lr_t * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and params is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u.astype(p.dtype if p is not None else m.dtype)

        if params is not None:
            updates = _tree_map(upd, mu, nu, params)
        else:
            updates = _tree_map(lambda m, v: upd(m, v, None), mu, nu)
        return updates, AdamState(step, mu, nu)

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.1, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    return _tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0,
        grad_clip: Optional[float] = None) -> Optimizer:
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return (jnp.zeros((), jnp.int32),
                _tree_map(jnp.zeros_like, params) if momentum else None)

    def update(grads, state, params=None):
        del params
        if grad_clip is not None:
            grads = clip_by_global_norm(grads, grad_clip)
        step, vel = state
        step = step + 1
        lr_t = sched(step)
        if momentum:
            vel = _tree_map(lambda v, g: momentum * v + g, vel, grads)
            upd = _tree_map(lambda v: -lr_t * v, vel)
        else:
            upd = _tree_map(lambda g: -lr_t * g, grads)
        return upd, (step, vel)

    return Optimizer(init, update)
