from repro.train import optimizer, recipes, trainer
