"""LM training / serving step factories — the functions the multi-pod
dry-run lowers and the CPU examples execute.

Distributed-optimisation features (all selectable):
* scan-over-layers remat (policy from ArchConfig.remat);
* microbatched gradient accumulation (``accum_steps``);
* int8 gradient compression with error feedback before the data-parallel
  reduction (``compress``; see train/compression.py);
* donated params/opt-state buffers (in-place update at scale).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.model import decode_step, forward
from repro.train.optimizer import Optimizer, apply_updates

Pytree = Any


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE; logits fp32 (B,S,V), labels (B,S)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def lm_loss(params: Pytree, cfg: ArchConfig, tokens: jax.Array):
    """tokens (B, S+1) -> (loss, metrics)."""
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, aux, _ = forward(params, cfg, inputs)
    ce = cross_entropy(logits, labels)
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    accum_steps: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    Gradient compression (int8 + error feedback) is an *optimizer*
    transform — wrap with ``repro.train.compression.compressed(...)``
    before passing it in, so the error-feedback buffers live in the
    optimizer state and checkpoint for free.
    """

    def train_step(params, opt_state, batch):
        tokens = batch["tokens"]
        if accum_steps > 1:
            b = tokens.shape[0]
            assert b % accum_steps == 0
            micro = tokens.reshape(accum_steps, b // accum_steps,
                                   *tokens.shape[1:])

            def acc(carry, mtoks):
                g_acc, l_acc = carry
                (loss, m), g = jax.value_and_grad(lm_loss, has_aux=True)(
                    params, cfg, mtoks)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + m["ce"]), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, ce_sum), _ = jax.lax.scan(acc, (zeros, 0.0), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum_steps, grads)
            ce = ce_sum / accum_steps
        else:
            (loss, m), grads = jax.value_and_grad(lm_loss, has_aux=True)(
                params, cfg, tokens)
            ce = m["ce"]
        updates, new_opt = optimizer.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        return new_params, new_opt, {"loss": ce}

    return train_step


def make_prefill_step(cfg: ArchConfig):
    """prefill(params, batch) -> (logits of the last position, caches)."""

    def prefill_step(params, batch):
        tokens = batch["tokens"][:, :-1]
        logits, aux, cache = forward(params, cfg, tokens, return_cache=True)
        return logits[:, -1, :], cache

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    """decode(params, batch, pos, cache) -> (next-token logits, cache')."""

    def serve_step(params, batch, pos, cache):
        logits, cache = decode_step(params, cfg, batch["tokens"], pos, cache)
        return logits[:, -1, :], cache

    return serve_step


def greedy_generate(params, cfg: ArchConfig, prompt: jax.Array,
                    num_tokens: int, max_seq: int):
    """CPU-scale greedy decoding driver (examples / tests)."""
    from repro.models.model import init_cache
    b, s = prompt.shape
    cache = init_cache(cfg, b, max_seq)
    # prefill by stepping (simple reference path)
    logits = None
    for i in range(s):
        logits, cache = decode_step(params, cfg, prompt[:, i:i + 1],
                                    jnp.asarray(i, jnp.int32), cache)
    toks = [jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)]
    for j in range(num_tokens - 1):
        logits, cache = decode_step(params, cfg, toks[-1][:, None],
                                    jnp.asarray(s + j, jnp.int32), cache)
        toks.append(jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32))
    return jnp.stack(toks, axis=1)
