"""Hardware-aware robust training: pass the weights through the analogue
write path *inside* the loss, so training optimises the weights the array
will actually realise.

The paper trains clean digital weights and programs them post-hoc
(quantise to 6-bit conductance levels, add programming noise, serve under
read noise).  PR 7 measured what that costs under device imperfection;
this module closes the loop: during ``fit()`` every loss evaluation sees
``params`` through the same device model the serving kernels apply —

  fold bias -> differential pair (G+, G-) -> 6-bit quantise ->
  multiplicative programming noise -> stuck-cell pinning ->
  drift snapshot -> multiplicative read noise -> back to weight units

— wrapped in a straight-through estimator (STE) so gradients flow as if
the chain were the identity:

    w_eff = w + stop_gradient(write_path(w) - w)

The forward pass is exactly the degraded weights; the backward pass is
``dL/dw = dL/dw_eff`` — the standard quantisation-aware-training
gradient, which needs NO changes to the fused reverse-time VJP kernel
(``params`` are already differentiable kernel inputs, the device model is
a weight-space pre-transform).

**Determinism contract.**  Every stochastic perturbation is drawn from
the counter-derived stream of :mod:`repro.kernels.noise` — the same
generator the analogue kernels use — keyed by ``(noise_seed,
global training step, draw index, layer, pair, channel)``.  No
``jax.random`` key is threaded for the device model, so the scan-compiled
training engine stays ONE jit, and the same seed gives a
bitwise-identical loss history (pinned by ``tests/test_hw_aware.py``).
Salts live in their own block (:data:`HW_SALT_BASE`), disjoint from the
kernels' read-noise salts (which count up from 0) and from the fault-mask
block (``FAULT_SALT_BASE = 0x0F00_0000``).

Read noise is a *per-evaluation* phenomenon in the kernels; here each
draw applies one weight-space realisation per step — the standard
noise-injection-training surrogate (fresh realisations every step make
the optimiser see the same perturbation distribution the serving rollout
integrates over).  The expectation over ``k_draws`` independent
realisations per step (:func:`expectation_over_draws`) reduces gradient
variance without leaving the single-jit engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.analogue import (AnalogueSpec, _fold_bias, conductance_pair,
                                 quantize_conductance)
from repro.kernels.noise import counter_normal, splitmix32

Pytree = Any

#: Base of the hw-aware training salt block.  The fused kernels' read
#: noise salts count up from 0 (8 * layers per RK4 step) and the fault
#: masks start at 0x0F00_0000, so this block sits safely between them
#: for any realistic (step * k_draws * layers) product.
HW_SALT_BASE = 0x0A00_0000


@dataclasses.dataclass(frozen=True)
class HwAwareConfig:
    """Policy object for hardware-aware training (``fit(hw_aware=...)``).

    ``spec`` is the device model trained against (quantisation levels,
    programming-noise sigma, read-noise sigma — load a measured one with
    :func:`repro.core.analogue.spec_from_calibration`).  ``read_sigma``
    overrides ``spec.read_noise`` for training only (train against a
    harsher read channel than you expect to serve).  ``k_draws``
    independent device realisations are averaged per step.

    Fault-ensemble sampling (optional): ``faults`` injects the composed
    device-fault model of :mod:`repro.core.faults` into the write path —
    stuck cells pinned at G_on/G_off, and (with ``drift_reads > 0``)
    drift snapshots spread across the draws so the ensemble covers array
    ages 0..``drift_reads``.  ``fault_ensemble=True`` re-derives the
    stuck mask per (step, draw) instead of training against one frozen
    mask — weights become robust to the *distribution* of arrays, not
    one unlucky array.
    """

    spec: AnalogueSpec = AnalogueSpec()
    k_draws: int = 4
    noise_seed: int = 0
    read_sigma: Optional[float] = None   # None = spec.read_noise
    faults: Optional[Any] = None         # FaultModel | None
    fault_ensemble: bool = False
    drift_reads: int = 0                 # max array age covered by draws

    def __post_init__(self):
        if self.k_draws < 1:
            raise ValueError(
                f"HwAwareConfig.k_draws must be >= 1, got {self.k_draws}")
        if self.read_sigma is not None and self.read_sigma < 0:
            raise ValueError(
                f"HwAwareConfig.read_sigma must be >= 0, "
                f"got {self.read_sigma}")
        if self.drift_reads < 0:
            raise ValueError(
                f"HwAwareConfig.drift_reads must be >= 0, "
                f"got {self.drift_reads}")
        if self.fault_ensemble and self.faults is None:
            raise ValueError(
                "HwAwareConfig.fault_ensemble=True needs a fault model "
                "(faults=...) to resample from")

    @property
    def effective_read_sigma(self) -> float:
        return (self.spec.read_noise if self.read_sigma is None
                else self.read_sigma)

    @classmethod
    def from_backend(cls, backend, **overrides) -> "HwAwareConfig":
        """Derive the training policy from a ``FusedAnalogueBackend`` —
        train against exactly the substrate that will serve (same spec,
        same fault model, noise stream keyed by the backend's
        ``read_seed``)."""
        kw = dict(spec=backend.spec, noise_seed=int(backend.read_seed),
                  faults=backend.faults)
        kw.update(overrides)
        return cls(**kw)


def _hw_salt(cfg: HwAwareConfig, step, draw: int, layer: int,
             pair: int, channel: int, num_layers: int):
    """Unique salt per (step, draw, layer, pair, channel); ``step`` may
    be traced (the scan engine's int32 counter)."""
    u = jnp.uint32
    s = jnp.asarray(step, u) * u(cfg.k_draws) + u(draw)
    s = (s * u(num_layers) + u(layer)) * u(4) + u(2 * pair + channel)
    return u(HW_SALT_BASE) + s


def write_path_tensor(folded: jax.Array, cfg: HwAwareConfig, step,
                      draw: int, layer: int, num_layers: int) -> jax.Array:
    """One tensor through the analogue write path (weight units in,
    weight units out; ``folded`` carries the bias as its last row).

    Mirrors ``program_tensor`` + the fused kernel's read model, with the
    ``jax.random`` draws replaced by the counter stream:
    differential-pair map, 6-bit quantise, multiplicative programming
    noise (clipped to the physical range like ``program_tensor``),
    stuck-cell pinning, drift snapshot, multiplicative read noise,
    differential read back to weight units.  Pure function of
    ``(folded, cfg, step, draw)`` — bitwise reproducible.
    """
    spec = cfg.spec
    gp, gm, scale = conductance_pair(folded, spec,
                                     name=f"params[{layer}] (w|b folded)")
    gp = quantize_conductance(gp, spec)
    gm = quantize_conductance(gm, spec)

    def salt(pair, channel):
        return _hw_salt(cfg, step, draw, layer, pair, channel, num_layers)

    if spec.prog_noise > 0:
        ep = counter_normal(cfg.noise_seed, salt(0, 0), gp.shape)
        em = counter_normal(cfg.noise_seed, salt(1, 0), gm.shape)
        gp = jnp.clip(gp * (1.0 + spec.prog_noise * ep), 0.0,
                      spec.g_max * 1.5)
        gm = jnp.clip(gm * (1.0 + spec.prog_noise * em), 0.0,
                      spec.g_max * 1.5)

    if cfg.faults is not None and cfg.faults.stuck_rate > 0:
        from repro.core.faults import fault_salt
        from repro.kernels.noise import stuck_cell_masks
        seed = jnp.uint32(cfg.faults.seed)
        if cfg.fault_ensemble:
            # fresh array per (step, draw): robustness to the fault
            # DISTRIBUTION, not one frozen mask
            seed = splitmix32(seed ^ (jnp.asarray(step, jnp.uint32)
                                      * jnp.uint32(cfg.k_draws)
                                      + jnp.uint32(draw)))
        rate = cfg.faults.stuck.rate
        on_frac = cfg.faults.stuck.on_frac
        for pair, g in ((0, gp), (1, gm)):
            is_stuck, stuck_on = stuck_cell_masks(
                seed, fault_salt(layer, pair), g.shape, rate, on_frac)
            val = jnp.where(stuck_on, jnp.float32(spec.g_max),
                            jnp.float32(spec.g_min))
            g = jnp.where(is_stuck, val, g)
            if pair == 0:
                gp = g
            else:
                gm = g

    if (cfg.faults is not None and cfg.faults.drift is not None
            and cfg.drift_reads > 0):
        # draws span array ages 0 .. drift_reads (both pair halves decay
        # together — a global gain droop, exactly the kernel's live model)
        from repro.core.faults import drift_factor
        age = cfg.drift_reads * draw // max(cfg.k_draws - 1, 1)
        dfac = drift_factor(cfg.faults, age)
        gp = gp * dfac
        gm = gm * dfac

    sigma = cfg.effective_read_sigma
    if sigma > 0:
        rp = counter_normal(cfg.noise_seed, salt(0, 1), gp.shape)
        rm = counter_normal(cfg.noise_seed, salt(1, 1), gm.shape)
        gp = gp * (1.0 + sigma * rp)
        gm = gm * (1.0 + sigma * rm)

    return (gp - gm) / scale


def hw_aware_params(params: Pytree, cfg: HwAwareConfig, step,
                    draw: int = 0) -> Pytree:
    """The core MLP param list through the write path, with the STE.

    Forward value: the degraded weights the array would realise at
    training step ``step``, device realisation ``draw``.  Gradient:
    identity (``dL/dw = dL/dw_eff``), so the chain composes with any
    differentiable rollout — digital adjoint or the fused reverse-time
    VJP — without touching the kernels.
    """
    L = len(params)
    out = []
    for li, layer in enumerate(params):
        folded = _fold_bias({"w": layer["w"].astype(jnp.float32),
                             "b": layer["b"].astype(jnp.float32)})
        w_hw = write_path_tensor(folded, cfg, step, draw, li, L)
        eff = folded + lax.stop_gradient(w_hw - folded)
        out.append({"w": eff[:-1], "b": eff[-1]})
    return out


def expectation_over_draws(per_draw_loss, cfg: HwAwareConfig):
    """Mean loss over ``k_draws`` independent device realisations.

    ``per_draw_loss(draw) -> scalar``; draws are unrolled statically
    (``k_draws`` is small), so the whole expectation stays inside the
    one scan-compiled jit.
    """
    losses = [per_draw_loss(d) for d in range(cfg.k_draws)]
    return jnp.mean(jnp.stack(losses))
