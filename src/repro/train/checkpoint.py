"""Fault-tolerant checkpointing (no orbax in the environment — built here).

Properties required at 1000+ node scale:
* **atomic** — write to a temp dir, fsync, rename; a crash mid-write never
  corrupts the latest checkpoint;
* **asynchronous** — device->host transfer happens synchronously (cheap),
  serialisation + disk I/O run on a writer thread so the train loop
  doesn't stall;
* **retention** — keep the newest K checkpoints, delete older ones;
* **elastic restore** — checkpoints store the *global* logical arrays
  (gathered per-leaf); ``restore(..., shardings=...)`` re-shards onto ANY
  mesh, so a job can restart on a different topology (elastic scaling /
  shrink-after-failure);
* **exact data resume** — the data pipeline is stateless (batch = f(seed,
  step)), so restoring ``step`` alone resumes the stream exactly.

Format: one ``.npz``-style directory per step with a JSON manifest of the
pytree structure (leaf paths -> file names, dtypes, shapes).
"""
from __future__ import annotations

import itertools
import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

_TMP_COUNTER = itertools.count()

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

#: On-disk manifest schema version.  Bump when the checkpoint layout
#: changes incompatibly; readers refuse manifests from a different major
#: schema instead of mis-parsing them.  (Checkpoints written before the
#: field existed are read as version 1 — the layout is identical.)
SCHEMA_VERSION = 1


def _flatten(tree: Pytree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        named.append((name, leaf))
    return named, treedef


def save(ckpt_dir: str, step: int, tree: Pytree, *, keep: int = 3,
         blocking: bool = True, extra: Optional[dict] = None) -> str:
    """Atomically persist a pytree; returns the final directory path.

    ``extra``: optional JSON-serialisable payload stored inside the
    manifest (the serving snapshots keep their queue/stat state here —
    it rides the same atomic publish as the arrays)."""
    named, _ = _flatten(tree)
    host = [(n, np.asarray(jax.device_get(x))) for n, x in named]
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + f".tmp{os.getpid()}_{next(_TMP_COUNTER)}"

    def write():
        from repro.launch import chaos
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        for i, (name, arr) in enumerate(host):
            fname = f"arr_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest[name] = {"file": fname, "dtype": str(arr.dtype),
                              "shape": list(arr.shape)}
        body = {"schema": SCHEMA_VERSION, "step": step, "leaves": manifest}
        if extra is not None:
            body["extra"] = extra
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(body, f)
        chaos.kill_point("snapshot:pre_rename")
        try:
            os.replace(tmp, final)      # atomic publish
        except OSError:
            # a concurrent save already published this step — drop ours
            shutil.rmtree(tmp, ignore_errors=True)
        _apply_retention(ckpt_dir, keep)

    if blocking:
        write()
    else:
        _writer().submit(write)
    return final


class _Writer:
    def __init__(self):
        self.q: queue.Queue = queue.Queue()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        while True:
            job = self.q.get()
            if job is None:
                return
            try:
                job()
            except Exception as e:       # pragma: no cover
                print(f"[checkpoint] async write failed: {e}")
            finally:
                self.q.task_done()

    def submit(self, job):
        self.q.put(job)

    def wait(self):
        self.q.join()


_WRITER: Optional[_Writer] = None


def _writer() -> _Writer:
    global _WRITER
    if _WRITER is None:
        _WRITER = _Writer()
    return _WRITER


def wait_for_async():
    if _WRITER is not None:
        _WRITER.wait()


def _apply_retention(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and ".tmp" not in d)
    for old in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, old), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def all_steps(ckpt_dir: str) -> list:
    """Every published checkpoint step under ``ckpt_dir``, ascending.
    In-flight ``.tmp`` writes (interrupted or concurrent) are excluded —
    only atomically renamed directories count as checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and ".tmp" not in d)


# ---------------------------------------------------------------------------
# Twin-serving convenience layer (used by repro.launch.fleet_serving)
# ---------------------------------------------------------------------------

def save_twin(ckpt_dir: str, params: Pytree, *, step: int = 0,
              blocking: bool = True, keep: int = 3) -> str:
    """Persist a trained twin's weight pytree (the fleet-shared model).

    A thin wrapper over :func:`save` with the canonical ``{"params": ...}``
    layout that :func:`load_twin` expects; ``step`` distinguishes
    successive versions of the same twin (retention keeps the newest
    ``keep``).  Returns the checkpoint directory for this step.
    """
    return save(ckpt_dir, step, {"params": params}, blocking=blocking,
                keep=keep)


def load_twin(ckpt_dir: str, params_template: Pytree, *,
              step: Optional[int] = None,
              shardings: Optional[Pytree] = None) -> Pytree:
    """Restore twin weights saved by :func:`save_twin`.

    ``params_template`` supplies the pytree structure/shapes/dtypes (an
    untrained ``twin.init(key)`` works — values are discarded);
    ``step=None`` loads the newest checkpoint.  ``shardings`` optionally
    places the weights directly onto a serving mesh (normally the
    replicated placement from ``fleet_param_shardings``).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"no twin checkpoint found under {ckpt_dir!r}")
    wrapped_sh = None if shardings is None else {"params": shardings}
    return restore(ckpt_dir, step, {"params": params_template},
                   shardings=wrapped_sh)["params"]


def read_manifest(path: str) -> dict:
    """Load + validate a checkpoint manifest, raising errors that say
    exactly what is wrong with the on-disk state (missing vs truncated
    vs corrupt vs incompatible) instead of a bare ``KeyError``."""
    mpath = os.path.join(path, "manifest.json")
    if not os.path.isdir(path):
        raise FileNotFoundError(
            f"checkpoint directory {path!r} does not exist")
    if not os.path.exists(mpath):
        raise FileNotFoundError(
            f"checkpoint {path!r} has no manifest.json — the write was "
            f"interrupted before the atomic publish (or the directory "
            f"was truncated); delete it and restore an older step")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except json.JSONDecodeError as e:
        raise ValueError(
            f"checkpoint manifest {mpath!r} is corrupt (invalid JSON: "
            f"{e}) — the checkpoint cannot be trusted") from e
    if not isinstance(manifest, dict) or "leaves" not in manifest:
        raise ValueError(
            f"checkpoint manifest {mpath!r} is malformed: expected a "
            f"JSON object with a 'leaves' table, got "
            f"{type(manifest).__name__}")
    schema = manifest.get("schema", 1)   # pre-versioned manifests == v1
    if schema != SCHEMA_VERSION:
        raise ValueError(
            f"checkpoint {path!r} uses manifest schema {schema}, this "
            f"reader understands schema {SCHEMA_VERSION} — upgrade the "
            f"checkpoint (or the reader) before restoring")
    return manifest


_read_manifest = read_manifest          # pre-public-API internal name


def load_arrays(path: str):
    """Blind restore of one checkpoint directory: every leaf the
    manifest lists, as raw NumPy arrays keyed by leaf name — no
    template required.  This is the flat-snapshot reader the serving
    recovery path uses (a snapshot's structure is data, not code).

    Returns ``(arrays, manifest)``; raises the same damage taxonomy as
    :func:`read_manifest` / :func:`restore` (missing dir, interrupted
    write, corrupt manifest, truncated or corrupt arrays, shape drift
    between manifest and file).
    """
    manifest = read_manifest(path)
    arrays = {}
    for name, meta in manifest["leaves"].items():
        fpath = os.path.join(path, meta["file"])
        if not os.path.exists(fpath):
            raise FileNotFoundError(
                f"checkpoint {path!r} is truncated: manifest lists "
                f"{meta['file']!r} for leaf {name!r} but the file is "
                f"missing")
        try:
            arr = np.load(fpath)
        except (ValueError, OSError) as e:
            raise ValueError(
                f"checkpoint array {fpath!r} (leaf {name!r}) is "
                f"corrupt: {e}") from e
        if list(arr.shape) != list(meta["shape"]):
            raise ValueError(
                f"{name}: array shape {list(arr.shape)} != manifest "
                f"shape {meta['shape']} — the checkpoint is internally "
                f"inconsistent")
        arrays[name] = arr
    return arrays, manifest


def restore(ckpt_dir: str, step: int, target: Pytree,
            shardings: Optional[Pytree] = None) -> Pytree:
    """Restore into the structure of ``target``.

    ``shardings``: optional NamedSharding tree — leaves are placed directly
    onto the (possibly different) mesh via ``jax.device_put``, which is
    what makes restarts elastic across topologies.

    Raises descriptive errors for on-disk damage (missing/truncated/
    corrupt manifests or arrays — see :func:`_read_manifest`) and for
    template mismatches (a leaf the checkpoint never stored, or stored
    with a different shape).
    """
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    manifest = _read_manifest(path)["leaves"]

    named, treedef = _flatten(target)
    shard_named = None
    if shardings is not None:
        shard_named, _ = _flatten(shardings)

    out = []
    for i, (name, tgt) in enumerate(named):
        if name not in manifest:
            raise KeyError(
                f"checkpoint {path!r} has no leaf {name!r} (stores "
                f"{sorted(manifest)[:8]}{'...' if len(manifest) > 8 else ''})"
                f" — the params template does not match the saved twin")
        fpath = os.path.join(path, manifest[name]["file"])
        if not os.path.exists(fpath):
            raise FileNotFoundError(
                f"checkpoint {path!r} is truncated: manifest lists "
                f"{manifest[name]['file']!r} for leaf {name!r} but the "
                f"file is missing")
        try:
            arr = np.load(fpath)
        except (ValueError, OSError) as e:
            raise ValueError(
                f"checkpoint array {fpath!r} (leaf {name!r}) is corrupt: "
                f"{e}") from e
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(
                f"{name}: checkpoint shape {tuple(arr.shape)} != template "
                f"shape {tuple(tgt.shape)} — the checkpointed twin has a "
                f"different architecture than the params template")
        if shard_named is not None:
            out.append(jax.device_put(arr.astype(tgt.dtype),
                                      shard_named[i][1]))
        else:
            out.append(jnp.asarray(arr, dtype=tgt.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
