"""End-to-end twin experiment recipes shared by examples, benchmarks, tests.

Each recipe returns a dict of metrics so the benchmark harness can emit
one CSV row per paper table/figure entry.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.analogue import AnalogueSpec
from repro.core.backends import AnalogueBackend, FusedPallasBackend
from repro.core.losses import dtw, l1, lyapunov_time, max_lyapunov_exponent, mre
from repro.core.twin import make_autonomous_twin, make_driven_twin
from repro.data import hp_memristor as hp
from repro.data import lorenz96 as l96
from repro.train import trainer
from repro.train.optimizer import adam, warmup_cosine_schedule

HP_AMP, HP_FREQ = 2.0, 2.0
L96_DT = 0.0025


# ---------------------------------------------------------------------------
# HP memristor twin (paper Fig. 3)
# ---------------------------------------------------------------------------

def train_hp_twin(seed: int = 42, pretrain_steps: int = 400,
                  train_steps: int = 600, hidden: int = 14,
                  backend=None, hw_aware=None):
    """Train the HP twin on the sine drive (paper Methods: 500 pts, 1e-3 s).

    ``backend``: training substrate for the trajectory phase (Backend
    instance or registry name).  ``backend="fused_pallas"`` trains on the
    serving substrate — the weights-stationary kernel plus its
    reverse-time VJP; the derivative-matching warm start stays digital
    (it evaluates the bare field, no ODE solve).

    ``hw_aware``: optional :class:`repro.train.hw_aware.HwAwareConfig` —
    the trajectory phase trains through the analogue write path (STE
    quantise + programming/read noise + optional fault ensemble) so the
    weights survive deployment on the analogue substrate.  The warm
    start stays clean: it shapes the field, the trajectory phase
    hardens it.
    """
    ts, xs, vs, cur = hp.generate("sine", num_points=500, dt=1e-3,
                                  amp=HP_AMP, freq=HP_FREQ)
    ys = xs[:, None]
    twin = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=HP_AMP, freq=HP_FREQ),
                            hidden=hidden)
    params = twin.init(jax.random.PRNGKey(seed))
    params, _ = trainer.pretrain_derivatives(
        twin.field, params, ts, ys, optimizer=adam(1e-2),
        num_steps=pretrain_steps)
    params, hist = trainer.train_twin(
        twin, params, ts, ys,
        optimizer=adam(warmup_cosine_schedule(3e-3, 50, train_steps)),
        num_steps=train_steps, segment_len=50, loss="l1", noise_std=0.002,
        key=jax.random.PRNGKey(seed + 1), backend=backend,
        hw_aware=hw_aware)
    return twin, params, float(hist[-1])


def hp_waveform_config(waveform: str) -> dict:
    if waveform == "modulated_sine":
        return dict(amp=HP_AMP, freq=2 * HP_FREQ)
    return dict(amp=HP_AMP, freq=HP_FREQ)


def eval_hp_twin(twin, params, waveform: str, num_points: int = 500,
                 backend=None):
    """MRE + DTW of the twin's state trajectory vs ground truth on a drive
    it was NOT trained on (except sine).

    ``backend``: optional execution substrate (Backend instance or
    registry name) — evaluate the same trained weights digitally, through
    the simulated crossbars, or through the fused Pallas kernel.
    """
    kw = hp_waveform_config(waveform)
    ts, xw, vw, _ = hp.generate(waveform, num_points=num_points, dt=1e-3,
                                **kw)
    drive = hp.WAVEFORMS[waveform](**kw)
    field_w = dataclasses.replace(twin.field, drive=drive)
    node_w = dataclasses.replace(twin.node, field=field_w)
    if backend is not None:
        from repro.core.backends import resolve_backend
        node_w = dataclasses.replace(node_w, backend=resolve_backend(backend))
    pred = node_w.trajectory(params, xw[:1], ts)[:, 0]
    return {"mre": float(mre(pred, xw)),
            "dtw": float(dtw(pred, xw) / num_points),
            "pred": pred, "true": xw, "ts": ts}


def hp_backend_matrix(twin, params, waveform: str = "sine",
                      analogue_spec: AnalogueSpec = AnalogueSpec(),
                      seed: int = 0) -> dict:
    """The substrate-portability claim as numbers: same trained weights
    evaluated on every backend, MRE vs ground truth each time."""
    backends = {
        "digital": None,
        "fused_pallas": FusedPallasBackend(batch_tile=1),
        "analogue": AnalogueBackend(spec=analogue_spec,
                                    prog_key=jax.random.PRNGKey(seed)),
    }
    return {name: eval_hp_twin(twin, params, waveform, backend=b)["mre"]
            for name, b in backends.items()}


def train_hp_resnet(seed: int = 42, train_steps: int = 600,
                    hidden: int = 14):
    """The paper's digital baseline: recurrent ResNet, same sizes."""
    from repro.models.baselines import RecurrentResNet
    ts, xs, vs, _ = hp.generate("sine", num_points=500, dt=1e-3,
                                amp=HP_AMP, freq=HP_FREQ)
    model = RecurrentResNet(sizes=(2, hidden, hidden, 1), state_dim=1)
    params = model.init(jax.random.PRNGKey(seed))
    params, hist = trainer.train_recurrent_resnet(
        model, params, vs[:, None], xs[:, None],
        optimizer=adam(warmup_cosine_schedule(3e-3, 50, train_steps)),
        num_steps=train_steps, segment_len=50)
    return model, params, float(hist[-1])


def eval_hp_resnet(model, params, waveform: str, num_points: int = 500):
    kw = hp_waveform_config(waveform)
    ts, xw, vw, _ = hp.generate(waveform, num_points=num_points, dt=1e-3,
                                **kw)
    drive = hp.WAVEFORMS[waveform](**kw)
    us = jax.vmap(drive)(ts)[:-1, None]
    pred = model.rollout(params, xw[:1], us)[:, 0]
    return {"mre": float(mre(pred, xw)),
            "dtw": float(dtw(pred, xw) / num_points)}


# ---------------------------------------------------------------------------
# Lorenz96 twin (paper Fig. 4)
# ---------------------------------------------------------------------------

def l96_data(num_points: int = 2400, dt: float = L96_DT):
    ts, ys_raw, split = l96.generate(num_points=num_points, dt=dt)
    ys, mean, std = l96.normalize(ys_raw)
    return ts, ys, split


def train_l96_twin(seed: int = 7, pretrain_steps: int = 5000,
                   train_steps: tuple = ((60, 600, 1e-3), (200, 600, 4e-4)),
                   hidden: int = 64, tube_noise: float = 0.03,
                   data=None, backend=None, hw_aware=None):
    """Noisy-tube derivative pretraining + multiple-shooting curriculum.

    ``backend``: trajectory-phase training substrate (see
    :func:`repro.train.trainer.segment_loss_fn`).  ``hw_aware``: optional
    :class:`repro.train.hw_aware.HwAwareConfig` — the curriculum phases
    train through the analogue write path (noise-aware training)."""
    ts, ys, split = data if data is not None else l96_data()
    ts_tr, ys_tr = ts[:split], ys[:split]
    twin = make_autonomous_twin(6, hidden=hidden)
    params = twin.init(jax.random.PRNGKey(seed))

    tsm, ysm, dys = trainer.finite_difference_derivatives(ts_tr, ys_tr)

    def pre_loss(p, key):
        noise = tube_noise * jax.random.normal(key, ysm.shape)
        preds = jax.vmap(lambda t, y: twin.field(t, y, p))(tsm, ysm + noise)
        return jnp.mean(jnp.abs(preds - dys))

    params, _ = trainer.fit(
        pre_loss, params,
        adam(warmup_cosine_schedule(5e-3, 100, pretrain_steps),
             weight_decay=1e-4),
        pretrain_steps, key=jax.random.PRNGKey(seed + 1))

    for seg, steps, lr in train_steps:
        params, hist = trainer.train_twin(
            twin, params, ts_tr, ys_tr,
            optimizer=adam(warmup_cosine_schedule(lr, 50, steps),
                           weight_decay=1e-4),
            num_steps=steps, segment_len=seg, loss="l1", noise_std=0.02,
            key=jax.random.PRNGKey(seed + 2), backend=backend,
            hw_aware=hw_aware)
    return twin, params


def eval_l96_twin(twin, params, data=None):
    """Paper protocol: interpolation = closed loop from t=0 over the
    training window; extrapolation = forecast from the observation-synced
    state at the train/test split."""
    ts, ys, split = data if data is not None else l96_data()
    pred_i = twin.simulate(params, ys[0], ts[:split])
    interp = float(l1(pred_i, ys[:split]))
    pred_x = twin.simulate(params, ys[split - 1], ts[split - 1:])
    extrap = float(l1(pred_x[1:], ys[split:]))
    return {"interp_l1": interp, "extrap_l1": extrap,
            "pred_extrap": pred_x[1:], "true_extrap": ys[split:]}


def eval_l96_baseline(cell: str, seed: int = 3, train_steps: int = 2500,
                      hidden: int = 64, data=None):
    from repro.models.baselines import RecurrentForecaster
    ts, ys, split = data if data is not None else l96_data()
    model = RecurrentForecaster(cell=cell, in_dim=6, hidden=hidden, out_dim=6)
    params = model.init(jax.random.PRNGKey(seed))
    params, _ = trainer.train_forecaster(
        model, params, ys[:split],
        optimizer=adam(warmup_cosine_schedule(3e-3, 100, train_steps)),
        num_steps=train_steps, noise_std=0.01,
        key=jax.random.PRNGKey(seed + 1))
    interp = model.closed_loop(params, ys[0], split - 1)
    e_i = float(l1(interp, ys[:split]))
    extrap = model.closed_loop(params, ys[split - 1], ys.shape[0] - split,
                               warmup=ys[:split - 1])
    e_x = float(l1(extrap[1:], ys[split:]))
    return {"interp_l1": e_i, "extrap_l1": e_x}


# ---------------------------------------------------------------------------
# Analogue deployment + noise robustness (paper Fig. 4j)
# ---------------------------------------------------------------------------

def noise_robustness_grid(twin, params, read_noises, prog_noises,
                          data=None, repeats: int = 3, seed: int = 0):
    """L1 extrapolation error under (read, programming) noise combinations."""
    ts, ys, split = data if data is not None else l96_data()
    rows = []
    for pn in prog_noises:
        for rn in read_noises:
            errs = []
            for r in range(repeats):
                spec = AnalogueSpec(prog_noise=pn, read_noise=rn)
                backend = AnalogueBackend(
                    spec=spec, prog_key=jax.random.PRNGKey(seed + 101 * r),
                    read_key=jax.random.PRNGKey(seed + 13 * r + 1))
                a_twin = twin.with_backend(backend)
                pred = a_twin.simulate(params, ys[split - 1], ts[split - 1:])
                errs.append(float(l1(pred[1:], ys[split:])))
            rows.append({"prog_noise": pn, "read_noise": rn,
                         "extrap_l1": sum(errs) / len(errs)})
    return rows


# ---------------------------------------------------------------------------
# Lorenz96 fleet serving (the multi-asset scale-up scenario)
# ---------------------------------------------------------------------------

def make_l96_fleet(cfg=None, backend=None):
    """Build the Lorenz96 fleet-serving scenario: one autonomous twin at
    the paper's Fig. 4 sizes, wrapped in a :class:`~repro.core.twin.TwinFleet`
    so N assets roll out as one program (sharded across devices when a
    twin mesh is passed to ``rollout_batch``/``FleetServer``).

    ``cfg``: a ``Lorenz96FleetConfig`` (default: the registry ``FLEET``).
    ``backend``: Backend instance or registry name; ``None`` uses the
    config's choice (``fused_pallas`` with its ``batch_tile``).
    """
    from repro.configs.lorenz96_twin import FLEET
    from repro.core.twin import TwinFleet
    cfg = cfg or FLEET
    twin = make_autonomous_twin(cfg.state_dim, hidden=cfg.hidden,
                                n_hidden_layers=cfg.n_hidden_layers)
    if backend is None:
        backend = (FusedPallasBackend(batch_tile=cfg.batch_tile)
                   if cfg.backend == "fused_pallas" else cfg.backend)
    if backend is not None and backend != "digital":
        twin = twin.with_backend(backend)
    return TwinFleet(twin)


def l96_fleet_ts(cfg=None, horizon=None):
    """The serving time grid: ``horizon`` RK4 steps at the training dt
    (uniform + concrete, as the fused kernel requires)."""
    from repro.configs.lorenz96_twin import FLEET
    cfg = cfg or FLEET
    h = cfg.horizon if horizon is None else int(horizon)
    return jnp.linspace(0.0, h * cfg.dt, h + 1)


def l96_fleet_requests(cfg=None, fleet_size=None, num_batches=1, seed=0):
    """Stream request batches of per-asset initial conditions.

    Each batch is a (fleet_size, state_dim) array of sensed states drawn
    around the normalised attractor (spread from the config) — the shape
    ``serve_fleet`` consumes for an autonomous fleet.
    """
    from repro.configs.lorenz96_twin import FLEET
    cfg = cfg or FLEET
    n = cfg.fleet_size if fleet_size is None else int(fleet_size)
    for i in range(num_batches):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        yield cfg.y0_spread * jax.random.normal(key, (n, cfg.state_dim))


def l96_lyapunov_info():
    f = l96.lorenz96_field(8.0)
    from repro.core.twin import reference_trajectory
    ys = reference_trajectory(f, l96.PAPER_Y0, jnp.arange(500) * 0.02,
                              steps_per_interval=8)
    mle = max_lyapunov_exponent(f, ys[-1], None, dt=0.01, num_steps=20000,
                                renorm_every=20)
    return {"mle": float(mle), "lyapunov_time": float(lyapunov_time(mle))}
