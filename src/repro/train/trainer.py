"""Training loops for the continuous-time digital twins.

Faithful to the paper's Methods: Adam, RK4 ODESolve, adjoint-state
gradients, (soft-)DTW or L1 objectives, and random state noise as a
regulariser during training (their ref. 46).  Two practical additions,
both documented in EXPERIMENTS.md:

* multiple-shooting segmentation — the trajectory is split into segments
  that are solved in parallel from ground-truth initial states (vmap over
  segments).  This is the standard stabiliser for chaotic NODE training
  and maps perfectly onto batched TPU execution.
* derivative-matching warm start — regress f_theta(x) onto finite-
  difference derivatives before trajectory training (a cheap collocation
  pretraining that cuts trajectory epochs ~10x).

The trajectory loss is substrate-selectable (``segment_loss_fn``'s
``backend=``): the default digital path vmaps one adjoint solve per
shooting segment, while ``backend="fused_pallas"`` batches all segments
through the weights-stationary Pallas kernel and differentiates through
its reverse-time checkpoint/replay VJP — training on the substrate that
serves.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.losses import l1, soft_dtw
from repro.train.optimizer import Optimizer, apply_updates

Pytree = Any


def _step_body(loss_fn: Callable, optimizer: Optimizer, has_key: bool,
               params, opt_state, key, step=None):
    """One descent step — the shared body of both training engines.

    ``step`` (a traced int32, threaded only for losses that declare
    ``loss_fn.wants_step = True``) is the global step counter that keys
    the hardware-aware device-model noise draws — see
    :mod:`repro.train.hw_aware`."""
    if has_key:
        key, sub = jax.random.split(key)
    else:
        sub = None
    if step is None:
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, sub))(params)
    else:
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, sub, step))(params)
    updates, opt_state = optimizer.update(grads, opt_state, params)
    params = apply_updates(params, updates)
    return params, opt_state, key, loss


def _wants_step(loss_fn: Callable) -> bool:
    """Does the loss want the global step counter as a third argument?

    Step-keyed losses (hardware-aware training) opt in by setting
    ``loss_fn.wants_step = True``; the engines then carry an int32 step
    counter through the scan and call ``loss_fn(params, key, step)``.
    Plain losses keep the exact legacy engine signatures."""
    return bool(getattr(loss_fn, "wants_step", False))


def make_step_fn(loss_fn: Callable, optimizer: Optimizer,
                 has_key: bool) -> Callable:
    """Jitted single step: (params, opt_state, key) -> same + loss.

    The per-step engine — one device dispatch per optimisation step.
    Kept as the reference implementation for :func:`fit_per_step` and the
    ``train_throughput`` benchmark baseline.  For step-keyed losses
    (``loss_fn.wants_step``) the signature gains a trailing int32 step
    counter: (params, opt_state, key, step) -> same + loss."""

    if _wants_step(loss_fn):
        @jax.jit
        def step_keyed(params, opt_state, key, step):
            params, opt_state, key, loss = _step_body(
                loss_fn, optimizer, has_key, params, opt_state, key,
                step=step)
            return params, opt_state, key, step + jnp.int32(1), loss

        return step_keyed

    @jax.jit
    def step(params, opt_state, key):
        return _step_body(loss_fn, optimizer, has_key, params, opt_state, key)

    return step


def make_scan_engine(loss_fn: Callable, optimizer: Optimizer, has_key: bool,
                     donate: bool = False, unroll: int = 8) -> Callable:
    """Scan-compiled engine: (params, opt_state, key, n) -> carries + losses.

    Runs ``n`` optimisation steps as one ``lax.scan`` inside a single jit,
    so the host dispatches once per *chunk* instead of once per step —
    the paper-sized MLPs are otherwise dominated by host round-trips.
    ``n`` is static (at most two compilations per fit: full chunk +
    remainder).  ``donate=True`` donates the (params, opt_state) carry
    buffers to the chunk (in-place on accelerators; ignored on CPU).
    ``unroll`` unrolls the scan body (same ops in the same order — purely
    a loop-overhead optimisation for the tiny paper-sized step bodies).

    For step-keyed losses (``loss_fn.wants_step``, hardware-aware
    training) the engine is instead
    (params, opt_state, key, step0, n) -> carries + step0 + losses —
    the int32 global step counter rides in the scan carry so the chunk
    remains ONE jit and every device-model draw is keyed by the absolute
    step, independent of chunking.
    """

    if _wants_step(loss_fn):
        def scan_step_keyed(carry, _):
            params, opt_state, key, si = carry
            params, opt_state, key, loss = _step_body(
                loss_fn, optimizer, has_key, params, opt_state, key, step=si)
            return (params, opt_state, key, si + jnp.int32(1)), loss

        @functools.partial(jax.jit, static_argnums=4,
                           donate_argnums=(0, 1) if donate else ())
        def run_chunk_keyed(params, opt_state, key, step0, n):
            carry = (params, opt_state, key, jnp.asarray(step0, jnp.int32))
            (params, opt_state, key, step0), losses = lax.scan(
                scan_step_keyed, carry, None, length=n,
                unroll=min(unroll, n))
            return params, opt_state, key, step0, losses

        return run_chunk_keyed

    def scan_step(carry, _):
        params, opt_state, key = carry
        params, opt_state, key, loss = _step_body(
            loss_fn, optimizer, has_key, params, opt_state, key)
        return (params, opt_state, key), loss

    @functools.partial(jax.jit, static_argnums=3,
                       donate_argnums=(0, 1) if donate else ())
    def run_chunk(params, opt_state, key, n):
        (params, opt_state, key), losses = lax.scan(
            scan_step, (params, opt_state, key), None, length=n,
            unroll=min(unroll, n))
        return params, opt_state, key, losses

    return run_chunk


def fit(loss_fn: Callable, params: Pytree, optimizer: Optimizer,
        num_steps: int, key: jax.Array | None = None,
        log_every: int = 0,
        scan_chunk: int | None = None) -> tuple[Pytree, jax.Array]:
    """Generic scan-compiled full-batch descent; loss_fn(params, key) -> scalar.

    The training loop is a ``lax.scan`` over chunks of ``scan_chunk``
    steps inside one jit with donated (params, opt_state) carries; the
    host syncs only at chunk boundaries, where the chunk's stacked loss
    history comes back as one array (logging reads from it — there is no
    per-step ``float(loss)`` device sync).

    Knobs:
      ``scan_chunk`` — steps per compiled chunk.  ``None`` runs all
      ``num_steps`` in a single chunk when not logging, or chunks at the
      logging cadence otherwise; at most two compilations ever happen
      (full chunk + remainder).
      ``unroll`` — the scan body is unrolled 8× inside the chunk (see
      :func:`make_scan_engine`'s ``unroll`` parameter): same ops in the
      same order, purely loop-overhead amortisation for the tiny
      paper-sized step bodies.

    Numerics are step-for-step identical to the per-step reference loop:
    :func:`fit_per_step` is kept as the equivalence oracle
    (``tests/test_trainer.py`` pins scan ≡ per-step across chunkings,
    optimizers and keyless losses) and as the ``train_throughput``
    benchmark baseline the scan engine is ratio-gated against.

    Returns ``(params, losses)`` with ``losses`` the full (num_steps,)
    loss history.
    """
    opt_state = optimizer.init(params)
    if num_steps <= 0:
        return params, jnp.zeros((0,), jnp.float32)
    if scan_chunk is None:
        scan_chunk = num_steps if not log_every else max(log_every, 100)
    scan_chunk = max(1, min(scan_chunk, num_steps))

    donate = jax.default_backend() != "cpu"
    if donate:
        # keep the caller's buffers alive — only fit-internal carries are
        # donated chunk to chunk
        params = jax.tree_util.tree_map(jnp.copy, params)
        opt_state = jax.tree_util.tree_map(jnp.copy, opt_state)
    run_chunk = make_scan_engine(loss_fn, optimizer, key is not None,
                                 donate=donate)
    wants_step = _wants_step(loss_fn)
    step0 = jnp.asarray(0, jnp.int32)

    chunks, done = [], 0
    while done < num_steps:
        n = min(scan_chunk, num_steps - done)
        if wants_step:
            params, opt_state, key, step0, losses = run_chunk(
                params, opt_state, key, step0, n)
        else:
            params, opt_state, key, losses = run_chunk(
                params, opt_state, key, n)
        if log_every:
            hist = np.asarray(losses)       # one host sync per chunk
            for t in range(n):
                i = done + t
                if i % log_every == 0 or i == num_steps - 1:
                    print(f"  step {i:5d}  loss {hist[t]:.6f}")
        chunks.append(losses)
        done += n
    return params, jnp.concatenate(chunks)


def fit_per_step(loss_fn: Callable, params: Pytree, optimizer: Optimizer,
                 num_steps: int, key: jax.Array | None = None,
                 log_every: int = 0) -> tuple[Pytree, jax.Array]:
    """Reference per-step loop (one jitted dispatch per step).

    Superseded by the scan-compiled :func:`fit` on every hot path; kept
    as the equivalence oracle and the ``train_throughput`` baseline.
    """
    opt_state = optimizer.init(params)
    step = make_step_fn(loss_fn, optimizer, key is not None)
    wants_step = _wants_step(loss_fn)
    si = jnp.asarray(0, jnp.int32)
    losses = []
    for i in range(num_steps):
        if wants_step:
            params, opt_state, key, si, loss = step(params, opt_state,
                                                    key, si)
        else:
            params, opt_state, key, loss = step(params, opt_state, key)
        losses.append(loss)
        if log_every and (i % log_every == 0 or i == num_steps - 1):
            print(f"  step {i:5d}  loss {float(loss):.6f}")
    return params, jnp.stack(losses) if losses else jnp.zeros((0,))


# ---------------------------------------------------------------------------
# Multiple-shooting segmentation
# ---------------------------------------------------------------------------

def make_segments(ts: jax.Array, ys: jax.Array, segment_len: int):
    """Split (T,)/(T,D) into overlapping shooting segments.

    Returns (ts_seg (S, L+1), ys_seg (S, L+1, D)) where consecutive
    segments share their boundary point.
    """
    T = ts.shape[0]
    L = segment_len
    S = (T - 1) // L
    idx = jnp.arange(S)[:, None] * L + jnp.arange(L + 1)[None, :]
    return ts[idx], ys[idx]


def _segment_objective(loss: str, gamma: float, preds, ys_seg,
                       kernelised: bool = False, interpret=None,
                       precision=None):
    """Shared loss combinators over (S, L+1, D) predictions/targets.

    ``kernelised=True`` (the fused training path) routes soft-DTW through
    the wavefront Pallas kernels — forward AND the closed-form E-matrix
    backward — instead of the pure-jnp reference DP; ``precision``
    threads the backend's mixed-precision policy into the soft-DTW cost
    slab (the R/E carries stay f32 — see ``docs/kernels.md``)."""
    preds = preds.astype(jnp.float32)      # bf16 rollouts meet f32 targets
    if kernelised and loss != "l1":
        from repro.kernels import ops
        from repro.kernels.fused_ode_mlp import _default_interpret
        itp = _default_interpret() if interpret is None else interpret
        sdtw = jnp.mean(ops.soft_dtw(preds, ys_seg, gamma, itp, precision))
    elif loss != "l1":
        per_seg = jax.vmap(lambda p, t: soft_dtw(p, t, gamma))(preds, ys_seg)
        sdtw = jnp.mean(per_seg)
    if loss == "l1":
        return l1(preds, ys_seg)
    if loss == "softdtw":
        return sdtw / ys_seg.shape[1]
    if loss == "l1+softdtw":
        return l1(preds, ys_seg) + 0.1 * sdtw / ys_seg.shape[1]
    raise ValueError(loss)


def _fused_segment_loss_fn(twin, backend, ts_seg, ys_seg, loss: str,
                           gamma: float, noise_std: float, hw_aware=None):
    """Multiple-shooting loss on the fused-Pallas substrate.

    The segments become the kernel's BATCH dimension: one grid-tiled
    weights-stationary solve integrates all S shooting segments at once
    (for a driven twin each segment gets its own drive slab, sampled at
    its absolute half-step times — the per-tile-drive kernel path), and
    the reverse-time kernel carries the gradients.  Differs from the
    digital vmap path only by the substrate; the objective, segmentation
    and noise regularisation are identical.

    ``hw_aware`` (an :class:`repro.train.hw_aware.HwAwareConfig`) makes
    the loss hardware-aware: each evaluation passes ``params`` through
    the analogue write path (STE quantise + programming/read noise +
    optional faults, keyed by the global training step) before the fused
    rollout, averaged over ``k_draws`` device realisations.  The device
    model is a weight-space pre-transform, so the reverse-time VJP kernel
    is untouched; the returned loss sets ``wants_step`` so the engines
    thread the step counter.
    """
    from repro.kernels import ops
    from repro.kernels.fused_ode_mlp import pad_fleet_to_tile

    # honour the twin's solver config: RK4 only (as the serving backend
    # enforces), with steps_per_interval densifying each segment's grid
    method = getattr(twin.node, "method", "rk4")
    if method != "rk4":
        raise ValueError(
            f"fused-backend training integrates RK4 only, got {method!r}")
    sub = int(getattr(twin.node, "steps_per_interval", 1))

    S, Lp1 = ts_seg.shape[0], ts_seg.shape[1]
    tsn = np.asarray(ts_seg, dtype=np.float64)
    # uniformity judged on the VALUES (float32 diffs wobble by ~eps*t),
    # mirroring FusedPallasBackend._grid: every segment must sit on one
    # shared-dt line starting at its own offset
    dt = float(np.mean(tsn[:, -1] - tsn[:, 0]) / (Lp1 - 1))
    drift = np.abs(tsn - (tsn[:, :1] + dt * np.arange(Lp1))).max()
    tol = max(32 * np.finfo(np.float32).eps * np.abs(tsn).max(), 1e-9)
    if dt == 0 or drift > tol:
        raise ValueError(
            "fused-backend training needs a uniform time grid (shared dt "
            "across all shooting segments)")
    T_fine = (Lp1 - 1) * sub
    drive = getattr(twin.field, "drive", None)
    if drive is None:
        uh = jnp.zeros((2 * T_fine + 1, 0), jnp.float32)
    else:
        # per-segment drive sampled at each segment's absolute (fine) times
        uh = jax.vmap(lambda row: ops.half_step_drive(
            drive, jnp.linspace(row[0], row[-1], T_fine + 1)))(ts_seg)
        uh = uh.astype(jnp.float32)

    def loss_fn(params, key, step=None):
        y0s = ys_seg[:, 0]
        if noise_std > 0 and key is not None:
            y0s = y0s + noise_std * jax.random.normal(key, y0s.shape)
        # pad segments up to a tile multiple, as rollout_batch_local does
        y0p, uhp, bt, _ = pad_fleet_to_tile(y0s, uh, backend.batch_tile)

        def rollout_loss(p):
            traj = ops.fused_node_rollout(
                p, y0p, uhp, dt / sub, batch_tile=bt,
                time_chunk=backend.time_chunk, interpret=backend.interpret,
                vmem_budget_bytes=backend.vmem_budget_bytes,
                gradient="fused_vjp", precision=backend.precision)
            preds = jnp.transpose(traj[::sub, :S], (1, 0, 2))  # (S, L+1, D)
            return _segment_objective(loss, gamma, preds, ys_seg,
                                      kernelised=True,
                                      interpret=backend.interpret,
                                      precision=backend.precision)

        if hw_aware is None:
            return rollout_loss(params)
        from repro.train.hw_aware import (expectation_over_draws,
                                          hw_aware_params)
        return expectation_over_draws(
            lambda d: rollout_loss(hw_aware_params(params, hw_aware,
                                                   step, d)),
            hw_aware)

    if hw_aware is not None:
        loss_fn.wants_step = True
    return loss_fn


def segment_loss_fn(twin, ts_seg, ys_seg, loss: str = "l1",
                    gamma: float = 0.1, noise_std: float = 0.0,
                    backend=None, hw_aware=None):
    """Loss over shooting segments solved in parallel.

    ``backend``: optional execution substrate (Backend instance or
    registry name); ``None`` uses the twin's own backend.  Digital and
    analogue substrates vmap one solve per segment; the fused-Pallas
    substrate batches all segments through one weights-stationary kernel
    with the reverse-time VJP (train where you serve).

    ``hw_aware``: optional :class:`repro.train.hw_aware.HwAwareConfig`
    turning on hardware-aware training — every loss evaluation sees the
    weights through the analogue write path (STE 6-bit quantise +
    programming/read noise + optional fault ensemble), step-keyed and
    bitwise-reproducible.  Works on any differentiable substrate.
    Training directly on an ``analogue_fused``/``FusedAnalogueBackend``
    substrate implies hardware-aware mode: the policy is auto-derived
    from the backend's own spec/faults (``HwAwareConfig.from_backend``)
    and the rollout integrates on the fused digital kernel with the
    device-degraded weights — previously such training silently fell
    through to the clean digital kernel with detached device physics.
    """
    from repro.core.backends import (FusedAnalogueBackend,
                                     FusedPallasBackend, resolve_backend)

    be = resolve_backend(backend) if backend is not None else twin.backend
    if hw_aware is None and isinstance(be, FusedAnalogueBackend):
        from repro.train.hw_aware import HwAwareConfig
        hw_aware = HwAwareConfig.from_backend(be)
    if isinstance(be, FusedPallasBackend):
        return _fused_segment_loss_fn(twin, be, ts_seg, ys_seg, loss,
                                      gamma, noise_std, hw_aware)
    if backend is not None:
        twin = twin.with_backend(be)

    def loss_fn(params, key, step=None):
        y0s = ys_seg[:, 0]
        if noise_std > 0 and key is not None:
            y0s = y0s + noise_std * jax.random.normal(key, y0s.shape)

        def rollout_loss(p):
            preds = jax.vmap(lambda y0, t: twin.simulate(p, y0, t))(
                y0s, ts_seg)
            return _segment_objective(loss, gamma, preds, ys_seg)

        if hw_aware is None:
            return rollout_loss(params)
        from repro.train.hw_aware import (expectation_over_draws,
                                          hw_aware_params)
        return expectation_over_draws(
            lambda d: rollout_loss(hw_aware_params(params, hw_aware,
                                                   step, d)),
            hw_aware)

    if hw_aware is not None:
        loss_fn.wants_step = True
    return loss_fn


def train_twin(twin, params, ts: jax.Array, ys: jax.Array, *,
               optimizer: Optimizer, num_steps: int,
               segment_len: int = 50, loss: str = "l1",
               gamma: float = 0.1, noise_std: float = 0.0,
               key: jax.Array | None = None, log_every: int = 0,
               backend=None, scan_chunk: int | None = None,
               hw_aware=None):
    """Train a twin on one observed trajectory (paper's training setup).

    ``backend`` selects the training substrate (see
    :func:`segment_loss_fn`): ``backend="fused_pallas"`` (or a
    ``FusedPallasBackend`` instance) runs every forward AND backward
    solve through the weights-stationary Pallas kernels.  The backend's
    ``precision`` policy rides along — e.g.
    ``backend=FusedPallasBackend(precision="bf16_f32acc")`` trains on
    the reduced-precision substrate (bf16 slabs, f32 accumulation; the
    loss and optimizer state stay f32).

    ``hw_aware`` (an :class:`repro.train.hw_aware.HwAwareConfig`) trains
    noise-aware weights: every loss evaluation passes ``params`` through
    the analogue write path first — 6-bit quantise-dequantise under a
    straight-through estimator, programming + read noise from the
    kernels' counter-derived stream keyed by the global step, optional
    stuck-cell/drift ensemble — averaged over ``k_draws`` realisations.
    The fit stays one scan-compiled jit; same seed ⇒ bitwise-identical
    loss history.
    """
    ts_seg, ys_seg = make_segments(ts, ys, segment_len)
    loss_fn = segment_loss_fn(twin, ts_seg, ys_seg, loss, gamma, noise_std,
                              backend=backend, hw_aware=hw_aware)
    if key is None:
        key = jax.random.PRNGKey(0)
    return fit(loss_fn, params, optimizer, num_steps, key, log_every,
               scan_chunk=scan_chunk)


# ---------------------------------------------------------------------------
# Derivative-matching warm start (collocation pretraining)
# ---------------------------------------------------------------------------

def finite_difference_derivatives(ts: jax.Array, ys: jax.Array):
    """Central differences on the interior points: (T-2,) ts, ys, dys."""
    dt = ts[2:] - ts[:-2]
    dys = (ys[2:] - ys[:-2]) / dt[:, None]
    return ts[1:-1], ys[1:-1], dys


def derivative_matching_loss(field, ts_mid, ys_mid, dys):
    def loss_fn(params, key):
        del key
        preds = jax.vmap(lambda t, y: field(t, y, params))(ts_mid, ys_mid)
        return jnp.mean(jnp.abs(preds - dys))
    return loss_fn


def pretrain_derivatives(field, params, ts, ys, *, optimizer,
                         num_steps: int, log_every: int = 0):
    ts_mid, ys_mid, dys = finite_difference_derivatives(ts, ys)
    loss_fn = derivative_matching_loss(field, ts_mid, ys_mid, dys)
    return fit(loss_fn, params, optimizer, num_steps, log_every=log_every)


# ---------------------------------------------------------------------------
# Baseline training (teacher-forced recurrent forecasters / ResNet)
# ---------------------------------------------------------------------------

def train_forecaster(model, params, ys: jax.Array, *, optimizer,
                     num_steps: int, noise_std: float = 0.0,
                     key: jax.Array | None = None, log_every: int = 0):
    def loss_fn(params, key):
        inp = ys
        if noise_std > 0 and key is not None:
            inp = ys + noise_std * jax.random.normal(key, ys.shape)
        preds = model.teacher_forced(params, inp)
        return l1(preds, ys[1:])
    if key is None:
        key = jax.random.PRNGKey(0)
    return fit(loss_fn, params, optimizer, num_steps, key, log_every)


def train_recurrent_resnet(model, params, us: jax.Array, ys: jax.Array, *,
                           optimizer, num_steps: int,
                           segment_len: int = 50,
                           key: jax.Array | None = None, log_every: int = 0):
    """Teacher-forced segment training of h_{t+1} = h_t + f([u_t, h_t])."""
    T = ys.shape[0]
    L = segment_len
    S = (T - 1) // L
    idx = jnp.arange(S)[:, None] * L + jnp.arange(L + 1)[None, :]
    ys_seg = ys[idx]                      # (S, L+1, D)
    us_seg = us[idx[:, :-1]]              # (S, L, U)

    def loss_fn(params, key):
        del key
        preds = jax.vmap(lambda y0, u: model.rollout(params, y0, u))(
            ys_seg[:, 0], us_seg)
        return l1(preds, ys_seg)

    return fit(loss_fn, params, optimizer, num_steps, key, log_every)
