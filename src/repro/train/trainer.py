"""Training loops for the continuous-time digital twins.

Faithful to the paper's Methods: Adam, RK4 ODESolve, adjoint-state
gradients, (soft-)DTW or L1 objectives, and random state noise as a
regulariser during training (their ref. 46).  Two practical additions,
both documented in EXPERIMENTS.md:

* multiple-shooting segmentation — the trajectory is split into segments
  that are solved in parallel from ground-truth initial states (vmap over
  segments).  This is the standard stabiliser for chaotic NODE training
  and maps perfectly onto batched TPU execution.
* derivative-matching warm start — regress f_theta(x) onto finite-
  difference derivatives before trajectory training (a cheap collocation
  pretraining that cuts trajectory epochs ~10x).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.losses import l1, soft_dtw
from repro.train.optimizer import Optimizer, apply_updates

Pytree = Any


def fit(loss_fn: Callable, params: Pytree, optimizer: Optimizer,
        num_steps: int, key: jax.Array | None = None,
        log_every: int = 0) -> tuple[Pytree, jax.Array]:
    """Generic jitted full-batch descent; loss_fn(params, key) -> scalar."""
    opt_state = optimizer.init(params)

    @jax.jit
    def step(params, opt_state, key):
        if key is not None:
            key, sub = jax.random.split(key)
        else:
            sub = None
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, sub))(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, key, loss

    losses = []
    for i in range(num_steps):
        params, opt_state, key, loss = step(params, opt_state, key)
        losses.append(loss)
        if log_every and (i % log_every == 0 or i == num_steps - 1):
            print(f"  step {i:5d}  loss {float(loss):.6f}")
    return params, jnp.stack(losses)


# ---------------------------------------------------------------------------
# Multiple-shooting segmentation
# ---------------------------------------------------------------------------

def make_segments(ts: jax.Array, ys: jax.Array, segment_len: int):
    """Split (T,)/(T,D) into overlapping shooting segments.

    Returns (ts_seg (S, L+1), ys_seg (S, L+1, D)) where consecutive
    segments share their boundary point.
    """
    T = ts.shape[0]
    L = segment_len
    S = (T - 1) // L
    idx = jnp.arange(S)[:, None] * L + jnp.arange(L + 1)[None, :]
    return ts[idx], ys[idx]


def segment_loss_fn(twin, ts_seg, ys_seg, loss: str = "l1",
                    gamma: float = 0.1, noise_std: float = 0.0):
    """Loss over shooting segments solved in parallel (vmap)."""

    def loss_fn(params, key):
        y0s = ys_seg[:, 0]
        if noise_std > 0 and key is not None:
            y0s = y0s + noise_std * jax.random.normal(key, y0s.shape)
        preds = jax.vmap(lambda y0, t: twin.simulate(params, y0, t))(
            y0s, ts_seg)
        if loss == "l1":
            return l1(preds, ys_seg)
        if loss == "softdtw":
            per_seg = jax.vmap(lambda p, t: soft_dtw(p, t, gamma))(
                preds, ys_seg)
            return jnp.mean(per_seg) / ys_seg.shape[1]
        if loss == "l1+softdtw":
            per_seg = jax.vmap(lambda p, t: soft_dtw(p, t, gamma))(
                preds, ys_seg)
            return l1(preds, ys_seg) + 0.1 * jnp.mean(per_seg) / ys_seg.shape[1]
        raise ValueError(loss)

    return loss_fn


def train_twin(twin, params, ts: jax.Array, ys: jax.Array, *,
               optimizer: Optimizer, num_steps: int,
               segment_len: int = 50, loss: str = "l1",
               gamma: float = 0.1, noise_std: float = 0.0,
               key: jax.Array | None = None, log_every: int = 0):
    """Train a twin on one observed trajectory (paper's training setup)."""
    ts_seg, ys_seg = make_segments(ts, ys, segment_len)
    loss_fn = segment_loss_fn(twin, ts_seg, ys_seg, loss, gamma, noise_std)
    if key is None:
        key = jax.random.PRNGKey(0)
    return fit(loss_fn, params, optimizer, num_steps, key, log_every)


# ---------------------------------------------------------------------------
# Derivative-matching warm start (collocation pretraining)
# ---------------------------------------------------------------------------

def finite_difference_derivatives(ts: jax.Array, ys: jax.Array):
    """Central differences on the interior points: (T-2,) ts, ys, dys."""
    dt = ts[2:] - ts[:-2]
    dys = (ys[2:] - ys[:-2]) / dt[:, None]
    return ts[1:-1], ys[1:-1], dys


def derivative_matching_loss(field, ts_mid, ys_mid, dys):
    def loss_fn(params, key):
        del key
        preds = jax.vmap(lambda t, y: field(t, y, params))(ts_mid, ys_mid)
        return jnp.mean(jnp.abs(preds - dys))
    return loss_fn


def pretrain_derivatives(field, params, ts, ys, *, optimizer,
                         num_steps: int, log_every: int = 0):
    ts_mid, ys_mid, dys = finite_difference_derivatives(ts, ys)
    loss_fn = derivative_matching_loss(field, ts_mid, ys_mid, dys)
    return fit(loss_fn, params, optimizer, num_steps, log_every=log_every)


# ---------------------------------------------------------------------------
# Baseline training (teacher-forced recurrent forecasters / ResNet)
# ---------------------------------------------------------------------------

def train_forecaster(model, params, ys: jax.Array, *, optimizer,
                     num_steps: int, noise_std: float = 0.0,
                     key: jax.Array | None = None, log_every: int = 0):
    def loss_fn(params, key):
        inp = ys
        if noise_std > 0 and key is not None:
            inp = ys + noise_std * jax.random.normal(key, ys.shape)
        preds = model.teacher_forced(params, inp)
        return l1(preds, ys[1:])
    if key is None:
        key = jax.random.PRNGKey(0)
    return fit(loss_fn, params, optimizer, num_steps, key, log_every)


def train_recurrent_resnet(model, params, us: jax.Array, ys: jax.Array, *,
                           optimizer, num_steps: int,
                           segment_len: int = 50,
                           key: jax.Array | None = None, log_every: int = 0):
    """Teacher-forced segment training of h_{t+1} = h_t + f([u_t, h_t])."""
    T = ys.shape[0]
    L = segment_len
    S = (T - 1) // L
    idx = jnp.arange(S)[:, None] * L + jnp.arange(L + 1)[None, :]
    ys_seg = ys[idx]                      # (S, L+1, D)
    us_seg = us[idx[:, :-1]]              # (S, L, U)

    def loss_fn(params, key):
        del key
        preds = jax.vmap(lambda y0, u: model.rollout(params, y0, u))(
            ys_seg[:, 0], us_seg)
        return l1(preds, ys_seg)

    return fit(loss_fn, params, optimizer, num_steps, key, log_every)
