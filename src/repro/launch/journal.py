"""Write-ahead journal + atomic snapshots for the streaming twin server.

`StreamingFleetServer` holds an entire resident population's carried ODE
state in volatile memory; this module is what survives the process dying
mid-pump.  Two artifacts per serving directory:

  ``journal.wal``   an append-only log of every externally visible event
                    (``register`` / ``submit`` / ``shed`` / ``expire`` /
                    ``quarantine`` / ``commit`` / ``complete``), each
                    record CRC-framed and fsync'd before the caller is
                    acknowledged;
  ``snapshots/``    periodic full-state checkpoints (hot slab flushed to
                    host, queue/partials/stats serialised) written with
                    :mod:`repro.train.checkpoint`'s tmp+rename protocol
                    and manifest schema, so recovery inherits its damage
                    taxonomy (interrupted write vs corrupt vs truncated)
                    for free.

Frame format — ``<u32 payload_len LE><u32 crc32 LE><payload>`` with the
payload a compact-JSON record.  A process death mid-``write`` leaves a
**torn tail**: a final frame whose length header, CRC or JSON does not
check out.  The reader stops at the first bad frame and reports the torn
byte count; :class:`Journal` truncates the tail before reopening for
append.  This is safe precisely because appends are acknowledged only
after fsync — every record anyone was ever *told* about is a complete,
CRC-valid frame, so dropping the tail can only drop work nobody was
promised.

Recovery = newest loadable snapshot + deterministic replay of the
journal suffix (``fleet_serving.StreamingFleetServer.recover``).  The
journal stores *decisions* (which requests, which tier, which window),
not trajectories: the serving loop's determinism contract — f32(f64(t0 +
dt·k)) time grids keyed by each twin's global step, analogue read noise
replayed by absolute step — makes re-executing a recorded decision
bitwise-identical to the first execution, which is what keeps the
journal tiny (tens of bytes per request) at ODE-solver throughput.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.launch import chaos
from repro.train import checkpoint as ckpt_lib

_FRAME = struct.Struct("<II")           # payload length, crc32(payload)

JOURNAL_NAME = "journal.wal"
SNAPSHOT_DIR = "snapshots"

#: Journal record-stream schema.  The config header pins it; readers
#: refuse a journal from a different schema instead of mis-replaying.
JOURNAL_SCHEMA = 1


def read_journal(path: str) -> Tuple[List[dict], int, int]:
    """Scan a journal: ``(records, valid_bytes, torn_bytes)``.

    Decodes frames until the first damaged one (short header, short
    payload, CRC mismatch, or invalid JSON) and treats everything from
    there on as the torn tail of an interrupted append.  A missing file
    is an empty journal, not an error.
    """
    if not os.path.exists(path):
        return [], 0, 0
    with open(path, "rb") as f:
        data = f.read()
    records: List[dict] = []
    off = 0
    while off + _FRAME.size <= len(data):
        length, crc = _FRAME.unpack_from(data, off)
        start = off + _FRAME.size
        payload = data[start:start + length]
        if len(payload) < length or zlib.crc32(payload) != crc:
            break
        try:
            rec = json.loads(payload.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            break
        records.append(rec)
        off = start + length
    return records, off, len(data) - off


class Journal:
    """Append-only CRC-framed record log with fsync durability.

    Opening an existing journal truncates any torn tail and resumes
    appending after the last valid record; ``lsn`` is the count of valid
    records (== the index the next append receives).  ``fsync=False``
    trades durability for latency (the recovery benchmark measures the
    gap); ``append(..., sync=False)`` + one :meth:`sync` is the group-
    commit pattern the pump uses for its record bursts.
    """

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = path
        self.fsync = bool(fsync)
        self.records, valid, torn = read_journal(path)
        self.torn_bytes_dropped = torn
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "ab")
        if torn:
            self._f.truncate(valid)
        self.lsn = len(self.records)

    def append(self, rec: dict, *, sync: Optional[bool] = None) -> int:
        """Durably append one record; returns its lsn."""
        payload = json.dumps(rec, separators=(",", ":")).encode("utf-8")
        frame = _FRAME.pack(len(payload), zlib.crc32(payload)) + payload

        def torn_write():
            # the damage a mid-write death leaves: half a frame, flushed
            self._f.write(frame[: _FRAME.size + max(1, len(payload) // 2)])
            self._f.flush()
            os.fsync(self._f.fileno())

        chaos.kill_point("journal:torn_append", torn_write)
        self._f.write(frame)
        self._f.flush()
        if self.fsync if sync is None else sync:
            os.fsync(self._f.fileno())
        self.records.append(rec)
        self.lsn += 1
        return self.lsn - 1

    def sync(self) -> None:
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self.sync()
            self._f.close()

    @property
    def nbytes(self) -> int:
        return self._f.tell()


# ---------------------------------------------------------------------------
# Snapshots: full-state checkpoints on the journal's lsn axis
# ---------------------------------------------------------------------------

def write_snapshot(serve_dir: str, lsn: int, arrays: Dict[str, np.ndarray],
                   extra: dict, *, keep: int = 3) -> str:
    """Atomically publish a snapshot covering journal records [0, lsn).

    Reuses :func:`repro.train.checkpoint.save` verbatim — tmp dir +
    fsync'd arrays + manifest + ``os.replace`` — with the journal lsn as
    the checkpoint "step", so snapshot ordering, retention and the
    damage taxonomy are the train checkpointer's.  ``extra`` carries the
    host-side server state (queue, partials, stats) inside the manifest.
    """
    snap_dir = os.path.join(serve_dir, SNAPSHOT_DIR)
    os.makedirs(snap_dir, exist_ok=True)
    return ckpt_lib.save(snap_dir, lsn, dict(arrays), keep=keep,
                         extra=extra)


def load_latest_snapshot(serve_dir: str
                         ) -> Optional[Tuple[int, Dict[str, np.ndarray],
                                             dict]]:
    """Newest *loadable* snapshot as ``(lsn, arrays, extra)``.

    Snapshots are tried newest-first; a damaged one (interrupted write,
    corrupt manifest, truncated arrays) is skipped with the next-older
    tried instead — the atomic publish protocol means damage can only
    be environmental, and an older consistent snapshot plus a longer
    journal replay is always a correct recovery.  Returns ``None`` when
    no snapshot directory exists (journal-only recovery); raises only
    when snapshots exist but none is loadable.
    """
    snap_dir = os.path.join(serve_dir, SNAPSHOT_DIR)
    steps = ckpt_lib.all_steps(snap_dir)
    if not steps:
        return None
    errors = []
    for lsn in reversed(steps):
        path = os.path.join(snap_dir, f"step_{lsn:010d}")
        try:
            arrays, manifest = ckpt_lib.load_arrays(path)
        except (FileNotFoundError, ValueError) as e:
            errors.append(f"{path}: {e}")
            continue
        return lsn, arrays, manifest.get("extra", {})
    raise ValueError(
        "every snapshot under {!r} is damaged:\n  {}".format(
            snap_dir, "\n  ".join(errors)))


def journal_path(serve_dir: str) -> str:
    return os.path.join(serve_dir, JOURNAL_NAME)


def json_floats(x) -> list:
    """Lossless f32 -> JSON: Python floats (f64) round-trip any float32
    exactly, so journalled initial conditions replay bitwise."""
    return [float(v) for v in np.asarray(x, np.float32).reshape(-1)]


def from_json_floats(vals, shape) -> np.ndarray:
    return np.asarray(vals, np.float32).reshape(shape)
