"""Sharded fleet serving: many digital twins, many devices, one program.

The paper's Industry-4.0 pitch is serving *fleets* of twins — one trained
neural ODE, thousands of physical assets, each with its own sensed state
and stimulus (Hartmann 2023, arXiv:2311.14691; Fuller et al. 2019,
arXiv:1911.01276).  A fleet rollout is embarrassingly parallel across
assets, so the multi-device mapping is the weights-stationary layout one
level up:

  * the trained weights are **replicated** onto every device (each
    device is "a crossbar chip" holding the full twin);
  * the fleet axis (``y0s``, per-twin ``drive_params``) is **sharded**
    over a 1-D ``("twins",)`` mesh with ``shard_map``;
  * each device runs its slice through the backend's single-device
    fleet implementation (``rollout_batch_local`` — vmap for
    digital/analogue, the fused-Pallas grid for TPU), with zero
    cross-device traffic during the solve;
  * uneven fleet sizes are padded up to a multiple of the shard count
    and the padded trajectories are dropped before results are returned
    (``pad_fleet_inputs`` also hands back the real-row mask for callers
    that keep padded outputs).

On a 1-device host the mesh is trivial and the sharded path runs the
identical program (same numerics — pinned by
``tests/test_fleet_serving.py``); on a pod it scales linearly in devices.

Layers (bottom-up):

  ``shard_rollout_batch``  backend-level shard_map wrapper (called by
                           ``Backend.rollout_batch(mesh=...)``)
  ``FleetServer``          programmed server: weights replicated once,
                           request batches in, trajectories out
  ``serve_fleet``          end-to-end pipeline: checkpoint -> server ->
                           streamed request batches -> gathered results

CLI smoke (Lorenz96 fleet, trivial mesh on CPU):

  PYTHONPATH=src python -m repro.launch.fleet_serving --fleet 256 \
      --horizon 100 --batches 2
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time
from typing import Any, Callable, Iterable, Iterator, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import TWIN_AXIS, make_twin_mesh, twin_shard_count
from repro.launch.sharding import (fleet_input_shardings,
                                   fleet_param_shardings)
from repro.train import checkpoint as ckpt_lib

Pytree = Any
Request = Union[jax.Array, tuple]


# ---------------------------------------------------------------------------
# Uneven-N padding
# ---------------------------------------------------------------------------

def padded_size(n: int, n_shards: int) -> int:
    """Smallest multiple of ``n_shards`` >= n."""
    return -(-n // n_shards) * n_shards


def pad_fleet_inputs(y0s: jax.Array,
                     drive_params: Optional[jax.Array],
                     n_shards: int):
    """Pad the fleet axis up to a multiple of the shard count.

    Padding rows replicate the LAST real asset (in-distribution values —
    a padded lane can never overflow into inf/NaN that a zero-filled
    state might, and its trajectory is discarded anyway).  Returns
    ``(y0s_padded, drive_params_padded, mask)`` where ``mask`` is a
    length-``padded_size`` bool vector marking the real rows; slicing the
    result back to ``mask.sum()`` rows undoes the padding exactly.
    """
    n = y0s.shape[0]
    if drive_params is not None and drive_params.shape[0] != n:
        raise ValueError(
            f"drive_params batch {drive_params.shape[0]} != y0s batch {n}")
    np_ = padded_size(n, n_shards)
    mask = np.arange(np_) < n

    def pad(x):
        if x is None or np_ == n:
            return x
        tail = jnp.repeat(x[-1:], np_ - n, axis=0)
        return jnp.concatenate([x, tail], axis=0)

    return pad(y0s), pad(drive_params), mask


# ---------------------------------------------------------------------------
# shard_map wrapper (the Backend.rollout_batch(mesh=...) implementation)
# ---------------------------------------------------------------------------

def shard_rollout_batch(backend, state, y0s: jax.Array, ts: jax.Array, *,
                        mesh, drive_family: Optional[Callable] = None,
                        drive_params: Optional[jax.Array] = None,
                        **solver_kw) -> jax.Array:
    """Shard a fleet rollout over the twin axis of ``mesh``.

    ``backend``/``state``: a programmed execution substrate (see
    :mod:`repro.core.backends`) — the state's weights are closed over,
    i.e. replicated to every device.  ``y0s`` (N, D) and optional
    ``drive_params`` (N, ...) are split along dim 0; each device calls
    ``backend.rollout_batch_local`` on its slice, so the per-device
    program is exactly the single-device one.  N that does not divide the
    shard count is padded (see :func:`pad_fleet_inputs`) and the padded
    trajectories are dropped before returning (N, T+1, D).

    ``solver_kw`` forwards verbatim to every device's
    ``rollout_batch_local`` — including the fused backend's
    ``precision=`` override, so a sharded fleet can serve the bf16
    substrate (half the replicated-weight bytes and per-device slab
    traffic) with one keyword.
    """
    n_shards = twin_shard_count(mesh)
    n = y0s.shape[0]
    y0s_p, dp_p, _ = pad_fleet_inputs(y0s, drive_params, n_shards)

    def per_device(y_loc, dp_loc):
        return backend.rollout_batch_local(
            state, y_loc, ts, drive_family=drive_family,
            drive_params=dp_loc, **solver_kw)

    if dp_p is None:
        sharded = shard_map(lambda y: per_device(y, None), mesh=mesh,
                            in_specs=P(TWIN_AXIS),
                            out_specs=P(TWIN_AXIS), check_rep=False)
        out = sharded(y0s_p)
    else:
        sharded = shard_map(per_device, mesh=mesh,
                            in_specs=(P(TWIN_AXIS), P(TWIN_AXIS)),
                            out_specs=P(TWIN_AXIS), check_rep=False)
        out = sharded(y0s_p, dp_p)
    return out[:n]


# ---------------------------------------------------------------------------
# Programmed fleet server
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetServer:
    """A twin fleet programmed for serving: weights placed once,
    request batches streamed through a cached compiled program.

    Construction replicates ``params`` onto every device of the twin
    mesh (the "program the crossbars" step at datacentre scale) and
    freezes the time grid; each :meth:`serve` call pads + shards the
    request batch, runs the jitted sharded rollout (compiled once per
    padded batch shape) and returns the unpadded trajectories.
    """
    fleet: Any                        # repro.core.twin.TwinFleet
    params: Pytree
    ts: Any                           # concrete uniform time grid
    mesh: Any = None                  # None -> all visible devices

    def __post_init__(self):
        if self.mesh is None:
            self.mesh = make_twin_mesh()
        self.ts = jnp.asarray(np.asarray(self.ts))   # concrete for Pallas
        self.params = jax.device_put(
            self.params, fleet_param_shardings(self.mesh, self.params))
        fleet, ts, mesh = self.fleet, self.ts, self.mesh
        self._rollout = jax.jit(
            lambda p, y0s, thetas: fleet.rollout_batch(p, y0s, ts, thetas,
                                                       mesh=mesh))

    @property
    def n_shards(self) -> int:
        return twin_shard_count(self.mesh)

    def serve(self, y0s: jax.Array,
              drive_params: Optional[jax.Array] = None) -> jax.Array:
        """Roll out one request batch -> (N, T+1, D) trajectories."""
        n = y0s.shape[0]
        y0s_p, dp_p, _ = pad_fleet_inputs(
            jnp.asarray(y0s),
            None if drive_params is None else jnp.asarray(drive_params),
            self.n_shards)
        place = fleet_input_shardings(self.mesh, {"y": y0s_p})["y"]
        y0s_p = jax.device_put(y0s_p, place)
        if dp_p is not None:
            dp_p = jax.device_put(
                dp_p, fleet_input_shardings(self.mesh, {"d": dp_p})["d"])
        return self._rollout(self.params, y0s_p, dp_p)[:n]


def serve_fleet(ckpt_dir: str, fleet, ts, requests: Iterable[Request], *,
                step: Optional[int] = None, mesh=None,
                params_template: Optional[Pytree] = None,
                init_key: Optional[jax.Array] = None
                ) -> Iterator[jax.Array]:
    """End-to-end serving pipeline over a stream of request batches.

    checkpoint load (:func:`repro.train.checkpoint.load_twin`) ->
    weights replicated onto the twin mesh once (:class:`FleetServer`) ->
    each request batch padded, sharded, rolled out -> trajectories
    yielded in order.

    ``requests`` yields either ``y0s`` arrays (autonomous fleets) or
    ``(y0s, drive_params)`` tuples (driven fleets).  ``params_template``
    gives the weight pytree structure for the restore; by default it is
    built with ``fleet.twin.init`` (``init_key`` seeds it — structure
    and shapes are all that matter, the values are overwritten).
    """
    if params_template is None:
        key = init_key if init_key is not None else jax.random.PRNGKey(0)
        params_template = fleet.twin.init(key)
    params = ckpt_lib.load_twin(ckpt_dir, params_template, step=step)
    server = FleetServer(fleet, params, ts, mesh=mesh)
    for req in requests:
        y0s, thetas = req if isinstance(req, tuple) else (req, None)
        yield server.serve(y0s, thetas)


# ---------------------------------------------------------------------------
# CLI smoke: the Lorenz96 fleet workload on whatever devices exist
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve a Lorenz96 twin fleet over the local twin mesh")
    ap.add_argument("--fleet", type=int, default=256,
                    help="assets per request batch")
    ap.add_argument("--horizon", type=int, default=100,
                    help="RK4 steps per rollout")
    ap.add_argument("--batches", type=int, default=2,
                    help="request batches to stream")
    ap.add_argument("--backend", default="fused_pallas",
                    choices=["digital", "fused_pallas"])
    ap.add_argument("--precision", default=None,
                    choices=["f32", "bf16", "bf16_f32acc"],
                    help="fused-substrate mixed-precision policy "
                         "(default: auto — bf16_f32acc on TPU, f32 "
                         "elsewhere)")
    ap.add_argument("--ckpt-dir", default="",
                    help="trained-twin checkpoint (default: untrained "
                         "weights saved to a temp dir — substrate smoke)")
    args = ap.parse_args(argv)

    from repro.train import recipes
    backend = args.backend
    if args.precision is not None:
        if backend != "fused_pallas":
            ap.error("--precision is a fused-substrate policy; it does "
                     "not apply to --backend digital")
        from repro.core.backends import FusedPallasBackend
        backend = FusedPallasBackend(precision=args.precision)
    fleet = recipes.make_l96_fleet(backend=backend)
    ts = recipes.l96_fleet_ts(horizon=args.horizon)
    mesh = make_twin_mesh()
    print(f"mesh: {twin_shard_count(mesh)} device(s) on axis '{TWIN_AXIS}'; "
          f"backend {args.backend} precision "
          f"{'n/a' if args.backend == 'digital' else args.precision or 'auto'}")

    ckpt_dir = args.ckpt_dir
    if not ckpt_dir:
        ckpt_dir = tempfile.mkdtemp(prefix="l96_fleet_ckpt_")
        params = fleet.twin.init(jax.random.PRNGKey(0))
        ckpt_lib.save_twin(ckpt_dir, params)
        print(f"no --ckpt-dir: saved untrained twin to {ckpt_dir}")

    reqs = list(recipes.l96_fleet_requests(fleet_size=args.fleet,
                                           num_batches=args.batches))
    t0 = time.perf_counter()
    outs = []
    for i, traj in enumerate(serve_fleet(ckpt_dir, fleet, ts, reqs,
                                         mesh=mesh)):
        traj = jax.block_until_ready(traj)
        outs.append(traj)
        dt_s = time.perf_counter() - t0
        rate = (i + 1) * args.fleet * args.horizon / dt_s
        print(f"  batch {i}: {tuple(traj.shape)} trajectories "
              f"({rate:,.0f} twin-steps/s cumulative)")
    assert all(bool(jnp.isfinite(o).all()) for o in outs)
    print(f"served {args.batches} x {args.fleet} twins x {args.horizon} "
          f"steps in {time.perf_counter() - t0:.2f}s")
    return outs


if __name__ == "__main__":
    main()
