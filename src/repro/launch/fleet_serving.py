"""Sharded fleet serving: many digital twins, many devices, one program.

The paper's Industry-4.0 pitch is serving *fleets* of twins — one trained
neural ODE, thousands of physical assets, each with its own sensed state
and stimulus (Hartmann 2023, arXiv:2311.14691; Fuller et al. 2019,
arXiv:1911.01276).  A fleet rollout is embarrassingly parallel across
assets, so the multi-device mapping is the weights-stationary layout one
level up:

  * the trained weights are **replicated** onto every device (each
    device is "a crossbar chip" holding the full twin);
  * the fleet axis (``y0s``, per-twin ``drive_params``) is **sharded**
    over a 1-D ``("twins",)`` mesh with ``shard_map``;
  * each device runs its slice through the backend's single-device
    fleet implementation (``rollout_batch_local`` — vmap for
    digital/analogue, the fused-Pallas grid for TPU), with zero
    cross-device traffic during the solve;
  * uneven fleet sizes are padded up to a multiple of the shard count
    and the padded trajectories are dropped before results are returned
    (``pad_fleet_inputs`` also hands back the real-row mask for callers
    that keep padded outputs).

On a 1-device host the mesh is trivial and the sharded path runs the
identical program (same numerics — pinned by
``tests/test_fleet_serving.py``); on a pod it scales linearly in devices.

Layers (bottom-up):

  ``shard_rollout_batch``  backend-level shard_map wrapper (called by
                           ``Backend.rollout_batch(mesh=...)``)
  ``FleetServer``          programmed server: weights replicated once,
                           request batches in, trajectories out
  ``serve_fleet``          end-to-end pipeline: checkpoint -> server ->
                           streamed request batches -> gathered results

CLI smoke (Lorenz96 fleet, trivial mesh on CPU):

  PYTHONPATH=src python -m repro.launch.fleet_serving --fleet 256 \
      --horizon 100 --batches 2
"""
from __future__ import annotations

import argparse
import copy
import dataclasses
import os
import tempfile
import time
from typing import Any, Callable, Iterable, Iterator, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.backends import (AnalogueBackend, DigitalBackend,
                                 FusedAnalogueBackend, FusedPallasBackend,
                                 _with_drive, resolve_backend)
from repro.launch import chaos
from repro.launch import journal as journal_lib
from repro.launch.mesh import TWIN_AXIS, make_twin_mesh, twin_shard_count
from repro.launch.sharding import (fleet_input_shardings,
                                   fleet_param_shardings)
from repro.launch.state_store import StoreStats, TwinStateStore
from repro.train import checkpoint as ckpt_lib

Pytree = Any
Request = Union[jax.Array, tuple]


# ---------------------------------------------------------------------------
# Front-door input validation
# ---------------------------------------------------------------------------

def validate_fleet_request(caller: str, y0s=None, ts=None,
                           drive_params=None) -> None:
    """Reject malformed serving inputs with errors naming the offending
    argument — a NaN initial condition or a backwards time grid would
    otherwise propagate silently through the whole rollout and surface
    as garbage trajectories.

    Value checks only run on concrete arrays: traced inputs (the jitted
    serving path) skip them, so this is free inside jit — callers
    validate at the host-side front door (``FleetServer.serve``) where
    values exist.
    """
    for name, x in (("y0s", y0s), ("drive_params", drive_params)):
        if x is None:
            continue
        x = jnp.asarray(x)
        if not jnp.issubdtype(x.dtype, jnp.floating):
            raise ValueError(
                f"{caller}: {name} has non-floating dtype {x.dtype}")
        if (not isinstance(x, jax.core.Tracer)
                and not bool(jnp.isfinite(x).all())):
            bad = int(jnp.sum(~jnp.isfinite(x)))
            raise ValueError(
                f"{caller}: {name} contains {bad} non-finite "
                f"(NaN/Inf) value(s) — rejecting the request instead of "
                f"rolling garbage through the fleet")
    if ts is not None and not isinstance(jnp.asarray(ts), jax.core.Tracer):
        tsn = np.asarray(ts)
        if tsn.ndim != 1 or tsn.size < 2:
            raise ValueError(
                f"{caller}: ts must be a 1-D time grid with >= 2 points, "
                f"got shape {tsn.shape}")
        if not bool(np.isfinite(tsn).all()):
            raise ValueError(f"{caller}: ts contains non-finite values")
        if not bool((np.diff(tsn) > 0).all()):
            raise ValueError(
                f"{caller}: ts must be strictly increasing (non-monotone "
                f"time grids silently break the fixed-step integrators)")


# ---------------------------------------------------------------------------
# Uneven-N padding
# ---------------------------------------------------------------------------

def padded_size(n: int, n_shards: int) -> int:
    """Smallest multiple of ``n_shards`` >= n."""
    return -(-n // n_shards) * n_shards


def pad_fleet_inputs(y0s: jax.Array,
                     drive_params: Optional[jax.Array],
                     n_shards: int):
    """Pad the fleet axis up to a multiple of the shard count.

    Padding rows replicate the LAST real asset (in-distribution values —
    a padded lane can never overflow into inf/NaN that a zero-filled
    state might, and its trajectory is discarded anyway).  Returns
    ``(y0s_padded, drive_params_padded, mask)`` where ``mask`` is a
    length-``padded_size`` bool vector marking the real rows; slicing the
    result back to ``mask.sum()`` rows undoes the padding exactly.
    """
    n = y0s.shape[0]
    if drive_params is not None and drive_params.shape[0] != n:
        raise ValueError(
            f"drive_params batch {drive_params.shape[0]} != y0s batch {n}")
    np_ = padded_size(n, n_shards)
    mask = np.arange(np_) < n

    def pad(x):
        if x is None or np_ == n:
            return x
        tail = jnp.repeat(x[-1:], np_ - n, axis=0)
        return jnp.concatenate([x, tail], axis=0)

    return pad(y0s), pad(drive_params), mask


# ---------------------------------------------------------------------------
# shard_map wrapper (the Backend.rollout_batch(mesh=...) implementation)
# ---------------------------------------------------------------------------

def shard_rollout_batch(backend, state, y0s: jax.Array, ts: jax.Array, *,
                        mesh, drive_family: Optional[Callable] = None,
                        drive_params: Optional[jax.Array] = None,
                        **solver_kw) -> jax.Array:
    """Shard a fleet rollout over the twin axis of ``mesh``.

    ``backend``/``state``: a programmed execution substrate (see
    :mod:`repro.core.backends`) — the state's weights are closed over,
    i.e. replicated to every device.  ``y0s`` (N, D) and optional
    ``drive_params`` (N, ...) are split along dim 0; each device calls
    ``backend.rollout_batch_local`` on its slice, so the per-device
    program is exactly the single-device one.  N that does not divide the
    shard count is padded (see :func:`pad_fleet_inputs`) and the padded
    trajectories are dropped before returning (N, T+1, D).

    ``solver_kw`` forwards verbatim to every device's
    ``rollout_batch_local`` — including the fused backend's
    ``precision=`` override, so a sharded fleet can serve the bf16
    substrate (half the replicated-weight bytes and per-device slab
    traffic) with one keyword.
    """
    validate_fleet_request("shard_rollout_batch", y0s=y0s, ts=ts,
                           drive_params=drive_params)
    n_shards = twin_shard_count(mesh)
    n = y0s.shape[0]
    y0s_p, dp_p, _ = pad_fleet_inputs(y0s, drive_params, n_shards)

    def per_device(y_loc, dp_loc):
        return backend.rollout_batch_local(
            state, y_loc, ts, drive_family=drive_family,
            drive_params=dp_loc, **solver_kw)

    if dp_p is None:
        sharded = shard_map(lambda y: per_device(y, None), mesh=mesh,
                            in_specs=P(TWIN_AXIS),
                            out_specs=P(TWIN_AXIS), check_rep=False)
        out = sharded(y0s_p)
    else:
        sharded = shard_map(per_device, mesh=mesh,
                            in_specs=(P(TWIN_AXIS), P(TWIN_AXIS)),
                            out_specs=P(TWIN_AXIS), check_rep=False)
        out = sharded(y0s_p, dp_p)
    return out[:n]


# ---------------------------------------------------------------------------
# Serving SLO + graceful degradation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServingSLO:
    """Correctness contract for analogue serving.

    ``max_rel_error``: worst tolerated relative deviation of a health
    probe from the digital reference (relative to the reference's peak
    magnitude).  ``probe_every``: run a golden-trajectory probe every
    this many requests (1 = every request).  ``probe_horizon`` /
    ``probe_fleet``: probe cost knobs — first ``probe_fleet`` rows of
    the request over the first ``probe_horizon`` grid points.
    ``max_retries``: extra tiers a single request may fall through when
    its output comes back non-finite.  ``timeout_s``: wall-clock budget
    per attempt (None = unbounded); overruns are counted, not killed —
    a slow answer is still an answer.
    """
    max_rel_error: float = 0.05
    probe_every: int = 8
    probe_horizon: int = 11
    probe_fleet: int = 2
    max_retries: int = 2
    timeout_s: Optional[float] = None

    def __post_init__(self):
        if self.max_rel_error <= 0:
            raise ValueError(f"ServingSLO.max_rel_error must be > 0, "
                             f"got {self.max_rel_error}")
        for f in ("probe_every", "probe_horizon", "probe_fleet"):
            if getattr(self, f) < 1:
                raise ValueError(f"ServingSLO.{f} must be >= 1, "
                                 f"got {getattr(self, f)}")
        if self.max_retries < 0:
            raise ValueError(f"ServingSLO.max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.timeout_s is not None and self.timeout_s <= 0:
            raise ValueError(f"ServingSLO.timeout_s must be > 0 or None, "
                             f"got {self.timeout_s}")


@dataclasses.dataclass
class ServingStats:
    """Counters the degradation machinery maintains (one per server)."""
    requests: int = 0
    probes: int = 0
    probe_demotions: int = 0
    probe_recoveries: int = 0
    nan_rescues: int = 0
    retries: int = 0
    transient_retries: int = 0
    timeouts: int = 0
    served_by: dict = dataclasses.field(default_factory=dict)
    probe_errors: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def fallback_chain(fleet) -> list:
    """Ordered degradation tiers ``[(name, fleet_variant), ...]`` for a
    serving fleet: primary substrate -> noise-free fused analogue (same
    programmed array and faults, stochastic read noise off) -> digital
    golden reference.  Each step strips one failure mode; the last tier
    cannot be degraded by array health at all, so a served fleet trades
    energy/throughput for correctness, never the reverse.
    """
    primary = resolve_backend(fleet.backend)
    tiers = [(primary.name, fleet)]
    if isinstance(primary, (AnalogueBackend, FusedAnalogueBackend)):
        spec = primary.spec
        if spec.read_noise > 0.0 or isinstance(primary, AnalogueBackend):
            clean_spec = dataclasses.replace(spec, read_noise=0.0)
            if isinstance(primary, FusedAnalogueBackend):
                clean = dataclasses.replace(primary, spec=clean_spec)
            else:
                # jnp-simulator primary: the quiet tier is the fused
                # substrate with the same programming physics.
                clean = FusedAnalogueBackend(
                    spec=clean_spec, prog_key=primary.prog_key,
                    storage=primary.storage, faults=primary.faults,
                    verify=primary.verify, n_reads=primary.n_reads)
            tiers.append((f"{clean.name}_clean", fleet.with_backend(clean)))
    if not isinstance(primary, DigitalBackend):
        tiers.append(("digital", fleet.with_backend(DigitalBackend())))
    return tiers


# ---------------------------------------------------------------------------
# Programmed fleet server
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetServer:
    """A twin fleet programmed for serving: weights placed once,
    request batches streamed through a cached compiled program.

    Construction replicates ``params`` onto every device of the twin
    mesh (the "program the crossbars" step at datacentre scale) and
    freezes the time grid; each :meth:`serve` call pads + shards the
    request batch, runs the jitted sharded rollout (compiled once per
    padded batch shape) and returns the unpadded trajectories.

    Passing an :class:`ServingSLO` arms graceful degradation for
    analogue substrates (``docs/robustness.md``): the server builds the
    :func:`fallback_chain` of tiers, health-probes the chain every
    ``probe_every`` requests (a short golden rollout on the request's
    own leading rows, checked against the digital reference) and serves
    each request from the healthiest tier that meets the SLO — probing
    always restarts from the primary tier, so a recovered array is
    promoted back automatically.  Any request whose trajectories come
    back non-finite is retried down the chain; the digital tier cannot
    be degraded by array health, so a served fleet loses energy
    efficiency under faults, never correctness.  ``stats`` counts what
    happened.
    """
    fleet: Any                        # repro.core.twin.TwinFleet
    params: Pytree
    ts: Any                           # concrete uniform time grid
    mesh: Any = None                  # None -> all visible devices
    slo: Optional[ServingSLO] = None  # None -> no degradation machinery

    def __post_init__(self):
        if self.mesh is None:
            self.mesh = make_twin_mesh()
        self.ts = jnp.asarray(np.asarray(self.ts))   # concrete for Pallas
        validate_fleet_request("FleetServer", ts=self.ts)
        self.params = jax.device_put(
            self.params, fleet_param_shardings(self.mesh, self.params))
        ts, mesh = self.ts, self.mesh
        self.stats = ServingStats()
        if self.slo is None:
            self._tiers = [(getattr(resolve_backend(self.fleet.backend),
                                    "name", "primary"), self.fleet)]
        else:
            self._tiers = fallback_chain(self.fleet)
        self._active = 0

        def compiled(f):
            return jax.jit(lambda p, y0s, thetas: f.rollout_batch(
                p, y0s, ts, thetas, mesh=mesh))

        self._rollouts = [compiled(f) for _, f in self._tiers]
        self._rollout = self._rollouts[0]     # primary tier, legacy name
        self._golden = (None if self.slo is None else
                        self.fleet.with_backend(DigitalBackend()))

    @property
    def n_shards(self) -> int:
        return twin_shard_count(self.mesh)

    @property
    def active_tier(self) -> str:
        """Name of the tier requests are currently served from."""
        return self._tiers[self._active][0]

    # -- health probing ----------------------------------------------------
    def _probe(self, y0s: jax.Array, thetas: Optional[jax.Array]) -> None:
        """Golden-trajectory health check: roll the request's first
        ``probe_fleet`` rows over ``ts[:probe_horizon]`` on each tier
        (eagerly, no mesh — the probe is tiny) and activate the first
        tier whose worst deviation from the digital reference meets the
        SLO.  Scanning from the top every time is what makes recovery
        automatic; the final (digital) tier is the reference itself and
        needs no probe."""
        s = self.slo
        self.stats.probes += 1
        h = min(s.probe_horizon, int(self.ts.shape[0]))
        ts_p = self.ts[:h]
        yp = y0s[: s.probe_fleet]
        tp = None if thetas is None else thetas[: s.probe_fleet]
        ref = np.asarray(self._golden.rollout_batch(self.params, yp, ts_p,
                                                    tp))
        scale = float(np.max(np.abs(ref))) + 1e-9
        prev, chosen = self._active, len(self._tiers) - 1
        for i, (name, tier) in enumerate(self._tiers[:-1]):
            out = np.asarray(tier.rollout_batch(self.params, yp, ts_p, tp))
            err = float(np.max(np.abs(out - ref))) / scale
            self.stats.probe_errors[name] = err
            if np.isfinite(err) and err <= s.max_rel_error:
                chosen = i
                break
        if chosen > prev:
            self.stats.probe_demotions += 1
        elif chosen < prev:
            self.stats.probe_recoveries += 1
        self._active = chosen

    # -- serving -----------------------------------------------------------
    def serve(self, y0s: jax.Array,
              drive_params: Optional[jax.Array] = None) -> jax.Array:
        """Roll out one request batch -> (N, T+1, D) trajectories.

        With an armed SLO the batch is served from the healthiest tier
        (see class docstring) and retried down the chain if its output
        is non-finite; raises ``RuntimeError`` only if even the digital
        tier returns non-finite values."""
        y0s = jnp.asarray(y0s)
        if drive_params is not None:
            drive_params = jnp.asarray(drive_params)
        validate_fleet_request("FleetServer.serve", y0s=y0s,
                               drive_params=drive_params)
        n = y0s.shape[0]
        y0s_p, dp_p, _ = pad_fleet_inputs(y0s, drive_params, self.n_shards)
        place = fleet_input_shardings(self.mesh, {"y": y0s_p})["y"]
        y0s_p = jax.device_put(y0s_p, place)
        if dp_p is not None:
            dp_p = jax.device_put(
                dp_p, fleet_input_shardings(self.mesh, {"d": dp_p})["d"])

        s = self.slo
        if s is None:
            self.stats.requests += 1
            out = self._rollout(self.params, y0s_p, dp_p)[:n]
            self.stats.served_by["primary"] = (
                self.stats.served_by.get("primary", 0) + 1)
            return out

        if len(self._tiers) > 1 and self.stats.requests % s.probe_every == 0:
            self._probe(y0s, drive_params)
        self.stats.requests += 1

        first = self._active
        last = min(first + s.max_retries, len(self._tiers) - 1)
        for i in range(first, last + 1):
            name = self._tiers[i][0]
            if i > first:
                self.stats.retries += 1
            t0 = time.perf_counter()
            out = jax.block_until_ready(
                self._rollouts[i](self.params, y0s_p, dp_p))[:n]
            if (s.timeout_s is not None
                    and time.perf_counter() - t0 > s.timeout_s):
                self.stats.timeouts += 1
            if bool(jnp.isfinite(out).all()):
                if i > first:
                    self.stats.nan_rescues += 1
                self.stats.served_by[name] = (
                    self.stats.served_by.get(name, 0) + 1)
                return out
        raise RuntimeError(
            "FleetServer: every fallback tier (including digital) "
            "returned non-finite trajectories — the request itself is "
            "pathological, not the substrate")


def serve_fleet(ckpt_dir: str, fleet, ts, requests: Iterable[Request], *,
                step: Optional[int] = None, mesh=None,
                params_template: Optional[Pytree] = None,
                init_key: Optional[jax.Array] = None
                ) -> Iterator[jax.Array]:
    """End-to-end serving pipeline over a stream of request batches.

    checkpoint load (:func:`repro.train.checkpoint.load_twin`) ->
    weights replicated onto the twin mesh once (:class:`FleetServer`) ->
    each request batch padded, sharded, rolled out -> trajectories
    yielded in order.

    ``requests`` yields either ``y0s`` arrays (autonomous fleets) or
    ``(y0s, drive_params)`` tuples (driven fleets).  ``params_template``
    gives the weight pytree structure for the restore; by default it is
    built with ``fleet.twin.init`` (``init_key`` seeds it — structure
    and shapes are all that matter, the values are overwritten).
    """
    if params_template is None:
        key = init_key if init_key is not None else jax.random.PRNGKey(0)
        params_template = fleet.twin.init(key)
    params = ckpt_lib.load_twin(ckpt_dir, params_template, step=step)
    server = FleetServer(fleet, params, ts, mesh=mesh)
    for req in requests:
        y0s, thetas = req if isinstance(req, tuple) else (req, None)
        yield server.serve(y0s, thetas)


# ---------------------------------------------------------------------------
# Streaming stateful serving: continuous batching over a resident population
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamRequest:
    """One queued streaming request: advance ``twin_id`` by ``horizon``
    RK4 steps from its carried state.  ``seq`` is the server-assigned
    arrival index (global FIFO order); ``remaining`` counts the steps
    still unserved (requests longer than the server's window are split
    across batches through the chunk-carry mechanism).  ``deadline`` is
    the latest virtual time the request may still be *started* —
    assembly drops stale requests (counted ``expired``); a request that
    has begun being served always runs to completion."""
    seq: int
    twin_id: Any
    horizon: int
    remaining: int
    t_arrival: float = 0.0
    deadline: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Completed:
    """A finished request: ``trajectory`` is the (horizon+1, D) host
    array with row 0 the state the request started from; ``tier`` names
    the substrate that served the final window."""
    seq: int
    twin_id: Any
    trajectory: np.ndarray
    start_step: int
    tier: str
    t_arrival: float
    t_done: float


@dataclasses.dataclass
class StreamStats:
    """Continuous-batching counters; conservation invariant (checked by
    ``tests/traffic.py``): every submitted request lands in exactly one
    terminal bucket — ``enqueued == served + failed + shed + expired +
    quarantined + pending``."""
    enqueued: int = 0
    served: int = 0
    failed: int = 0
    shed: int = 0            # load-shedding victims (bounded queue)
    expired: int = 0         # deadline passed before assembly
    quarantined: int = 0     # poison requests parked with a diagnostic
    batches: int = 0
    twin_steps: int = 0      # real (unpadded) RK4 steps served
    padded_steps: int = 0    # ragged-horizon + batch padding overhead
    splits: int = 0          # requests split across serving windows

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Quarantined:
    """A poison request, parked instead of served: even the digital tier
    produced non-finite output for its batch.  ``reason`` records what
    every tier said — the diagnostic an operator starts from.  The
    twin's carried state is untouched."""
    seq: int
    twin_id: Any
    horizon: int
    remaining: int
    t_arrival: float
    reason: str


@dataclasses.dataclass
class ServerStats:
    """The one structured observability snapshot
    (:meth:`StreamingFleetServer.stats`): continuous-batching counters,
    degradation-machinery counters, and the state store's paging
    counters under a single ``as_dict`` schema — what the benches and
    the traffic invariant checkers consume."""
    stream: StreamStats
    serving: ServingStats
    store: StoreStats

    def as_dict(self) -> dict:
        return {"stream": self.stream.as_dict(),
                "serving": self.serving.as_dict(),
                "store": self.store.as_dict()}


class StreamingFleetServer:
    """Continuous batching for a resident twin population.

    Where :class:`FleetServer` rolls fixed request batches from t0, this
    server keeps per-twin ODE state alive BETWEEN requests: a stream of
    sensor windows (``submit``) feeds a queue; each ``pump`` assembles
    the longest admissible batch (one in-flight request per twin — a
    twin's next window consumes its previous one's end state), fetches
    the carried states from the :class:`TwinStateStore` (host-paged, LRU
    — the population may exceed the hot slab), coalesces the ragged
    horizons into ONE fused-kernel launch padded to the batch's widest
    window, then scatters the end states back and advances each twin's
    global step counter.

    Determinism contract (``docs/serving.md``): every time value any
    twin ever sees is the canonical float64 grid ``t0 + dt*k`` rounded
    to f32 once, keyed by the twin's own global step ``k`` — so the
    trajectory a twin accumulates over any sequence of windows is
    bit-identical (f32 substrates) to one uninterrupted rollout, no
    matter how the scheduler batched, split, or paged it.  Requests
    longer than ``max_window`` steps are split across pumps through the
    same chunk-carry path.

    Compiled-shape discipline: batches are padded to ``max_batch`` rows
    and window lengths quantised up to ``horizon_quantum`` multiples
    (capped at ``max_window``), so each serving tier compiles one
    program per window length instead of one per batch composition.

    Passing an :class:`ServingSLO` arms the same degradation machinery
    as :class:`FleetServer`: the :func:`fallback_chain` tiers are
    programmed once at construction, a golden window probe re-picks the
    healthiest tier every ``probe_every`` batches, and a batch whose
    trajectories come back non-finite is retried down the chain; a
    request that even the digital tier cannot serve is quarantined with
    a per-tier diagnostic (its carried state is left untouched) instead
    of killing the stream or looping the fallback chain.

    Admission control: ``max_queue`` bounds the request queue; an
    arrival past the bound is load-shed per ``shed_policy`` —
    ``"reject_new"`` (the arrival itself is refused, ``submit`` returns
    ``None``) or ``"drop_oldest"`` (the submitting twin's oldest
    still-unstarted request is dropped to make room).  Per-request
    ``deadline``s are checked at assembly time; transient tier
    exceptions are retried ``transient_retries`` times with exponential
    backoff before falling down the chain.

    Durability: pass ``durability_dir`` to arm the write-ahead journal +
    periodic snapshots (:mod:`repro.launch.journal`) — every externally
    visible event is fsync'd before it is acknowledged, and
    :meth:`recover` rebuilds a bitwise-identical (f32) server from disk
    after a crash at ANY point.  Twin ids must be JSON-serialisable
    scalars when durability is armed.
    """

    def __init__(self, fleet, params, *, dt: float, t0: float = 0.0,
                 hot_capacity: int = 64, max_batch: int = 32,
                 max_window: int = 64, horizon_quantum: int = 8,
                 slo: Optional[ServingSLO] = None,
                 max_queue: Optional[int] = None,
                 shed_policy: str = "reject_new",
                 transient_retries: int = 2,
                 backoff_base_s: float = 0.01,
                 durability_dir: Optional[str] = None,
                 snapshot_every: int = 16, snapshot_keep: int = 3,
                 fsync: bool = True):
        if dt <= 0:
            raise ValueError(f"StreamingFleetServer: dt must be > 0, "
                             f"got {dt}")
        if not 1 <= max_batch <= hot_capacity:
            raise ValueError(
                f"StreamingFleetServer: need 1 <= max_batch <= "
                f"hot_capacity, got max_batch={max_batch}, "
                f"hot_capacity={hot_capacity}")
        if max_window < 1 or horizon_quantum < 1:
            raise ValueError(
                "StreamingFleetServer: max_window and horizon_quantum "
                "must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"StreamingFleetServer: max_queue must be "
                             f">= 1 or None, got {max_queue}")
        if shed_policy not in ("reject_new", "drop_oldest"):
            raise ValueError(
                f"StreamingFleetServer: shed_policy must be 'reject_new'"
                f" or 'drop_oldest', got {shed_policy!r}")
        if transient_retries < 0 or backoff_base_s < 0:
            raise ValueError(
                "StreamingFleetServer: transient_retries and "
                "backoff_base_s must be >= 0")
        if snapshot_every < 0 or snapshot_keep < 1:
            raise ValueError(
                "StreamingFleetServer: need snapshot_every >= 0 "
                "(0 = manual snapshots only) and snapshot_keep >= 1")
        self.fleet = fleet
        self.params = params
        self.dt = float(dt)
        self.t0 = float(t0)
        self.max_batch = int(max_batch)
        self.max_window = int(max_window)
        self.horizon_quantum = int(horizon_quantum)
        self.slo = slo
        self.max_queue = None if max_queue is None else int(max_queue)
        self.shed_policy = shed_policy
        self.transient_retries = int(transient_retries)
        self.backoff_base_s = float(backoff_base_s)
        self.snapshot_every = int(snapshot_every)
        self.snapshot_keep = int(snapshot_keep)
        self.store = TwinStateStore(fleet.twin.state_dim, hot_capacity)
        self.stream_stats = StreamStats()
        self.serving_stats = ServingStats()
        self.quarantine: dict = {}             # seq -> Quarantined
        self._audit = os.environ.get("REPRO_STORE_AUDIT", "") == "1"
        self._journal: Optional[journal_lib.Journal] = None
        self._serve_dir: Optional[str] = None
        self._pumps_since_snapshot = 0
        self._tiers = (fallback_chain(fleet) if slo is not None else
                       [(getattr(resolve_backend(fleet.backend), "name",
                                 "primary"), fleet)])
        self._active = 0
        # Program every tier ONCE (the "write the crossbars" step); the
        # jitted window programs are built lazily per (tier, H) shape.
        self._backends, self._states = [], []
        for _, tier_fleet in self._tiers:
            backend = resolve_backend(tier_fleet.backend)
            node = tier_fleet.twin.node
            self._backends.append(backend)
            self._states.append(backend.program(node.field, params))
        self._window_fns: dict = {}            # (tier_idx, H) -> jit fn
        self._queue: list = []                 # FIFO of StreamRequest
        self._partial: dict = {}               # seq -> list of row blocks
        self._seq = 0
        if durability_dir is not None:
            self._attach_durability(durability_dir, fsync=fsync,
                                    resume=False)

    # -- population / ingest -------------------------------------------------
    @property
    def active_tier(self) -> str:
        return self._tiers[self._active][0]

    @property
    def pending(self) -> int:
        return len(self._queue)

    def stats(self) -> ServerStats:
        """One structured observability snapshot: stream + serving +
        store counters (copies — mutating the snapshot cannot corrupt
        the live counters)."""
        return ServerStats(stream=copy.deepcopy(self.stream_stats),
                           serving=copy.deepcopy(self.serving_stats),
                           store=copy.deepcopy(self.store.stats))

    def register_twin(self, twin_id, y0, *, theta=None) -> None:
        """Admit a twin with its initial condition (and per-twin drive
        parameters for driven fleets) — host-side, no device traffic.
        Rejects non-finite / mis-shaped ``y0`` and ``theta`` with errors
        naming the argument (the store checks ``y0``)."""
        if (theta is None) != (self.fleet.drive_family is None):
            raise ValueError(
                "register_twin: theta must be given exactly when the "
                "fleet has a drive_family")
        if theta is not None:
            th = np.asarray(theta)
            if not np.issubdtype(th.dtype, np.floating):
                raise ValueError(
                    f"register_twin: theta has non-floating dtype "
                    f"{th.dtype}")
            if not np.isfinite(th).all():
                raise ValueError(
                    f"register_twin: theta for twin {twin_id!r} contains "
                    f"non-finite (NaN/Inf) values")
        self.store.register(twin_id, y0, theta=theta)
        if self._journal is not None:
            rec = {"t": "register", "id": twin_id,
                   "y0": journal_lib.json_floats(
                       self.store.peek(twin_id)[0])}
            if theta is not None:
                th32 = np.asarray(theta, np.float32)
                rec["theta"] = journal_lib.json_floats(th32)
                rec["tshape"] = list(th32.shape)
            self._journal.append(rec)

    def submit(self, twin_id, horizon: int, t_arrival: float = 0.0, *,
               deadline: Optional[float] = None) -> Optional[int]:
        """Enqueue a request to advance ``twin_id`` by ``horizon`` RK4
        steps; returns its ``seq``, or ``None`` if the bounded queue
        shed it (``shed_policy="reject_new"``).  Per-twin FIFO order is
        guaranteed; cross-twin order is whatever batching finds
        profitable.  ``deadline`` (virtual time, same clock as
        ``t_arrival``/``pump(now)``) is the latest the request may still
        be started.  Malformed arguments raise ``ValueError`` naming the
        offender at the front door — nothing invalid reaches a batch."""
        if twin_id not in self.store:
            raise KeyError(f"submit: twin {twin_id!r} is not registered")
        if isinstance(horizon, bool) or not isinstance(
                horizon, (int, np.integer)):
            raise ValueError(
                f"submit: horizon must be an integer step count, got "
                f"{type(horizon).__name__} {horizon!r}")
        horizon = int(horizon)
        if horizon < 1:
            raise ValueError(f"submit: horizon must be >= 1, got {horizon}")
        t_arrival = float(t_arrival)
        if not np.isfinite(t_arrival):
            raise ValueError(
                f"submit: t_arrival must be finite, got {t_arrival}")
        if deadline is not None:
            deadline = float(deadline)
            if not np.isfinite(deadline):
                raise ValueError(
                    f"submit: deadline must be finite (omit it for "
                    f"no deadline), got {deadline}")
            if deadline < t_arrival:
                raise ValueError(
                    f"submit: deadline {deadline} precedes t_arrival "
                    f"{t_arrival} — the request is dead on arrival")
        seq = self._seq
        self._seq += 1
        self.stream_stats.enqueued += 1
        jrec = {"t": "submit", "seq": seq, "id": twin_id, "h": horizon,
                "ta": t_arrival, "dl": deadline}
        if (self.max_queue is not None
                and len(self._queue) >= self.max_queue):
            victim = None
            if self.shed_policy == "drop_oldest":
                # oldest still-unstarted request of THIS twin — a
                # half-served continuation is never shed (its work is
                # already paid for and its state already advanced).
                victim = next(
                    (r for r in self._queue if r.twin_id == twin_id
                     and r.remaining == r.horizon), None)
            if victim is None:
                # reject_new policy, or drop_oldest with nothing of this
                # twin's to drop: the newcomer itself is shed.
                self.stream_stats.shed += 1
                if self._journal is not None:
                    self._journal.append({**jrec, "shed": True})
                return None
            self._queue.remove(victim)
            self.stream_stats.shed += 1
            if self._journal is not None:
                self._journal.append({"t": "shed", "seq": victim.seq},
                                     sync=False)
        req = StreamRequest(seq=seq, twin_id=twin_id, horizon=horizon,
                            remaining=horizon, t_arrival=t_arrival,
                            deadline=deadline)
        self._queue.append(req)
        if self._journal is not None:
            self._journal.append(jrec)
        return req.seq

    # -- batch assembly ------------------------------------------------------
    def _assemble(self):
        """Pop the next batch: scan the queue in FIFO order, taking the
        FIRST pending request of each twin (later requests for the same
        twin must wait — their start state does not exist yet) up to
        ``max_batch``.  Returns the requests and the padded window
        length H."""
        picked, skipped, seen = [], [], set()
        for req in self._queue:
            if req.twin_id in seen or len(picked) == self.max_batch:
                skipped.append(req)
            else:
                seen.add(req.twin_id)
                picked.append(req)
        self._queue = skipped
        if not picked:
            return [], 0
        h_max = min(self.max_window,
                    max(r.remaining for r in picked))
        q = self.horizon_quantum
        H = min(self.max_window, -(-h_max // q) * q)
        return picked, H

    # -- window programs -----------------------------------------------------
    def _window_fn(self, tier_idx: int, H: int):
        """The jitted fixed-shape window solve of one tier: carried
        states (B, D) + canonical time/drive windows in, (B, H+1, D)
        trajectories out.  Fused tiers take the pre-sampled per-twin
        half-step drive slabs; digital/analogue tiers take the per-twin
        time grids (odeint consumes time VALUES, so traced per-row
        grids keep bitwise parity with the canonical windows)."""
        key = (tier_idx, H)
        fn = self._window_fns.get(key)
        if fn is not None:
            return fn
        backend = self._backends[tier_idx]
        state = self._states[tier_idx]
        _, tier_fleet = self._tiers[tier_idx]
        node = tier_fleet.twin.node
        drive_family = tier_fleet.drive_family
        if isinstance(backend, FusedPallasBackend):
            from repro.kernels.fused_ode_mlp import pad_fleet_to_tile

            def run(ys, uh):
                y0s, uh_p, bt, B = pad_fleet_to_tile(ys, uh,
                                                     backend.batch_tile)
                traj = backend._solve(state, y0s, uh_p, self.dt, bt,
                                      "stopgrad", None)
                return jnp.transpose(traj[:, :B], (1, 0, 2))
        else:
            kw = node._solver_kw()
            if drive_family is None:
                def run(ys, tss):
                    return jax.vmap(lambda y, ts: backend.rollout(
                        state, y, ts, **kw))(ys, tss)
            else:
                def run(ys, tss, thetas):
                    def single(y, ts, th):
                        st = _with_drive(state,
                                         lambda t: drive_family(t, th))
                        return backend.rollout(st, y, ts, **kw)
                    return jax.vmap(single)(ys, tss, thetas)
        fn = jax.jit(run)
        self._window_fns[key] = fn
        return fn

    def _run_tier(self, tier_idx: int, ys, starts: np.ndarray, thetas,
                  H: int):
        """Serve one assembled window on one tier.  The canonical
        time/drive windows are built HOST-side (concrete float64 grid —
        the determinism contract) and only the solve is jitted."""
        from repro.kernels import ops
        backend = self._backends[tier_idx]
        state = self._states[tier_idx]
        drive_family = self._tiers[tier_idx][1].drive_family
        fn = self._window_fn(tier_idx, H)
        if isinstance(backend, FusedPallasBackend):
            uh = backend._u_half_window(state, self.t0, self.dt, H,
                                        starts, drive_family, thetas)
            if uh.ndim == 2 and uh.shape[-1] > 0:
                uh = jnp.broadcast_to(uh, (ys.shape[0],) + uh.shape)
            return fn(ys, uh)
        tss = ops.window_times(self.t0, self.dt, H, starts)
        if drive_family is None:
            return fn(ys, tss)
        return fn(ys, tss, thetas)

    def _probe(self, ys, starts, thetas, H: int) -> None:
        """Golden-window health check (the streaming analogue of
        ``FleetServer._probe``): roll the batch's first ``probe_fleet``
        rows over a short window on every non-digital tier, compare to
        the digital reference, activate the healthiest tier that meets
        the SLO."""
        s = self.slo
        self.serving_stats.probes += 1
        nf = min(s.probe_fleet, int(ys.shape[0]))
        h = min(s.probe_horizon - 1, H)
        yp, sp = ys[:nf], starts[:nf]
        tp = None if thetas is None else thetas[:nf]
        ref_backend = self._backends[-1]      # digital tier, by chain
        ref_state = self._states[-1]
        drive_family = self._tiers[-1][1].drive_family
        ref = np.asarray(ref_backend.rollout_batch_resumed(
            ref_state, yp, dt=self.dt, num_steps=h, t0=self.t0,
            start_steps=sp, drive_family=drive_family, drive_params=tp))
        scale = float(np.max(np.abs(ref))) + 1e-9
        prev, chosen = self._active, len(self._tiers) - 1
        for i, (name, tier_fleet) in enumerate(self._tiers[:-1]):
            out = np.asarray(self._backends[i].rollout_batch_resumed(
                self._states[i], yp, dt=self.dt, num_steps=h, t0=self.t0,
                start_steps=sp,
                drive_family=tier_fleet.drive_family, drive_params=tp))
            err = float(np.max(np.abs(out - ref))) / scale
            self.serving_stats.probe_errors[name] = err
            if np.isfinite(err) and err <= s.max_rel_error:
                chosen = i
                break
        if chosen > prev:
            self.serving_stats.probe_demotions += 1
        elif chosen < prev:
            self.serving_stats.probe_recoveries += 1
        self._active = chosen

    # -- the serving loop ----------------------------------------------------
    def _fetch_padded(self, ids):
        """Fetch carried state for a batch and pad it to the fixed
        compiled width (replicating the last row keeps padding
        in-distribution; results are sliced back).  Returns
        ``(ys, starts, thetas, n)`` with ``n`` the real row count."""
        ys, starts, thetas = self.store.fetch(ids)
        n = len(ids)
        pad = self.max_batch - n
        if pad:
            ys = jnp.concatenate(
                [ys, jnp.broadcast_to(ys[-1:], (pad,) + ys.shape[1:])])
            starts = np.concatenate([starts, np.repeat(starts[-1:], pad)])
            if thetas is not None:
                thetas = jnp.concatenate(
                    [thetas,
                     jnp.broadcast_to(thetas[-1:],
                                      (pad,) + thetas.shape[1:])])
        return ys, starts, thetas, n

    def _expire(self, now: float) -> None:
        """Deadline check at assembly time: drop queued requests whose
        deadline has passed before they were ever started.  A split
        continuation (``remaining < horizon``) is exempt — its state has
        already advanced, so dropping it would tear the twin's
        trajectory; it runs to completion."""
        stale = [r for r in self._queue
                 if r.deadline is not None and r.remaining == r.horizon
                 and now > r.deadline]
        if not stale:
            return
        dead = {r.seq for r in stale}
        self._queue = [r for r in self._queue if r.seq not in dead]
        self.stream_stats.expired += len(stale)
        if self._journal is not None:
            self._journal.append({"t": "expire", "seqs": sorted(dead)},
                                 sync=False)

    def _attempt_tier(self, tier_idx: int, ys, starts, thetas, H: int):
        """One tier's solve with retry-with-exponential-backoff for
        transient failures (device hiccups, preemptions — anything that
        raises an ``Exception``).  Injected ``SimulatedCrash``es are
        ``BaseException`` and pass straight through: a crash is not a
        retryable fault.  Raises the last exception when retries are
        exhausted."""
        s = self.slo
        delay = self.backoff_base_s
        last_exc: Optional[Exception] = None
        for attempt in range(self.transient_retries + 1):
            if attempt:
                time.sleep(delay)
                delay *= 2.0
                self.serving_stats.transient_retries += 1
            try:
                chaos.fault_point("pump:run_tier")
                t_start = time.perf_counter()
                out = jax.block_until_ready(
                    self._run_tier(tier_idx, ys, starts, thetas, H))
                if (s is not None and s.timeout_s is not None
                        and time.perf_counter() - t_start > s.timeout_s):
                    self.serving_stats.timeouts += 1
                return out
            except Exception as e:
                last_exc = e
        raise last_exc

    def _solve_batch(self, ys, starts, thetas, H: int, n: int):
        """Run the fallback chain over one assembled window.  Returns
        ``(traj, tier_idx, diags)`` — ``traj is None`` means even the
        final (digital) tier produced non-finite output, with ``diags``
        naming what each tier said.  A tier whose attempts all raise
        transiently falls through to the next tier; the FINAL tier
        exhausting its retries re-raises (that is infrastructure
        failure, not a poison request)."""
        s = self.slo
        first = self._active
        last = (len(self._tiers) - 1 if s is None
                else min(first + s.max_retries, len(self._tiers) - 1))
        diags = []
        for i in range(first, last + 1):
            name = self._tiers[i][0]
            if i > first:
                self.serving_stats.retries += 1
            try:
                out = self._attempt_tier(i, ys, starts, thetas, H)
            except Exception as e:
                if i == last:
                    raise
                diags.append(f"{name}: raised {type(e).__name__}: {e}")
                continue
            if bool(jnp.isfinite(out[:n]).all()):
                if i > first:
                    self.serving_stats.nan_rescues += 1
                return out, i, diags
            diags.append(f"{name}: non-finite output")
        return None, None, diags

    def _commit_batch(self, picked, ids, traj, starts, n: int, H: int,
                      tier_idx: int, now: float) -> list:
        """Apply one solved window: scatter end states into the store,
        advance step counters, stitch/stream partial trajectories, and
        re-queue split continuations.  Shared verbatim between the live
        pump and journal replay — which is what makes replay reproduce
        the crash-free state transition exactly."""
        tier_name = self._tiers[tier_idx][0]
        traj_h = np.asarray(traj[:n], np.float32)
        served = [min(r.remaining, H) for r in picked]
        end_states = traj[jnp.arange(n), jnp.asarray(served)]
        self.store.commit(ids, end_states,
                          starts[:n] + np.asarray(served))
        chaos.kill_point("pump:post_commit")
        self.stream_stats.twin_steps += int(sum(served))
        self.stream_stats.padded_steps += int(
            self.max_batch * H - sum(served))
        self.serving_stats.requests += 1
        self.serving_stats.served_by[tier_name] = (
            self.serving_stats.served_by.get(tier_name, 0) + 1)
        done = []
        for i, req in enumerate(picked):
            h = served[i]
            rows = traj_h[i, : h + 1]
            blocks = self._partial.setdefault(req.seq, [])
            blocks.append(rows if not blocks else rows[1:])
            if h < req.remaining:
                # Long request: re-queue the remainder at the FRONT so
                # it stays ahead of the twin's later requests.
                self.stream_stats.splits += 1
                self._queue.insert(0, dataclasses.replace(
                    req, remaining=req.remaining - h))
                continue
            full = np.concatenate(self._partial.pop(req.seq), axis=0)
            done.append(Completed(
                seq=req.seq, twin_id=req.twin_id, trajectory=full,
                start_step=int(starts[i]) - (req.horizon - h),
                tier=tier_name, t_arrival=req.t_arrival, t_done=now))
            self.stream_stats.served += 1
        return done

    def pump(self, now: float = 0.0) -> list:
        """Assemble and serve ONE batch; returns the list of
        :class:`Completed` requests it finished (possibly empty — a
        window that only partially serves long requests completes
        nothing).  Call repeatedly (``drain``) to empty the queue."""
        done = self._pump(now)
        if self._audit:
            self.store.check_invariants()
        if self._journal is not None and self.snapshot_every:
            self._pumps_since_snapshot += 1
            if self._pumps_since_snapshot >= self.snapshot_every:
                self.snapshot()
        return done

    def _pump(self, now: float) -> list:
        self._expire(now)
        picked, H = self._assemble()
        if not picked:
            if self._journal is not None:
                self._journal.sync()    # flush any expire records
            return []
        ids = [r.twin_id for r in picked]
        ys, starts, thetas, n = self._fetch_padded(ids)
        s = self.slo
        if (s is not None and len(self._tiers) > 1
                and self.stream_stats.batches % s.probe_every == 0):
            self._probe(ys[:n], starts[:n], None if thetas is None
                        else thetas[:n], H)
        self.stream_stats.batches += 1
        traj, tier_idx, diags = self._solve_batch(ys, starts, thetas, H, n)
        chaos.kill_point("pump:pre_commit")
        if traj is None:
            # Even the digital tier returned non-finite values: the
            # requests themselves are poison.  Park them with the
            # per-tier diagnostic; carried states stay untouched.
            reason = "; ".join(diags) or "non-finite on every tier"
            for req in picked:
                self.stream_stats.quarantined += 1
                self._partial.pop(req.seq, None)
                self.quarantine[req.seq] = Quarantined(
                    seq=req.seq, twin_id=req.twin_id, horizon=req.horizon,
                    remaining=req.remaining, t_arrival=req.t_arrival,
                    reason=reason)
            if self._journal is not None:
                self._journal.append(
                    {"t": "quarantine", "seqs": [r.seq for r in picked],
                     "reason": reason}, sync=False)
                self._journal.sync()
            return []
        done = self._commit_batch(picked, ids, traj, starts, n, H,
                                  tier_idx, now)
        if self._journal is not None:
            self._journal.append(
                {"t": "commit", "seqs": [r.seq for r in picked],
                 "tier": tier_idx, "H": H,
                 "served": [min(r.remaining, H) for r in picked],
                 "now": now}, sync=False)
            for c in done:
                self._journal.append({"t": "complete", "seq": c.seq},
                                     sync=False)
            self._journal.sync()
        return done

    # -- durability: journal, snapshots, crash recovery ----------------------
    def _config(self) -> dict:
        """Constructor arguments the journal header pins, so
        :meth:`recover` rebuilds a server with identical batching/
        shedding behaviour — replay determinism needs the same
        scheduler, not just the same records."""
        return {"dt": self.dt, "t0": self.t0,
                "hot_capacity": self.store.hot_capacity,
                "max_batch": self.max_batch,
                "max_window": self.max_window,
                "horizon_quantum": self.horizon_quantum,
                "max_queue": self.max_queue,
                "shed_policy": self.shed_policy,
                "transient_retries": self.transient_retries,
                "backoff_base_s": self.backoff_base_s,
                "snapshot_every": self.snapshot_every,
                "snapshot_keep": self.snapshot_keep}

    def _attach_durability(self, serve_dir: str, *, fsync: bool,
                           resume: bool) -> None:
        os.makedirs(serve_dir, exist_ok=True)
        jrnl = journal_lib.Journal(journal_lib.journal_path(serve_dir),
                                   fsync=fsync)
        if jrnl.lsn and not resume:
            jrnl.close()
            raise ValueError(
                f"StreamingFleetServer: {serve_dir!r} already holds a "
                f"journal with {jrnl.lsn} record(s) — use "
                f"StreamingFleetServer.recover() to resume it (a fresh "
                f"server writing over live state would fork history)")
        self._serve_dir = serve_dir
        self._journal = jrnl
        if jrnl.lsn == 0:
            jrnl.append({"t": "config",
                         "schema": journal_lib.JOURNAL_SCHEMA,
                         "cfg": self._config()})

    def snapshot(self) -> str:
        """Atomically publish a full-state snapshot covering every
        journal record so far: the store (hot slab flushed to host),
        the queue, in-flight partial trajectories, quarantine, and all
        counters.  Returns the snapshot path.  Called automatically
        every ``snapshot_every`` pumps; callable any time."""
        if self._journal is None:
            raise RuntimeError(
                "snapshot: durability is not armed — construct with "
                "durability_dir=")
        self._journal.sync()
        lsn = self._journal.lsn
        ids, ys, steps, thetas = self.store.export_state()
        arrays = {"store_ys": ys, "store_steps": steps}
        if thetas is not None:
            arrays["store_thetas"] = thetas
        for seq, blocks in self._partial.items():
            for i, b in enumerate(blocks):
                arrays[f"partial/{seq}/{i}"] = np.asarray(b, np.float32)
        extra = {
            "ids": list(ids),
            "seq": self._seq,
            "active": self._active,
            "queue": [[r.seq, r.twin_id, r.horizon, r.remaining,
                       r.t_arrival, r.deadline] for r in self._queue],
            "partial": {str(s): len(b) for s, b in self._partial.items()},
            "quarantine": [dataclasses.asdict(q)
                           for q in self.quarantine.values()],
            "stream_stats": self.stream_stats.as_dict(),
            "serving_stats": self.serving_stats.as_dict(),
            "store_stats": self.store.stats.as_dict(),
        }
        path = journal_lib.write_snapshot(self._serve_dir, lsn, arrays,
                                          extra, keep=self.snapshot_keep)
        self._pumps_since_snapshot = 0
        return path

    def _restore_snapshot(self, arrays: dict, extra: dict) -> None:
        ys, steps = arrays["store_ys"], arrays["store_steps"]
        thetas = arrays.get("store_thetas")
        for i, tid in enumerate(extra["ids"]):
            self.store.register(
                tid, ys[i], theta=None if thetas is None else thetas[i],
                step=int(steps[i]))
        self._seq = int(extra["seq"])
        self._active = int(extra["active"])
        self._queue = [
            StreamRequest(seq=q[0], twin_id=q[1], horizon=q[2],
                          remaining=q[3], t_arrival=q[4], deadline=q[5])
            for q in extra["queue"]]
        self._partial = {
            int(s): [arrays[f"partial/{s}/{i}"] for i in range(nb)]
            for s, nb in extra["partial"].items()}
        self.quarantine = {q["seq"]: Quarantined(**q)
                           for q in extra["quarantine"]}
        self.stream_stats = StreamStats(**extra["stream_stats"])
        self.serving_stats = ServingStats(**extra["serving_stats"])
        self.store.stats = StoreStats(**extra["store_stats"])

    def _drop_seqs(self, seqs) -> list:
        want = set(seqs)
        dropped = [r for r in self._queue if r.seq in want]
        if len(dropped) != len(want):
            have = {r.seq for r in dropped}
            raise ValueError(
                f"recover: journal references request seq(s) "
                f"{sorted(want - have)} that are not pending — the "
                f"journal is inconsistent beyond its torn tail")
        self._queue = [r for r in self._queue if r.seq not in want]
        return dropped

    def _replay(self, rec: dict) -> list:
        """Apply one journal record during recovery.  Decision records
        (register/submit/shed/expire/quarantine) are applied directly;
        ``commit`` records are re-EXECUTED through the recorded tier —
        the determinism contract makes the recompute bitwise-identical
        to the pre-crash execution.  Returns completions the replayed
        record (re)produces."""
        t = rec["t"]
        if t == "register":
            theta = None
            if "theta" in rec:
                theta = journal_lib.from_json_floats(rec["theta"],
                                                     rec["tshape"])
            self.store.register(
                rec["id"],
                journal_lib.from_json_floats(rec["y0"],
                                             (self.store.state_dim,)),
                theta=theta)
            return []
        if t == "submit":
            self.stream_stats.enqueued += 1
            self._seq = max(self._seq, rec["seq"] + 1)
            if rec.get("shed"):
                self.stream_stats.shed += 1
                return []
            self._queue.append(StreamRequest(
                seq=rec["seq"], twin_id=rec["id"], horizon=rec["h"],
                remaining=rec["h"], t_arrival=rec["ta"],
                deadline=rec["dl"]))
            return []
        if t == "shed":
            self._drop_seqs([rec["seq"]])
            self.stream_stats.shed += 1
            return []
        if t == "expire":
            self._drop_seqs(rec["seqs"])
            self.stream_stats.expired += len(rec["seqs"])
            return []
        if t == "quarantine":
            for req in self._drop_seqs(rec["seqs"]):
                self.stream_stats.quarantined += 1
                self._partial.pop(req.seq, None)
                self.quarantine[req.seq] = Quarantined(
                    seq=req.seq, twin_id=req.twin_id,
                    horizon=req.horizon, remaining=req.remaining,
                    t_arrival=req.t_arrival, reason=rec["reason"])
            return []
        if t == "commit":
            return self._replay_commit(rec)
        if t == "complete":
            return []                   # verified by recover()'s caller
        raise ValueError(f"recover: unknown journal record type {t!r}")

    def _replay_commit(self, rec: dict) -> list:
        by_seq = {r.seq: r for r in self._queue}
        missing = [s for s in rec["seqs"] if s not in by_seq]
        if missing:
            raise ValueError(
                f"recover: commit record references seq(s) {missing} "
                f"that are not pending — the journal is inconsistent")
        picked = [by_seq[s] for s in rec["seqs"]]
        taken = set(rec["seqs"])
        self._queue = [r for r in self._queue if r.seq not in taken]
        ids = [r.twin_id for r in picked]
        ys, starts, thetas, n = self._fetch_padded(ids)
        H, tier_idx = int(rec["H"]), int(rec["tier"])
        served = [min(r.remaining, H) for r in picked]
        if served != [int(x) for x in rec["served"]]:
            raise ValueError(
                "recover: replayed window disagrees with the journalled "
                "served step counts — scheduler state diverged")
        self.stream_stats.batches += 1
        traj = jax.block_until_ready(
            self._run_tier(tier_idx, ys, starts, thetas, H))
        if not bool(jnp.isfinite(traj[:n]).all()):
            raise ValueError(
                "recover: a journalled commit re-executed to non-finite "
                "output — the substrate changed since the crash")
        return self._commit_batch(picked, ids, traj, starts, n, H,
                                  tier_idx, float(rec.get("now", 0.0)))

    @classmethod
    def recover(cls, serve_dir: str, fleet, params, *,
                slo: Optional[ServingSLO] = None, fsync: bool = True):
        """Rebuild a crashed server from its serving directory.

        Loads the newest loadable snapshot (damaged ones are skipped for
        older siblings — the atomic publish protocol guarantees any
        published snapshot is internally consistent), replays the
        journal suffix deterministically through the recorded tiers, and
        reopens the journal (torn tail truncated) so serving continues
        appending where the crash left off.

        Returns ``(server, redelivered)``: ``redelivered`` holds the
        :class:`Completed` results regenerated by replayed commits —
        results whose original delivery may or may not have reached the
        caller before the crash (at-least-once delivery; state advance
        is exactly-once).  The server's store, queue, partials and
        counters are bitwise-equal (f32) to a crash-free run's.
        """
        records, _, _ = journal_lib.read_journal(
            journal_lib.journal_path(serve_dir))
        if not records or records[0].get("t") != "config":
            raise ValueError(
                f"recover: {serve_dir!r} has no usable journal (missing "
                f"or torn config header) — nothing to recover")
        if records[0].get("schema") != journal_lib.JOURNAL_SCHEMA:
            raise ValueError(
                f"recover: journal schema {records[0].get('schema')!r} "
                f"!= supported {journal_lib.JOURNAL_SCHEMA}")
        server = cls(fleet, params, slo=slo, **records[0]["cfg"])
        snap = journal_lib.load_latest_snapshot(serve_dir)
        start = 1                       # past the config header
        if snap is not None:
            lsn, arrays, extra = snap
            server._restore_snapshot(arrays, extra)
            start = lsn
        redelivered, completed_seqs = [], set()
        for rec in records[start:]:
            out = server._replay(rec)
            completed_seqs.update(c.seq for c in out)
            redelivered.extend(out)
            if rec["t"] == "complete" and rec["seq"] not in completed_seqs:
                raise ValueError(
                    f"recover: journal records completion of seq "
                    f"{rec['seq']} that replay never produced — the "
                    f"journal is inconsistent beyond its torn tail")
        server._attach_durability(serve_dir, fsync=fsync, resume=True)
        return server, redelivered

    def drain(self, now: float = 0.0) -> list:
        """Pump until the queue is empty; returns all completions.
        Safe with quarantined requests pending (they are already out of
        the queue) and immediately after :meth:`recover` (replay leaves
        the queue exactly as the crash-free schedule would have)."""
        done = []
        while self._queue:
            done.extend(self.pump(now))
        return done

    def serve_trace(self, trace, *, y0_of, theta_of=None,
                    auto_register: bool = True, start: int = 0,
                    sink: Optional[list] = None) -> list:
        """Replay a recorded arrival trace (see
        :mod:`repro.launch.traffic`) through the streaming loop.

        Arrivals are ingested in timestamp order; a batch is pumped
        whenever the queue can fill one, and the tail is drained at the
        end.  ``y0_of(twin_id)`` (and ``theta_of(twin_id)`` for driven
        fleets) lazily registers first-contact twins.  Returns the
        completions in service order — the deterministic-schedule
        replay the stress tests assert invariants over.

        ``start`` skips the first ``start`` arrivals — the crash-
        recovery resume idiom: a recovered server already holds every
        arrival its journal acknowledged, so the caller re-feeds the
        trace from ``server.stream_stats.enqueued`` onward (an arrival
        whose submit never reached the journal is simply re-submitted —
        the client-retry contract).

        ``sink``: optional list that completions are ALSO appended to as
        they are delivered.  A consumer that may die mid-trace (the
        chaos harness, any real streaming client) passes one so the
        completions delivered before the death are not lost to the
        raised exception — completions already committed to a snapshot
        are deliberately NOT redelivered by recovery.
        """
        done = [] if sink is None else sink
        for arrival in trace[start:]:
            if auto_register and arrival.twin_id not in self.store:
                theta = None if theta_of is None else theta_of(
                    arrival.twin_id)
                self.register_twin(arrival.twin_id, y0_of(arrival.twin_id),
                                   theta=theta)
            self.submit(arrival.twin_id, arrival.horizon,
                        t_arrival=arrival.time,
                        deadline=getattr(arrival, "deadline", None))
            if self.pending >= self.max_batch:
                done.extend(self.pump(now=arrival.time))
        t_end = trace[-1].time if trace else 0.0
        done.extend(self.drain(now=t_end))
        return done


# ---------------------------------------------------------------------------
# CLI smoke: the Lorenz96 fleet workload on whatever devices exist
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Serve a Lorenz96 twin fleet over the local twin mesh")
    ap.add_argument("--fleet", type=int, default=256,
                    help="assets per request batch")
    ap.add_argument("--horizon", type=int, default=100,
                    help="RK4 steps per rollout")
    ap.add_argument("--batches", type=int, default=2,
                    help="request batches to stream")
    ap.add_argument("--backend", default="fused_pallas",
                    choices=["digital", "fused_pallas"])
    ap.add_argument("--precision", default=None,
                    choices=["f32", "bf16", "bf16_f32acc"],
                    help="fused-substrate mixed-precision policy "
                         "(default: auto — bf16_f32acc on TPU, f32 "
                         "elsewhere)")
    ap.add_argument("--ckpt-dir", default="",
                    help="trained-twin checkpoint (default: untrained "
                         "weights saved to a temp dir — substrate smoke)")
    args = ap.parse_args(argv)

    from repro.train import recipes
    backend = args.backend
    if args.precision is not None:
        if backend != "fused_pallas":
            ap.error("--precision is a fused-substrate policy; it does "
                     "not apply to --backend digital")
        from repro.core.backends import FusedPallasBackend
        backend = FusedPallasBackend(precision=args.precision)
    fleet = recipes.make_l96_fleet(backend=backend)
    ts = recipes.l96_fleet_ts(horizon=args.horizon)
    mesh = make_twin_mesh()
    print(f"mesh: {twin_shard_count(mesh)} device(s) on axis '{TWIN_AXIS}'; "
          f"backend {args.backend} precision "
          f"{'n/a' if args.backend == 'digital' else args.precision or 'auto'}")

    ckpt_dir = args.ckpt_dir
    if not ckpt_dir:
        ckpt_dir = tempfile.mkdtemp(prefix="l96_fleet_ckpt_")
        params = fleet.twin.init(jax.random.PRNGKey(0))
        ckpt_lib.save_twin(ckpt_dir, params)
        print(f"no --ckpt-dir: saved untrained twin to {ckpt_dir}")

    reqs = list(recipes.l96_fleet_requests(fleet_size=args.fleet,
                                           num_batches=args.batches))
    t0 = time.perf_counter()
    outs = []
    for i, traj in enumerate(serve_fleet(ckpt_dir, fleet, ts, reqs,
                                         mesh=mesh)):
        traj = jax.block_until_ready(traj)
        outs.append(traj)
        dt_s = time.perf_counter() - t0
        rate = (i + 1) * args.fleet * args.horizon / dt_s
        print(f"  batch {i}: {tuple(traj.shape)} trajectories "
              f"({rate:,.0f} twin-steps/s cumulative)")
    assert all(bool(jnp.isfinite(o).all()) for o in outs)
    print(f"served {args.batches} x {args.fleet} twins x {args.horizon} "
          f"steps in {time.perf_counter() - t0:.2f}s")
    return outs


if __name__ == "__main__":
    main()
