"""Crash and fault injection for the serving stack (the chaos harness).

Crash-safety claims are only as strong as the crashes they were tested
against, so the durable serving loop (`repro.launch.journal`,
`repro.launch.fleet_serving.StreamingFleetServer`) is instrumented with
named **kill points** — places where a real process death would be most
damaging — and this module arms them:

  ``pump:pre_commit``       batch computed, store NOT yet updated
  ``pump:post_commit``      store scattered, journal records NOT yet
                            durable (the mid-scatter window)
  ``store:evict``           mid-eviction inside an LRU page-out
  ``snapshot:pre_rename``   snapshot tmp dir fully written, atomic
                            rename NOT yet issued (also arms the train
                            checkpointer — same publish protocol)
  ``journal:torn_append``   process dies mid-``write``: a torn half
                            frame is left on the journal tail

An armed kill point raises :class:`SimulatedCrash`, which subclasses
``BaseException`` on purpose: the serving loop's retry/fallback paths
catch ``Exception``, and a chaos test must prove recovery works when the
process actually dies — not that some retry loop swallowed the "crash".

Transient (recoverable) faults use the separate **fault point**
mechanism: :func:`flaky` arms a named site to raise an ordinary
exception ``times`` times, which is how the exponential-backoff retry
path is exercised.

Both registries are process-global and test-scoped: ``crash_at`` /
``flaky`` are context managers that always disarm on exit, so a failing
test cannot leak chaos into its neighbours.

CLI smoke (the CI chaos-smoke step runs the pytest matrix; this is the
human-sized equivalent):

  PYTHONPATH=src python -m repro.launch.chaos --kill pump:post_commit
"""
from __future__ import annotations

import argparse
import contextlib
from typing import Callable, Dict, Iterator, List, Optional, Type

#: Every kill point the serving stack exposes.  ``crash_at`` validates
#: against this list so a typo'd name fails the test instead of silently
#: never firing.
KILL_POINTS = (
    "pump:pre_commit",
    "pump:post_commit",
    "store:evict",
    "snapshot:pre_rename",
    "journal:torn_append",
)

_armed: Dict[str, int] = {}            # kill point -> hits until crash
_faults: Dict[str, List] = {}          # fault point -> [count, exc_type]


class SimulatedCrash(BaseException):
    """An injected process death.

    Deliberately NOT an ``Exception`` subclass: recovery must be proven
    against crashes that no ``except Exception`` handler (the transient
    retry path, the tier fallback loop) can intercept.
    """


def kill_point(name: str, partial: Optional[Callable[[], None]] = None
               ) -> None:
    """Declare a crash site.  No-op unless armed via :func:`crash_at`.

    ``partial``: optional side effect to run *just before* dying —
    journal appends use it to leave a torn half-frame on disk, the
    damage a real mid-``write`` death produces.
    """
    hits = _armed.get(name)
    if hits is None:
        return
    if hits > 1:
        _armed[name] = hits - 1
        return
    del _armed[name]
    if partial is not None:
        partial()
    raise SimulatedCrash(f"simulated crash at kill point {name!r}")


@contextlib.contextmanager
def crash_at(name: str, hit: int = 1) -> Iterator[None]:
    """Arm ``name`` to crash on its ``hit``-th execution (1 = first).

    Always disarms on exit — including when the crash fired — so chaos
    never leaks across tests.
    """
    if name not in KILL_POINTS:
        raise ValueError(
            f"unknown kill point {name!r}; chaos knows {KILL_POINTS}")
    if hit < 1:
        raise ValueError(f"crash_at: hit must be >= 1, got {hit}")
    _armed[name] = hit
    try:
        yield
    finally:
        _armed.pop(name, None)


def fault_point(name: str) -> None:
    """Declare a transient-fault site.  No-op unless armed via
    :func:`flaky`; when armed, raises the configured ``Exception`` the
    next ``times`` executions, then heals."""
    ent = _faults.get(name)
    if ent is None:
        return
    count, exc_type = ent
    if count <= 1:
        del _faults[name]
    else:
        ent[0] = count - 1
    raise exc_type(f"injected transient fault at {name!r}")


@contextlib.contextmanager
def flaky(name: str, times: int = 1,
          exc_type: Type[Exception] = RuntimeError) -> Iterator[None]:
    """Arm fault point ``name`` to fail ``times`` times then heal —
    the shape of a transient infrastructure fault (device hiccup,
    preempted kernel) the retry-with-backoff path must absorb."""
    if times < 1:
        raise ValueError(f"flaky: times must be >= 1, got {times}")
    _faults[name] = [times, exc_type]
    try:
        yield
    finally:
        _faults.pop(name, None)


def reset() -> None:
    """Disarm everything (test-session hygiene)."""
    _armed.clear()
    _faults.clear()


# ---------------------------------------------------------------------------
# CLI smoke: one crash/recover cycle at a chosen kill point
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Crash a streaming serve mid-flight at a named kill "
                    "point, recover from the journal, and verify parity "
                    "with an uninterrupted run")
    ap.add_argument("--kill", default="pump:post_commit",
                    choices=list(KILL_POINTS))
    ap.add_argument("--hit", type=int, default=2,
                    help="crash on the N-th execution of the kill point")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args(argv)

    import tempfile

    import jax
    import numpy as np

    from repro.core.backends import FusedPallasBackend
    from repro.core.twin import TwinFleet, make_autonomous_twin
    from repro.launch import traffic
    from repro.launch.fleet_serving import StreamingFleetServer

    twin = make_autonomous_twin(state_dim=3, hidden=8, n_hidden_layers=1,
                                backend=FusedPallasBackend(
                                    precision="f32"))
    params = twin.init(jax.random.PRNGKey(0))
    fleet = TwinFleet(twin)
    trace = traffic.poisson_trace(args.seed, args.requests, population=8,
                                  max_horizon=12)
    rng = np.random.default_rng(1)
    y0s = {tid: np.float32(rng.normal(size=3) * 0.1) for tid in range(8)}
    y0_of = y0s.__getitem__
    kw = dict(dt=0.01, hot_capacity=4, max_batch=4, max_window=8,
              horizon_quantum=4)

    ref = StreamingFleetServer(fleet, params, **kw)
    ref_done = ref.serve_trace(trace, y0_of=y0_of)

    with tempfile.TemporaryDirectory() as d:
        live = StreamingFleetServer(fleet, params, durability_dir=d,
                                    snapshot_every=3, **kw)
        delivered = []       # completions received before the crash
        try:
            with crash_at(args.kill, hit=args.hit):
                live.serve_trace(trace, y0_of=y0_of, sink=delivered)
            raise SystemExit(f"kill point {args.kill!r} never fired "
                             f"(hit={args.hit} too deep for this trace?)")
        except SimulatedCrash as e:
            print(f"crashed: {e}")
        rec, redelivered = StreamingFleetServer.recover(d, fleet, params)
        resumed = rec.serve_trace(trace, y0_of=y0_of,
                                  start=rec.stream_stats.enqueued)
        rec_done = ({c.seq for c in delivered}
                    | {c.seq for c in redelivered}
                    | {c.seq for c in resumed})
        for tid in y0s:
            if tid in ref.store:
                y_ref, s_ref = ref.store.peek(tid)
                y_rec, s_rec = rec.store.peek(tid)
                assert s_ref == s_rec and np.array_equal(y_ref, y_rec), \
                    f"twin {tid} diverged after recovery"
        ref_seqs = {c.seq for c in ref_done}
        assert rec_done == ref_seqs, \
            f"completion sets differ: lost {ref_seqs - rec_done}, " \
            f"phantom {rec_done - ref_seqs}"
        print(f"recovered: {len(rec_done)} completions, "
              f"{len(ref.store)} twins bitwise-equal to the "
              f"uninterrupted run")


if __name__ == "__main__":
    # ``python -m repro.launch.chaos`` executes this file as __main__ —
    # a SECOND module instance whose _armed registry the serving stack
    # (which imports repro.launch.chaos) never consults.  Dispatch to
    # the canonical instance so armed kill points actually fire.
    from repro.launch import chaos as _canonical
    _canonical.main()
