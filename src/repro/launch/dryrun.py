import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds shape-only params/opt-state/caches
(jax.eval_shape — nothing is allocated), resolves shardings through the
divisibility-aware logical-axis rules, lowers the jitted step under the
production mesh, compiles it, and records memory analysis, cost analysis
and the per-device collective traffic parsed from the optimised HLO —
the inputs to EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out runs/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, runnable_shapes
from repro.configs.base import ShapeConfig
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   opt_state_shardings, param_shardings)
from repro.models.model import init_cache, init_params
from repro.roofline.analysis import Roofline, model_flops
from repro.roofline.hlo_parse import analyze as hlo_analyze
from repro.train.lm_trainer import make_prefill_step, make_serve_step, \
    make_train_step
from repro.train.optimizer import adam


def _cost_number(cost, key):
    if cost is None:
        return 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return float(cost.get(key, 0.0))


def _bytes_accessed(cost) -> float:
    if cost is None:
        return 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    total = 0.0
    for k, v in cost.items():
        if k == "bytes accessed" or k.startswith("bytes accessed"):
            # avoid double counting: prefer the plain key if present
            pass
    if "bytes accessed" in cost:
        return float(cost["bytes accessed"])
    return float(sum(v for k, v in cost.items()
                     if k.startswith("bytes accessed")))


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True,
             variant: str = "baseline") -> dict:
    if variant == "opt":
        from repro.configs.optimized import get_optimized
        cfg = get_optimized(arch)
    else:
        cfg = get_config(arch)
    shape: ShapeConfig = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    from repro.models import model as model_lib
    model_lib.set_batch_axes(batch_axes(mesh))
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()

    key = jax.random.PRNGKey(0)
    no_attn_tp = cfg.shard_profile == "no_attn_tp"
    params_sds = jax.eval_shape(lambda k: init_params(cfg, k), key)
    pshard = param_shardings(mesh, params_sds, no_attn_tp=no_attn_tp)

    batch_sds = {"tokens": jax.ShapeDtypeStruct(
        (shape.global_batch,
         (shape.seq_len + 1) if shape.kind in ("train", "prefill") else 1),
        jnp.int32)}
    bshard = batch_shardings(mesh, batch_sds)

    if shape.kind == "train":
        opt = adam(3e-4, grad_clip=1.0, mu_dtype=cfg.jdtype)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        oshard = opt_state_shardings(mesh, opt_sds, no_attn_tp=no_attn_tp)
        step_fn = make_train_step(cfg, opt)
        with mesh:
            jitted = jax.jit(step_fn,
                             in_shardings=(pshard, oshard, bshard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(cfg)
        with mesh:
            jitted = jax.jit(step_fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_sds, batch_sds)
            compiled = lowered.compile()
    else:  # decode
        cache_sds = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        cshard = cache_shardings(mesh, cache_sds, shape.global_batch)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        step_fn = make_serve_step(cfg)
        with mesh:
            jitted = jax.jit(step_fn,
                             in_shardings=(pshard, bshard,
                                           NamedSharding(mesh, P()), cshard),
                             donate_argnums=(3,))
            lowered = jitted.lower(params_sds, batch_sds, pos_sds, cache_sds)
            compiled = lowered.compile()

    compile_s = time.time() - t0
    cost = compiled.cost_analysis()
    flat_flops = _cost_number(cost, "flops")
    flat_bytes = _bytes_accessed(cost)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}

    # loop-aware analysis of the optimised HLO (cost_analysis counts
    # while bodies once; see roofline/hlo_parse.py)
    hlo = compiled.as_text()
    parsed = hlo_analyze(hlo)

    rl = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=parsed["flops"],
        hlo_bytes_per_chip=parsed["traffic_bytes"],
        coll_bytes_per_chip=parsed["collective_bytes"],
        model_flops_global=model_flops(cfg, shape),
        coll_breakdown=parsed["coll_breakdown"],
    )
    record = {**rl.row(), "compile_s": compile_s, "memory": mem_info,
              "coll_breakdown": rl.coll_breakdown,
              "coll_counts": parsed["coll_counts"],
              "flat_cost_analysis": {"flops": flat_flops,
                                     "bytes": flat_bytes},
              "n_while": parsed["n_while"], "status": "ok"}

    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] compiled in "
              f"{compile_s:.1f}s")
        print(f"  flops/chip {parsed['flops']:.3e}  traffic/chip "
              f"{parsed['traffic_bytes']:.3e}  coll bytes/chip "
              f"{parsed['collective_bytes']:.3e}")
        print(f"  terms: compute {rl.t_compute*1e3:.2f} ms | memory "
              f"{rl.t_memory*1e3:.2f} ms | collective "
              f"{rl.t_collective*1e3:.2f} ms -> {rl.bottleneck}-bound; "
              f"useful-flops ratio {rl.useful_flops_ratio:.2f}; "
              f"roofline fraction {rl.roofline_fraction:.2%}")
        print(f"  memory: {mem_info}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_name}.json".replace("/", "_")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(record, f, indent=1, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt"])
    args = ap.parse_args()

    from repro.configs import ARCH_NAMES
    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        shapes = runnable_shapes(cfg) if (args.all or args.shape is None) \
            else [args.shape]
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                             variant=args.variant)
                except Exception:
                    traceback.print_exc()
                    failures.append((arch, shape, mp))
    if failures:
        print(f"\nFAILED cells: {failures}")
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
