"""Per-twin ODE state carried between streaming requests, with host paging.

A streaming twin population is resident state, not request payload: each
physical asset owns a carried ``(y, global step)`` pair that every new
sensor window advances.  The population can vastly exceed what should sit
in device memory next to the serving kernels, so the store is two-level:

  * **hot slab** — one device array of ``hot_capacity`` rows.  Twins that
    are about to be batched are promoted here; the batch assembler gathers
    their rows with one indexed read and scatters results back with one
    indexed write (no per-twin device round-trips on the serving path).
  * **cold pages** — plain NumPy host arrays, one per twin.  Eviction is
    LRU over the hot slot order: promoting into a full slab pages the
    least-recently-used resident twin's row back to host FIRST, then
    reuses its slot — state is never dropped, only moved (the invariant
    ``tests/traffic.py`` checks after every stress schedule).

Metadata (global step index, per-twin drive parameters) always lives on
the host: steps parameterise the canonical float64 time grid
(:func:`repro.kernels.ops.window_times`) and must stay concrete Python
integers for the determinism contract to hold.

The store is deliberately synchronous and single-writer — the streaming
server (`repro.launch.fleet_serving.StreamingFleetServer`) owns it and
serialises access through its batch loop.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import chaos

TwinId = Any


@dataclasses.dataclass
class StoreStats:
    """Paging counters (one per store)."""
    registered: int = 0
    hot_hits: int = 0        # fetches served from the hot slab
    page_ins: int = 0        # cold -> hot promotions
    evictions: int = 0       # hot -> cold LRU pagings
    commits: int = 0         # state writes after served batches

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class TwinStateStore:
    """Two-level (device-hot / host-cold) store of per-twin ODE state.

    ``hot_capacity`` bounds the device-resident population; everything
    beyond it pages to host NumPy arrays with LRU eviction.  ``fetch``
    promotes + gathers, ``commit`` scatters back; both operate on id
    lists so the serving loop touches the device once per batch.
    """

    def __init__(self, state_dim: int, hot_capacity: int, *,
                 dtype=jnp.float32):
        if hot_capacity < 1:
            raise ValueError(
                f"TwinStateStore: hot_capacity must be >= 1, got "
                f"{hot_capacity}")
        self.state_dim = int(state_dim)
        self.hot_capacity = int(hot_capacity)
        self._hot = jnp.zeros((self.hot_capacity, self.state_dim), dtype)
        self._free: list[int] = list(range(self.hot_capacity))[::-1]
        self._slot_of: "OrderedDict[TwinId, int]" = OrderedDict()  # LRU order
        self._cold: dict[TwinId, np.ndarray] = {}
        self._step: dict[TwinId, int] = {}
        self._theta: dict[TwinId, Optional[np.ndarray]] = {}
        self.stats = StoreStats()

    # -- population --------------------------------------------------------
    def __contains__(self, twin_id: TwinId) -> bool:
        return twin_id in self._step

    def __len__(self) -> int:
        return len(self._step)

    @property
    def hot_ids(self) -> list:
        """Device-resident twins, least recently used first."""
        return list(self._slot_of)

    def register(self, twin_id: TwinId, y0, *, theta=None,
                 step: int = 0) -> None:
        """Admit a new twin with its initial state (host-side — nothing
        touches the device until the twin is first batched)."""
        if twin_id in self:
            raise ValueError(f"twin {twin_id!r} already registered")
        y0 = np.asarray(y0, np.float32)
        if y0.shape != (self.state_dim,):
            raise ValueError(
                f"twin {twin_id!r}: y0 shape {y0.shape} != "
                f"({self.state_dim},)")
        if not np.isfinite(y0).all():
            raise ValueError(
                f"twin {twin_id!r}: y0 contains non-finite values")
        self._cold[twin_id] = y0
        self._step[twin_id] = int(step)
        self._theta[twin_id] = (None if theta is None
                                else np.asarray(theta, np.float32))
        self.stats.registered += 1

    # -- paging ------------------------------------------------------------
    def _evict_lru(self, pinned: set) -> int:
        """Page the least-recently-used unpinned hot twin to host and
        return its freed slot.  The device row is copied out BEFORE the
        slot is handed over — eviction moves state, never loses it."""
        chaos.kill_point("store:evict")
        for twin_id in self._slot_of:          # iteration order = LRU
            if twin_id not in pinned:
                slot = self._slot_of.pop(twin_id)
                self._cold[twin_id] = np.asarray(self._hot[slot],
                                                 np.float32)
                self.stats.evictions += 1
                return slot
        raise RuntimeError(
            f"TwinStateStore: cannot evict — all {self.hot_capacity} hot "
            f"slots are pinned by the current batch (batch larger than "
            f"hot_capacity?)")

    def fetch(self, twin_ids: Sequence[TwinId]):
        """Promote ``twin_ids`` to the hot slab and gather their state.

        Returns ``(ys, steps, thetas)``: ``ys`` a (n, D) device array of
        carried states, ``steps`` a host (n,) int64 vector of global step
        indices, ``thetas`` a (n, ...) float32 array of drive parameters
        (or None if none of the twins carries one).  All requested twins
        are pinned for the duration of the promotion, so a fetch of more
        than ``hot_capacity`` twins raises instead of thrashing.
        """
        ids = list(twin_ids)
        unknown = [i for i in ids if i not in self]
        if unknown:
            raise KeyError(f"unregistered twin(s): {unknown!r}")
        if len(set(ids)) != len(ids):
            raise ValueError(
                "fetch: duplicate twin ids in one batch (a twin's next "
                "window depends on its previous one — serialise them)")
        if len(ids) > self.hot_capacity:
            raise ValueError(
                f"fetch: batch of {len(ids)} exceeds hot_capacity "
                f"{self.hot_capacity}")
        pinned = set(ids)
        page_in = []                           # (slot, host_row) pairs
        for twin_id in ids:
            if twin_id in self._slot_of:
                self.stats.hot_hits += 1
                self._slot_of.move_to_end(twin_id)    # touch: now MRU
            else:
                slot = (self._free.pop() if self._free
                        else self._evict_lru(pinned))
                page_in.append((slot, self._cold.pop(twin_id)))
                self._slot_of[twin_id] = slot
                self.stats.page_ins += 1
        if page_in:
            slots = jnp.asarray([s for s, _ in page_in], jnp.int32)
            rows = jnp.asarray(np.stack([r for _, r in page_in]))
            self._hot = self._hot.at[slots].set(rows)
        gather = jnp.asarray([self._slot_of[i] for i in ids], jnp.int32)
        ys = self._hot[gather]
        steps = np.asarray([self._step[i] for i in ids], np.int64)
        th = [self._theta[i] for i in ids]
        if all(t is None for t in th):
            thetas = None
        elif any(t is None for t in th):
            raise ValueError(
                "fetch: mixed drive parameters — a fleet either drives "
                "every twin (register all with theta=) or none")
        else:
            thetas = jnp.asarray(np.stack(th))
        return ys, steps, thetas

    def commit(self, twin_ids: Sequence[TwinId], ys, steps) -> None:
        """Scatter served end-states back into the hot slab and advance
        the per-twin global step counters.  ``ys`` is (n, D) (device or
        host); ``steps`` the new ABSOLUTE step indices."""
        ids = list(twin_ids)
        missing = [i for i in ids if i not in self._slot_of]
        if missing:
            raise KeyError(
                f"commit: twin(s) {missing!r} are not hot — fetch pins "
                f"a batch's twins until its commit")
        slots = jnp.asarray([self._slot_of[i] for i in ids], jnp.int32)
        self._hot = self._hot.at[slots].set(
            jnp.asarray(ys, self._hot.dtype))
        for i, s in zip(ids, np.asarray(steps, np.int64)):
            self._step[i] = int(s)
        self.stats.commits += 1

    # -- inspection (tests, checkpointing) ----------------------------------
    def export_state(self):
        """Flush the whole population to host for a snapshot:
        ``(ids, ys, steps, thetas)`` in registration order, with hot
        rows read out of the device slab (LRU order untouched).
        ``thetas`` is ``None`` for undriven populations, else a stacked
        (N, ...) float32 array."""
        ids = list(self._step)
        if not ids:
            return ids, np.zeros((0, self.state_dim), np.float32), \
                np.zeros((0,), np.int64), None
        ys = np.stack([self.peek(i)[0] for i in ids])
        steps = np.asarray([self._step[i] for i in ids], np.int64)
        th = [self._theta[i] for i in ids]
        thetas = None if all(t is None for t in th) else \
            np.stack(th).astype(np.float32)
        return ids, ys, steps, thetas

    def peek(self, twin_id: TwinId):
        """Read one twin's ``(y, step)`` without touching LRU order."""
        if twin_id not in self:
            raise KeyError(f"unregistered twin {twin_id!r}")
        if twin_id in self._slot_of:
            y = np.asarray(self._hot[self._slot_of[twin_id]], np.float32)
        else:
            y = self._cold[twin_id]
        return y, self._step[twin_id]

    def theta(self, twin_id: TwinId):
        return self._theta[twin_id]

    def check_invariants(self) -> None:
        """Structural audit used by the stress tests: every registered
        twin is in exactly one tier, slots are bijective, no state row is
        non-finite."""
        hot, cold = set(self._slot_of), set(self._cold)
        if hot & cold:
            raise AssertionError(f"twins in both tiers: {hot & cold}")
        if hot | cold != set(self._step):
            raise AssertionError("registered twins != hot + cold")
        slots = list(self._slot_of.values())
        if len(set(slots)) != len(slots):
            raise AssertionError("hot slot collision")
        if set(slots) & set(self._free):
            raise AssertionError("occupied slot on the free list")
        if len(slots) + len(self._free) != self.hot_capacity:
            raise AssertionError("slot leak: occupied + free != capacity")
        for tid in self._step:
            y, _ = self.peek(tid)
            if not np.isfinite(y).all():
                raise AssertionError(f"twin {tid!r} state went non-finite")
