"""Mesh construction for twin-fleet serving and the LM dry-run.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialisation).

Twin serving (the primary workload — see
:mod:`repro.launch.fleet_serving`) uses a 1-D mesh over the ``"twins"``
axis: the trained weights are replicated onto every device and the fleet
(initial conditions + per-twin stimulus parameters) is sharded, so each
device rolls out its slice of the assets with zero cross-device
communication during the solve.

The LM dry-run meshes are kept for the roofline study:
Single pod:  (16, 16)  ("data", "model")   = 256 chips (one v5e pod)
Multi-pod:   (2, 16, 16) ("pod", "data", "model") = 512 chips.

"pod" composes with "data" for batch/grad-sync (pure DP across pods —
the DCI-friendly axis); "model" carries TP/EP within a pod (ICI).
"""
from __future__ import annotations

from typing import Optional

import jax

TWIN_AXIS = "twins"


def make_twin_mesh(n_devices: Optional[int] = None) -> jax.sharding.Mesh:
    """1-D mesh over the ``"twins"`` axis for fleet serving.

    ``n_devices=None`` uses every visible device (a single-host CPU run
    gets the trivial 1-device mesh and the sharded path degenerates to
    the single-device program — same numerics, same code).
    """
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"make_twin_mesh: asked for {n} devices, have {len(devs)}")
    return jax.make_mesh((n,), (TWIN_AXIS,), devices=devs[:n])


def twin_shard_count(mesh) -> int:
    """How many ways the twin axis is split on ``mesh`` (1 if absent)."""
    return int(mesh.shape.get(TWIN_AXIS, 1))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """The mesh axes that jointly shard the batch dimension."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
