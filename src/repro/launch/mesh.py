"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialisation).

Single pod:  (16, 16)  ("data", "model")   = 256 chips (one v5e pod)
Multi-pod:   (2, 16, 16) ("pod", "data", "model") = 512 chips.

"pod" composes with "data" for batch/grad-sync (pure DP across pods —
the DCI-friendly axis); "model" carries TP/EP within a pod (ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """The mesh axes that jointly shard the batch dimension."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
