"""Sharding rules: twin-fleet serving specs + LM logical-axis rules.

**Twin fleets** (the serving workload, :mod:`repro.launch.fleet_serving`)
shard on one logical axis only: the fleet dimension.  ``fleet_batch_spec``
puts dim 0 of every request tensor (initial conditions ``y0s``, per-twin
drive parameters ``thetas``) on the ``"twins"`` mesh axis;
``fleet_param_shardings`` replicates the trained weights onto every
device — the multi-device transposition of the paper's one-chip-many-
assets deployment.  Nothing else is sharded: a neural-ODE rollout is
embarrassingly parallel across fleet members.

**LM rules** (kept for the roofline dry-run):
every parameter leaf is matched by (leaf-name, rank) to an ordered list of
tensor-parallel candidate dims; the first dim divisible by the mesh's
"model" axis wins (so qwen1.5's 40 heads fall back to head_dim, xlstm's
4 heads fall back to the projected dim, etc.).  A second pass assigns the
"data" axis FSDP-style to the largest remaining dim >= the threshold —
that is what makes 236B parameters + Adam state fit 16 GB/chip; GSPMD
re-gathers weights per scan step (costed in the roofline's collective
term).  The "pod" axis stays pure-DP (params replicated across pods, the
gradient all-reduce crosses DCI once per step).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import TWIN_AXIS, axis_size, batch_axes

Pytree = Any


# ---------------------------------------------------------------------------
# Twin-fleet serving specs
# ---------------------------------------------------------------------------

def fleet_batch_spec(ndim: int) -> P:
    """PartitionSpec sharding dim 0 (the fleet axis) on ``"twins"``."""
    return P(TWIN_AXIS, *([None] * (ndim - 1)))


def fleet_input_shardings(mesh, tree: Pytree) -> Pytree:
    """NamedShardings placing request tensors (y0s/thetas/...) with their
    leading fleet dimension split across the twin mesh."""
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, fleet_batch_spec(len(x.shape))), tree)


def fleet_param_shardings(mesh, params: Pytree) -> Pytree:
    """NamedShardings replicating the trained twin weights on every
    device (weights-stationary serving: each device keeps a full copy)."""
    return replicated(mesh, params)


# ---------------------------------------------------------------------------
# LM logical-axis rules (roofline dry-run)
# ---------------------------------------------------------------------------

# (leaf name, rank) -> ordered TP candidate dims (stack axis not counted)
MODEL_DIM_PREFS = {
    ("embed", 2): [0], ("head", 2): [0],
    # canonical Megatron flow: shard q heads; kv heads replicate when they
    # don't divide (NO head_dim fallback — contracting a sharded head_dim
    # turns every flash score tile into a partial-sum all-reduce)
    ("wq", 3): [1], ("wk", 3): [1], ("wv", 3): [1],
    ("wo", 3): [0],
    ("bq", 2): [0], ("bk", 2): [0], ("bv", 2): [0],
    # MLA
    ("w_dkv", 2): [0], ("w_uk", 3): [1], ("w_uv", 3): [1],
    ("w_kr", 2): [], ("w_dq", 2): [0], ("w_uq", 3): [1],
    # dense MLP
    ("w_up", 2): [1], ("w_gate", 2): [1], ("w_down", 2): [0],
    # MoE (expert parallelism on the expert axis)
    ("router", 2): [1],
    ("w_up", 3): [0], ("w_gate", 3): [0], ("w_down", 3): [0],
    ("sh_up", 2): [1], ("sh_gate", 2): [1], ("sh_down", 2): [0],
    # Mamba
    ("in_proj", 2): [1], ("conv_w", 2): [1], ("conv_b", 1): [0],
    ("x_proj", 2): [0], ("dt_proj", 2): [1], ("dt_bias", 1): [0],
    ("A_log", 2): [0], ("D", 1): [0], ("out_proj", 2): [0],
    # xLSTM
    ("up", 2): [1], ("down", 2): [0], ("up_gate", 2): [1],
    ("wi", 2): [0], ("wf", 2): [0], ("gn", 1): [], ("r", 3): [1, 2],
    ("wx", 2): [1], ("b", 1): [],
    # norms / misc (replicated)
    ("scale", 1): [], ("bias", 1): [], ("q_norm", 1): [], ("k_norm", 1): [],
    ("dt_norm", 1): [], ("b_norm", 1): [], ("c_norm", 1): [],
}

# KV / state cache leaves: TP candidates per name
CACHE_MODEL_PREFS = {
    "k": [2, 3], "v": [2, 3],        # (B, S, kv_heads, hd)
    "k_scale": [2], "v_scale": [2],  # int8-cache scales (B, S, kv, 1)
    "ckv": [2], "k_rope": [2],       # (B, S, lora/rope)
    "ssm": [1], "conv": [2],         # (B, di, N) / (B, k-1, di)
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def _is_stacked(path) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and e.key == "stack"
               for e in path)


_ATTN_LEAVES = {"wq", "wk", "wv", "wo", "bq", "bk", "bv", "w_dkv", "w_uk",
                "w_uv", "w_kr", "w_dq", "w_uq", "q_norm", "k_norm"}


def param_spec(path, shape, mesh, *, fsdp_threshold: int = 2048,
               no_attn_tp: bool = False) -> P:
    """PartitionSpec for one parameter leaf."""
    name = _leaf_name(path)
    stacked = _is_stacked(path)
    off = 1 if stacked else 0
    rank = len(shape) - off
    model = axis_size(mesh, "model")
    data = axis_size(mesh, "data")

    spec = [None] * len(shape)
    prefs = MODEL_DIM_PREFS.get((name, rank))
    if prefs is None:
        prefs = []                       # unknown leaf -> replicate TP
    if no_attn_tp and name in _ATTN_LEAVES:
        prefs = []                       # replicate attn over the TP axis
    model_dim = None
    for d in prefs:
        dd = d + off
        if shape[dd] % model == 0 and shape[dd] >= model:
            spec[dd] = "model"
            model_dim = dd
            break

    # FSDP: largest remaining dim divisible by `data` and big enough
    if data > 1:
        cands = [d for d in range(off, len(shape))
                 if d != model_dim and shape[d] % data == 0
                 and shape[d] >= fsdp_threshold]
        if cands:
            best = max(cands, key=lambda d: shape[d])
            spec[best] = "data"
    return P(*spec)


def param_shardings(mesh, params_tree: Pytree,
                    fsdp_threshold: int = 2048,
                    no_attn_tp: bool = False) -> Pytree:
    """NamedSharding tree matching a (shape-only or concrete) params tree."""
    def leaf(path, x):
        return NamedSharding(mesh, param_spec(
            path, x.shape, mesh, fsdp_threshold=fsdp_threshold,
            no_attn_tp=no_attn_tp))
    return jax.tree_util.tree_map_with_path(leaf, params_tree)


def opt_state_shardings(mesh, opt_shapes,
                        no_attn_tp: bool = False) -> Pytree:
    """Optimizer state: mu/nu leaves mirror the param specs (their leaf
    names are the param names), scalars (step) replicate."""
    def leaf(path, x):
        if len(x.shape) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, param_spec(path, x.shape, mesh,
                                              no_attn_tp=no_attn_tp))
    return jax.tree_util.tree_map_with_path(leaf, opt_shapes)


def cache_spec(path, shape, mesh, *, global_batch: int) -> P:
    name = _leaf_name(path)
    stacked = _is_stacked(path)
    off = 1 if stacked else 0
    model = axis_size(mesh, "model")
    dp = 1
    for a in batch_axes(mesh):
        dp *= axis_size(mesh, a)

    spec = [None] * len(shape)
    # batch dim
    if shape[off] % dp == 0 and shape[off] >= dp:
        spec[off] = batch_axes(mesh)
        batch_sharded = True
    else:
        batch_sharded = False

    prefs = CACHE_MODEL_PREFS.get(name)
    if prefs is None:
        # tuple states (mLSTM c/n/m, sLSTM): try dims after batch
        prefs = list(range(1, len(shape) - off))
    for d in prefs:
        dd = d + off
        if dd < len(shape) and shape[dd] % model == 0 and shape[dd] >= model:
            spec[dd] = "model"
            break

    # unshardable batch (e.g. long_500k batch=1): shard the seq dim on data
    if not batch_sharded and name in ("k", "v", "ckv", "k_rope"):
        seq_dim = off + 1
        data = axis_size(mesh, "data")
        if spec[seq_dim] is None and shape[seq_dim] % data == 0:
            spec[seq_dim] = "data"
    return P(*spec)


def cache_shardings(mesh, cache_tree: Pytree, global_batch: int) -> Pytree:
    def leaf(path, x):
        return NamedSharding(mesh, cache_spec(path, x.shape, mesh,
                                              global_batch=global_batch))
    return jax.tree_util.tree_map_with_path(leaf, cache_tree)


def batch_shardings(mesh, batch_tree: Pytree) -> Pytree:
    """Token batches: shard dim0 on (pod, data) when divisible."""
    dp = 1
    for a in batch_axes(mesh):
        dp *= axis_size(mesh, a)

    def leaf(x):
        if x.shape and x.shape[0] % dp == 0 and x.shape[0] >= dp:
            return NamedSharding(mesh, P(batch_axes(mesh),
                                         *([None] * (len(x.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(x.shape))))
    return jax.tree_util.tree_map(leaf, batch_tree)


def replicated(mesh, tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, P(*([None] * len(x.shape)))), tree)
