"""Seeded traffic generators for the streaming twin-serving layer.

Streaming correctness depends on *scheduling* — batch composition,
eviction order, state handoff — so the serving loop is exercised with
reproducible arrival traces rather than live load: every generator is a
pure function of its seed, and a trace replayed through
:class:`repro.launch.fleet_serving.StreamingFleetServer` makes the whole
schedule (batches, evictions, carried states) deterministic.  The
stress-test invariants (``tests/traffic.py``) and the latency benchmark
(``benchmarks/run.py --only serving_latency``) both draw from here.

Shapes of traffic:

  ``poisson_trace``      memoryless sensor uplinks — the steady-state
                         workload the latency benchmark measures;
  ``bursty_trace``       synchronized fleet wake-ups (burst of requests,
                         quiet gap) — stresses batch assembly;
  ``all_cold_trace``     every request hits a twin never seen before —
                         maximal paging pressure, zero hot reuse;
  ``hot_loop_trace``     every request hits ONE twin — continuous
                         batching degenerates to serial windows, the
                         per-twin ordering invariant's worst case;
  ``ragged_trace``       log-uniform horizons — maximal padding waste
                         per batch, exercises the per-time-chunk padding.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One streaming request: advance ``twin_id`` by ``horizon`` RK4
    steps, arriving at virtual time ``time`` (seconds).  ``deadline``
    (same clock) is the latest the request may still be *started*;
    ``None`` means no deadline — the admission-control path ignores
    it."""
    time: float
    twin_id: int
    horizon: int
    deadline: Optional[float] = None


def _emit(times, twins, horizons, deadlines=None) -> List[Arrival]:
    order = np.argsort(times, kind="stable")
    return [Arrival(float(times[i]), int(twins[i]), int(horizons[i]),
                    None if deadlines is None else float(deadlines[i]))
            for i in order]


def poisson_trace(seed: int, n_requests: int, *, rate_hz: float = 200.0,
                  population: int = 64, min_horizon: int = 4,
                  max_horizon: int = 32) -> List[Arrival]:
    """Memoryless arrivals: exponential inter-arrival gaps at
    ``rate_hz``, twin ids uniform over ``population``, horizons uniform
    in ``[min_horizon, max_horizon]``."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n_requests)
    times = np.cumsum(gaps)
    twins = rng.integers(0, population, size=n_requests)
    horizons = rng.integers(min_horizon, max_horizon + 1, size=n_requests)
    return _emit(times, twins, horizons)


def bursty_trace(seed: int, n_requests: int, *, burst_size: int = 16,
                 burst_gap_s: float = 0.05, population: int = 64,
                 min_horizon: int = 4, max_horizon: int = 32
                 ) -> List[Arrival]:
    """Synchronized wake-ups: ``burst_size`` near-simultaneous requests,
    then a quiet gap — the batcher sees deep queues and empty ones."""
    rng = np.random.default_rng(seed)
    burst_idx = np.arange(n_requests) // burst_size
    jitter = rng.uniform(0.0, 1e-4, size=n_requests)
    times = burst_idx * burst_gap_s + jitter
    twins = rng.integers(0, population, size=n_requests)
    horizons = rng.integers(min_horizon, max_horizon + 1, size=n_requests)
    return _emit(times, twins, horizons)


def all_cold_trace(seed: int, n_requests: int, *, rate_hz: float = 200.0,
                   min_horizon: int = 4, max_horizon: int = 32
                   ) -> List[Arrival]:
    """Adversarial paging: request i targets twin i — no twin is ever
    re-requested, so every fetch is a page-in and (once the hot slab
    fills) every promotion an eviction."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    twins = np.arange(n_requests)
    horizons = rng.integers(min_horizon, max_horizon + 1, size=n_requests)
    return _emit(times, twins, horizons)


def hot_loop_trace(seed: int, n_requests: int, *, rate_hz: float = 200.0,
                   twin_id: int = 0, min_horizon: int = 4,
                   max_horizon: int = 32) -> List[Arrival]:
    """Adversarial serialisation: every request targets one twin, so no
    two can share a batch (each window consumes the previous one's end
    state) — continuous batching must degrade to in-order windows, never
    reorder or coalesce them."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    twins = np.full(n_requests, twin_id)
    horizons = rng.integers(min_horizon, max_horizon + 1, size=n_requests)
    return _emit(times, twins, horizons)


def ragged_trace(seed: int, n_requests: int, *, rate_hz: float = 200.0,
                 population: int = 64, max_horizon: int = 128
                 ) -> List[Arrival]:
    """Adversarial padding: horizons log-uniform in [1, max_horizon] —
    most batches mix tiny and huge windows, maximising the padded tail
    the chunk-carry kernel streams past."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    twins = rng.integers(0, population, size=n_requests)
    horizons = np.exp(rng.uniform(0.0, np.log(max_horizon),
                                  size=n_requests)).astype(int) + 1
    return _emit(times, twins, horizons)


def deadline_trace(seed: int, n_requests: int, *, rate_hz: float = 200.0,
                   population: int = 64, min_horizon: int = 4,
                   max_horizon: int = 32, slack_s: float = 0.5,
                   tight_fraction: float = 0.25) -> List[Arrival]:
    """Poisson arrivals where every request carries a deadline: most get
    ``slack_s`` of headroom (comfortably served), but a
    ``tight_fraction`` get essentially zero slack — they expire the
    moment any later arrival's pump looks at them.  The admission-
    control trace: a correct server sheds exactly the stale ones and
    accounts for every seq once."""
    rng = np.random.default_rng(seed)
    times = np.cumsum(rng.exponential(1.0 / rate_hz, size=n_requests))
    twins = rng.integers(0, population, size=n_requests)
    horizons = rng.integers(min_horizon, max_horizon + 1, size=n_requests)
    tight = rng.random(n_requests) < tight_fraction
    deadlines = times + np.where(tight, 1e-9, slack_s)
    return _emit(times, twins, horizons, deadlines)


#: name -> generator, for CLI/benchmark selection.
TRACES = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "all_cold": all_cold_trace,
    "hot_loop": hot_loop_trace,
    "ragged": ragged_trace,
    "deadline": deadline_trace,
}


def population_of(trace) -> int:
    """Number of distinct twins a trace touches (registration size)."""
    return len({a.twin_id for a in trace})
