"""Batched serving driver: prefill a batch of prompts, then step the
decode loop with the pre-allocated KV/state caches — the CPU-scale twin
of the ``decode_*`` dry-run cells.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.models.model import decode_step, forward, init_cache, init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    max_seq = args.prompt_len + args.gen
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)

    step = jax.jit(lambda p, t, pos, c: decode_step(cfg=cfg, params=p,
                                                    tokens=t, pos=pos,
                                                    cache=c))
    cache = init_cache(cfg, args.batch, max_seq)

    # prefill by stepping (keeps one compiled program; a chunked-prefill
    # path is the production option)
    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, prompts[:, i:i + 1],
                             jnp.asarray(i, jnp.int32), cache)
    prefill_s = time.time() - t0

    toks = [jnp.argmax(logits[:, -1, :], -1)]
    t0 = time.time()
    for j in range(args.gen - 1):
        logits, cache = step(params, toks[-1][:, None].astype(jnp.int32),
                             jnp.asarray(args.prompt_len + j, jnp.int32),
                             cache)
        toks.append(jnp.argmax(logits[:, -1, :], -1))
    gen_s = time.time() - t0
    out = jnp.stack(toks, axis=1)

    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} steps: {prefill_s:.2f}s | decode "
          f"{args.gen} steps: {gen_s:.2f}s "
          f"({args.batch * args.gen / max(gen_s, 1e-9):.1f} tok/s)")
    print("sample continuation token ids:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
