"""LM training driver — the end-to-end "train a ~100M model for a few
hundred steps" entry point, with the production fault-tolerance loop:

* checkpoint/restart: atomic async checkpoints every --ckpt-every steps,
  automatic resume from the newest one (exact data replay via the
  stateless pipeline);
* preemption handling: SIGTERM/SIGINT trigger a final checkpoint before
  exit (the cluster scheduler contract);
* optional int8 gradient compression with error feedback;
* microbatch gradient accumulation;
* XLA latency-hiding-scheduler flags recorded below are what a real TPU
  launch would set for compute/collective overlap (no-ops on CPU):
    --xla_tpu_enable_latency_hiding_scheduler=true
    --xla_tpu_overlap_compute_collective_tc=true

Usage (CPU demo, ~100M model):
  PYTHONPATH=src python -m repro.launch.legacy.train --arch qwen3-1.7b --smoke \
      --d-model 512 --layers 8 --steps 200
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data.tokens import TokenPipeline
from repro.train import checkpoint as ckpt_lib
from repro.train.compression import compressed
from repro.train.lm_trainer import make_train_step
from repro.train.optimizer import adam, warmup_cosine_schedule


def build_config(args):
    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
    if args.layers:
        overrides["n_layers"] = args.layers
    if args.vocab:
        overrides["vocab"] = args.vocab
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-bits", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = build_config(args)
    from repro.configs.base import param_count
    print(f"arch={cfg.name}  params~{param_count(cfg)/1e6:.1f}M  "
          f"batch={args.batch}x{args.seq}")

    opt = adam(warmup_cosine_schedule(args.lr, 20, args.steps),
               grad_clip=1.0)
    if args.compress_bits:
        opt = compressed(opt, bits=args.compress_bits)
        print(f"int{args.compress_bits} gradient compression "
              f"(error feedback) enabled")
    step_fn = jax.jit(make_train_step(cfg, opt, accum_steps=args.accum))

    from repro.models.model import init_params
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    start_step = 0

    if args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None:
            print(f"resuming from checkpoint step {latest}")
            state = ckpt_lib.restore(args.ckpt_dir, latest,
                                     {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         batch=args.batch, mode="markov")

    stop = {"flag": False}

    def _preempt(signum, frame):
        print(f"\n[preemption] signal {signum}: checkpointing and exiting")
        stop["flag"] = True

    signal.signal(signal.SIGTERM, _preempt)
    signal.signal(signal.SIGINT, _preempt)

    t0 = time.time()
    losses = []
    step = start_step
    for step in range(start_step, args.steps):
        batch = pipe.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * (step - start_step + 1) / \
                max(time.time() - t0, 1e-9)
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"({tok_s:,.0f} tok/s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, step + 1,
                          {"params": params, "opt": opt_state},
                          blocking=False)
        if stop["flag"]:
            break

    if args.ckpt_dir and losses:
        ckpt_lib.save(args.ckpt_dir, step + 1,
                      {"params": params, "opt": opt_state}, blocking=True)
        ckpt_lib.wait_for_async()
    if losses:
        print(f"done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    else:
        print("nothing to do (already at target step)")
    return losses


if __name__ == "__main__":
    main()
