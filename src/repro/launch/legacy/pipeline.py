"""GPipe-style pipeline parallelism over a "pipe" mesh axis.

Optional at the 512-chip scale (the default production mesh uses
DP x TP/EP; PP becomes attractive beyond ~1k chips or for >400B dense
models).  Implemented with ``shard_map`` + ``lax.ppermute``: stage
parameters are sharded along the pipe axis, microbatches stream through
the classic GPipe schedule (n_micro + n_stages - 1 ticks), activations
hop stage-to-stage over ICI neighbours (the collective-permute pattern).

Differentiable end-to-end (ppermute has a transpose rule), so the same
machinery backs pipelined training; bubble fraction = (S-1)/(M+S-1).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def make_pipeline_forward(block_fn: Callable, n_stages: int, n_micro: int,
                          mesh):
    """Returns fwd(stacked_params, x) running x through n_stages blocks.

    ``stacked_params``: pytree with a leading stage axis (n_stages, ...),
    sharded P('pipe', ...); ``x``: (n_micro, micro_batch, ...) replicated.
    ``block_fn(params_one_stage, x_micro) -> y_micro`` (same shape).
    """
    from jax.experimental.shard_map import shard_map

    def per_device(params, x):
        stage = lax.axis_index("pipe")
        my_params = jax.tree_util.tree_map(lambda p: p[0], params)
        ticks = n_micro + n_stages - 1
        zero = jnp.zeros_like(x[0])

        def tick(carry, t):
            recv, outs = carry
            idx = jnp.clip(t, 0, n_micro - 1)
            inp = jnp.where(stage == 0,
                            jnp.where(t < n_micro, x[idx], zero), recv)
            y = block_fn(my_params, inp)
            # pass activations to the next stage (ring; last link unused)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            recv = lax.ppermute(y, "pipe", perm)
            out_idx = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (out_idx >= 0)
            outs = lax.cond(
                write,
                lambda o: lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            return (recv, outs), None

        outs0 = jnp.zeros_like(x)
        (recv, outs), _ = lax.scan(tick, (zero, outs0), jnp.arange(ticks))
        # broadcast final outputs from the last stage to every device
        outs = outs * (stage == n_stages - 1).astype(outs.dtype)
        return lax.psum(outs, "pipe")

    return shard_map(per_device, mesh=mesh,
                     in_specs=(P("pipe"), P()), out_specs=P(),
                     check_rep=False)


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
