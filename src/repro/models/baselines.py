"""Digital baselines the paper compares against: recurrent ResNet (HP twin,
Fig. 3j) and LSTM / GRU / RNN (Lorenz96, Fig. 4g-i).  From-scratch cells.

All models share one contract for the twin tasks:
  * driven (HP):    carry -> carry', given input u_t; observable via head.
  * autonomous (L96): next-state predictor y_t -> y_{t+1}; teacher-forced
    training, closed-loop rollout at evaluation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.node import dense_linear, mlp_apply, mlp_init


# ---------------------------------------------------------------------------
# Recurrent ResNet (paper Eq. 8): h_{t+1} = h_t + f([u_t, h_t])
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RecurrentResNet:
    """Finite-depth discrete-transition model — the paper's digital twin
    baseline.  Same MLP sizes as the neural ODE for parameter parity."""
    sizes: tuple          # (u_dim + state_dim, hidden..., state_dim)
    state_dim: int

    def init(self, key):
        # Near-identity residual init: zero the last layer so the T-step
        # transition starts as h_{t+1} = h_t.  With a generic last layer
        # the 50-step training segments compound O(1) residuals into
        # overflow before the first update and training diverges to NaN
        # (seed 42 did exactly that).
        params = mlp_init(key, self.sizes)
        params[-1] = {"w": jnp.zeros_like(params[-1]["w"]),
                      "b": params[-1]["b"]}
        return params

    def rollout(self, params, y0: jax.Array, us: jax.Array) -> jax.Array:
        """y0: (state,); us: (T, u_dim) drive samples. Returns (T+1, state)."""
        def step(y, u):
            inp = jnp.concatenate([u, y], axis=-1)
            y = y + mlp_apply(params, inp)
            return y, y

        _, ys = lax.scan(step, y0, us)
        return jnp.concatenate([y0[None], ys], axis=0)


# ---------------------------------------------------------------------------
# Gated recurrent cells (from scratch)
# ---------------------------------------------------------------------------

def _dense_init(key, din, dout, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(din)
    kw, _ = jax.random.split(key)
    return {"w": scale * jax.random.normal(kw, (din, dout)),
            "b": jnp.zeros((dout,))}


def lstm_init(key, in_dim, hidden):
    ks = jax.random.split(key, 2)
    return {"wx": _dense_init(ks[0], in_dim, 4 * hidden),
            "wh": _dense_init(ks[1], hidden, 4 * hidden)}


def lstm_step(params, carry, x):
    h, c = carry
    z = (dense_linear(params["wx"]["w"], params["wx"]["b"], x)
         + dense_linear(params["wh"]["w"], params["wh"]["b"], h))
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def gru_init(key, in_dim, hidden):
    ks = jax.random.split(key, 2)
    return {"wx": _dense_init(ks[0], in_dim, 3 * hidden),
            "wh": _dense_init(ks[1], hidden, 3 * hidden)}


def gru_step(params, carry, x):
    h = carry
    zx = dense_linear(params["wx"]["w"], params["wx"]["b"], x)
    zh = dense_linear(params["wh"]["w"], params["wh"]["b"], h)
    rx, ux, cx = jnp.split(zx, 3, axis=-1)
    rh, uh, ch = jnp.split(zh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    u = jax.nn.sigmoid(ux + uh)
    c = jnp.tanh(cx + r * ch)
    h = u * h + (1 - u) * c
    return h, h


def rnn_init(key, in_dim, hidden):
    ks = jax.random.split(key, 2)
    return {"wx": _dense_init(ks[0], in_dim, hidden),
            "wh": _dense_init(ks[1], hidden, hidden)}


def rnn_step(params, carry, x):
    h = carry
    h = jnp.tanh(dense_linear(params["wx"]["w"], params["wx"]["b"], x)
                 + dense_linear(params["wh"]["w"], params["wh"]["b"], h))
    return h, h


CELLS = {
    "lstm": (lstm_init, lstm_step,
             lambda h: (jnp.zeros((h,)), jnp.zeros((h,)))),
    "gru": (gru_init, gru_step, lambda h: jnp.zeros((h,))),
    "rnn": (rnn_init, rnn_step, lambda h: jnp.zeros((h,))),
}


@dataclasses.dataclass(frozen=True)
class RecurrentForecaster:
    """cell + linear head; next-step prediction of a multivariate series."""
    cell: str
    in_dim: int
    hidden: int
    out_dim: int

    def init(self, key):
        cinit, _, _ = CELLS[self.cell]
        k1, k2 = jax.random.split(key)
        return {"cell": cinit(k1, self.in_dim, self.hidden),
                "head": _dense_init(k2, self.hidden, self.out_dim)}

    def _step(self, params, carry, x):
        _, cstep, _ = CELLS[self.cell]
        carry, h = cstep(params["cell"], carry, x)
        y = dense_linear(params["head"]["w"], params["head"]["b"], h)
        return carry, y

    def teacher_forced(self, params, ys: jax.Array) -> jax.Array:
        """Predict ys[1:] from ys[:-1]; returns (T-1, out_dim)."""
        _, _, c0 = CELLS[self.cell]
        carry = c0(self.hidden)
        step = lambda c, x: self._step(params, c, x)
        _, preds = lax.scan(step, carry, ys[:-1])
        return preds

    def closed_loop(self, params, y0: jax.Array, num_steps: int,
                    warmup: jax.Array | None = None) -> jax.Array:
        """Autoregressive rollout from y0 (optionally after a warmup prefix);
        returns (num_steps+1, out_dim) including y0."""
        _, _, c0 = CELLS[self.cell]
        carry = c0(self.hidden)
        if warmup is not None:
            step = lambda c, x: (self._step(params, c, x)[0], None)
            carry, _ = lax.scan(step, carry, warmup)

        def step(state, _):
            carry, y = state
            carry, y = self._step(params, carry, y)
            return (carry, y), y

        (_, _), ys = lax.scan(step, (carry, y0), None, length=num_steps)
        return jnp.concatenate([y0[None], ys], axis=0)
