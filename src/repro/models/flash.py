"""Memory-bounded causal attention: online-softmax over KV chunks
(the FlashAttention schedule in pure JAX, lax.scan over chunk grids).

Scores never materialise beyond one (q_chunk x kv_chunk) tile per head —
this is what lets the 32k prefill and 4k train cells fit HBM without a
custom kernel; XLA fuses the tile loop body.  Supports additive score
decompositions (list of (q_i, k_i) parts) so MLA's latent+rope scoring
and GQA's grouped heads share one implementation.

Perf knobs (see EXPERIMENTS.md §Perf):
* ``causal_skip=True`` — statically banded kv loop: q-chunk qi only visits
  kv chunks that can be visible, skipping the fully-masked upper triangle
  (~2x fewer score tiles + FLOPs).  Static python unroll over q chunks
  (exact trip counts for the roofline parser) up to 32 chunks, dynamic
  ``fori_loop`` beyond.
* ``score_dtype`` — dtype of the materialised score/prob tiles.  The
  online max-subtraction bounds exp() in [0,1], so bf16 tiles cost ~1e-2
  relative logit error while halving the dominant HBM traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def _part_scores(q, k, scale, score_dtype):
    """q: (B,qc,H,d); k: (B,kc,Hkv,d) with Hkv | H. -> (B,H,qc,kc)."""
    b, qc, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, qc, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    return (s.reshape(b, h, qc, k.shape[1]) * scale).astype(score_dtype)


def _pv(p, v, h):
    """p: (B,H,qc,kc); v: (B,kc,Hkv,dv) -> (B,qc,H,dv) f32."""
    b, _, qc, kc = p.shape
    hkv = v.shape[2]
    g = h // hkv
    pg = p.reshape(b, hkv, g, qc, kc)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pg.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, qc, h, v.shape[-1])


def flash_attention(q_parts, k_parts, v, *, scale: float,
                    q_pos0=0, kv_pos0: int = 0,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    causal_skip: bool = False,
                    score_dtype=jnp.float32):
    """Causal attention with additive multi-part scores.

    q_parts: list of (B, Sq, H, d_i); k_parts: list of (B, Skv, Hkv_i, d_i)
    (Hkv_i must divide H); v: (B, Skv, Hkv_v, dv).
    Query i (absolute pos q_pos0+i) attends kv j (absolute kv_pos0+j) with
    j_abs <= i_abs.  Returns (B, Sq, H, dv).
    """
    b, sq, h, _ = q_parts[0].shape
    skv = k_parts[0].shape[1]
    dv = v.shape[-1]
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    assert sq % qc == 0 and skv % kc == 0
    nq, nk = sq // qc, skv // kc

    q_parts = [p.reshape(b, nq, qc, h, p.shape[-1]).swapaxes(0, 1)
               for p in q_parts]
    k_parts = [p.reshape(b, nk, kc, p.shape[2], p.shape[-1]).swapaxes(0, 1)
               for p in k_parts]
    v_c = v.reshape(b, nk, kc, v.shape[2], dv).swapaxes(0, 1)

    q_pos = q_pos0 + jnp.arange(sq).reshape(nq, qc)
    kv_pos = kv_pos0 + jnp.arange(skv).reshape(nk, kc)

    def make_kv_step(qi_parts, qpos):
        def step(carry, kv_in):
            m, l, acc = carry
            kjs, vj, kpos = kv_in[:-2], kv_in[-2], kv_in[-1]
            s = sum(_part_scores(qq, kk, scale, score_dtype)
                    for qq, kk in zip(qi_parts, kjs))     # (B,H,qc,kc)
            mask = kpos[None, :] <= qpos[:, None]         # (qc,kc)
            s = jnp.where(mask[None, None], s, score_dtype(NEG_INF)
                          if score_dtype == jnp.float32 else
                          jnp.asarray(-3e38, score_dtype))
            s32 = s.astype(jnp.float32)
            m_new = jnp.maximum(m, s32.max(-1))
            p = jnp.exp(s32 - m_new[..., None]).astype(score_dtype)
            corr = jnp.exp(m - m_new)
            l = l * corr + p.astype(jnp.float32).sum(-1)
            acc = acc * corr[..., None] + \
                _pv(p, vj, h).swapaxes(1, 2)              # (B,H,qc,dv)
            return (m_new, l, acc), None
        return step

    def init_carry():
        return (jnp.full((b, h, qc), NEG_INF, jnp.float32),
                jnp.zeros((b, h, qc), jnp.float32),
                jnp.zeros((b, h, qc, dv), jnp.float32))

    def finish(m, l, acc):
        return (acc / jnp.maximum(l, 1e-30)[..., None]).swapaxes(1, 2)

    if causal_skip and nq <= 32:
        # statically banded: q-chunk qi visits ceil(((qi+1)*qc)/kc) kv
        # chunks (exact trip counts -> exact roofline accounting)
        outs = []
        for qi in range(nq):
            last_abs = int(q_pos0) + (qi + 1) * qc - 1 if isinstance(
                q_pos0, int) else (qi + 1) * qc - 1
            nk_i = min((last_abs - int(kv_pos0)) // kc + 1, nk) \
                if isinstance(q_pos0, int) else min(
                    ((qi + 1) * qc - 1) // kc + 1, nk)
            nk_i = max(nk_i, 1)
            qi_parts = [p[qi] for p in q_parts]
            step = make_kv_step(qi_parts, q_pos[qi])
            xs = tuple(kp[:nk_i] for kp in k_parts) + \
                (v_c[:nk_i], kv_pos[:nk_i])
            (m, l, acc), _ = lax.scan(step, init_carry(), xs)
            outs.append(finish(m, l, acc))
        out = jnp.stack(outs, axis=0)
    elif causal_skip:
        # dynamic banded loop (very long sequences); NOTE: the HLO
        # roofline parser cannot see the dynamic trip count — prefer the
        # static path for measured cells.
        def q_step(_, q_in):
            qi_parts, qpos = q_in[:-1], q_in[-1]
            step = make_kv_step(qi_parts, qpos)
            last_q = qpos[-1]
            nk_needed = jnp.clip((last_q - kv_pos0) // kc + 1, 1,
                                 nk).astype(jnp.int32)

            def body(i, carry):
                kv_in = tuple(kp[i] for kp in k_parts) + \
                    (v_c[i], kv_pos[i])
                new_carry, _ = step(carry, kv_in)
                return new_carry

            m, l, acc = lax.fori_loop(0, nk_needed, body, init_carry())
            return None, finish(m, l, acc)

        _, out = lax.scan(q_step, None, tuple(q_parts) + (q_pos,))
    else:
        def q_step(_, q_in):
            qi_parts, qpos = q_in[:-1], q_in[-1]
            step = make_kv_step(qi_parts, qpos)
            (m, l, acc), _ = lax.scan(step, init_carry(),
                                      tuple(k_parts) + (v_c, kv_pos))
            return None, finish(m, l, acc)

        _, out = lax.scan(q_step, None, tuple(q_parts) + (q_pos,))

    return out.swapaxes(0, 1).reshape(b, sq, h, dv).astype(v.dtype)
