"""Attention variants: GQA (llama/qwen/internlm/musicgen/chameleon/jamba)
and MLA (DeepSeek-V2 multi-head latent attention, compressed KV cache).

Both expose the same three entry points:
    init(key, cfg)                    -> params
    prefill(params, cfg, x, pos)      -> (out, cache)
    decode(params, cfg, x, pos, cache)-> (out, cache)

Cache layouts:
    GQA: {"k": (B, S_max, n_kv, hd), "v": same}
    MLA: {"ckv": (B, S_max, kv_lora), "k_rope": (B, S_max, rope_dim)}
    (the MLA cache is the paper-faithful compressed latent — ~1/serveral
    of the GQA cache at 128 heads)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.flash import flash_attention
from repro.models.layers import apply_rope, dense_init, head_rmsnorm

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False           # qwen3
    qkv_bias: bool = False          # qwen1.5
    # MLA-specific
    kv_lora: int = 0                # >0 selects MLA
    q_lora: int = 0                 # 0 = direct q projection
    rope_dim: int = 64
    v_head_dim: int = 0             # defaults to head_dim
    # memory-bounded attention (flash schedule) for long sequences
    flash_threshold: int = 1024
    q_chunk: int = 512
    kv_chunk: int = 512
    causal_skip: bool = False
    score_dtype: str = "float32"   # bfloat16 halves score-tile traffic
    kv_cache_quant: bool = False   # int8 KV cache (per-token-head scales)


def _causal_mask(sq: int, skv: int, offset) -> jax.Array:
    """(sq, skv) boolean mask; query i attends kv j where j <= i + offset."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    return kj <= qi


def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,Hq,hd) k/v: (B,Skv,Hkv,hd) grouped; fp32 softmax."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    q = q.reshape(b, sq, hkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype),
        "wk": dense_init(ks[1], (d, kvh, hd), dtype),
        "wv": dense_init(ks[2], (d, kvh, hd), dtype),
        "wo": dense_init(ks[3], (h, hd, d), dtype, scale=(h * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kvh, hd), dtype)
        p["bv"] = jnp.zeros((kvh, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _gqa_qkv(params, cfg: AttnConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"], q)
        k = head_rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_prefill(params, cfg: AttnConfig, x, *, pos0: int = 0):
    b, s, _ = x.shape
    positions = pos0 + jnp.arange(s)[None, :]
    q, k, v = _gqa_qkv(params, cfg, x, positions)
    scale = cfg.head_dim ** -0.5
    if s > cfg.flash_threshold:
        out = flash_attention([q], [k], v, scale=scale, q_pos0=pos0,
                              kv_pos0=pos0, q_chunk=cfg.q_chunk,
                              kv_chunk=cfg.kv_chunk,
                              causal_skip=cfg.causal_skip,
                              score_dtype=jnp.dtype(cfg.score_dtype).type)
    else:
        mask = _causal_mask(s, s, 0)
        out = _sdpa(q, k, v, mask, scale)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, {"k": k, "v": v}


def _quant_kv(t):
    """(B,1,H,hd) -> (int8 values, f32 per-(B,1,H,1) scales)."""
    scale = jnp.max(jnp.abs(t), axis=-1, keepdims=True).astype(
        jnp.float32) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale), -127,
                 127).astype(jnp.int8)
    return q, scale


def gqa_decode(params, cfg: AttnConfig, x, pos, cache):
    """x: (B, 1, d); pos: scalar int32 (current index); cache pre-allocated
    to S_max.  Returns (out, cache').

    With ``kv_cache_quant`` the cache stores int8 values + per-token-head
    scales (KIVI-style): 2x less HBM footprint and read traffic — the fix
    that puts qwen1.5's 40-head MHA 32k cache under the 16 GB/chip budget.
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _gqa_qkv(params, cfg, x, positions)
    upd = lambda buf, val: jax.lax.dynamic_update_slice_in_dim(
        buf, val, pos, axis=1)
    if cfg.kv_cache_quant:
        kq, ks = _quant_kv(k)
        vq, vs = _quant_kv(v)
        cache = {"k": upd(cache["k"], kq), "v": upd(cache["v"], vq),
                 "k_scale": upd(cache["k_scale"], ks),
                 "v_scale": upd(cache["v_scale"], vs)}
        ck = cache["k"].astype(q.dtype) * cache["k_scale"].astype(q.dtype)
        cv = cache["v"].astype(q.dtype) * cache["v_scale"].astype(q.dtype)
    else:
        cache = {"k": upd(cache["k"], k), "v": upd(cache["v"], v)}
        ck, cv = cache["k"], cache["v"]
    skv = ck.shape[1]
    mask = jnp.arange(skv)[None, :] <= pos          # (1, skv)
    out = _sdpa(q, ck, cv, mask, cfg.head_dim ** -0.5)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: AttnConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    vd = cfg.v_head_dim or hd
    p = {
        # KV compression path
        "w_dkv": dense_init(ks[0], (d, cfg.kv_lora), dtype),
        "w_uk": dense_init(ks[1], (cfg.kv_lora, h, hd), dtype),
        "w_uv": dense_init(ks[2], (cfg.kv_lora, h, vd), dtype),
        "w_kr": dense_init(ks[3], (d, cfg.rope_dim), dtype),
        "wo": dense_init(ks[4], (h, vd, d), dtype, scale=(h * vd) ** -0.5),
    }
    if cfg.q_lora:
        p["w_dq"] = dense_init(ks[5], (d, cfg.q_lora), dtype)
        p["w_uq"] = dense_init(ks[6], (cfg.q_lora, h, hd + cfg.rope_dim),
                               dtype)
    else:
        p["wq"] = dense_init(ks[5], (d, h, hd + cfg.rope_dim), dtype)
    return p


def _mla_q(params, cfg: AttnConfig, x, positions):
    if cfg.q_lora:
        cq = x @ params["w_dq"]
        q = jnp.einsum("bsl,lhk->bshk", cq, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = q[..., :cfg.head_dim], q[..., cfg.head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_attend(params, cfg, q_nope, q_rope, ckv, k_rope, mask):
    """Absorbed-matrix MLA attention: scores computed against the latent
    cache directly (q_nope absorbed through w_uk), so the per-token cache
    is kv_lora + rope_dim — the paper-faithful compressed KV."""
    vd = cfg.v_head_dim or cfg.head_dim
    scale = (cfg.head_dim + cfg.rope_dim) ** -0.5
    # absorb W_uk into the query:  (B,S,H,hd) x (lora,H,hd) -> (B,S,H,lora)
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, params["w_uk"])
    s_lat = jnp.einsum("bshl,btl->bhst", q_lat, ckv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scores = (s_lat + s_rope) * scale
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    o_lat = jnp.einsum("bhst,btl->bshl", probs, ckv)
    out = jnp.einsum("bshl,lhv->bshv", o_lat, params["w_uv"])
    return jnp.einsum("bshv,hvd->bsd", out, params["wo"])


def mla_prefill(params, cfg: AttnConfig, x, *, pos0: int = 0):
    b, s, _ = x.shape
    positions = pos0 + jnp.arange(s)[None, :]
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    ckv = x @ params["w_dkv"]                              # (B,S,lora)
    k_rope = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]        # (B,S,rope)
    if s > cfg.flash_threshold:
        # absorbed flash: latent + rope additive scores, latent values
        q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, params["w_uk"])
        scale = (cfg.head_dim + cfg.rope_dim) ** -0.5
        o_lat = flash_attention(
            [q_lat, q_rope], [ckv[:, :, None, :], k_rope[:, :, None, :]],
            ckv[:, :, None, :], scale=scale, q_pos0=pos0, kv_pos0=pos0,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            causal_skip=cfg.causal_skip,
            score_dtype=jnp.dtype(cfg.score_dtype).type)
        out = jnp.einsum("bshl,lhv->bshv", o_lat, params["w_uv"])
        out = jnp.einsum("bshv,hvd->bsd", out, params["wo"])
    else:
        mask = _causal_mask(s, s, 0)
        out = _mla_attend(params, cfg, q_nope, q_rope, ckv, k_rope, mask)
    return out, {"ckv": ckv, "k_rope": k_rope}


def mla_decode(params, cfg: AttnConfig, x, pos, cache):
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    ckv_new = x @ params["w_dkv"]
    kr_new = apply_rope((x @ params["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, pos,
                                              axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new,
                                                 pos, axis=1)
    mask = (jnp.arange(ckv.shape[1])[None, :] <= pos)
    out = _mla_attend(params, cfg, q_nope, q_rope, ckv, k_rope, mask)
    return out, {"ckv": ckv, "k_rope": k_rope}
