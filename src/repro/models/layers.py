"""Shared transformer layers: norms, RoPE, embeddings, MLPs.

Conventions used across the model zoo:
* params are nested dicts of jnp arrays; repeated layers are stacked on a
  leading "layers" axis and driven by ``lax.scan``;
* every initializer takes an explicit key; shapes follow (in, out) for
  matmuls so ``x @ w`` applies them;
* computation dtype = param dtype (bf16 for at-scale configs) with fp32
  softmax/norm accumulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


def head_rmsnorm(scale: jax.Array, x: jax.Array,
                 eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (qwen3): normalise over head_dim."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / embedding initialisers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype=jnp.float32, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else fan_in ** -0.5
    return (std * jax.random.normal(key, shape)).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, dim)) * dim ** -0.5).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                     # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN): SwiGLU / GELU / ReLU
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, mlp_type: str = "swiglu",
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), dtype)}
    if mlp_type == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def mlp_apply(params: dict, x: jax.Array, mlp_type: str = "swiglu"):
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif mlp_type == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    elif mlp_type == "relu":
        h = jax.nn.relu(x @ params["w_up"])
    else:
        raise ValueError(mlp_type)
    return h @ params["w_down"]


def mlp_flops(d_model: int, d_ff: int, mlp_type: str = "swiglu") -> int:
    mats = 3 if mlp_type == "swiglu" else 2
    return 2 * mats * d_model * d_ff


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """(B,S,d) @ (V,d)^T in fp32 accumulation."""
    return jnp.einsum("bsd,vd->bsv", x, table,
                      preferred_element_type=jnp.float32)
