"""Mixture-of-Experts layer: shared + routed experts, top-k routing,
capacity-bounded scatter dispatch (GShard-style, static shapes).

Covers DeepSeek-V2 (2 shared + 64/160 routed, top-6, softmax gates) and
Jamba (16 routed, top-2, renormalised gates).  Dispatch is O(T*k) memory:
tokens are argsorted by expert, given a position-in-expert, and scattered
into an (E, C, d) buffer (over-capacity tokens drop, the standard
trade-off); expert FFNs run as one batched einsum over the expert axis —
the axis the mesh shards (EP).  A Switch-style load-balancing aux loss is
returned for training.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert FFN width
    n_shared: int = 0              # always-active shared experts
    capacity_factor: float = 1.25
    norm_topk: bool = False        # renormalise the top-k gates (Mixtral)
    aux_weight: float = 0.01
    mlp_type: str = "swiglu"


def moe_init(key, cfg: MoEConfig, d_model: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    e, f = cfg.n_experts, cfg.d_ff
    p = {
        "router": dense_init(ks[0], (d_model, e), jnp.float32),
        "w_up": dense_init(ks[1], (e, d_model, f), dtype),
        "w_down": dense_init(ks[2], (e, f, d_model), dtype),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = dense_init(ks[3], (e, d_model, f), dtype)
    if cfg.n_shared:
        fs = cfg.n_shared * f
        p["sh_up"] = dense_init(ks[4], (d_model, fs), dtype)
        p["sh_down"] = dense_init(ks[5], (fs, d_model), dtype)
        if cfg.mlp_type == "swiglu":
            p["sh_gate"] = dense_init(ks[6], (d_model, fs), dtype)
    return p


def _expert_ffn(params, cfg: MoEConfig, x):           # x: (G, E, C, d)
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", x, params["w_gate"]))
        h = h * jnp.einsum("gecd,edf->gecf", x, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", x, params["w_up"]))
    return jnp.einsum("gecf,efd->gecd", h, params["w_down"])


def _shared_ffn(params, cfg: MoEConfig, x):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["sh_gate"]) * (x @ params["sh_up"])
    else:
        h = jax.nn.gelu(x @ params["sh_up"])
    return h @ params["sh_down"]


def capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(4, -(-c // 4) * 4)   # round up to a multiple of 4


def moe_apply(params, cfg: MoEConfig, x: jax.Array):
    """x: (B, S, d) -> (y, aux_loss).

    GShard-style GROUPED dispatch: each batch row is its own dispatch
    group (capacity enforced per group), and the scatter/gather runs
    under ``vmap`` over the batch dim.  This keeps every scatter local
    to the data shard that owns the row — without the group dim, GSPMD
    replicates the (T_global*k, d) scatter across the model axis and
    all-reduces it (measured: 4.2 TB/step on jamba train_4k, §Perf).
    """
    b, s, d = x.shape
    k = cfg.top_k
    e = cfg.n_experts
    c = capacity(s, cfg)

    logits = (x.astype(jnp.float32) @ params["router"])       # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                      # (B, S, k)
    if cfg.norm_topk:
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)

    def dispatch_one(xg, idx_g, gates_g):
        """xg: (S, d); returns (buf (E*C, d), st, sg, valid, slot)."""
        e_flat = idx_g.reshape(-1)                            # (S*k,)
        g_flat = gates_g.reshape(-1)
        tok = jnp.repeat(jnp.arange(s), k)
        order = jnp.argsort(e_flat)                           # stable
        se, st, sg = e_flat[order], tok[order], g_flat[order]
        starts = jnp.searchsorted(se, jnp.arange(e))          # (E,)
        pos = jnp.arange(s * k) - starts[se]
        valid = pos < c
        slot = jnp.where(valid, se * c + pos, e * c)          # OOB -> drop
        buf = jnp.zeros((e * c, d), x.dtype)
        buf = buf.at[slot].set(xg[st], mode="drop")
        return buf, st, sg, valid, slot

    bufs, st, sg, valid, slot = jax.vmap(dispatch_one)(x, idx, gates)
    out = _expert_ffn(params, cfg, bufs.reshape(b, e, c, d))
    out = out.reshape(b, e * c, d)

    def combine_one(out_g, st_g, sg_g, valid_g, slot_g):
        slot_safe = jnp.minimum(slot_g, e * c - 1)
        contrib = out_g[slot_safe] * \
            (sg_g * valid_g)[:, None].astype(x.dtype)
        return jnp.zeros((s, d), x.dtype).at[st_g].add(contrib)

    y = jax.vmap(combine_one)(out, st, sg, valid, slot)

    if cfg.n_shared:
        y = y + _shared_ffn(params, cfg, x)

    # ---- Switch load-balance aux loss ------------------------------------
    me = probs.reshape(-1, e).mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        1.0) / (b * s * k)
    aux = cfg.aux_weight * e * jnp.sum(me * ce)
    return y, aux
