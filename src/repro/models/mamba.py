"""Mamba selective-SSM block (Jamba's sequence mixer).

Training path: chunked linear-recurrence scan — ``lax.scan`` across
chunks carrying the (d_inner, d_state) SSM state, ``associative_scan``
within each chunk.  This bounds the materialised (B, chunk, d_in, N)
tensor (the TPU VMEM-friendly adaptation of Mamba's fused CUDA scan) while
keeping wall-clock parallelism inside chunks.

Decode path: O(1) recurrent update carrying (ssm_state, conv_state) —
this is what makes the hybrid run the 500k-context cell.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rmsnorm


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 -> ceil(d_model / 16)
    chunk: int = 128
    scan_dtype: str = "float32"   # bfloat16 halves scan-tree traffic

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def mamba_init(key, cfg: MambaConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank_
    # S4D-real initialisation for A; dt bias for softplus in [1e-3, 1e-1]
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt = jnp.exp(jax.random.uniform(ks[0], (di,)) *
                 (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "in_proj": dense_init(ks[1], (d, 2 * di), dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.d_conv, di)) *
                   cfg.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[3], (di, r + 2 * n), dtype),
        "dt_proj": dense_init(ks[4], (r, di), dtype, scale=r ** -0.5),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype),
        "dt_norm": jnp.ones((r,), dtype),       # Jamba's dt/B/C RMSNorms
        "b_norm": jnp.ones((n,), dtype),
        "c_norm": jnp.ones((n,), dtype),
    }


def _dbc(params, cfg: MambaConfig, xc):
    """Project conv output to (dt, B, C) with Jamba's RMS norms."""
    n, r = cfg.d_state, cfg.dt_rank_
    dbc = xc @ params["x_proj"]
    dt, b_, c_ = jnp.split(dbc, [r, r + n], axis=-1)
    dt = rmsnorm({"scale": params["dt_norm"]}, dt)
    b_ = rmsnorm({"scale": params["b_norm"]}, b_)
    c_ = rmsnorm({"scale": params["c_norm"]}, c_)
    dt = jax.nn.softplus(dt @ params["dt_proj"] +
                         params["dt_bias"]).astype(jnp.float32)
    return dt, b_.astype(jnp.float32), c_.astype(jnp.float32)


def _causal_conv(params, cfg: MambaConfig, x):
    """Depthwise causal conv over time: x (B, S, di)."""
    k = cfg.d_conv
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * params["conv_w"][i]
              for i in range(k))
    return out + params["conv_b"]


def mamba_prefill(params, cfg: MambaConfig, u: jax.Array):
    """u: (B, S, d) -> (y, state) with state for continued decode."""
    b, s, d = u.shape
    di, n = cfg.d_inner, cfg.d_state
    xz = u @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(params, cfg, x))
    dt, b_, c_ = _dbc(params, cfg, xc)

    a = -jnp.exp(params["A_log"])                          # (di, N)

    chunk = min(cfg.chunk, s)
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by mamba chunk {chunk}")
    nc = s // chunk
    sdt = jnp.dtype(cfg.scan_dtype)

    def to_chunks(t):                                      # (B,S,...)->(nc,B,chunk,...)
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    dt_c, b_c, c_c = to_chunks(dt), to_chunks(b_), to_chunks(c_)
    xc_c = to_chunks(xc.astype(jnp.float32))

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def chunk_step(h, inp):
        dt_k, b_k, c_k, xc_k = inp
        # build decay/input only for this chunk (the (B,chunk,di,N) state
        # never materialises globally — contraction with C happens here)
        da_k = jnp.exp(dt_k[..., None] * a).astype(sdt)
        dbx_k = (dt_k[..., None] * b_k[:, :, None, :] *
                 xc_k[..., None]).astype(sdt)
        pa, pb = lax.associative_scan(assoc, (da_k, dbx_k), axis=1)
        hs = pa.astype(jnp.float32) * h[:, None] + pb.astype(jnp.float32)
        y_k = jnp.einsum("bsdn,bsn->bsd", hs, c_k)         # (B,chunk,di)
        return hs[:, -1], y_k

    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_last, ys = lax.scan(chunk_step, h0, (dt_c, b_c, c_c, xc_c))
    y = ys.swapaxes(0, 1).reshape(b, s, di)

    y = y + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(u.dtype)) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    state = {"ssm": h_last.astype(jnp.float32),
             "conv": x[:, -(cfg.d_conv - 1):, :]}
    return out, state


def mamba_decode(params, cfg: MambaConfig, u: jax.Array, state: dict):
    """u: (B, 1, d); state {'ssm': (B,di,N), 'conv': (B,k-1,di)}."""
    b = u.shape[0]
    xz = u @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)                       # (B,1,di)
    conv_in = jnp.concatenate([state["conv"], x], axis=1)  # (B,k,di)
    xc = sum(conv_in[:, i, :] * params["conv_w"][i]
             for i in range(cfg.d_conv)) + params["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]                       # (B,1,di)
    dt, b_, c_ = _dbc(params, cfg, xc)

    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt[:, 0, :, None] * a)                    # (B,di,N)
    dbx = (dt[:, 0, :, None] * b_[:, 0, None, :] *
           xc.astype(jnp.float32)[:, 0, :, None])
    h = da * state["ssm"] + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_[:, 0])
    y = y + params["D"] * xc.astype(jnp.float32)[:, 0]
    y = y.astype(u.dtype)[:, None, :] * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return out, {"ssm": h, "conv": conv_in[:, 1:, :]}
