"""Model assembly: config -> init / forward / decode for every arch family.

Layer-stacking strategy: each architecture is decomposed into an optional
*prelude* (unstacked, e.g. DeepSeek's first dense layer) plus N identical
*periods* (e.g. Jamba's 8-layer Mamba/attn/MoE group, xLSTM's 6-block
mLSTM/sLSTM group, or a single dense block).  Period parameters are
stacked on a leading axis and driven by ``lax.scan`` — keeping the HLO a
constant size in depth, which is what makes the 60-layer/236B dry-runs
compile quickly and remat-cheaply.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.attention import AttnConfig
from repro.models.layers import (dense_init, embed_init, mlp_apply, mlp_init,
                                 rmsnorm, rmsnorm_init, unembed)

Pytree = Any

# Ambient batch mesh axes for activation sharding constraints.  Set by the
# launcher (dryrun/train) before lowering; None on single-device CPU runs.
_BATCH_AXES: tuple | None = None


def set_batch_axes(axes):
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes) if axes else None


def _constrain_tokens_batch(h):
    """Pin (B, S, d) activations to batch-sharded/replicated layout at
    block boundaries — prevents GSPMD from drifting into exotic layouts
    inside the scanned body (observed as 'involuntary full remat')."""
    if _BATCH_AXES is None or h.ndim != 3:
        return h
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            h, P(_BATCH_AXES, *([None] * (h.ndim - 1))))
    except Exception:
        return h


# ---------------------------------------------------------------------------
# Block program
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSpec:
    mixer: str                    # gqa | mla | mamba | mlstm | slstm
    ffn: Optional[tuple] = None   # ('mlp', width) | ('moe',) | None


def block_program(cfg: ArchConfig):
    """Returns (prelude: list[BlockSpec], period: list[BlockSpec], n_periods)."""
    if cfg.pattern == "dense":
        mixer = cfg.attn
        if cfg.moe is None:
            return [], [BlockSpec(mixer, ("mlp", cfg.d_ff))], cfg.n_layers
        prelude = [BlockSpec(mixer, ("mlp", cfg.d_ff_dense_))
                   ] * cfg.first_k_dense
        rem = cfg.n_layers - cfg.first_k_dense
        if cfg.moe_every == 1:
            return prelude, [BlockSpec(mixer, ("moe",))], rem
        period = [BlockSpec(mixer, ("moe",) if i == cfg.moe_offset
                            else ("mlp", cfg.d_ff))
                  for i in range(cfg.moe_every)]
        assert rem % cfg.moe_every == 0
        return prelude, period, rem // cfg.moe_every
    if cfg.pattern == "jamba":
        assert cfg.n_layers % cfg.jamba_period == 0
        period = []
        for pos in range(cfg.jamba_period):
            mixer = "gqa" if pos == cfg.jamba_attn_pos else "mamba"
            ffn = ("moe",) if (pos % 2 == 1 and cfg.moe is not None) \
                else ("mlp", cfg.d_ff)
            period.append(BlockSpec(mixer, ffn))
        return [], period, cfg.n_layers // cfg.jamba_period
    if cfg.pattern == "xlstm":
        assert cfg.n_layers % cfg.xlstm_period == 0
        period = [BlockSpec("mlstm")] * (cfg.xlstm_period - 1) + \
            [BlockSpec("slstm")]
        return [], period, cfg.n_layers // cfg.xlstm_period
    raise ValueError(cfg.pattern)


def attn_config(cfg: ArchConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
        qkv_bias=cfg.qkv_bias, kv_lora=cfg.mla_kv_lora,
        q_lora=cfg.mla_q_lora, rope_dim=cfg.mla_rope_dim,
        v_head_dim=cfg.hd, flash_threshold=cfg.flash_threshold,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        causal_skip=cfg.attn_causal_skip,
        score_dtype=cfg.attn_score_dtype,
        kv_cache_quant=cfg.kv_cache_quant)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, spec: BlockSpec) -> dict:
    dtype = cfg.jdtype
    ks = jax.random.split(key, 4)
    p = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if spec.mixer == "gqa":
        p["mixer"] = attn_lib.gqa_init(ks[0], attn_config(cfg), dtype)
    elif spec.mixer == "mla":
        p["mixer"] = attn_lib.mla_init(ks[0], attn_config(cfg), dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_lib.mamba_init(ks[0], cfg.mamba, dtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm_lib.mlstm_init(ks[0], cfg.xlstm_cfg(), dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm_lib.slstm_init(ks[0], cfg.xlstm_cfg(), dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn is not None:
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        if spec.ffn[0] == "mlp":
            p["ffn"] = mlp_init(ks[1], cfg.d_model, spec.ffn[1],
                                cfg.mlp_type, dtype)
        else:
            p["ffn"] = moe_lib.moe_init(ks[1], cfg.moe, cfg.d_model, dtype)
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> Pytree:
    prelude, period, n_periods = block_program(cfg)
    if cfg.ode_depth:
        n_periods = 1              # weight-tied continuous-depth stack
    ks = jax.random.split(key, 4 + len(prelude))
    dtype = cfg.jdtype
    params = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(ks[1], cfg.vocab, cfg.d_model, dtype)
    params["prelude"] = [
        _init_block(ks[4 + i], cfg, spec) for i, spec in enumerate(prelude)]

    def init_period(k):
        kks = jax.random.split(k, len(period))
        return {f"b{i}": _init_block(kks[i], cfg, spec)
                for i, spec in enumerate(period)}

    params["stack"] = jax.vmap(init_period)(
        jax.random.split(ks[2], n_periods))
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_block(p, cfg: ArchConfig, spec: BlockSpec, h, *, pos0=0,
                 want_cache=False):
    acfg = attn_config(cfg)
    cache = None
    x = rmsnorm(p["norm1"], h, cfg.norm_eps)
    if spec.mixer == "gqa":
        out, cache = attn_lib.gqa_prefill(p["mixer"], acfg, x, pos0=pos0)
    elif spec.mixer == "mla":
        out, cache = attn_lib.mla_prefill(p["mixer"], acfg, x, pos0=pos0)
    elif spec.mixer == "mamba":
        out, cache = mamba_lib.mamba_prefill(p["mixer"], cfg.mamba, x)
    elif spec.mixer == "mlstm":
        out, cache = xlstm_lib.mlstm_prefill(p["mixer"], cfg.xlstm_cfg(), x)
    elif spec.mixer == "slstm":
        out, cache = xlstm_lib.slstm_prefill(p["mixer"], cfg.xlstm_cfg(), x)
    h = _constrain_tokens_batch(h + out)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn is not None:
        x = rmsnorm(p["norm2"], h, cfg.norm_eps)
        if spec.ffn[0] == "mlp":
            h = h + mlp_apply(p["ffn"], x, cfg.mlp_type)
        else:
            y, aux = moe_lib.moe_apply(p["ffn"], cfg.moe, x)
            h = h + y
        h = _constrain_tokens_batch(h)
    if not want_cache:
        cache = None
    return h, aux, cache


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)      # full remat


def forward(params: Pytree, cfg: ArchConfig, tokens: jax.Array,
            *, return_cache: bool = False):
    """tokens (B, S) int32 -> (logits (B,S,V) f32, aux, cache|None)."""
    prelude, period, n_periods = block_program(cfg)
    h = _constrain_tokens_batch(params["embed"][tokens].astype(cfg.jdtype))
    aux = jnp.zeros((), jnp.float32)
    pre_caches = []
    for p, spec in zip(params["prelude"], prelude):
        h, a, c = _apply_block(p, cfg, spec, h, want_cache=return_cache)
        aux = aux + a
        pre_caches.append(c)

    if cfg.ode_depth:
        # Paper technique: the stacked residual group as a neural ODE
        # (weight-tied, RK4 in pseudo-depth over the original depth).
        from repro.core.node import ContinuousDepthBlock
        group = jax.tree_util.tree_map(lambda x: x[0], params["stack"])

        def residual(gp, hh):
            out = hh
            for i, spec in enumerate(period):
                out, _, _ = _apply_block(gp[f"b{i}"], cfg, spec, out)
            return out - hh

        _, _, real_n = block_program(cfg)
        blk = ContinuousDepthBlock(residual, depth=float(real_n),
                                   num_steps=cfg.ode_depth)
        h = blk(group, h)
        stack_caches = None
    else:
        def body(carry, layer):
            h, aux = carry
            caches = {}
            for i, spec in enumerate(period):
                h, a, c = _apply_block(layer[f"b{i}"], cfg, spec, h,
                                       want_cache=return_cache)
                aux = aux + a
                caches[f"b{i}"] = c
            return (h, aux), caches if return_cache else None

        (h, aux), stack_caches = lax.scan(_remat(body, cfg), (h, aux),
                                          params["stack"])

    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(h, table)
    cache = {"prelude": pre_caches, "stack": stack_caches} \
        if return_cache else None
    return logits, aux, cache


# ---------------------------------------------------------------------------
# Decode (single token with pre-allocated caches)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Pytree:
    prelude, period, n_periods = block_program(cfg)
    dtype = cfg.jdtype
    acfg = attn_config(cfg)

    def block_cache(spec: BlockSpec):
        if spec.mixer == "gqa":
            shape = (batch, max_seq, cfg.n_kv, cfg.hd)
            if cfg.kv_cache_quant:
                sshape = (batch, max_seq, cfg.n_kv, 1)
                return {"k": jnp.zeros(shape, jnp.int8),
                        "v": jnp.zeros(shape, jnp.int8),
                        "k_scale": jnp.zeros(sshape, jnp.float32),
                        "v_scale": jnp.zeros(sshape, jnp.float32)}
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if spec.mixer == "mla":
            return {"ckv": jnp.zeros((batch, max_seq, cfg.mla_kv_lora), dtype),
                    "k_rope": jnp.zeros((batch, max_seq, cfg.mla_rope_dim),
                                        dtype)}
        if spec.mixer == "mamba":
            mc = cfg.mamba
            return {"ssm": jnp.zeros((batch, mc.d_inner, mc.d_state),
                                     jnp.float32),
                    "conv": jnp.zeros((batch, mc.d_conv - 1, mc.d_inner),
                                      dtype)}
        if spec.mixer == "mlstm":
            xc = cfg.xlstm_cfg()
            return (jnp.zeros((batch, xc.n_heads, xc.head_dim, xc.head_dim),
                              jnp.float32),
                    jnp.zeros((batch, xc.n_heads, xc.head_dim), jnp.float32),
                    jnp.full((batch, xc.n_heads), -1e30, jnp.float32))
        if spec.mixer == "slstm":
            return xlstm_lib.slstm_zero_state(cfg.xlstm_cfg(), batch)
        raise ValueError(spec.mixer)

    stack = {f"b{i}": jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape),
        block_cache(spec)) for i, spec in enumerate(period)}
    return {"prelude": [block_cache(s) for s in prelude], "stack": stack}


def _decode_block(p, cfg: ArchConfig, spec: BlockSpec, h, pos, cache):
    acfg = attn_config(cfg)
    x = rmsnorm(p["norm1"], h, cfg.norm_eps)
    if spec.mixer == "gqa":
        out, cache = attn_lib.gqa_decode(p["mixer"], acfg, x, pos, cache)
    elif spec.mixer == "mla":
        out, cache = attn_lib.mla_decode(p["mixer"], acfg, x, pos, cache)
    elif spec.mixer == "mamba":
        out, cache = mamba_lib.mamba_decode(p["mixer"], cfg.mamba, x, cache)
    elif spec.mixer == "mlstm":
        out, cache = xlstm_lib.mlstm_decode(p["mixer"], cfg.xlstm_cfg(), x,
                                            cache)
    elif spec.mixer == "slstm":
        out, cache = xlstm_lib.slstm_decode(p["mixer"], cfg.xlstm_cfg(), x,
                                            cache)
    h = _constrain_tokens_batch(h + out)
    if spec.ffn is not None:
        x = rmsnorm(p["norm2"], h, cfg.norm_eps)
        if spec.ffn[0] == "mlp":
            h = h + mlp_apply(p["ffn"], x, cfg.mlp_type)
        else:
            y, _ = moe_lib.moe_apply(p["ffn"], cfg.moe, x)
            h = h + y
    return h, cache


def decode_step(params: Pytree, cfg: ArchConfig, tokens: jax.Array,
                pos, cache: Pytree):
    """tokens (B, 1); pos: scalar current position; returns (logits, cache')."""
    if cfg.ode_depth:
        raise NotImplementedError("ODE-depth mode is train/prefill only")
    prelude, period, n_periods = block_program(cfg)
    h = _constrain_tokens_batch(params["embed"][tokens].astype(cfg.jdtype))
    new_pre = []
    for p, spec, c in zip(params["prelude"], prelude, cache["prelude"]):
        h, c2 = _decode_block(p, cfg, spec, h, pos, c)
        new_pre.append(c2)

    def body(h, xs):
        layer, lcache = xs
        new_cache = {}
        for i, spec in enumerate(period):
            h, new_cache[f"b{i}"] = _decode_block(
                layer[f"b{i}"], cfg, spec, h, pos, lcache[f"b{i}"])
        return h, new_cache

    h, new_stack = lax.scan(body, h, (params["stack"], cache["stack"]))
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(h, table)
    return logits, {"prelude": new_pre, "stack": new_stack}
