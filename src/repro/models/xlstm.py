"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory, chunkwise-
parallel training, O(1) recurrent decode) and sLSTM (scalar memory,
sequential recurrence with exponential gating).

The mLSTM training path uses the chunkwise linear-attention form with
log-space gate stabilisation — within-chunk parallel (L x L per head,
VPU/MXU friendly) and an inter-chunk carried state (C, n, m), the same
schedule as the Mamba chunked scan.  The sLSTM is inherently sequential
(its recurrent gates read h_{t-1}); it runs as a ``lax.scan`` — noted in
DESIGN.md as the faithful (non-parallelisable) structure.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rmsnorm


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    n_heads: int
    m_proj_factor: float = 2.0     # mLSTM up-projection
    s_proj_factor: float = 4.0 / 3.0
    d_conv: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return int(self.m_proj_factor * self.d_model)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    d, di, h = cfg.d_model, cfg.d_inner, cfg.n_heads
    return {
        "up": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, di))
                   * cfg.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], (di, di), dtype),
        "wk": dense_init(ks[3], (di, di), dtype),
        "wv": dense_init(ks[4], (di, di), dtype),
        "wi": dense_init(ks[5], (di, h), jnp.float32),
        "wf": dense_init(ks[6], (di, h), jnp.float32),
        "gn": jnp.ones((di,), dtype),
        "down": dense_init(ks[7], (di, d), dtype),
    }


def _conv_silu(params, cfg, x):
    k = cfg.d_conv
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * params["conv_w"][i]
              for i in range(k)) + params["conv_b"]
    return jax.nn.silu(out)


def _heads(x, h):
    b, s, di = x.shape
    return x.reshape(b, s, h, di // h)


def _mlstm_chunk(q, k, v, lgi, lgf, state):
    """One chunk of the stabilised chunkwise mLSTM.

    q,k,v: (B,H,L,dk); lgi/lgf: (B,H,L) log input gate preact / log f.
    state: (c (B,H,dk,dv), n (B,H,dk), m (B,H)).  Returns (h, state').
    """
    bsz, nh, L, dk = q.shape
    cum = jnp.cumsum(lgf, axis=-1)                         # (B,H,L)
    # intra-chunk decay matrix D_ij = cum_i - cum_j + lgi_j  (j <= i)
    D = cum[..., :, None] - cum[..., None, :] + lgi[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(mask, D, -jnp.inf)
    m_intra = jnp.max(D, axis=-1)                          # (B,H,L)
    c_prev, n_prev, m_prev = state
    m_inter = cum + m_prev[..., None]
    m = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)

    scale = dk ** -0.5
    qk = jnp.einsum("bhld,bhkd->bhlk", q, k,
                    preferred_element_type=jnp.float32) * scale
    S = qk * jnp.exp(D - m[..., :, None])
    inter_w = jnp.exp(m_inter - m)                         # (B,H,L)
    num = (jnp.einsum("bhlk,bhkv->bhlv", S, v) +
           inter_w[..., None] *
           jnp.einsum("bhld,bhdv->bhlv", q * scale, c_prev))
    den = (jnp.abs(S.sum(-1) +
                   inter_w * jnp.einsum("bhld,bhd->bhl", q * scale, n_prev)))
    den = jnp.maximum(den, jnp.exp(-m))
    h = num / den[..., None]

    # state update to the chunk end
    cL = cum[..., -1]                                      # (B,H)
    log_wj = cL[..., None] - cum + lgi                     # (B,H,L)
    m_new = jnp.maximum(m_prev + cL, jnp.max(log_wj, axis=-1))
    m_new = jnp.maximum(m_new, -1e30)
    carry_scale = jnp.exp(m_prev + cL - m_new)             # (B,H)
    kv_w = jnp.exp(log_wj - m_new[..., None])
    c_new = (carry_scale[..., None, None] * c_prev +
             jnp.einsum("bhl,bhld,bhlv->bhdv", kv_w, k, v))
    n_new = (carry_scale[..., None] * n_prev +
             jnp.einsum("bhl,bhld->bhd", kv_w, k))
    return h, (c_new, n_new, m_new)


def mlstm_prefill(params, cfg: XLSTMConfig, x: jax.Array):
    b, s, _ = x.shape
    h_, hd = cfg.n_heads, cfg.head_dim
    up = x @ params["up"]
    xm, z = jnp.split(up, 2, axis=-1)
    xc = _conv_silu(params, cfg, xm)
    q = _heads(xc @ params["wq"], h_).swapaxes(1, 2).astype(jnp.float32)
    k = _heads(xc @ params["wk"], h_).swapaxes(1, 2).astype(jnp.float32)
    v = _heads(xm @ params["wv"], h_).swapaxes(1, 2).astype(jnp.float32)
    lgi = (xm.astype(jnp.float32) @ params["wi"]).swapaxes(1, 2)  # (B,H,S)
    lgf = jax.nn.log_sigmoid(
        (xm.astype(jnp.float32) @ params["wf"]).swapaxes(1, 2))

    L = min(cfg.chunk, s)
    if s % L:
        raise ValueError(f"seq {s} % chunk {L} != 0")
    nc = s // L

    def split_c(t):  # (B,H,S,...) -> (nc, B,H,L,...)
        return t.reshape(t.shape[0], t.shape[1], nc, L,
                         *t.shape[3:]).swapaxes(0, 2).swapaxes(1, 2)

    qc, kc, vc = split_c(q), split_c(k), split_c(v)
    lgic, lgfc = split_c(lgi), split_c(lgf)

    state = (jnp.zeros((b, h_, hd, hd), jnp.float32),
             jnp.zeros((b, h_, hd), jnp.float32),
             jnp.full((b, h_), -1e30, jnp.float32))

    def step(st, inp):
        qk, kk, vk, ik, fk = inp
        hk, st = _mlstm_chunk(qk, kk, vk, ik, fk, st)
        return st, hk

    state, hs = lax.scan(step, state, (qc, kc, vc, lgic, lgfc))
    hs = hs.swapaxes(0, 2).swapaxes(1, 2).reshape(b, h_, s, hd)
    hs = hs.swapaxes(1, 2).reshape(b, s, cfg.d_inner).astype(x.dtype)
    hs = rmsnorm({"scale": params["gn"]}, hs)              # group-norm-ish
    y = (hs + xc) * jax.nn.silu(z)
    return y @ params["down"], state


def mlstm_decode(params, cfg: XLSTMConfig, x: jax.Array, state):
    """x: (B,1,d); state (c,n,m) as in prefill."""
    b = x.shape[0]
    h_, hd = cfg.n_heads, cfg.head_dim
    up = x @ params["up"]
    xm, z = jnp.split(up, 2, axis=-1)
    # NOTE: decode drops the short conv's history (k-1 tokens) for state
    # economy; xLSTM's conv is a local smoother and this is the standard
    # serving simplification. (A conv cache could be added as in mamba.)
    xc = jax.nn.silu(xm * params["conv_w"][-1] + params["conv_b"])
    q = (xc @ params["wq"]).reshape(b, h_, hd).astype(jnp.float32)
    k = (xc @ params["wk"]).reshape(b, h_, hd).astype(jnp.float32)
    v = (xm @ params["wv"]).reshape(b, h_, hd).astype(jnp.float32)
    lgi = (xm.astype(jnp.float32) @ params["wi"]).reshape(b, h_)
    lgf = jax.nn.log_sigmoid(
        (xm.astype(jnp.float32) @ params["wf"])).reshape(b, h_)

    c_prev, n_prev, m_prev = state
    m_new = jnp.maximum(lgf + m_prev, lgi)
    f_s = jnp.exp(lgf + m_prev - m_new)
    i_s = jnp.exp(lgi - m_new)
    c = f_s[..., None, None] * c_prev + i_s[..., None, None] * \
        jnp.einsum("bhd,bhv->bhdv", k, v)
    n = f_s[..., None] * n_prev + i_s[..., None] * k
    scale = hd ** -0.5
    num = jnp.einsum("bhd,bhdv->bhv", q * scale, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q * scale, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, 1, cfg.d_inner).astype(x.dtype)
    h = rmsnorm({"scale": params["gn"]}, h)
    y = (h + xc[:, None, :].reshape(b, 1, -1)) * jax.nn.silu(z)
    return y @ params["down"], (c, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: XLSTMConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    df = int(cfg.s_proj_factor * d)
    return {
        "wx": dense_init(ks[0], (d, 4 * d), dtype),        # z,i,f,o inputs
        "r": (jax.random.normal(ks[1], (h, hd, 4 * hd))
              * hd ** -0.5).astype(dtype),                 # block-diag recur
        "b": jnp.zeros((4 * d,), jnp.float32),
        "gn": jnp.ones((d,), dtype),
        "up_gate": dense_init(ks[2], (d, df), dtype),
        "up": dense_init(ks[3], (d, df), dtype),
        "down": dense_init(ks[4], (df, d), dtype),
    }


def _slstm_step(params, cfg: XLSTMConfig, carry, wx_t):
    """carry: (h, c, n, m) each (B, H, hd) / (B, H, hd) scalars per unit."""
    h_prev, c_prev, n_prev, m_prev = carry
    b = h_prev.shape[0]
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    rec = jnp.einsum("bhd,hdk->bhk", h_prev, params["r"])  # (B,H,4*hd)
    zifo = (wx_t.reshape(b, nh, 4 * hd) + rec).astype(jnp.float32) \
        + params["b"].reshape(nh, 4 * hd)
    z, i, f, o = jnp.split(zifo, 4, axis=-1)               # (B,H,hd)
    lgf = jax.nn.log_sigmoid(f)
    m = jnp.maximum(lgf + m_prev, i)
    i_s = jnp.exp(i - m)
    f_s = jnp.exp(lgf + m_prev - m)
    c = f_s * c_prev + i_s * jnp.tanh(z)
    n = jnp.maximum(f_s * n_prev + i_s, 1e-6)
    h = jax.nn.sigmoid(o) * c / n
    return (h.astype(h_prev.dtype), c, n, m)


def slstm_zero_state(cfg: XLSTMConfig, batch: int):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    z32 = jnp.zeros((batch, nh, hd), jnp.float32)
    return (z32, z32, z32, jnp.full((batch, nh, hd), -1e30, jnp.float32))


def slstm_prefill(params, cfg: XLSTMConfig, x: jax.Array):
    b, s, d = x.shape
    wx = x @ params["wx"]                                  # (B,S,4d)

    def step(carry, wx_t):
        carry = _slstm_step(params, cfg, carry, wx_t)
        return carry, carry[0]

    carry, hs = lax.scan(step, slstm_zero_state(cfg, b), wx.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    hs = rmsnorm({"scale": params["gn"]}, hs)
    y = jax.nn.gelu(hs @ params["up_gate"]) * (hs @ params["up"])
    return y @ params["down"], carry


def slstm_decode(params, cfg: XLSTMConfig, x: jax.Array, state):
    b = x.shape[0]
    wx = (x @ params["wx"])[:, 0, :]
    carry = _slstm_step(params, cfg, state, wx)
    h = carry[0].reshape(b, 1, cfg.d_model).astype(x.dtype)
    h = rmsnorm({"scale": params["gn"]}, h)
    y = jax.nn.gelu(h @ params["up_gate"]) * (h @ params["up"])
    return y @ params["down"], carry
