"""Loop-aware analysis of optimised HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — with
scan-over-layers and flash-attention chunk loops that undercounts FLOPs,
traffic and collectives by 1-3 orders of magnitude.  This module parses
the optimised HLO module text, reconstructs the computation call graph,
extracts each while loop's trip count from its condition computation, and
scales per-computation costs by the product of enclosing trip counts:

  * FLOPs       — 2 * prod(result dims) * prod(lhs contracting dims) per
                  dot (dots inside fusions included);
  * HBM traffic — sum of instruction result bytes x 2 (write + read) over
                  *materialising* instructions (fusion-internal and
                  scalar-lambda computations excluded; parameters,
                  constants, GTEs, tuples, bitcasts excluded);
  * collectives — max(result, operand) bytes per collective op.

All numbers are per device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLSITE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                       r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
# ops that materialise an HBM buffer on TPU (elementwise/broadcast/convert
# would be fused into neighbours by the TPU backend, so they are skipped —
# the CPU backend's fusion granularity would otherwise inflate traffic)
_MATERIALIZING = ("fusion", "dot", "convolution", "copy", "dynamic-slice",
                  "dynamic-update-slice", "gather", "scatter", "reduce",
                  "sort", "select-and-scatter", "cholesky", "fft",
                  "triangular-solve", "concatenate", "pad")


def _shape_dims(type_str):
    m = _TYPE_RE.match(type_str)
    if not m:
        return None, []
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",")] if dims else []


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in (dims.split(",") if dims else []):
            n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    raw: str


def parse_computations(text: str) -> dict:
    comps: dict[str, list[Instr]] = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # result type: balanced-paren tuple or single token (may contain
        # /*index=N*/ comments, so scan parens instead of regexing)
        if rhs.startswith("("):
            depth = 0
            end = 0
            for i, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            result_type = rhs[:end]
            rest = rhs[end:]
        else:
            sp = rhs.find(" ")
            result_type = rhs[:sp] if sp > 0 else rhs
            rest = rhs[sp:] if sp > 0 else ""
        om = re.match(r"\s*([\w\-]+)[(.]", rest)
        if not om:
            om = re.match(r"\s*([\w\-]+)", rest)
        if not om:
            continue
        comps[cur].append(Instr(name=name, opcode=om.group(1),
                                result_type=result_type, raw=rhs))
    return comps


def _callees(instr: Instr) -> list[str]:
    out = []
    for m in _CALLSITE.finditer(instr.raw):
        for name in m.group(1).split(","):
            out.append(name.strip().lstrip("%"))
    return out


def _while_parts(instr: Instr):
    body = re.search(r"body=%?([\w.\-]+)", instr.raw)
    cond = re.search(r"condition=%?([\w.\-]+)", instr.raw)
    return (body.group(1) if body else None,
            cond.group(1) if cond else None)


def _trip_count(cond_instrs: list[Instr]) -> int:
    """Trip count from the condition computation: the integer constant
    feeding the ROOT compare.  Falls back to the max int constant."""
    consts = {}
    for ins in cond_instrs:
        cm = re.search(r"constant\((\d+)\)", ins.raw)
        if cm and ins.result_type.split("[")[0] in ("s32", "u32", "s64",
                                                    "u64"):
            consts[ins.name] = int(cm.group(1))
    for ins in cond_instrs:
        if ins.opcode == "compare":
            args = re.findall(r"%([\w.\-]+)", ins.raw)
            for a in args:
                if a in consts:
                    return max(consts[a], 1)
    return max(consts.values(), default=1)


def analyze(text: str) -> dict:
    comps = parse_computations(text)
    entry = None
    for line in text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:
        # fall back: computation named main*
        entry = next((c for c in comps if c.startswith("main")),
                     next(iter(comps)))

    # computations called via fusion/to_apply don't materialise buffers
    fusion_called = set()
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.opcode in ("fusion", "reduce", "map", "sort", "scatter",
                              "reduce-window", "select-and-scatter",
                              "all-reduce", "reduce-scatter"):
                fusion_called.update(_callees(ins))

    # accumulate execution scales over the call graph
    scales = defaultdict(float)
    scales[entry] = 1.0
    work = [entry]
    visited_edges = set()
    while work:
        cname = work.pop()
        my = scales[cname]
        for ins in comps.get(cname, []):
            if ins.opcode == "while":
                body, cond = _while_parts(ins)
                trip = _trip_count(comps.get(cond, []))
                for child, mult in ((body, trip), (cond, trip + 1)):
                    if child is None:
                        continue
                    key = (cname, child, ins.name)
                    if key in visited_edges:
                        continue
                    visited_edges.add(key)
                    scales[child] += my * mult
                    work.append(child)
            else:
                for child in _callees(ins):
                    key = (cname, child, ins.name)
                    if key in visited_edges or child not in comps:
                        continue
                    visited_edges.add(key)
                    scales[child] += my
                    work.append(child)

    flops = 0.0
    traffic = 0.0
    coll = {k: 0.0 for k in _COLL_OPS}
    coll_counts = {k: 0 for k in _COLL_OPS}
    for cname, instrs in comps.items():
        scale = scales.get(cname, 0.0)
        if scale == 0.0:
            continue
        materialises = cname not in fusion_called
        types = {i.name: i.result_type for i in instrs}
        for ins in instrs:
            if ins.opcode == "dot":
                _, rdims = _shape_dims(ins.result_type)
                # operands are name-only in scheduled HLO: resolve the lhs
                # type from its defining instruction in this computation
                call = ins.raw[ins.raw.find("("):]
                opnames = re.findall(r"%([\w.\-]+)", call)
                contr = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                  ins.raw)
                k = 1
                if opnames and contr and contr.group(1):
                    lhs_t = types.get(opnames[0], "")
                    _, ldims = _shape_dims(lhs_t)
                    for ci in contr.group(1).split(","):
                        ci = int(ci)
                        if ldims and ci < len(ldims):
                            k *= ldims[ci]
                n = 1
                for d in rdims or []:
                    n *= d
                flops += 2.0 * n * k * scale
            base = None
            for op in _COLL_OPS:
                if ins.opcode == op or ins.opcode.startswith(op + "-start") \
                        or ins.opcode.startswith(op + "."):
                    base = op
                    break
            if base and not ins.opcode.endswith("-done"):
                res_b = _type_bytes(ins.result_type)
                call = ins.raw[ins.raw.find("("):]
                opnd_b = sum(_type_bytes(types.get(n, ""))
                             for n in re.findall(r"%([\w.\-]+)",
                                                 call.split("),")[0] + ")"))
                coll[base] += max(res_b, opnd_b) * scale
                coll_counts[base] += 1
            if materialises and ins.opcode in _MATERIALIZING:
                # write the result + read each (locally resolvable) operand
                call = ins.raw[ins.raw.find("("):]
                first_args = call.split("),")[0] + ")"
                reads = sum(_type_bytes(types.get(n, ""))
                            for n in re.findall(r"%([\w.\-]+)", first_args))
                traffic += (_type_bytes(ins.result_type) + reads) * scale

    coll_total = sum(coll.values())
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collective_bytes": coll_total,
        "coll_breakdown": coll,
        "coll_counts": coll_counts,
        "n_computations": len(comps),
        "n_while": sum(1 for i in comps.values()
                       for x in i if x.opcode == "while"),
    }
