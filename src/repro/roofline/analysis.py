"""Roofline-term derivation from compiled dry-run artifacts.

TPU v5e hardware model (per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI link bandwidth ~50 GB/s

Terms (seconds, per step, per chip — the compiled SPMD module is the
per-device program, so cost_analysis() numbers are already per chip):

    compute    = HLO_FLOPs / peak
    memory     = HLO_bytes_accessed / HBM_bw
    collective = sum(max(operand, result) bytes over collective ops) / link_bw

collective bytes come from parsing the optimised HLO text (they are NOT
in cost_analysis); MODEL_FLOPS = 6*N_active*tokens (train) or
2*N_active*tokens (prefill/decode), and the ratio MODEL_FLOPS /
(HLO_FLOPs * chips) exposes remat/attention/dispatch overcompute.
"""
from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    """Sum bytes over every array type mentioned in a type string
    (handles tuples '(bf16[..], bf16[..])')."""
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type traffic from optimised HLO (per device).

    For each collective instruction takes max(result bytes, operand bytes)
    as the per-device traffic proxy.  ``-done`` halves of async pairs are
    skipped (the ``-start`` carries the shapes).
    """
    out = {k: 0 for k in _COLL_OPS}
    count = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        lhs, rhs = ls.split(" = ", 1)
        m = re.match(r"(\([^)]*\)|\S+)\s+(%?[\w-]+)", rhs)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2).lstrip("%")
        base = None
        for op in _COLL_OPS:
            if opname == op or opname.startswith(op + "-start") or \
                    opname.startswith(op + "."):
                base = op
                break
        if base is None or opname.endswith("-done"):
            continue
        res_b = _type_bytes(result_type)
        # operand types appear inside the parens of the call
        args = rhs[rhs.find("("):]
        opnd_b = _type_bytes(args)
        out[base] += max(res_b, opnd_b)
        count[base] += 1
    out["total"] = sum(out[k] for k in _COLL_OPS)
    out["counts"] = count
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops_global: float
    coll_breakdown: dict

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.hlo_flops_per_chip * self.chips
        return self.model_flops_global / max(hlo_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the per-chip compute roofline the step achieves,
        counting only MODEL (useful) FLOPs: the score we hillclimb."""
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful = (self.model_flops_global / self.chips) / PEAK_FLOPS
        return t_useful / max(t_step, 1e-30)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_global,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
        }


def model_flops(cfg, shape) -> float:
    """6*N_active*D for training, 2*N_active*D for inference."""
    from repro.configs.base import active_param_count
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def from_artifact(path: str) -> Roofline:
    with open(path) as f:
        d = json.load(f)
    return Roofline(**{k: d[k] for k in (
        "arch", "shape", "mesh", "chips", "hlo_flops_per_chip",
        "hlo_bytes_per_chip", "coll_bytes_per_chip", "model_flops_global",
        "coll_breakdown")})
