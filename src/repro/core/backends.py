"""Pluggable execution backends: one trained twin, many substrates.

The paper's central claim is substrate portability — the same trained
neural-ODE weights execute digitally (GPU/TPU), on analogue memristor
crossbars, or (our TPU transposition) inside the weights-stationary fused
Pallas kernel.  This module is the single abstraction behind all three:

    Backend.program(field, params) -> ExecState     ("deploy" the weights)
    Backend.apply(state, t, x)     -> dx/dt         (one vector-field eval)
    Backend.rollout(state, y0, ts) -> ys            (full IVP solve)
    Backend.rollout_batch(state, y0s, ts) -> yss    (fleet of N twins)

``program`` is the deployment step: for the digital backend it is the
identity, for the analogue backend it writes conductances onto simulated
crossbars (quantisation + programming noise, frozen), and for the fused
backend it stages float32 weight/bias operands for VMEM residency.

``rollout`` has a default odeint-based implementation (direct RK4 over
``apply``); backends override it when the substrate integrates
differently — the fused backend runs the whole RK4 trajectory inside one
``pallas_call``, sampling the drive at half-steps itself.

``rollout_batch`` is the fleet primitive: N independent initial
conditions (and optionally per-twin drive parameters) in ONE device
program — vmap for digital/analogue, grid batch-tiling for fused Pallas.
It is also the mesh-aware entry point: passing ``mesh=`` (a
``jax.sharding.Mesh`` with a ``"twins"`` axis) shards the fleet dimension
across devices with ``shard_map`` — weights replicated, ``y0s`` and
per-twin drive parameters split, each device running its slice through
the SAME per-device implementation (``rollout_batch_local``).  Backends
therefore customise ``rollout_batch_local`` and inherit multi-device
serving for free; the sharding machinery itself lives in
:mod:`repro.launch.fleet_serving`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adjoint import odeint_adjoint
from repro.core.analogue import (AnalogueMLPVectorField, AnalogueSpec,
                                 VerifyConfig, program_mlp,
                                 program_mlp_with_verify, stage_uint8)
from repro.core.faults import FaultModel, apply_faults_to_mlp
from repro.core.ode import make_odeint, odeint
from repro.kernels.fused_ode_mlp import DEFAULT_VMEM_BUDGET

Pytree = Any


class ExecState(NamedTuple):
    """A programmed twin: the executable field plus whatever parameters
    still live off-substrate (None when the weights are frozen in)."""
    field: Callable          # f(t, y, params) -> dy/dt
    params: Pytree           # pytree threaded to the field, or None
    extra: Any = None        # backend-private staging (e.g. fused operands)


def _with_drive(state: ExecState, drive: Optional[Callable]) -> ExecState:
    """Re-bind the drive u(t) on a programmed field (fields are frozen
    dataclasses with a ``drive`` attribute)."""
    return state._replace(field=dataclasses.replace(state.field, drive=drive))


@runtime_checkable
class Backend(Protocol):
    """Structural type every execution substrate implements.

    Lifecycle: ``program`` once per set of weights, then any number of
    ``apply``/``rollout``/``rollout_batch`` calls against the returned
    :class:`ExecState`.  See ``docs/architecture.md`` for how the layers
    compose and :class:`BaseBackend` for the default implementations.
    """

    name: str

    def program(self, field: Callable, params: Pytree) -> ExecState:
        """Deploy ``params`` onto the substrate; returns the programmed
        state (digital: identity; analogue: conductances written, frozen;
        fused: f32 operands staged for VMEM residency)."""
        ...

    def apply(self, state: ExecState, t: jax.Array, x: jax.Array) -> jax.Array:
        """One vector-field evaluation dx/dt = f(t, x) on the substrate."""
        ...

    def rollout(self, state: ExecState, y0: jax.Array, ts: jax.Array, *,
                method: str = "rk4", steps_per_interval: int = 1,
                gradient: str = "direct") -> jax.Array:
        """Solve the IVP from ``y0`` over ``ts`` -> (T+1, D) trajectory."""
        ...

    def rollout_batch(self, state: ExecState, y0s: jax.Array,
                      ts: jax.Array, **kw) -> jax.Array:
        """Fleet solve: N initial conditions -> (N, T+1, D) in one device
        program; ``mesh=`` shards the fleet axis across devices."""
        ...


@dataclasses.dataclass(frozen=True, eq=False)
class BaseBackend:
    """Default implementations shared by the concrete backends."""

    name = "base"

    def program(self, field: Callable, params: Pytree) -> ExecState:
        return ExecState(field=field, params=params)

    def apply(self, state: ExecState, t, x):
        return state.field(t, x, state.params)

    def rollout(self, state: ExecState, y0, ts, *, method: str = "rk4",
                steps_per_interval: int = 1,
                gradient: str = "direct") -> jax.Array:
        """Default: direct fixed-step odeint over ``apply``."""
        del gradient  # substrate-specific backends decide differentiability
        if method == "dopri5":
            return make_odeint("dopri5")(state.field, y0, ts, state.params)
        return odeint(state.field, y0, ts, state.params, method=method,
                      steps_per_interval=steps_per_interval)

    def rollout_batch(self, state: ExecState, y0s, ts, *,
                      drive_family: Optional[Callable] = None,
                      drive_params: Optional[jax.Array] = None,
                      mesh=None, **kw) -> jax.Array:
        """Fleet rollout: N independent twins in one device program.

        ``drive_family(t, theta)`` + per-twin ``drive_params`` (N, ...)
        re-binds each fleet member's drive; returns (N, T+1, D) matching
        ``jnp.stack([rollout(y0_i) for i])``.

        ``mesh``: optional ``jax.sharding.Mesh`` with a ``"twins"`` axis.
        When given, the fleet dimension is sharded across the mesh with
        ``shard_map`` (weights replicated, N padded up to a multiple of
        the shard count, padded rows dropped from the result) and each
        device runs
        :meth:`rollout_batch_local` on its slice; ``mesh=None`` runs the
        whole fleet on the current device.  Results are identical either
        way — sharding only changes placement.
        """
        if mesh is not None:
            from repro.launch.fleet_serving import shard_rollout_batch
            return shard_rollout_batch(self, state, y0s, ts, mesh=mesh,
                                       drive_family=drive_family,
                                       drive_params=drive_params, **kw)
        return self.rollout_batch_local(state, y0s, ts,
                                        drive_family=drive_family,
                                        drive_params=drive_params, **kw)

    def rollout_batch_local(self, state: ExecState, y0s, ts, *,
                            drive_family: Optional[Callable] = None,
                            drive_params: Optional[jax.Array] = None,
                            **kw) -> jax.Array:
        """Single-device fleet implementation (the shard body): vmap N
        independent rollouts into one device program.  Subclasses override
        THIS (not ``rollout_batch``) to keep the mesh dispatch in one
        place."""
        if drive_family is None:
            return jax.vmap(lambda y0: self.rollout(state, y0, ts, **kw))(y0s)

        def single(y0, theta):
            st = _with_drive(state, lambda t: drive_family(t, theta))
            return self.rollout(st, y0, ts, **kw)

        return jax.vmap(single)(y0s, drive_params)

    # -- resume-from-state rollouts (streaming serving) ---------------------
    @staticmethod
    def _resume_starts(start_steps, n: int) -> np.ndarray:
        """Normalise ``start_steps`` to a concrete (N,) int64 vector of
        per-twin global step offsets.  Offsets are HOST values by design
        — they index the canonical time grid, which must be computed in
        float64 outside any trace (see :func:`repro.kernels.ops
        .window_times`); a traced offset would force the 1-ulp-wrong
        on-device grid arithmetic the contract exists to forbid."""
        if start_steps is None:
            return np.zeros(n, np.int64)
        if isinstance(start_steps, jax.core.Tracer):
            raise ValueError(
                "rollout_batch_resumed: start_steps must be concrete host "
                "integers (they parameterise the canonical float64 time "
                "grid); do not pass them through jit")
        starts = np.asarray(start_steps, np.int64)
        if starts.ndim == 0:
            starts = np.broadcast_to(starts, (n,)).copy()
        if starts.shape != (n,) or (starts < 0).any():
            raise ValueError(
                f"rollout_batch_resumed: start_steps must be {n} "
                f"non-negative per-twin step offsets, got shape "
                f"{starts.shape}")
        return starts

    def rollout_batch_resumed(self, state: ExecState, ys, *, dt: float,
                              num_steps: int, t0: float = 0.0,
                              start_steps=None,
                              drive_family: Optional[Callable] = None,
                              drive_params: Optional[jax.Array] = None,
                              **kw) -> jax.Array:
        """Fleet rollout resuming each twin from a carried (y, t) instead
        of t0: twin i advances ``num_steps`` RK4 steps from its own
        global step ``start_steps[i]`` on the canonical uniform grid
        ``t = t0 + dt*k``.  Returns (N, num_steps+1, D) with row 0 the
        carried states.

        The determinism contract (``docs/serving.md``, enforced by
        ``tests/test_streaming.py``): every time value is derived in
        float64 from ``(t0, dt, global step index)`` and rounded to f32
        once (:func:`repro.kernels.ops.window_times`), so serving
        ``[0, k)`` then ``[k, T)`` through a state store is bit-identical
        (f32 substrates) to serving ``[0, T)`` in one call — splitting
        never changes the arithmetic, only where the HBM round-trip
        happens.  ``start_steps=None`` means all twins start at t0
        (fresh rollout through the same code path).
        """
        from repro.kernels.ops import window_times
        ys = jnp.asarray(ys)
        starts = self._resume_starts(start_steps, ys.shape[0])
        tss = window_times(t0, dt, int(num_steps), starts)     # (N, H+1)
        if drive_family is None:
            return jax.vmap(
                lambda y, ts: self.rollout(state, y, ts, **kw))(ys, tss)

        def single(y, ts, theta):
            st = _with_drive(state, lambda t: drive_family(t, theta))
            return self.rollout(st, y, ts, **kw)

        return jax.vmap(single)(ys, tss, drive_params)


# ---------------------------------------------------------------------------
# Digital backend — the training substrate
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class DigitalBackend(BaseBackend):
    """Plain jnp execution (the current training path, bit-for-bit).

    The only backend that is differentiable through the solve: supports
    the adjoint method (O(1) memory) and backprop-through-solver, plus the
    adaptive dopri5 integrator.
    """

    name = "digital"

    def rollout(self, state: ExecState, y0, ts, *, method: str = "rk4",
                steps_per_interval: int = 1,
                gradient: str = "adjoint") -> jax.Array:
        if method == "dopri5":
            return make_odeint("dopri5")(state.field, y0, ts, state.params)
        if gradient == "adjoint":
            return odeint_adjoint(state.field, y0, ts, state.params,
                                  method, steps_per_interval)
        return odeint(state.field, y0, ts, state.params, method=method,
                      steps_per_interval=steps_per_interval)


# ---------------------------------------------------------------------------
# Analogue backend — simulated memristor crossbars
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class AnalogueBackend(BaseBackend):
    """Deploys the MLP onto simulated differential crossbar pairs.

    ``program`` performs the paper's deployment: differential conductance
    mapping, 6-bit quantisation and multiplicative programming noise,
    frozen at program time; ``apply``/``rollout`` then re-sample read
    noise per VMM.  Weights no longer exist as parameters afterwards
    (``ExecState.params is None``) — they live in the crossbars.

    ``progs`` short-circuits programming with already-written crossbars
    (the ``deploy_analogue`` legacy shim uses this).

    ``storage="uint8"`` additionally stages each array's 6-bit level
    indices (requires ``prog_noise=0`` — noise moves conductances off
    the level grid): large noise-free reads then execute on the blocked
    Pallas kernel with dequant fused into the MXU feed instead of
    reading float conductances (see ``analogue_matmul``'s dispatch).

    Robustness knobs (see :mod:`repro.core.faults` and
    ``docs/robustness.md``): ``faults`` degrades the array with the
    composed device-fault model — stuck cells pinned, single-pulse write
    failures, and a conductance-drift snapshot after ``n_reads``
    evaluations; ``verify`` switches programming to the closed-loop
    write–verify routine (:func:`repro.core.analogue.program_with_verify`
    — read-back, bounded retry with backoff, differential-pair remap of
    stuck cells).  Either one makes ``program`` simulate the write
    physics pulse-by-pulse and surface the per-layer
    :class:`repro.core.analogue.RepairReport` list through
    ``ExecState.extra["repair_reports"]``.
    """

    name = "analogue"
    spec: AnalogueSpec = AnalogueSpec()
    prog_key: Optional[jax.Array] = None
    read_key: Optional[jax.Array] = None
    progs: Optional[tuple] = None
    storage: str = "float"          # "float" | "uint8" level indices
    faults: Optional[FaultModel] = None
    verify: Optional[VerifyConfig] = None
    n_reads: int = 0                # drift snapshot: reads already served

    def program(self, field: Callable, params: Pytree) -> ExecState:
        if self.storage not in ("float", "uint8"):
            raise ValueError(
                f"AnalogueBackend storage={self.storage!r}; have "
                f"'float', 'uint8'")
        if (self.storage == "uint8" and self.faults is not None
                and self.faults.drift is not None):
            raise ValueError(
                "AnalogueBackend: conductance drift moves cells off the "
                "6-bit level grid, so storage='uint8' cannot carry a "
                "drift snapshot — use float storage, or "
                "FusedAnalogueBackend whose kernel drifts in-kernel")
        progs, reports = self.progs, None
        if progs is None:
            if params is None:
                raise ValueError(
                    "AnalogueBackend needs params to program the crossbars "
                    "(or pre-programmed `progs`)")
            key = (self.prog_key if self.prog_key is not None
                   else jax.random.PRNGKey(0))
            if self.faults is not None or self.verify is not None:
                # One code path simulates the write physics: 'naive'
                # faulty programming is the same routine with zero
                # retries (a single uncorrected pulse train).
                vc = (self.verify if self.verify is not None
                      else VerifyConfig(max_retries=0))
                progs, reports = program_mlp_with_verify(
                    key, params, self.spec, faults=self.faults, verify=vc)
                progs = tuple(progs)
                if self.faults is not None and self.faults.drift is not None:
                    drift_only = dataclasses.replace(
                        self.faults, stuck=None, write_fail=None)
                    progs = tuple(apply_faults_to_mlp(
                        progs, drift_only, self.spec, n_reads=self.n_reads))
            else:
                progs = tuple(program_mlp(key, params, self.spec))
        if self.storage == "uint8":
            progs = tuple(stage_uint8(p, self.spec) for p in progs)
        a_field = AnalogueMLPVectorField(
            progs=progs, spec=self.spec,
            drive=getattr(field, "drive", None), key=self.read_key)
        extra = None if reports is None else {"repair_reports": reports}
        return ExecState(field=a_field, params=None, extra=extra)


# ---------------------------------------------------------------------------
# Fused-Pallas backend — weights-stationary TPU kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class FusedPallasBackend(BaseBackend):
    """Whole-trajectory RK4 inside one ``pallas_call`` (weights pinned in
    VMEM — the TPU transposition of in-memory computing).

    ``rollout`` ignores the per-step odeint and instead samples the drive
    on the RK4 half-step grid and hands the full solve to
    :func:`repro.kernels.fused_ode_mlp.fused_node_rollout`.  Requires a
    uniform, concrete time grid and ``method='rk4'``.

    The substrate is DIFFERENTIABLE: any ``gradient`` mode other than
    ``"stopgrad"`` routes the solve through the reverse-time
    checkpoint/replay kernel (:mod:`repro.kernels.fused_ode_mlp_bwd`),
    so the same weights-stationary program that serves the fleet also
    trains it (discretise-then-optimise — gradients match
    backprop-through-the-unrolled-RK4 to float32 rounding).  Pass
    ``gradient="stopgrad"`` to detach an inference-only solve.

    ``rollout_batch`` tiles the fleet across the Pallas grid — one cell
    per ``batch_tile`` twins, weights broadcast to every cell — instead
    of vmapping N separate solves.  Fleet sizes that do not divide the
    tile are padded up to the next tile multiple (padded rows replicate
    the last twin and are dropped from the result), so a prime fleet
    size costs one extra tile instead of degenerating to 1-twin cells.

    Long horizons stream through VMEM in time chunks: the kernel carries
    the integration state across a second grid dimension, so ``T`` is
    unbounded (serving at T>=10k works) while the weights stay resident.
    ``time_chunk=None`` auto-sizes the chunk from ``vmem_budget_bytes``.

    ``precision`` selects the mixed-precision policy of the substrate
    ("f32" | "bf16" | "bf16_f32acc"; ``None`` = auto — bf16_f32acc on
    TPU, f32 elsewhere): the bf16 policies store weights, drive and
    trajectory slabs at half width (the VMEM planner packs ~2x the time
    chunk) while matmuls accumulate at f32 and gradients always come
    back f32.  Error model: ``docs/kernels.md``.  Every ``rollout`` /
    ``rollout_batch`` call accepts a per-call ``precision=`` override.
    """

    name = "fused_pallas"
    batch_tile: int = 64
    time_chunk: Optional[int] = None        # None = auto from VMEM budget
    interpret: Optional[bool] = None        # None = auto (TPU -> compiled)
    vmem_budget_bytes: int = DEFAULT_VMEM_BUDGET
    precision: Optional[str] = None         # None = auto (TPU -> bf16_f32acc)

    # -- staging -----------------------------------------------------------
    def program(self, field: Callable, params: Pytree) -> ExecState:
        """Stage full-precision (f32) master operands; the precision
        policy rounds them to its storage dtype at solve time.  Staging
        the masters — not pre-rounded bf16 copies — keeps the per-call
        ``precision`` override honest: ``precision="f32"`` on a
        bf16-policy backend really is the exact path, and bf16→f32→bf16
        round-trips cannot double-round."""
        if params is None:
            raise ValueError("FusedPallasBackend needs the MLP params")
        weights = [p["w"].astype(jnp.float32) for p in params]
        biases = [p["b"].astype(jnp.float32) for p in params]
        return ExecState(field=field, params=params,
                         extra={"weights": weights, "biases": biases})

    def _grid(self, ts: jax.Array, steps_per_interval: int):
        """Validate + densify the time grid; returns (ts_fine, dt, sub)."""
        try:
            tsn = np.asarray(ts, dtype=np.float64)
        except jax.errors.TracerArrayConversionError as e:
            raise ValueError(
                "FusedPallasBackend needs a concrete (non-traced) time "
                "grid: the step count and dt are kernel compile-time "
                "constants. Close over ts instead of passing it as a jit "
                "argument.") from e
        if tsn.size < 2:
            raise ValueError("FusedPallasBackend needs a uniform time grid")
        # Uniformity is judged on the grid VALUES, not consecutive diffs:
        # float32 linspace diffs wobble by ~eps*t_max (which falsely
        # rejected T>=10k grids under a fixed rtol), but the values stay
        # within float32 rounding of the ideal line — and that distance
        # is exactly the time error incurred by integrating with a
        # constant dt.
        dt0 = (tsn[-1] - tsn[0]) / (tsn.size - 1)
        drift = np.abs(tsn - (tsn[0] + dt0 * np.arange(tsn.size))).max()
        tol = max(32 * np.finfo(np.float32).eps * np.abs(tsn).max(), 1e-9)
        if dt0 == 0 or drift > tol:
            raise ValueError("FusedPallasBackend needs a uniform time grid")
        sub = int(steps_per_interval)
        T = (tsn.size - 1) * sub
        ts_fine = jnp.asarray(
            np.linspace(tsn[0], tsn[-1], T + 1), dtype=jnp.float32)
        dt = float(dt0) / sub
        return ts_fine, dt, sub

    def _u_half(self, drive: Optional[Callable], ts_fine: jax.Array):
        """Sample u(t) on the RK4 half-step grid, (2T+1, Du)."""
        from repro.kernels.ops import half_step_drive
        T = ts_fine.shape[0] - 1
        if drive is None:
            return jnp.zeros((2 * T + 1, 0), jnp.float32)
        return half_step_drive(drive, ts_fine).astype(jnp.float32)

    def _solve(self, state: ExecState, y0s, uh, dt, bt, gradient,
               precision=None, step_offset=0):
        """Dispatch the fused solve in the requested gradient mode.

        Every differentiable mode ('adjoint'/'direct'/'fused_vjp') maps
        onto the one substrate-native VJP (reverse-time checkpoint/
        replay); 'stopgrad' detaches.  The dispatch itself lives in
        :func:`repro.kernels.ops.fused_node_rollout` — one copy.
        ``precision=None`` falls back to the backend's policy.
        ``step_offset`` (the global step index of ``y0s`` in a resumed
        rollout) is irrelevant here — the digital RK4 arithmetic is
        time-translation-invariant once the drive is sampled — but the
        analogue subclass keys its noise/drift streams on it.

        NOTE: under the fused VJP the drive is data (zero cotangent), so
        gradients w.r.t. per-twin ``drive_params`` are silently zero on
        this substrate — calibrate drive parameters on the digital
        backend.
        """
        del step_offset
        from repro.kernels import ops
        params = [{"w": w, "b": b} for w, b in
                  zip(state.extra["weights"], state.extra["biases"])]
        mode = "stopgrad" if gradient == "stopgrad" else "fused_vjp"
        return ops.fused_node_rollout(
            params, y0s, uh, dt, batch_tile=bt, time_chunk=self.time_chunk,
            interpret=self.interpret,
            vmem_budget_bytes=self.vmem_budget_bytes, gradient=mode,
            precision=self.precision if precision is None else precision)

    def _u_half_window(self, state: ExecState, t0, dt, num_steps,
                       starts: np.ndarray,
                       drive_family: Optional[Callable],
                       drive_params: Optional[jax.Array]) -> jax.Array:
        """Drive on the canonical half-step window of each twin: shared
        (2H+1, Du) when every twin sits at the same global step with one
        drive, per-twin (N, 2H+1, Du) otherwise (ragged phases — the
        kernel's per-tile drive slabs take it from there)."""
        from repro.kernels import ops
        drive = getattr(state.field, "drive", None)
        if drive_family is not None:
            ths = ops.half_step_times(t0, dt, num_steps, starts)

            def row(ts_row, theta):
                u = jax.vmap(lambda t: drive_family(t, theta))(ts_row)
                return u[:, None] if u.ndim == 1 else u

            return jax.vmap(row)(ths, drive_params).astype(jnp.float32)
        if drive is None:
            return jnp.zeros((2 * num_steps + 1, 0), jnp.float32)
        homogeneous = starts.size > 0 and bool((starts == starts[0]).all())
        start = int(starts[0]) if homogeneous else starts
        return ops.sample_drive_window(
            drive, t0, dt, num_steps, start).astype(jnp.float32)

    def rollout_batch_resumed(self, state: ExecState, ys, *, dt: float,
                              num_steps: int, t0: float = 0.0,
                              start_steps=None,
                              drive_family: Optional[Callable] = None,
                              drive_params: Optional[jax.Array] = None,
                              method: str = "rk4",
                              steps_per_interval: int = 1,
                              gradient: str = "fused_vjp",
                              precision: Optional[str] = None) -> jax.Array:
        """Resume-from-state fleet solve on the fused substrate.

        Each twin's carried state enters the kernel through the same
        storage-dtype seed path as trajectory rows leave it (see the
        chunk-carry contract in :mod:`repro.kernels.fused_ode_mlp`), and
        its drive window is sampled on the canonical global half-step
        grid — so splitting a rollout at any step and resuming from the
        stored row is bit-identical to the uninterrupted solve under f32
        (and pure bf16) storage, and within one storage rounding under
        bf16_f32acc.  Mixed phases batch fine: heterogeneous
        ``start_steps`` switch to per-twin drive slabs.
        """
        from repro.kernels.fused_ode_mlp import pad_fleet_to_tile
        if method != "rk4" or steps_per_interval != 1:
            raise ValueError(
                "FusedPallasBackend.rollout_batch_resumed integrates "
                "plain RK4 on the canonical step grid (method='rk4', "
                f"steps_per_interval=1), got method={method!r}, "
                f"steps_per_interval={steps_per_interval}")
        ys = jnp.asarray(ys)
        starts = self._resume_starts(start_steps, ys.shape[0])
        uh = self._u_half_window(state, t0, dt, int(num_steps), starts,
                                 drive_family, drive_params)
        homogeneous = starts.size > 0 and bool((starts == starts[0]).all())
        offset = int(starts[0]) if homogeneous else 0
        y0s, uh, bt, B = pad_fleet_to_tile(ys, uh, self.batch_tile)
        traj = self._solve(state, y0s, uh, float(dt), bt, gradient,
                           precision, step_offset=offset)
        return jnp.transpose(traj[:, :B], (1, 0, 2))

    # -- execution ---------------------------------------------------------
    def rollout(self, state: ExecState, y0, ts, *, method: str = "rk4",
                steps_per_interval: int = 1,
                gradient: str = "fused_vjp",
                precision: Optional[str] = None) -> jax.Array:
        if method != "rk4":
            raise ValueError(
                f"FusedPallasBackend integrates RK4 only, got {method!r}")
        ts_fine, dt, sub = self._grid(ts, steps_per_interval)
        uh = self._u_half(getattr(state.field, "drive", None), ts_fine)
        traj = self._solve(state, y0[None, :], uh, dt, 1, gradient,
                           precision)
        return traj[::sub, 0, :]

    def rollout_batch_local(self, state: ExecState, y0s, ts, *,
                            drive_family: Optional[Callable] = None,
                            drive_params: Optional[jax.Array] = None,
                            method: str = "rk4", steps_per_interval: int = 1,
                            gradient: str = "fused_vjp",
                            precision: Optional[str] = None) -> jax.Array:
        """Per-device fleet solve: tile the local batch across the Pallas
        grid (weights broadcast to every cell, per-twin drives sampled on
        the half-step grid per tile).  ``precision`` overrides the
        backend's mixed-precision policy per call (it rides through
        ``rollout_batch(mesh=...)``'s ``solver_kw``, so sharded fleets
        serve reduced precision too)."""
        if method != "rk4":
            raise ValueError(
                f"FusedPallasBackend integrates RK4 only, got {method!r}")
        ts_fine, dt, sub = self._grid(ts, steps_per_interval)
        B = y0s.shape[0]
        if drive_family is None:
            uh = self._u_half(getattr(state.field, "drive", None), ts_fine)
        else:
            # per-twin drive: (B, 2T+1, Du) -> per-tile blocks in-kernel
            uh = jax.vmap(
                lambda th_: self._u_half(lambda t: drive_family(t, th_),
                                         ts_fine))(drive_params)
        # pad the fleet up to a tile multiple instead of shrinking the
        # tile to a divisor: a prime B used to degenerate to bt=1 and one
        # grid cell per twin (B=1021 -> 1021 cells); now it costs at most
        # one padded tile.
        from repro.kernels.fused_ode_mlp import pad_fleet_to_tile
        y0s, uh, bt, B = pad_fleet_to_tile(y0s, uh, self.batch_tile)
        traj = self._solve(state, y0s, uh, dt, bt, gradient, precision)
        return jnp.transpose(traj[::sub, :B], (1, 0, 2))


# ---------------------------------------------------------------------------
# Fused-analogue backend — crossbar semantics on the weights-stationary kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class FusedAnalogueBackend(FusedPallasBackend):
    """The analogue substrate on the fused kernel: one ``pallas_call``
    runs the whole RK4 trajectory with the *crossbar* read semantics
    traced in-kernel (:mod:`repro.kernels.fused_analogue`) — the jnp
    simulator's per-step dispatch is gone, while ``program`` stays the
    paper's deployment exactly (same ``program_mlp``, bitwise-identical
    conductances to :class:`AnalogueBackend`).

    ``program`` is the one-time deployment step — run it once per set of
    weights (outside any per-request jit) so the frozen conductances are
    concrete, like a physical array would be; serving then closes over
    them.  ``storage="uint8"`` deploys the 6-bit level indices instead
    of float conductances (4x less stationary weight traffic, dequant
    fused into the MXU feed; requires ``prog_noise=0``).

    Read noise (``spec.read_noise``) is re-sampled per crossbar
    evaluation from a counter-derived stream keyed on ``read_seed`` —
    deterministic and replayable, but a *different* sequence from the
    ``jax.random`` stream of :class:`AnalogueBackend` (equal in
    distribution, not bitwise).

    Serving is inference-only: frozen conductances are physical
    quantities, so the fused analogue rollout detaches every gradient
    mode and always runs float32 (the mixed-precision policies do not
    apply).  ``trainable=True`` arms the *differentiable training mode*:
    ``program`` additionally stages the float32 master weights, and a
    non-detached ``_solve`` passes them through the hardware-aware write
    path (:mod:`repro.train.hw_aware` — STE quantise + programming/read
    noise + this backend's fault model, keyed by ``read_seed`` and
    ``step_offset``) before integrating on the fused digital kernel with
    its reverse-time VJP.  Forward sees device-degraded weights; the
    gradient reaches the masters through the straight-through estimator.
    (`train_twin(backend="analogue_fused")` routes through the same
    transform at the loss level — see ``segment_loss_fn``.)

    ``apply`` (single vector-field evaluations) keeps the jnp simulator
    path of the programmed field — only the rollouts are fused.
    """

    name = "analogue_fused"
    spec: AnalogueSpec = AnalogueSpec()
    prog_key: Optional[jax.Array] = None
    read_seed: int = 0
    storage: str = "float"          # "float" | "uint8" level indices
    faults: Optional[FaultModel] = None
    verify: Optional[VerifyConfig] = None
    n_reads: int = 0                # reads already served before t0 (drift)
    trainable: bool = False         # arm the differentiable training mode

    # -- deployment --------------------------------------------------------
    def program(self, field: Callable, params: Pytree) -> ExecState:
        if self.storage not in ("float", "uint8"):
            raise ValueError(
                f"FusedAnalogueBackend storage={self.storage!r}; have "
                f"'float', 'uint8'")
        if params is None:
            raise ValueError(
                "FusedAnalogueBackend needs params to program the "
                "crossbars")
        key = (self.prog_key if self.prog_key is not None
               else jax.random.PRNGKey(0))
        reports = None
        if self.faults is not None or self.verify is not None:
            # Same write-physics simulation as AnalogueBackend: stuck
            # cells and failed pulses are baked into the deployed
            # conductances (that IS the physical array); the kernel then
            # re-derives the same stuck masks in-kernel (idempotent) and
            # advances the drift decay live with the step count.
            vc = (self.verify if self.verify is not None
                  else VerifyConfig(max_retries=0))
            progs, reports = program_mlp_with_verify(
                key, params, self.spec, faults=self.faults, verify=vc)
            progs = tuple(progs)
        else:
            progs = tuple(program_mlp(key, params, self.spec))
        staged = {
            "scales": jnp.stack([p["scale"] for p in progs]),
            "g_step": None,
            "g_min": self.spec.g_min,
            "g_max": self.spec.g_max,
            "v_clamp": self.spec.v_clamp,
        }
        if self.faults is not None:
            staged["fault"] = self.faults.kernel_args(self.n_reads)
        if reports is not None:
            staged["repair_reports"] = reports
        if self.storage == "uint8":
            progs = tuple(stage_uint8(p, self.spec) for p in progs)
            staged["gps"] = [p["gp_idx"] for p in progs]
            staged["gms"] = [p["gm_idx"] for p in progs]
            staged["g_step"] = ((self.spec.g_max - self.spec.g_min)
                                / (self.spec.levels - 1))
        else:
            staged["gps"] = [p["gp"].astype(jnp.float32) for p in progs]
            staged["gms"] = [p["gm"].astype(jnp.float32) for p in progs]
        a_field = AnalogueMLPVectorField(
            progs=progs, spec=self.spec,
            drive=getattr(field, "drive", None), key=None)
        if self.trainable:
            # training mode keeps the f32 masters alongside the frozen
            # conductances — the differentiable _solve path reads them
            staged["weights"] = [p["w"].astype(jnp.float32)
                                 for p in params]
            staged["biases"] = [p["b"].astype(jnp.float32)
                                for p in params]
        return ExecState(field=a_field, params=None, extra=staged)

    # -- execution ---------------------------------------------------------
    def _solve(self, state: ExecState, y0s, uh, dt, bt, gradient,
               precision=None, step_offset=0):
        """Dispatch the fused analogue solve.  ``precision`` is ignored
        (the substrate is float32).  ``step_offset`` keys the read-noise
        salts and drift exponent to the global step index of ``y0s``, so
        a resumed rollout replays the uninterrupted noise stream — it is
        only exact when the whole batch shares one offset
        (``rollout_batch_resumed`` passes 0 for mixed-phase batches:
        deterministic per batch, equal in distribution, not a bitwise
        replay).

        Serving (``trainable=False``) always detaches, whatever
        ``gradient`` says.  With ``trainable=True`` and a non-detached
        ``gradient``, the solve becomes differentiable: the staged f32
        masters go through the hardware-aware write path (one device
        realisation keyed by ``(read_seed, step_offset)``) and the fused
        digital kernel's reverse-time VJP carries the gradient back to
        them through the STE."""
        del precision
        from repro.kernels import ops
        if self.trainable and gradient != "stopgrad":
            from repro.train.hw_aware import (HwAwareConfig,
                                              hw_aware_params)
            masters = [{"w": w, "b": b}
                       for w, b in zip(state.extra["weights"],
                                       state.extra["biases"])]
            cfg = HwAwareConfig.from_backend(self, k_draws=1)
            eff = hw_aware_params(masters, cfg, step_offset, draw=0)
            return ops.fused_node_rollout(
                eff, y0s, uh, dt, batch_tile=bt,
                time_chunk=self.time_chunk, interpret=self.interpret,
                vmem_budget_bytes=self.vmem_budget_bytes,
                gradient="fused_vjp", precision="f32")
        del gradient
        return ops.fused_analogue_rollout(
            state.extra, y0s, uh, dt, batch_tile=bt,
            time_chunk=self.time_chunk, interpret=self.interpret,
            vmem_budget_bytes=self.vmem_budget_bytes,
            read_noise=self.spec.read_noise, noise_seed=self.read_seed,
            step_offset=step_offset)


DEFAULT_BACKEND = DigitalBackend()

#: Registry of substrate names accepted anywhere a Backend is expected
#: (``twin.with_backend("fused_pallas")``, recipe ``backend=`` kwargs).
BACKENDS = {
    "digital": DigitalBackend,
    "analogue": AnalogueBackend,
    "fused_pallas": FusedPallasBackend,
    "analogue_fused": FusedAnalogueBackend,
}


def resolve_backend(backend) -> Backend:
    """Accept a Backend instance, a registry name, or None (digital)."""
    if backend is None:
        return DEFAULT_BACKEND
    if isinstance(backend, str):
        try:
            return BACKENDS[backend]()
        except KeyError:
            raise ValueError(
                f"unknown backend {backend!r}; have {sorted(BACKENDS)}")
    return backend
