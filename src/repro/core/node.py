"""Neural-ODE modules: the paper's core contribution as composable JAX.

Pieces:

* ``mlp_init`` / ``mlp_apply`` — the small ReLU MLP the paper deploys on
  the memristor crossbars (HP twin: 2->14->14->1; Lorenz96: 3-layer, 64
  hidden).  ``mlp_apply`` takes a pluggable ``linear_fn`` so the same
  network can execute digitally (jnp dot), through the analogue-crossbar
  simulator (:mod:`repro.core.analogue`) or through the fused Pallas
  kernel (:mod:`repro.kernels`).
* ``NeuralODE`` — ties a vector field to an integrator + gradient mode
  (adjoint vs backprop-through-solver); handles driven systems (external
  input u(t), HP twin) and autonomous systems (Lorenz96 twin).
* ``ContinuousDepthBlock`` — lifts the idea to any residual stack: a
  weight-tied block integrated in pseudo-depth, the paper's Eq. (8)/(9)
  equivalence as a framework feature usable inside the LM models.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.backends import Backend, resolve_backend
from repro.core.ode import odeint

Pytree = Any


# ---------------------------------------------------------------------------
# MLP vector field
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, sizes: Sequence[int],
             dtype=jnp.float32) -> list[dict]:
    """He-init MLP parameters: list of {'w': (in,out), 'b': (out,)}."""
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, din, dout in zip(keys, sizes[:-1], sizes[1:]):
        w = jax.random.normal(k, (din, dout), dtype) * jnp.sqrt(2.0 / din)
        params.append({"w": w, "b": jnp.zeros((dout,), dtype)})
    return params


def dense_linear(w: jax.Array, b: jax.Array, x: jax.Array) -> jax.Array:
    return x @ w + b


def mlp_apply(params: list[dict], x: jax.Array,
              activation: Callable = jax.nn.relu,
              linear_fn: Callable = dense_linear) -> jax.Array:
    """ReLU MLP, no activation on the output layer (paper, Methods)."""
    for i, layer in enumerate(params):
        x = linear_fn(layer["w"], layer["b"], x)
        if i < len(params) - 1:
            x = activation(x)
    return x


@dataclasses.dataclass(frozen=True)
class MLPVectorField:
    """dy/dt = MLP([u(t), y]) (driven) or MLP(y) (autonomous).

    ``drive``: optional continuous input signal u(t) -> array; mirrors the
    analogue waveform generator feeding x1 in the paper's HP-twin loop.
    """
    sizes: tuple
    drive: Optional[Callable[[jax.Array], jax.Array]] = None
    activation: Callable = jax.nn.relu
    linear_fn: Callable = dense_linear

    def init(self, key: jax.Array) -> Pytree:
        return mlp_init(key, self.sizes)

    def __call__(self, t: jax.Array, y: jax.Array, params: Pytree) -> jax.Array:
        if self.drive is not None:
            u = jnp.atleast_1d(jnp.asarray(self.drive(t), dtype=y.dtype))
            inp = jnp.concatenate([u, y], axis=-1)
        else:
            inp = y
        return mlp_apply(params, inp, self.activation, self.linear_fn)


# ---------------------------------------------------------------------------
# NeuralODE module
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NeuralODE:
    """The memristive neural-ODE solver's software twin.

    gradient: 'adjoint' (O(1) memory; paper's training method) or
    'direct' (backprop through the unrolled solver).

    ``backend`` selects the execution substrate (None -> digital): the
    field is programmed onto it once per solve and the backend owns the
    integration (see :mod:`repro.core.backends`).
    """
    field: Callable  # f(t, y, params) -> dy/dt
    method: str = "rk4"
    steps_per_interval: int = 1
    gradient: str = "adjoint"
    backend: Optional[Backend] = None

    def init(self, key: jax.Array) -> Pytree:
        init = getattr(self.field, "init", None)
        if init is None:
            raise ValueError("vector field has no .init; pass params explicitly")
        return init(key)

    def _solver_kw(self) -> dict:
        return dict(method=self.method,
                    steps_per_interval=self.steps_per_interval,
                    gradient=self.gradient)

    def trajectory(self, params: Pytree, y0: jax.Array,
                   ts: jax.Array) -> jax.Array:
        """Solve the IVP, returning y at every ts (leading axis len(ts))."""
        backend = resolve_backend(self.backend)
        state = backend.program(self.field, params)
        return backend.rollout(state, y0, ts, **self._solver_kw())

    def trajectory_batch(self, params: Pytree, y0s: jax.Array,
                         ts: jax.Array, *, drive_family=None,
                         drive_params=None, mesh=None) -> jax.Array:
        """Fleet solve: N initial conditions (and optionally per-twin
        drive parameters) in one device program, (N, len(ts), D).

        ``mesh``: optional twin mesh — shards the fleet dimension across
        devices (see :meth:`repro.core.backends.BaseBackend.rollout_batch`).
        """
        backend = resolve_backend(self.backend)
        state = backend.program(self.field, params)
        return backend.rollout_batch(state, y0s, ts,
                                     drive_family=drive_family,
                                     drive_params=drive_params,
                                     mesh=mesh,
                                     **self._solver_kw())

    def __call__(self, params, y0, ts):
        return self.trajectory(params, y0, ts)


# ---------------------------------------------------------------------------
# Continuous-depth residual block (paper Eq. 8 <-> Eq. 9 as a feature)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ContinuousDepthBlock:
    """Weight-tied residual block integrated in pseudo-depth.

    A discrete stack ``h <- h + block(h)`` repeated K times is the Euler
    discretisation of ``dh/ds = block(h)`` on s in [0, K].  This module
    integrates that ODE with RK4 instead, giving the infinite-depth
    approximation of the paper with a single block's parameters.

    ``block_fn(params, h) -> residual`` must be s-independent (weight tied).
    """
    block_fn: Callable[[Pytree, jax.Array], jax.Array]
    depth: float = 1.0          # pseudo-time horizon (== #discrete layers)
    num_steps: int = 4          # RK4 steps across the horizon
    method: str = "rk4"

    def __call__(self, params: Pytree, h: jax.Array) -> jax.Array:
        def f(t, y, p):
            del t
            return self.block_fn(p, y)

        ts = jnp.linspace(0.0, self.depth, self.num_steps + 1, dtype=h.dtype)
        ys = odeint(f, h, ts, params, method=self.method)
        return jax.tree_util.tree_map(lambda x: x[-1], ys)
