"""The paper's energy scorecard, connected to real compiled programs.

:mod:`repro.core.energy` carries the calibrated speed/energy model and
the paper's reported anchors (Fig. 3k,l / Fig. 4h,i); the roofline HLO
parser (:mod:`repro.roofline.hlo_parse`) counts what a compiled rollout
*actually* executes.  This module joins the two through the Backend
protocol:

1. **Anchor rows** — the four headline ratios the paper reports
   (HP: 4.2x speed, 41.4x energy vs the GPU neural-ODE; Lorenz96:
   12.6x speed, 189.7x energy), recomputed from the calibrated model
   and checked against the paper values within :data:`ANCHOR_TOL`.
   These are the CI gates.

2. **Backend rows** — for each registered substrate, the twin's rollout
   is compiled (``jit(...).lower().compile()``), its optimised HLO is
   parsed loop-aware into MAC/traffic counts, and the counts feed the
   projection:

   * digital substrates (``digital``, ``fused_pallas``) project time
     and energy from the *measured* MACs through
     :func:`repro.core.energy.project_from_macs` — the model's MAC
     constants applied to what XLA really scheduled;
   * analogue substrates (``analogue``, ``analogue_fused``) project
     from array physics (settling time x stages, peripheral + array
     power) via :func:`repro.core.energy.project` — an analogue array
     does not execute MACs, it settles; the HLO counts of the
     *simulator* are still reported for transparency (the differential
     pair doubles the simulator's dot count, and that factor is visible
     in the rows).

The two workloads are the paper's: the HP memristor twin (hidden 64,
500 steps) and the Lorenz96 twin (hidden 512, 1800 interpolation
steps), both three crossbar layers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import energy

#: Relative tolerance for the paper-anchor assertions (the calibrated
#: model hits most anchors to <6%, the worst to ~17%).
ANCHOR_TOL = 0.20


@dataclasses.dataclass(frozen=True)
class Workload:
    """One paper workload: a twin topology plus a trajectory length."""
    name: str
    state_dim: int
    drive_dim: int            # 0 = autonomous (Lorenz96), 1 = driven (HP)
    hidden: int
    n_layers: int = 3         # weight matrices (= crossbar arrays)
    n_steps: int = 500

    @property
    def in_dim(self) -> int:
        return self.state_dim + self.drive_dim

    @property
    def out_dim(self) -> int:
        return self.state_dim

    def mlp_sizes(self) -> tuple:
        return ((self.in_dim,) + (self.hidden,) * (self.n_layers - 1)
                + (self.out_dim,))

    def macs_per_eval(self) -> float:
        s = self.mlp_sizes()
        return float(sum(a * b for a, b in zip(s[:-1], s[1:])))

    def macs_per_trajectory(self) -> float:
        return 4.0 * self.n_steps * self.macs_per_eval()   # RK4: 4 f-evals


#: Fig. 3k,l configuration: HP memristor twin, MLP 2 -> 64 -> 64 -> 1.
HP = Workload("hp", state_dim=1, drive_dim=1, hidden=64, n_steps=500)
#: Fig. 4h,i configuration: Lorenz96 twin, MLP 6 -> 512 -> 512 -> 6.
LORENZ96 = Workload("lorenz96", state_dim=6, drive_dim=0, hidden=512,
                    n_steps=1800)
WORKLOADS = (HP, LORENZ96)

#: Substrate class of each registered backend — selects the projection
#: path (measured MACs through the digital model vs array physics).
BACKEND_SUBSTRATE = {
    "digital": "digital",
    "fused_pallas": "digital",
    "analogue": "analogue",
    "analogue_fused": "analogue",
}


# ---------------------------------------------------------------------------
# Anchor rows — the four CI-gated paper ratios
# ---------------------------------------------------------------------------

def _workload_ratios(w: Workload):
    kw = dict(in_dim=w.in_dim, out_dim=w.out_dim, n_layers=w.n_layers,
              n_steps=w.n_steps)
    t_a, e_a = energy.project("analogue_node", w.hidden, **kw)
    t_d, e_d = energy.project("node_gpu", w.hidden, **kw)
    return t_d / t_a, e_d / e_a


def anchor_rows(tol: float = ANCHOR_TOL) -> list:
    """The four headline paper anchors vs the calibrated model.

    Returns one row per anchor: ``{workload, name, model, paper,
    rel_err, tol, within_tol}``.  CI asserts every ``within_tol``.
    """
    anchors = [
        ("hp", "speedup_vs_node_gpu",
         energy.PAPER_ANCHORS["hp"]["speedup_vs_node_gpu"]),
        ("hp", "energy_gain_vs_node_gpu",
         energy.PAPER_ANCHORS["hp"]["energy_gain_vs_node_gpu"]),
        ("lorenz96", "speed_gain_vs_node_gpu",
         energy.PAPER_ANCHORS["lorenz96"]["speed_gain"]["node_gpu"]),
        ("lorenz96", "energy_gain_vs_node_gpu",
         energy.PAPER_ANCHORS["lorenz96"]["energy_gain"]["node_gpu"]),
    ]
    by_workload = {w.name: _workload_ratios(w) for w in WORKLOADS}
    rows = []
    for wname, aname, paper in anchors:
        speed, egain = by_workload[wname]
        model = speed if "speed" in aname else egain
        rel = abs(model - paper) / paper
        rows.append({"workload": wname, "name": aname,
                     "model": float(model), "paper": float(paper),
                     "rel_err": float(rel), "tol": tol,
                     "within_tol": bool(rel <= tol)})
    return rows


def assert_anchors(rows: Optional[list] = None) -> list:
    """Raise if any paper anchor drifts outside its tolerance."""
    rows = anchor_rows() if rows is None else rows
    bad = [r for r in rows if not r["within_tol"]]
    if bad:
        detail = "; ".join(
            f"{r['workload']}/{r['name']}: model {r['model']:.2f} vs "
            f"paper {r['paper']:.2f} ({r['rel_err']:.1%} > {r['tol']:.0%})"
            for r in bad)
        raise AssertionError(f"paper anchors out of tolerance: {detail}")
    return rows


# ---------------------------------------------------------------------------
# Backend rows — HLO-measured op counts through the projection model
# ---------------------------------------------------------------------------

def _build_twin(w: Workload, hidden: Optional[int] = None,
                n_steps: Optional[int] = None):
    """Twin + params + uniform time grid for a workload (optionally at a
    reduced size — tests use small plumbing sizes, the bench the paper's)."""
    from repro.core.twin import make_autonomous_twin, make_driven_twin
    hidden = w.hidden if hidden is None else hidden
    n_steps = w.n_steps if n_steps is None else n_steps
    n_hid = w.n_layers - 1
    if w.drive_dim:
        twin = make_driven_twin(w.state_dim,
                                drive=lambda t: jnp.sin(2.0 * t),
                                hidden=hidden, n_hidden_layers=n_hid)
    else:
        twin = make_autonomous_twin(w.state_dim, hidden=hidden,
                                    n_hidden_layers=n_hid)
    params = twin.init(jax.random.PRNGKey(0))
    ts = jnp.linspace(0.0, 1.0, n_steps + 1)
    y0 = jnp.zeros((w.state_dim,), jnp.float32)
    return twin, params, ts, y0


def measure_backend(backend_name: str, w: Workload, *,
                    hidden: Optional[int] = None,
                    n_steps: Optional[int] = None) -> dict:
    """Compile one rollout on a substrate and count what it executes.

    ``program`` runs once outside the compiled function (deployment is
    one-time; for the analogue substrates the conductances must be
    concrete, like a physical array), then ``rollout`` is lowered,
    compiled, and its optimised HLO parsed loop-aware.  Returns the
    :func:`repro.roofline.hlo_parse.analyze` counts plus ``macs``
    (= flops / 2).
    """
    from repro.core.backends import FusedPallasBackend, resolve_backend
    from repro.roofline.hlo_parse import analyze

    be = resolve_backend(backend_name)
    twin, params, ts, y0 = _build_twin(w, hidden, n_steps)
    state = be.program(twin.node.field, params)
    grad = ("stopgrad" if isinstance(be, FusedPallasBackend) else "direct")
    fn = lambda y: be.rollout(state, y, ts, gradient=grad)
    text = jax.jit(fn).lower(y0).compile().as_text()
    counts = analyze(text)
    counts["macs"] = counts["flops"] / 2.0
    return counts


def backend_rows(workloads: Sequence[Workload] = WORKLOADS,
                 backends: Sequence[str] = tuple(BACKEND_SUBSTRATE),
                 *, hidden: Optional[int] = None,
                 n_steps: Optional[int] = None,
                 measure: bool = True) -> list:
    """Per-(workload, backend) scorecard rows.

    Each row carries the substrate class, the projected per-trajectory
    ``time_us``/``energy_uj`` (digital: from measured MACs through
    :func:`energy.project_from_macs`; analogue: from array physics),
    the analytic MAC count, and — when ``measure`` — the compiled HLO's
    measured counts.  ``hidden``/``n_steps`` override the workload size
    for *both* measurement and projection (test plumbing runs small).
    """
    rows = []
    for w in workloads:
        if hidden is not None or n_steps is not None:
            w = dataclasses.replace(w, hidden=hidden or w.hidden,
                                    n_steps=n_steps or w.n_steps)
        for name in backends:
            substrate = BACKEND_SUBSTRATE[name]
            row = {"workload": w.name, "backend": name,
                   "substrate": substrate,
                   "hidden": w.hidden, "n_steps": w.n_steps,
                   "model_macs": w.macs_per_trajectory()}
            if measure:
                counts = measure_backend(name, w)
                row["hlo"] = {
                    "macs": counts["macs"],
                    "flops": counts["flops"],
                    "traffic_bytes": counts["traffic_bytes"],
                    "n_while": counts["n_while"],
                }
            if substrate == "digital":
                macs = (row["hlo"]["macs"] if measure
                        else row["model_macs"])
                t_us, e_uj = energy.project_from_macs(
                    "node_gpu", macs, w.hidden, w.n_steps)
            else:
                # array physics: settling + peripheral/array power; the
                # simulator's HLO MACs (2x the analytic count — the
                # differential pair) stay in the row for transparency
                t_us, e_uj = energy.project(
                    "analogue_node", w.hidden, in_dim=w.in_dim,
                    out_dim=w.out_dim, n_layers=w.n_layers,
                    n_steps=w.n_steps)
            row["projected"] = {"time_us": float(t_us),
                                "energy_uj": float(e_uj)}
            rows.append(row)
    return rows


def scorecard(*, measure: bool = True,
              backends: Sequence[str] = tuple(BACKEND_SUBSTRATE),
              hidden: Optional[int] = None,
              n_steps: Optional[int] = None) -> dict:
    """The full scorecard: anchor rows + per-backend projection rows."""
    return {"anchors": anchor_rows(),
            "backends": backend_rows(backends=backends, hidden=hidden,
                                     n_steps=n_steps, measure=measure)}
