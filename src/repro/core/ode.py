"""Explicit ODE integrators used by the neural-ODE digital twin.

The paper's analogue system integrates continuously in physical time with a
capacitor; the digital-twin-on-TPU equivalent is a high-order explicit
integrator.  RK4 is the paper's own ODESolve choice for training (Methods,
"Multivariate time series extrapolation"), so it is the default here.

All integrators share one contract:

    f(t, y, *f_args) -> dy/dt        (y is any pytree)

and are pure-JAX (``lax.scan`` / ``lax.while_loop``) so they can be jitted,
vmapped, differentiated, and lowered inside pjit programs.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
from jax import lax

Pytree = Any
VectorField = Callable[..., Pytree]

_tree_map = jax.tree_util.tree_map


def _axpy(a, xs, ys):
    """ys + a * xs over pytrees."""
    return _tree_map(lambda x, y: y + a * x, xs, ys)


def _weighted_sum(coeffs: Sequence[float], trees: Sequence[Pytree]) -> Pytree:
    acc = _tree_map(lambda x: coeffs[0] * x, trees[0])
    for c, t in zip(coeffs[1:], trees[1:]):
        acc = _tree_map(lambda a, x: a + c * x, acc, t)
    return acc


# ---------------------------------------------------------------------------
# Fixed-step Butcher tableaux steps
# ---------------------------------------------------------------------------

def euler_step(f: VectorField, t, y, dt, *f_args):
    return _axpy(dt, f(t, y, *f_args), y)


def heun_step(f: VectorField, t, y, dt, *f_args):
    k1 = f(t, y, *f_args)
    k2 = f(t + dt, _axpy(dt, k1, y), *f_args)
    return _axpy(dt / 2.0, _tree_map(lambda a, b: a + b, k1, k2), y)


def midpoint_step(f: VectorField, t, y, dt, *f_args):
    k1 = f(t, y, *f_args)
    k2 = f(t + dt / 2.0, _axpy(dt / 2.0, k1, y), *f_args)
    return _axpy(dt, k2, y)


def rk4_step(f: VectorField, t, y, dt, *f_args):
    """Classic 4th-order Runge-Kutta — the paper's ODESolve."""
    k1 = f(t, y, *f_args)
    k2 = f(t + dt / 2.0, _axpy(dt / 2.0, k1, y), *f_args)
    k3 = f(t + dt / 2.0, _axpy(dt / 2.0, k2, y), *f_args)
    k4 = f(t + dt, _axpy(dt, k3, y), *f_args)
    incr = _weighted_sum([1 / 6, 1 / 3, 1 / 3, 1 / 6], [k1, k2, k3, k4])
    return _axpy(dt, incr, y)


def rk38_step(f: VectorField, t, y, dt, *f_args):
    """Kutta's 3/8 rule (4th order, slightly better error constant)."""
    k1 = f(t, y, *f_args)
    k2 = f(t + dt / 3.0, _axpy(dt / 3.0, k1, y), *f_args)
    k3 = f(t + 2 * dt / 3.0,
           _axpy(dt, _weighted_sum([-1 / 3, 1.0], [k1, k2]), y), *f_args)
    k4 = f(t + dt,
           _axpy(dt, _weighted_sum([1.0, -1.0, 1.0], [k1, k2, k3]), y), *f_args)
    incr = _weighted_sum([1 / 8, 3 / 8, 3 / 8, 1 / 8], [k1, k2, k3, k4])
    return _axpy(dt, incr, y)


STEP_FNS = {
    "euler": euler_step,
    "heun": heun_step,
    "midpoint": midpoint_step,
    "rk4": rk4_step,
    "rk38": rk38_step,
}


def odeint(
    f: VectorField,
    y0: Pytree,
    ts: jax.Array,
    *f_args,
    method: str = "rk4",
    steps_per_interval: int = 1,
) -> Pytree:
    """Integrate ``dy/dt = f(t, y)`` and return y at every ``ts``.

    Returns a pytree whose leaves have a leading axis of ``len(ts)`` —
    ``y[0] == y0`` (matching Eq. 9 of the paper / torchdiffeq convention).

    ``steps_per_interval`` sub-divides each [t_i, t_{i+1}] for accuracy
    without densifying the output grid.
    """
    if method not in STEP_FNS:
        raise ValueError(f"unknown method {method!r}; have {sorted(STEP_FNS)}")
    step = STEP_FNS[method]
    sub = steps_per_interval

    def interval(y, t_pair):
        t0, t1 = t_pair
        dt = (t1 - t0) / sub

        def substep(i, y):
            return step(f, t0 + i * dt, y, dt, *f_args)

        y = lax.fori_loop(0, sub, substep, y)
        return y, y

    t_pairs = jnp.stack([ts[:-1], ts[1:]], axis=-1)
    _, ys = lax.scan(interval, y0, t_pairs)
    # prepend the initial condition
    return _tree_map(
        lambda first, rest: jnp.concatenate([first[None], rest], axis=0),
        y0, ys)


# ---------------------------------------------------------------------------
# Adaptive Dormand-Prince 5(4)
# ---------------------------------------------------------------------------

# Dopri5 tableau.
_DP_C = jnp.array([0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0])
_DP_A = [
    [],
    [1 / 5],
    [3 / 40, 9 / 40],
    [44 / 45, -56 / 15, 32 / 9],
    [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729],
    [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656],
    [35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84],
]
_DP_B5 = jnp.array([35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0])
_DP_B4 = jnp.array([5179 / 57600, 0.0, 7571 / 16695, 393 / 640,
                    -92097 / 339200, 187 / 2100, 1 / 40])


class _DopriState(NamedTuple):
    t: jax.Array
    y: Pytree
    dt: jax.Array
    nfe: jax.Array


def _dopri5_step(f, t, y, dt, *f_args):
    ks = []
    for i in range(7):
        yi = y
        for j, a in enumerate(_DP_A[i]):
            yi = _axpy(dt * a, ks[j], yi)
        ks.append(f(t + _DP_C[i] * dt, yi, *f_args))
    y5 = y
    y4 = y
    for i in range(7):
        y5 = _axpy(dt * _DP_B5[i], ks[i], y5)
        y4 = _axpy(dt * _DP_B4[i], ks[i], y4)
    err = _tree_map(lambda a, b: a - b, y5, y4)
    return y5, err


def _error_norm(err, y0, y1, rtol, atol):
    def leaf_norm(e, a, b):
        scale = atol + rtol * jnp.maximum(jnp.abs(a), jnp.abs(b))
        r = (e / scale) ** 2
        return jnp.sum(r), r.size

    leaves = jax.tree_util.tree_leaves(
        _tree_map(leaf_norm, err, y0, y1), is_leaf=lambda x: isinstance(x, tuple))
    total = sum(l[0] for l in leaves)
    count = sum(l[1] for l in leaves)
    return jnp.sqrt(total / count)


def odeint_dopri5(
    f: VectorField,
    y0: Pytree,
    ts: jax.Array,
    *f_args,
    rtol: float = 1e-5,
    atol: float = 1e-6,
    max_steps: int = 4096,
    safety: float = 0.9,
) -> Pytree:
    """Adaptive Dormand-Prince 5(4) with PI step control (lax.while_loop).

    Output convention matches :func:`odeint`.  Gradients flow by
    backprop-through-the-solver only (use the adjoint wrapper for O(1)
    memory); the while_loop makes reverse-mode unavailable, so this solver
    is for inference/ground-truth generation.
    """

    def advance_to(y, t0, t1, dt0):
        def cond(s: _DopriState):
            return (s.t < t1) & (s.nfe < max_steps)

        def body(s: _DopriState):
            dt = jnp.minimum(s.dt, t1 - s.t)
            y_new, err = _dopri5_step(f, s.t, s.y, dt, *f_args)
            en = _error_norm(err, s.y, y_new, rtol, atol)
            accept = en <= 1.0
            factor = jnp.clip(safety * (en + 1e-12) ** -0.2, 0.2, 5.0)
            new_dt = jnp.maximum(dt * factor, 1e-12)
            t_next = jnp.where(accept, s.t + dt, s.t)
            y_next = _tree_map(lambda a, b: jnp.where(accept, a, b), y_new, s.y)
            return _DopriState(t_next, y_next, new_dt, s.nfe + 1)

        init = _DopriState(t0, y, dt0, jnp.array(0, jnp.int32))
        out = lax.while_loop(cond, body, init)
        return out.y, out.dt

    def interval(carry, t_pair):
        y, dt = carry
        t0, t1 = t_pair
        y, dt = advance_to(y, t0, t1, dt)
        return (y, dt), y

    dt0 = (ts[1] - ts[0]) / 8.0
    t_pairs = jnp.stack([ts[:-1], ts[1:]], axis=-1)
    (_, _), ys = lax.scan(interval, (y0, dt0), t_pairs)
    return _tree_map(
        lambda first, rest: jnp.concatenate([first[None], rest], axis=0),
        y0, ys)


def make_odeint(method: str = "rk4", **kwargs) -> Callable:
    """Factory returning an odeint with the method baked in."""
    if method == "dopri5":
        return functools.partial(odeint_dopri5, **kwargs)
    return functools.partial(odeint, method=method, **kwargs)
