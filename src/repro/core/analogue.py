"""Simulation of the paper's analogue memristor crossbar execution.

Models, with the paper's measured device statistics:

* differential-pair weight mapping  W -> (G+, G-), G in [20, 100] uS
  (Fig. 2f; Fig. 3e reports 2.2% average relative error in that range);
* 6-bit analogue conductance (>= 64 states, Fig. 2h) — uniform
  quantisation of each conductance;
* programming noise — multiplicative Gaussian with sigma = 4.36%
  (Fig. 2k), frozen at programming time;
* read noise — multiplicative Gaussian per VMM evaluation (Fig. 4j
  sweeps 0-2%);
* peripheral clamp — output voltage protection (Fig. 2d).

Biases are folded into the crossbar as an extra row driven by a constant
1-V line, the standard crossbar idiom.  ``analogue_mlp_apply`` mirrors
:func:`repro.core.node.mlp_apply` so a trained digital twin can be
"deployed" onto the simulated arrays unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AnalogueSpec:
    g_min: float = 20e-6          # S  (paper: 20 uS)
    g_max: float = 100e-6         # S  (paper: 100 uS)
    levels: int = 64              # 6-bit analogue conductance
    prog_noise: float = 0.0436    # relative sigma, Fig. 2k
    read_noise: float = 0.0       # relative sigma per read
    v_clamp: Optional[float] = None  # output clamp (model units), None = off
    quantize: bool = True


def weight_scale(w: jax.Array, spec: AnalogueSpec) -> jax.Array:
    """Per-tensor scale mapping max|w| to the full differential range."""
    g_range = spec.g_max - spec.g_min
    return g_range / jnp.maximum(jnp.max(jnp.abs(w)), 1e-12)


def _require_programmable(w: jax.Array, name: str) -> jax.Array:
    """Gate conductance programming on a sane weight tensor.

    Conductances are continuous physical quantities: integer weights
    cannot be mapped, and a NaN weight would silently poison every
    downstream VMM through the differential pair.  Raises a
    ``ValueError`` naming the offending input (mirrors the ops-level
    validation of the fused kernels); the NaN check only runs on
    concrete values — traced programming (inside jit) skips it.
    """
    w = jnp.asarray(w)
    if not jnp.issubdtype(w.dtype, jnp.floating):
        raise ValueError(
            f"analogue programming: {name} has non-floating dtype "
            f"{w.dtype}; crossbar conductances are continuous — cast "
            f"{name} to a floating dtype first")
    if not isinstance(w, jax.core.Tracer) and bool(jnp.isnan(w).any()):
        raise ValueError(
            f"analogue programming: {name} contains NaN — a NaN weight "
            f"has no conductance representation and would propagate "
            f"through every crossbar read")
    return w


def conductance_pair(w: jax.Array, spec: AnalogueSpec, name: str = "w"):
    """Map weights to a differential conductance pair.

    w >= 0: G+ carries the value, G- parked at g_min (and vice versa), so
    G+ - G- = scale * w exactly (before quantisation/noise).
    """
    w = _require_programmable(w, name)
    scale = weight_scale(w, spec)
    mag = jnp.abs(w) * scale
    gp = jnp.where(w >= 0, spec.g_min + mag, spec.g_min)
    gm = jnp.where(w >= 0, spec.g_min, spec.g_min + mag)
    return gp, gm, scale


def quantize_conductance(g: jax.Array, spec: AnalogueSpec) -> jax.Array:
    """Snap to the device's discrete analogue levels (64 = 6-bit)."""
    if not spec.quantize:
        return g
    step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    q = jnp.round((g - spec.g_min) / step)
    return spec.g_min + jnp.clip(q, 0, spec.levels - 1) * step


def program_tensor(key: jax.Array, w: jax.Array, spec: AnalogueSpec,
                   name: str = "w") -> dict:
    """Program a weight tensor onto a (simulated) crossbar.

    Quantisation then multiplicative programming noise, frozen — this is
    the post-programming conductance of Fig. 2k.
    """
    gp, gm, scale = conductance_pair(w, spec, name)
    gp = quantize_conductance(gp, spec)
    gm = quantize_conductance(gm, spec)
    if spec.prog_noise > 0:
        kp, km = jax.random.split(key)
        gp = gp * (1.0 + spec.prog_noise * jax.random.normal(kp, gp.shape))
        gm = gm * (1.0 + spec.prog_noise * jax.random.normal(km, gm.shape))
        gp = jnp.clip(gp, 0.0, spec.g_max * 1.5)
        gm = jnp.clip(gm, 0.0, spec.g_max * 1.5)
    return {"gp": gp, "gm": gm, "scale": scale}


def programming_error(prog: dict, w: jax.Array, spec: AnalogueSpec):
    """Relative error between target and realised differential conductance."""
    target = w * prog["scale"]
    realised = prog["gp"] - prog["gm"]
    return jnp.abs(realised - target) / (spec.g_max - spec.g_min)


def _read_key(key: jax.Array, t: jax.Array) -> jax.Array:
    """Derive a per-read key from continuous time (read noise is i.i.d.
    per evaluation; fold the time stamp in at 1 ns resolution)."""
    tick = jnp.asarray(jnp.mod(jnp.abs(t) * 1e6, jnp.float32(2 ** 31 - 1)),
                       jnp.uint32)
    return jax.random.fold_in(key, tick)


#: Crossbar reads with at least this many cells (K x N) route through the
#: blocked Pallas kernel instead of plain jnp dots — below it the kernel's
#: tile padding (everything rounds up to 128x128) costs more than the
#: fused epilogue saves.  HP-sized arrays (15x14) stay jnp; hidden >= 128
#: twins dispatch.
KERNEL_DISPATCH_MIN_CELLS = 16384


def _kernel_dispatchable(prog: dict, x: jax.Array, spec: AnalogueSpec,
                         key: Optional[jax.Array]) -> bool:
    """Route noise-free 2-D reads of large arrays through the kernel.

    Noisy reads stay on the jnp path: their perturbation stream is keyed
    by ``jax.random`` (the kernel's counter-derived stream is a different
    — deterministic — sequence, used by the fused rollout)."""
    if spec.read_noise > 0 and key is not None:
        return False
    if x.ndim != 2:
        return False
    K, N = prog["gp"].shape
    return K * N >= KERNEL_DISPATCH_MIN_CELLS


def analogue_matmul(prog: dict, x: jax.Array, spec: AnalogueSpec,
                    key: Optional[jax.Array] = None) -> jax.Array:
    """x @ W through the differential crossbar: I = V G+ - V G- (Ohm +
    Kirchhoff), rescaled back to weight units.

    Large noise-free reads execute on the blocked Pallas kernel
    (:mod:`repro.kernels.crossbar_vmm`) — uint8 level indices with fused
    dequant when the program was staged quantised (``gp_idx`` present),
    float conductances otherwise; small or noisy reads keep the plain
    jnp path (identical semantics)."""
    if _kernel_dispatchable(prog, x, spec, key):
        # deferred import: repro.kernels.ops imports this module
        from repro.kernels.crossbar_vmm import crossbar_matmul
        if "gp_idx" in prog:
            g_step = (spec.g_max - spec.g_min) / (spec.levels - 1)
            y = crossbar_matmul(x, prog["gp_idx"], prog["gm_idx"],
                                inv_scale=1.0,
                                g_step=float(g_step)) / prog["scale"]
        else:
            y = crossbar_matmul(x, prog["gp"], prog["gm"],
                                inv_scale=1.0) / prog["scale"]
        # the clamp acts in post-scale units and scale is traced, so it
        # cannot ride the kernel epilogue here
        if spec.v_clamp is not None:
            y = jnp.clip(y, -spec.v_clamp, spec.v_clamp)
        return y
    gp, gm = prog["gp"], prog["gm"]
    if spec.read_noise > 0 and key is not None:
        kp, km = jax.random.split(key)
        gp = gp * (1.0 + spec.read_noise * jax.random.normal(kp, gp.shape))
        gm = gm * (1.0 + spec.read_noise * jax.random.normal(km, gm.shape))
    y = (x @ gp - x @ gm) / prog["scale"]
    if spec.v_clamp is not None:
        y = jnp.clip(y, -spec.v_clamp, spec.v_clamp)
    return y


# ---------------------------------------------------------------------------
# Whole-MLP programming / execution (bias folded as constant-input row)
# ---------------------------------------------------------------------------

def _fold_bias(layer: dict) -> jax.Array:
    return jnp.concatenate([layer["w"], layer["b"][None, :]], axis=0)


def program_mlp(key: jax.Array, params: list[dict],
                spec: AnalogueSpec) -> list[dict]:
    keys = jax.random.split(key, len(params))
    return [program_tensor(k, _fold_bias(layer), spec,
                           name=f"params[{i}] (w|b folded)")
            for i, (k, layer) in enumerate(zip(keys, params))]


def stage_uint8(prog: dict, spec: AnalogueSpec) -> dict:
    """Add uint8 level-index storage (``gp_idx``/``gm_idx``) to a
    noise-free quantised program — the device's native 6-bit state,
    4x less weight traffic, dequant fused into the kernel read.

    Only exact for programs whose conductances still sit ON the level
    grid: programming noise moves them off-grid, so it must be disabled.
    """
    if spec.prog_noise > 0:
        raise ValueError(
            "uint8 staging requires prog_noise=0: programming noise "
            "moves conductances off the 6-bit level grid, so level "
            "indices cannot represent them")
    if not spec.quantize:
        raise ValueError("uint8 staging requires quantize=True")
    step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    to_idx = lambda g: jnp.clip(jnp.round((g - spec.g_min) / step),
                                0, spec.levels - 1).astype(jnp.uint8)
    return dict(prog, gp_idx=to_idx(prog["gp"]), gm_idx=to_idx(prog["gm"]))


def analogue_mlp_apply(progs: list[dict], x: jax.Array, spec: AnalogueSpec,
                       key: Optional[jax.Array] = None,
                       activation=jax.nn.relu) -> jax.Array:
    """Forward through the programmed arrays; ReLU between layers is the
    peripheral dual-diode circuit (Fig. 2d-e)."""
    ones = jnp.ones(x.shape[:-1] + (1,), x.dtype)
    for i, prog in enumerate(progs):
        xa = jnp.concatenate([x, ones], axis=-1)
        k = None
        if key is not None:
            key, k = jax.random.split(key)
        x = analogue_matmul(prog, xa, spec, k)
        if i < len(progs) - 1:
            x = activation(x)
        ones = jnp.ones(x.shape[:-1] + (1,), x.dtype)
    return x


@dataclasses.dataclass(frozen=True)
class AnalogueMLPVectorField:
    """Analogue-deployed counterpart of MLPVectorField.

    Wraps programmed crossbars; read noise is re-sampled per f-evaluation,
    keyed on (base key, time stamp) — matching i.i.d. read noise in the
    closed analogue loop.
    """
    progs: tuple
    spec: AnalogueSpec
    drive: Optional[Any] = None
    key: Optional[jax.Array] = None

    def __call__(self, t, y, params=None):
        del params  # weights live in the (frozen) crossbar programs
        if self.drive is not None:
            u = jnp.atleast_1d(jnp.asarray(self.drive(t), dtype=y.dtype))
            inp = jnp.concatenate([u, y], axis=-1)
        else:
            inp = y
        k = None
        if self.key is not None and self.spec.read_noise > 0:
            k = _read_key(self.key, t)
        return analogue_mlp_apply(list(self.progs), inp, self.spec, k)
