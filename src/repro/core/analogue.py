"""Simulation of the paper's analogue memristor crossbar execution.

Models, with the paper's measured device statistics:

* differential-pair weight mapping  W -> (G+, G-), G in [20, 100] uS
  (Fig. 2f; Fig. 3e reports 2.2% average relative error in that range);
* 6-bit analogue conductance (>= 64 states, Fig. 2h) — uniform
  quantisation of each conductance;
* programming noise — multiplicative Gaussian with sigma = 4.36%
  (Fig. 2k), frozen at programming time;
* read noise — multiplicative Gaussian per VMM evaluation (Fig. 4j
  sweeps 0-2%);
* peripheral clamp — output voltage protection (Fig. 2d).

Biases are folded into the crossbar as an extra row driven by a constant
1-V line, the standard crossbar idiom.  ``analogue_mlp_apply`` mirrors
:func:`repro.core.node.mlp_apply` so a trained digital twin can be
"deployed" onto the simulated arrays unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AnalogueSpec:
    g_min: float = 20e-6          # S  (paper: 20 uS)
    g_max: float = 100e-6         # S  (paper: 100 uS)
    levels: int = 64              # 6-bit analogue conductance
    prog_noise: float = 0.0436    # relative sigma, Fig. 2k
    read_noise: float = 0.0       # relative sigma per read
    v_clamp: Optional[float] = None  # output clamp (model units), None = off
    quantize: bool = True

    def __post_init__(self):
        # Degenerate-but-positive ranges (g_on ~ g_off) are legal — they
        # model a worn array and the fault tests exercise them — but a
        # zero/negative range has no differential representation at all.
        if not self.g_max > self.g_min:
            raise ValueError(
                f"AnalogueSpec: g_max ({self.g_max}) must exceed g_min "
                f"({self.g_min}); the differential range g_max - g_min "
                f"is the weight-mapping denominator")
        if self.levels < 2:
            raise ValueError(
                f"AnalogueSpec: levels must be >= 2, got {self.levels}")
        if self.prog_noise < 0 or self.read_noise < 0:
            raise ValueError(
                f"AnalogueSpec: noise sigmas must be >= 0, got "
                f"prog_noise={self.prog_noise} read_noise={self.read_noise}")


def weight_scale(w: jax.Array, spec: AnalogueSpec) -> jax.Array:
    """Per-tensor scale mapping max|w| to the full differential range."""
    g_range = spec.g_max - spec.g_min
    return g_range / jnp.maximum(jnp.max(jnp.abs(w)), 1e-12)


def _require_programmable(w: jax.Array, name: str) -> jax.Array:
    """Gate conductance programming on a sane weight tensor.

    Conductances are continuous physical quantities: integer weights
    cannot be mapped, and a NaN weight would silently poison every
    downstream VMM through the differential pair.  Raises a
    ``ValueError`` naming the offending input (mirrors the ops-level
    validation of the fused kernels); the NaN check only runs on
    concrete values — traced programming (inside jit) skips it.
    """
    w = jnp.asarray(w)
    if not jnp.issubdtype(w.dtype, jnp.floating):
        raise ValueError(
            f"analogue programming: {name} has non-floating dtype "
            f"{w.dtype}; crossbar conductances are continuous — cast "
            f"{name} to a floating dtype first")
    if not isinstance(w, jax.core.Tracer) and bool(jnp.isnan(w).any()):
        raise ValueError(
            f"analogue programming: {name} contains NaN — a NaN weight "
            f"has no conductance representation and would propagate "
            f"through every crossbar read")
    return w


def conductance_pair(w: jax.Array, spec: AnalogueSpec, name: str = "w"):
    """Map weights to a differential conductance pair.

    w >= 0: G+ carries the value, G- parked at g_min (and vice versa), so
    G+ - G- = scale * w exactly (before quantisation/noise).
    """
    w = _require_programmable(w, name)
    scale = weight_scale(w, spec)
    mag = jnp.abs(w) * scale
    gp = jnp.where(w >= 0, spec.g_min + mag, spec.g_min)
    gm = jnp.where(w >= 0, spec.g_min, spec.g_min + mag)
    return gp, gm, scale


def quantize_conductance(g: jax.Array, spec: AnalogueSpec) -> jax.Array:
    """Snap to the device's discrete analogue levels (64 = 6-bit)."""
    if not spec.quantize:
        return g
    step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    q = jnp.round((g - spec.g_min) / step)
    return spec.g_min + jnp.clip(q, 0, spec.levels - 1) * step


def program_tensor(key: jax.Array, w: jax.Array, spec: AnalogueSpec,
                   name: str = "w") -> dict:
    """Program a weight tensor onto a (simulated) crossbar.

    Quantisation then multiplicative programming noise, frozen — this is
    the post-programming conductance of Fig. 2k.
    """
    gp, gm, scale = conductance_pair(w, spec, name)
    gp = quantize_conductance(gp, spec)
    gm = quantize_conductance(gm, spec)
    if spec.prog_noise > 0:
        kp, km = jax.random.split(key)
        gp = gp * (1.0 + spec.prog_noise * jax.random.normal(kp, gp.shape))
        gm = gm * (1.0 + spec.prog_noise * jax.random.normal(km, gm.shape))
        gp = jnp.clip(gp, 0.0, spec.g_max * 1.5)
        gm = jnp.clip(gm, 0.0, spec.g_max * 1.5)
    return {"gp": gp, "gm": gm, "scale": scale}


def programming_error(prog: dict, w: jax.Array, spec: AnalogueSpec):
    """Relative error between target and realised differential conductance."""
    target = w * prog["scale"]
    realised = prog["gp"] - prog["gm"]
    return jnp.abs(realised - target) / (spec.g_max - spec.g_min)


def _read_key(key: jax.Array, t: jax.Array) -> jax.Array:
    """Derive a per-read key from continuous time (read noise is i.i.d.
    per evaluation; fold the time stamp in at 1 ns resolution)."""
    tick = jnp.asarray(jnp.mod(jnp.abs(t) * 1e6, jnp.float32(2 ** 31 - 1)),
                       jnp.uint32)
    return jax.random.fold_in(key, tick)


#: Crossbar reads with at least this many cells (K x N) route through the
#: blocked Pallas kernel instead of plain jnp dots — below it the kernel's
#: tile padding (everything rounds up to 128x128) costs more than the
#: fused epilogue saves.  HP-sized arrays (15x14) stay jnp; hidden >= 128
#: twins dispatch.
KERNEL_DISPATCH_MIN_CELLS = 16384


def _kernel_dispatchable(prog: dict, x: jax.Array, spec: AnalogueSpec,
                         key: Optional[jax.Array]) -> bool:
    """Route noise-free 2-D reads of large arrays through the kernel.

    Noisy reads stay on the jnp path: their perturbation stream is keyed
    by ``jax.random`` (the kernel's counter-derived stream is a different
    — deterministic — sequence, used by the fused rollout)."""
    if spec.read_noise > 0 and key is not None:
        return False
    if x.ndim != 2:
        return False
    K, N = prog["gp"].shape
    return K * N >= KERNEL_DISPATCH_MIN_CELLS


def analogue_matmul(prog: dict, x: jax.Array, spec: AnalogueSpec,
                    key: Optional[jax.Array] = None) -> jax.Array:
    """x @ W through the differential crossbar: I = V G+ - V G- (Ohm +
    Kirchhoff), rescaled back to weight units.

    Large noise-free reads execute on the blocked Pallas kernel
    (:mod:`repro.kernels.crossbar_vmm`) — uint8 level indices with fused
    dequant when the program was staged quantised (``gp_idx`` present),
    float conductances otherwise; small or noisy reads keep the plain
    jnp path (identical semantics)."""
    if _kernel_dispatchable(prog, x, spec, key):
        # deferred import: repro.kernels.ops imports this module
        from repro.kernels.crossbar_vmm import crossbar_matmul
        if "gp_idx" in prog:
            g_step = (spec.g_max - spec.g_min) / (spec.levels - 1)
            y = crossbar_matmul(x, prog["gp_idx"], prog["gm_idx"],
                                inv_scale=1.0,
                                g_step=float(g_step)) / prog["scale"]
        else:
            y = crossbar_matmul(x, prog["gp"], prog["gm"],
                                inv_scale=1.0) / prog["scale"]
        # the clamp acts in post-scale units and scale is traced, so it
        # cannot ride the kernel epilogue here
        if spec.v_clamp is not None:
            y = jnp.clip(y, -spec.v_clamp, spec.v_clamp)
        return y
    gp, gm = prog["gp"], prog["gm"]
    if spec.read_noise > 0 and key is not None:
        kp, km = jax.random.split(key)
        gp = gp * (1.0 + spec.read_noise * jax.random.normal(kp, gp.shape))
        gm = gm * (1.0 + spec.read_noise * jax.random.normal(km, gm.shape))
    y = (x @ gp - x @ gm) / prog["scale"]
    if spec.v_clamp is not None:
        y = jnp.clip(y, -spec.v_clamp, spec.v_clamp)
    return y


# ---------------------------------------------------------------------------
# Whole-MLP programming / execution (bias folded as constant-input row)
# ---------------------------------------------------------------------------

def _fold_bias(layer: dict) -> jax.Array:
    return jnp.concatenate([layer["w"], layer["b"][None, :]], axis=0)


def program_mlp(key: jax.Array, params: list[dict],
                spec: AnalogueSpec) -> list[dict]:
    keys = jax.random.split(key, len(params))
    return [program_tensor(k, _fold_bias(layer), spec,
                           name=f"params[{i}] (w|b folded)")
            for i, (k, layer) in enumerate(zip(keys, params))]


def stage_uint8(prog: dict, spec: AnalogueSpec) -> dict:
    """Add uint8 level-index storage (``gp_idx``/``gm_idx``) to a
    noise-free quantised program — the device's native 6-bit state,
    4x less weight traffic, dequant fused into the kernel read.

    Only exact for programs whose conductances still sit ON the level
    grid: programming noise moves them off-grid, so it must be disabled.
    """
    if spec.prog_noise > 0:
        raise ValueError(
            "uint8 staging requires prog_noise=0: programming noise "
            "moves conductances off the 6-bit level grid, so level "
            "indices cannot represent them")
    if not spec.quantize:
        raise ValueError("uint8 staging requires quantize=True")
    step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    to_idx = lambda g: jnp.clip(jnp.round((g - spec.g_min) / step),
                                0, spec.levels - 1).astype(jnp.uint8)
    return dict(prog, gp_idx=to_idx(prog["gp"]), gm_idx=to_idx(prog["gm"]))


# ---------------------------------------------------------------------------
# Closed-loop write–verify programming (read-back, retry, repair report)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VerifyConfig:
    """Write–verify loop knobs.

    ``tol`` is the per-cell acceptance threshold on the *differential*
    read-back error, in units of the full conductance range (the same
    normalisation as :func:`programming_error`); the default is one
    quantisation step of a 6-bit array.  ``backoff`` shrinks the write
    pulse's noise sigma each retry — the physics of fine-tuning pulses:
    later pulses move the filament less, so they land more precisely.
    """
    tol: float = 1.0 / 63.0
    max_retries: int = 6
    backoff: float = 0.5

    def __post_init__(self):
        if self.tol <= 0:
            raise ValueError(f"VerifyConfig.tol must be > 0, got {self.tol}")
        if self.max_retries < 0:
            raise ValueError(f"VerifyConfig.max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if not 0.0 < self.backoff <= 1.0:
            raise ValueError(f"VerifyConfig.backoff must be in (0, 1], "
                             f"got {self.backoff}")


@dataclasses.dataclass(frozen=True)
class RepairReport:
    """What write–verify could and could not fix for one tensor.

    ``unrepairable`` marks cells still outside tolerance after the last
    retry — with stuck faults these are cells whose partner-device
    compensation clipped against the conductance range.
    ``projected_rollout_error`` is the first-order estimate of the
    rollout impact: ``||W_realised - W||_F / ||W||_F`` (realised weights
    read back in weight units).  Fields are arrays when programming runs
    traced (inside jit) and concrete numbers otherwise.
    """
    name: str
    attempts: int
    tol: float
    unrepairable: jax.Array        # bool, weight-shaped
    n_cells: int
    n_unrepairable: jax.Array      # int32 scalar
    max_error: jax.Array           # float32, programming_error units
    mean_error: jax.Array
    projected_rollout_error: jax.Array

    def summary(self) -> dict:
        """Plain-python scalars for logs / bench artifacts (concrete
        reports only)."""
        return {
            "name": self.name,
            "attempts": int(self.attempts),
            "n_cells": int(self.n_cells),
            "n_unrepairable": int(self.n_unrepairable),
            "max_error": float(self.max_error),
            "mean_error": float(self.mean_error),
            "projected_rollout_error": float(self.projected_rollout_error),
        }


def _simulate_write(key: jax.Array, current: jax.Array, target: jax.Array,
                    sigma: float, spec: AnalogueSpec, faults,
                    salt: int) -> jax.Array:
    """One programming pulse against the (simulated) faulty physics:
    quantise the target, land with multiplicative noise ``sigma``, keep
    the previous state where the pulse failed to switch, and pin stuck
    cells — the same stuck stream the kernels re-derive in-kernel."""
    g = quantize_conductance(target, spec)
    if sigma > 0:
        g = g * (1.0 + sigma * jax.random.normal(key, g.shape))
        g = jnp.clip(g, 0.0, spec.g_max * 1.5)
    if faults is not None and faults.write_fail_rate > 0:
        u = jax.random.uniform(jax.random.fold_in(key, 0x57F), g.shape)
        g = jnp.where(u < faults.write_fail_rate, current, g)
    if faults is not None and faults.stuck_rate > 0:
        from repro.core.faults import apply_stuck
        g = apply_stuck(g, faults.seed, salt, faults.stuck_rate,
                        faults.stuck.on_frac, spec.g_max, spec.g_min)
    return g


def program_with_verify(key: jax.Array, w: jax.Array, spec: AnalogueSpec,
                        *, faults=None, verify: VerifyConfig = VerifyConfig(),
                        name: str = "w", layer: int = 0):
    """Closed-loop programming: write, read back, retry out-of-tolerance
    cells, report what stayed broken.

    Each retry re-reads the realised differential conductance and
    rewrites only the failing cells, alternating which side of the pair
    it corrects (G+ on even retries, G- on odd) — the rewritten side is
    retargeted against the *actual* value of its partner, so a stuck G+
    is compensated by moving G- to ``G+_stuck - scale*w`` (clipped to the
    device range; cells where the clip bites are the unrepairable ones).
    Write noise backs off geometrically per retry
    (``sigma_k = prog_noise * backoff**k``), modelling fine-tuning
    pulses.  jit-safe: when ``w`` is traced the loop runs all
    ``max_retries`` iterations with masked updates; concrete programming
    exits as soon as every cell verifies.

    Returns ``(prog, report)`` where ``prog`` is a standard program dict
    (drop-in for :func:`analogue_matmul`) and ``report`` is a
    :class:`RepairReport`.
    """
    from repro.core.faults import fault_salt
    gp_t, gm_t, scale = conductance_pair(w, spec, name)
    gp_t = quantize_conductance(gp_t, spec)
    gm_t = quantize_conductance(gm_t, spec)
    target = gp_t - gm_t
    g_range = spec.g_max - spec.g_min
    salt_p, salt_m = fault_salt(layer, 0), fault_salt(layer, 1)
    traced = isinstance(jnp.asarray(w), jax.core.Tracer)

    # Initial pulses from the pristine (erased, g_min) array.
    key, kp, km = jax.random.split(key, 3)
    pristine = jnp.full_like(gp_t, spec.g_min)
    gp = _simulate_write(kp, pristine, gp_t, spec.prog_noise, spec,
                         faults, salt_p)
    gm = _simulate_write(km, pristine, gm_t, spec.prog_noise, spec,
                         faults, salt_m)

    attempts = 1
    for k in range(verify.max_retries):
        err = jnp.abs((gp - gm) - target) / g_range
        need = err > verify.tol
        if not traced and not bool(need.any()):
            break
        attempts += 1
        sigma = spec.prog_noise * verify.backoff ** (k + 1)
        key, kw = jax.random.split(key)
        if k % 2 == 0:
            # retarget G+ against the partner's actual value
            want = jnp.clip(gm + target, spec.g_min, spec.g_max)
            wrote = _simulate_write(kw, gp, want, sigma, spec, faults, salt_p)
            gp = jnp.where(need, wrote, gp)
        else:
            want = jnp.clip(gp - target, spec.g_min, spec.g_max)
            wrote = _simulate_write(kw, gm, want, sigma, spec, faults, salt_m)
            gm = jnp.where(need, wrote, gm)

    err = jnp.abs((gp - gm) - target) / g_range
    unrepairable = err > verify.tol
    w_realised = (gp - gm) / scale
    w_norm = jnp.maximum(jnp.linalg.norm(jnp.ravel(w)), 1e-12)
    report = RepairReport(
        name=name, attempts=attempts, tol=verify.tol,
        unrepairable=unrepairable, n_cells=int(w.size),
        n_unrepairable=jnp.sum(unrepairable).astype(jnp.int32),
        max_error=jnp.max(err), mean_error=jnp.mean(err),
        projected_rollout_error=(
            jnp.linalg.norm(jnp.ravel(w_realised - w)) / w_norm))
    return {"gp": gp, "gm": gm, "scale": scale}, report


def program_mlp_with_verify(key: jax.Array, params: list[dict],
                            spec: AnalogueSpec, *, faults=None,
                            verify: VerifyConfig = VerifyConfig()):
    """Per-layer :func:`program_with_verify` over an MLP (bias folded as
    the constant-1 row, as in :func:`program_mlp`).  Returns
    ``(progs, reports)``."""
    keys = jax.random.split(key, len(params))
    progs, reports = [], []
    for i, (k, layer) in enumerate(zip(keys, params)):
        prog, rep = program_with_verify(
            k, _fold_bias(layer), spec, faults=faults, verify=verify,
            name=f"params[{i}] (w|b folded)", layer=i)
        progs.append(prog)
        reports.append(rep)
    return progs, reports


def analogue_mlp_apply(progs: list[dict], x: jax.Array, spec: AnalogueSpec,
                       key: Optional[jax.Array] = None,
                       activation=jax.nn.relu) -> jax.Array:
    """Forward through the programmed arrays; ReLU between layers is the
    peripheral dual-diode circuit (Fig. 2d-e)."""
    ones = jnp.ones(x.shape[:-1] + (1,), x.dtype)
    for i, prog in enumerate(progs):
        xa = jnp.concatenate([x, ones], axis=-1)
        k = None
        if key is not None:
            key, k = jax.random.split(key)
        x = analogue_matmul(prog, xa, spec, k)
        if i < len(progs) - 1:
            x = activation(x)
        ones = jnp.ones(x.shape[:-1] + (1,), x.dtype)
    return x


@dataclasses.dataclass(frozen=True)
class AnalogueMLPVectorField:
    """Analogue-deployed counterpart of MLPVectorField.

    Wraps programmed crossbars; read noise is re-sampled per f-evaluation,
    keyed on (base key, time stamp) — matching i.i.d. read noise in the
    closed analogue loop.
    """
    progs: tuple
    spec: AnalogueSpec
    drive: Optional[Any] = None
    key: Optional[jax.Array] = None

    def __call__(self, t, y, params=None):
        del params  # weights live in the (frozen) crossbar programs
        if self.drive is not None:
            u = jnp.atleast_1d(jnp.asarray(self.drive(t), dtype=y.dtype))
            inp = jnp.concatenate([u, y], axis=-1)
        else:
            inp = y
        k = None
        if self.key is not None and self.spec.read_noise > 0:
            k = _read_key(self.key, t)
        return analogue_mlp_apply(list(self.progs), inp, self.spec, k)


# ---------------------------------------------------------------------------
# Hardware-in-the-loop calibration
# ---------------------------------------------------------------------------
#
# A real array is characterised once (g_on/g_off, level count, noise
# sigmas, drift law, peripheral power) and the measurements land in a
# small JSON file; these loaders swap the measured constants into the
# device model (`spec_from_calibration`), the fault model
# (`drift_from_calibration`) and the energy projection
# (`repro.core.energy.constants_from_calibration`) — so the whole stack
# (training, serving, scorecard) runs against the characterised device
# instead of the paper's published statistics.  See
# `calibration/paper_device.json` for the reference file (the paper's
# Fig. 2 numbers).

CALIBRATION_SCHEMA = 1

#: field name -> (required, constraint) per section; constraints are
#: "pos" (> 0), "nonneg" (>= 0), "int" (positive integer) or None
_CALIBRATION_FIELDS = {
    "device": {
        "g_off_S": (True, "pos"),
        "g_on_S": (True, "pos"),
        "levels": (True, "int"),
        "prog_noise_sigma": (True, "nonneg"),
        "read_noise_sigma": (True, "nonneg"),
        "v_clamp": (False, "pos"),          # null = no clamp
    },
    "drift": {
        "nu": (True, "nonneg"),
        "tau": (True, "pos"),
    },
    "energy": {
        "t_settle_us": (False, "pos"),
        "p_base_w": (False, "pos"),
        "p_int_w": (False, "pos"),
        "v_read": (False, "pos"),
        "g_mean_s": (False, "pos"),
    },
}


def _check_calibration_field(sec: str, key: str, value, constraint):
    where = f"calibration: {sec}.{key}"
    if constraint == "int":
        if isinstance(value, bool) or not isinstance(value, int):
            raise ValueError(f"{where} must be an integer, got {value!r}")
        if value < 2:
            raise ValueError(f"{where} must be >= 2, got {value}")
        return value
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        raise ValueError(f"{where} must be a number, got {value!r}")
    v = float(value)
    if constraint == "pos" and not v > 0:
        raise ValueError(f"{where} must be > 0, got {value}")
    if constraint == "nonneg" and v < 0:
        raise ValueError(f"{where} must be >= 0, got {value}")
    return v


def load_calibration(source) -> dict:
    """Load + validate a measured device-constants file.

    ``source`` is a path to a JSON measurement file or an already-parsed
    dict.  Returns the validated dict (numbers coerced to float).  Every
    validation error names the offending field (``calibration:
    device.g_on_S must be > 0, got ...``), matching the repo's
    error-message convention.

    Schema (``"schema": 1``): a required ``device`` section (``g_off_S``,
    ``g_on_S``, ``levels``, ``prog_noise_sigma``, ``read_noise_sigma``,
    optional ``v_clamp``), plus optional ``drift`` (``nu``, ``tau``) and
    ``energy`` (any of ``t_settle_us``, ``p_base_w``, ``p_int_w``,
    ``v_read``, ``g_mean_s``; missing ones keep the paper-calibrated
    defaults) sections.  Unknown sections/fields are rejected by name —
    a typo must not silently fall back to a default.
    """
    import json
    import os

    if isinstance(source, (str, os.PathLike)):
        with open(source) as fh:
            try:
                cal = json.load(fh)
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"calibration file {os.fspath(source)}: invalid JSON "
                    f"({e})") from e
    elif isinstance(source, dict):
        cal = source
    else:
        raise TypeError(
            f"load_calibration takes a path or a dict, got "
            f"{type(source).__name__}")
    if not isinstance(cal, dict):
        raise ValueError("calibration: top level must be a JSON object")

    schema = cal.get("schema")
    if schema != CALIBRATION_SCHEMA:
        raise ValueError(
            f"calibration: schema must be {CALIBRATION_SCHEMA}, "
            f"got {schema!r}")

    known = set(_CALIBRATION_FIELDS) | {"schema", "source"}
    for sec in cal:
        if sec not in known:
            raise ValueError(f"calibration: unknown section {sec!r}")
    if "device" not in cal:
        raise ValueError("calibration: missing required section 'device'")

    out = {"schema": CALIBRATION_SCHEMA}
    if "source" in cal:
        out["source"] = str(cal["source"])
    for sec, fields in _CALIBRATION_FIELDS.items():
        if sec not in cal:
            continue
        raw = cal[sec]
        if not isinstance(raw, dict):
            raise ValueError(
                f"calibration: section {sec!r} must be an object, "
                f"got {raw!r}")
        parsed = {}
        for key in raw:
            if key not in fields:
                raise ValueError(
                    f"calibration: unknown field {sec}.{key}")
        for key, (required, constraint) in fields.items():
            if key not in raw or raw[key] is None:
                if required:
                    raise ValueError(
                        f"calibration: missing field {sec}.{key}")
                continue
            parsed[key] = _check_calibration_field(
                sec, key, raw[key], constraint)
        out[sec] = parsed

    dev = out["device"]
    if not dev["g_on_S"] > dev["g_off_S"]:
        raise ValueError(
            f"calibration: device.g_on_S ({dev['g_on_S']}) must exceed "
            f"device.g_off_S ({dev['g_off_S']}) — the differential range "
            f"is the weight-mapping denominator")
    return out


def spec_from_calibration(source, **overrides) -> AnalogueSpec:
    """Build an :class:`AnalogueSpec` from a measured calibration file.

    ``overrides`` replace individual spec fields after the measured
    values are applied (e.g. ``read_noise=0.0`` to model a clean read
    channel on a characterised array).
    """
    dev = load_calibration(source)["device"]
    kw = dict(g_min=dev["g_off_S"], g_max=dev["g_on_S"],
              levels=dev["levels"],
              prog_noise=dev["prog_noise_sigma"],
              read_noise=dev["read_noise_sigma"],
              v_clamp=dev.get("v_clamp"))
    kw.update(overrides)
    return AnalogueSpec(**kw)


def drift_from_calibration(source):
    """The measured drift law as a :class:`repro.core.faults.ConductanceDrift`
    mechanism (``None`` when the file has no ``drift`` section)."""
    cal = load_calibration(source)
    if "drift" not in cal:
        return None
    from repro.core.faults import ConductanceDrift
    return ConductanceDrift(nu=cal["drift"]["nu"], tau=cal["drift"]["tau"])
