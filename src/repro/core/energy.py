"""Projected speed / energy model — reproduces the paper's Fig. 3k,l and
Fig. 4h,i comparisons between the analogue memristive neural-ODE solver
and digital (GPU) baselines.

Two layers of fidelity:

1. ``PAPER_ANCHORS`` — numbers the paper reports verbatim.
2. A parametric projection model whose constants were *calibrated from
   the anchors themselves* (they are mutually consistent to ~10%):

   * digital time  = macs * T_MAC + evals * T_EVAL (+ fevals * T_SOLVER for
     the ODE solver's per-step framework overhead).  T_MAC = 0.205 ps/MAC
     reproduces the paper's LSTM/GRU/RNN times at h=512 to <1%.
   * digital energy = macs * e_mac(h), with the utilisation-dependent
     e_mac(h) = 5530/h - 3.1 pJ — this single curve reproduces the
     paper's 705.4 uJ (NODE h=64), 176.4 uJ (ResNet h=64) and the h=512
     energy ratios to ~15%.
   * analogue time = steps * stages * T_SETTLE with stages = crossbar
     layers + 1 (the IVP integrator); T_SETTLE = 5.57 ns puts the
     paper's 40.1 us (Lorenz96, 1800 steps x 4 stages) exactly on the
     line and the HP point within 17%.
   * analogue energy = (P_base + P_int*n_integrators + V^2*G*cells) * t;
     P_base = 1.4 W, P_int = 0.134 W (discrete op-amp board) reproduces
     17.0 uJ (HP) exactly and the Lorenz96 energy-gain column to <=17%.

Tests assert the model hits every anchor within 20% (most are <6%).

The analogue-side constants are replaceable with measured values
(hardware in the loop): :class:`EnergyConstants` carries them,
:func:`constants_from_calibration` loads them from the same JSON
measurement file as ``repro.core.analogue.spec_from_calibration``, and
``project(..., constants=...)`` projects with the characterised device
instead of the paper-calibrated defaults.
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Paper-reported anchors (verbatim from the text)
# ---------------------------------------------------------------------------

PAPER_ANCHORS = {
    # HP memristor twin, hidden size 64 (Fig. 3k,l)
    "hp": {
        "speedup_vs_node_gpu": 4.2,
        "energy_uj": {"analogue_node": 17.0,
                      "resnet_gpu": 176.4,
                      "node_gpu": 705.4},
        "energy_gain_vs_node_gpu": 41.4,
        "energy_gain_vs_resnet_gpu": 10.4,
    },
    # Lorenz96 twin, hidden size 512 (Fig. 4h,i)
    "lorenz96": {
        "time_us": {"node_gpu": 505.8, "lstm_gpu": 392.5, "gru_gpu": 294.9,
                    "rnn_gpu": 98.8, "analogue_node": 40.1},
        "speed_gain": {"node_gpu": 12.6, "lstm_gpu": 9.8,
                       "gru_gpu": 7.4, "rnn_gpu": 2.5},
        "energy_gain": {"node_gpu": 189.7, "lstm_gpu": 147.2,
                        "gru_gpu": 100.6, "rnn_gpu": 37.1},
    },
}

# ---------------------------------------------------------------------------
# Calibrated constants (see module docstring for provenance)
# ---------------------------------------------------------------------------

T_MAC_US = 2.05e-7        # us per MAC (digital, small-batch effective)
T_EVAL_US = 5.6e-4        # us per network evaluation (launch overhead)
T_SOLVER_US = 1.85e-2     # us per ODE f-eval (solver framework overhead)
E_MAC_A_PJ = 5530.0       # e_mac(h) = A/h + B  (utilisation curve)
E_MAC_B_PJ = -3.1
E_MAC_FLOOR_PJ = 0.5
T_SETTLE_US = 5.57e-3     # analogue per-stage loop settling
P_BASE_W = 1.4            # analogue peripheral board power, fixed part
P_INT_W = 0.134           # per IVP-integrator channel power
V_READ = 0.1              # V (inference read amplitude, calibrated)
G_MEAN_S = 30e-6          # mean device conductance incl. parked G_min pairs

@dataclasses.dataclass(frozen=True)
class EnergyConstants:
    """The analogue-side constants of the projection model, as one
    swappable value object.  Defaults are the paper-calibrated numbers
    above; :func:`constants_from_calibration` fills them from a measured
    device file instead."""

    t_settle_us: float = T_SETTLE_US
    p_base_w: float = P_BASE_W
    p_int_w: float = P_INT_W
    v_read: float = V_READ
    g_mean_s: float = G_MEAN_S

    def __post_init__(self):
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or not v > 0:
                raise ValueError(
                    f"EnergyConstants.{f.name} must be a number > 0, "
                    f"got {v!r}")


DEFAULT_CONSTANTS = EnergyConstants()


def constants_from_calibration(source) -> EnergyConstants:
    """Measured :class:`EnergyConstants` from a calibration JSON file (or
    parsed dict) — the ``energy`` section of the schema validated by
    :func:`repro.core.analogue.load_calibration`.  Fields absent from the
    file keep the paper-calibrated defaults; validation errors name the
    offending field."""
    from repro.core.analogue import load_calibration
    cal = load_calibration(source)
    return EnergyConstants(**cal.get("energy", {}))


SYSTEMS = ("analogue_node", "node_gpu", "resnet_gpu", "lstm_gpu", "gru_gpu",
           "rnn_gpu")
_GATES = {"lstm_gpu": 4.0, "gru_gpu": 3.0, "rnn_gpu": 1.0, "resnet_gpu": 1.0}


def _mlp_macs(sizes) -> float:
    return float(sum(a * b for a, b in zip(sizes[:-1], sizes[1:])))


def _recurrent_macs(hidden: int, in_dim: int, gates: float) -> float:
    return gates * hidden * (hidden + in_dim)


def _e_mac_pj(hidden: int) -> float:
    return max(E_MAC_A_PJ / hidden + E_MAC_B_PJ, E_MAC_FLOOR_PJ)


def project_from_macs(system: str, macs: float, hidden: int, n_steps: int):
    """Project (time_us, energy_uj) for a *digital* system from a MAC
    count — the bridge between this calibrated model and measured op
    counts (the roofline HLO parser feeds compiled-program MACs straight
    in here; see :mod:`repro.core.scorecard`).

    ``macs`` is the whole-trajectory count; ``hidden`` only sets the
    utilisation-dependent energy per MAC; ``n_steps`` sets the per-step
    launch/framework overhead (``node_gpu`` additionally pays the ODE
    solver's per-f-eval overhead, 4 per RK4 step).
    """
    if system == "analogue_node":
        raise ValueError(
            "project_from_macs models digital substrates only — analogue "
            "time/energy follow array physics, not MAC counts; use "
            "project()")
    t_us = macs * T_MAC_US + n_steps * T_EVAL_US
    if system == "node_gpu":
        t_us += 4 * n_steps * T_SOLVER_US
    e_uj = macs * _e_mac_pj(hidden) * 1e-6
    return t_us, e_uj


def project(system: str, hidden: int, in_dim: int = 2, out_dim: int = 1,
            n_layers: int = 3, n_steps: int = 500,
            constants: EnergyConstants | None = None):
    """Project (time_us, energy_uj) for one inference trajectory.

    ``n_layers`` counts weight matrices (HP twin: 3; Lorenz96 twin: 4).
    ``n_steps``: trajectory length (HP: 500; Lorenz96 interpolation: 1800).
    ``constants`` swaps in measured analogue-side constants
    (:func:`constants_from_calibration`); digital systems ignore it.
    """
    sizes = [in_dim] + [hidden] * (n_layers - 1) + [out_dim]
    if system == "analogue_node":
        c = DEFAULT_CONSTANTS if constants is None else constants
        # stages = crossbar layers + the IVP-integrator stage
        t_us = n_steps * (n_layers + 1) * c.t_settle_us
        cells = 2.0 * _mlp_macs(sizes)
        p_array_w = cells * c.v_read ** 2 * c.g_mean_s
        p_w = c.p_base_w + c.p_int_w * out_dim + p_array_w
        e_uj = p_w * t_us
        return t_us, e_uj
    if system == "node_gpu":
        macs = _mlp_macs(sizes) * 4 * n_steps        # RK4: 4 f-evals/step
    elif system == "resnet_gpu":
        macs = _mlp_macs(sizes) * n_steps            # one block/step
    elif system in _GATES:
        macs = _recurrent_macs(hidden, in_dim, _GATES[system]) * n_steps
    else:
        raise ValueError(f"unknown system {system!r}")
    return project_from_macs(system, macs, hidden, n_steps)


def gains_table(hidden_sizes, **kw):
    """Speed/energy gain of the analogue system vs each digital baseline."""
    rows = []
    for h in hidden_sizes:
        t_a, e_a = project("analogue_node", h, **kw)
        row = {"hidden": h, "analogue_time_us": t_a, "analogue_energy_uj": e_a}
        for sys in SYSTEMS[1:]:
            t_d, e_d = project(sys, h, **kw)
            row[f"{sys}_time_us"] = t_d
            row[f"{sys}_energy_uj"] = e_d
            row[f"{sys}_speed_gain"] = t_d / t_a
            row[f"{sys}_energy_gain"] = e_d / e_a
        rows.append(row)
    return rows


def hp_projection():
    """HP twin at hidden 64 (Fig. 3k,l configuration)."""
    return gains_table([8, 16, 32, 64], in_dim=2, out_dim=1, n_layers=3,
                       n_steps=500)


def lorenz96_projection():
    """Lorenz96 twin (Fig. 4h,i: three-layer net per Methods, 1800 steps)."""
    return gains_table([64, 128, 256, 512], in_dim=6, out_dim=6, n_layers=3,
                       n_steps=1800)
