"""Digital-twin façade: driven and autonomous continuous-time twins.

A twin = (vector field, integrator, gradient mode) + an optional analogue
deployment.  This is the public API the examples and benchmarks use.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.analogue import (AnalogueMLPVectorField, AnalogueSpec,
                                 program_mlp)
from repro.core.node import MLPVectorField, NeuralODE
from repro.core.ode import odeint

Pytree = Any


@dataclasses.dataclass(frozen=True)
class DigitalTwin:
    """Continuous-time digital twin of a physical asset."""
    field: Any                       # f(t, y, params)
    node: NeuralODE
    state_dim: int

    def init(self, key: jax.Array) -> Pytree:
        return self.field.init(key)

    def simulate(self, params: Pytree, y0: jax.Array, ts: jax.Array):
        return self.node.trajectory(params, y0, ts)

    def deploy_analogue(self, key: jax.Array, params: Pytree,
                        spec: AnalogueSpec,
                        read_key: Optional[jax.Array] = None) -> "DigitalTwin":
        """Program the trained weights onto simulated crossbars and return a
        twin that runs fully through the analogue path."""
        progs = tuple(program_mlp(key, params, spec))
        a_field = AnalogueMLPVectorField(
            progs=progs, spec=spec,
            drive=getattr(self.field, "drive", None),
            key=read_key)
        a_node = dataclasses.replace(self.node, field=a_field,
                                     gradient="direct")
        return dataclasses.replace(self, field=a_field, node=a_node)


def make_driven_twin(state_dim: int, drive: Callable, hidden: int = 14,
                     n_hidden_layers: int = 2, method: str = "rk4",
                     gradient: str = "adjoint",
                     steps_per_interval: int = 1) -> DigitalTwin:
    """HP-memristor-style twin: dy/dt = MLP([u(t), y]).

    Default sizes (2 -> 14 -> 14 -> 1) are the paper's three crossbar
    arrays (2x14, 14x14, 14x1) for state_dim=1.
    """
    sizes = (1 + state_dim,) + (hidden,) * n_hidden_layers + (state_dim,)
    field = MLPVectorField(sizes=sizes, drive=drive)
    node = NeuralODE(field=field, method=method, gradient=gradient,
                     steps_per_interval=steps_per_interval)
    return DigitalTwin(field=field, node=node, state_dim=state_dim)


def make_autonomous_twin(state_dim: int, hidden: int = 64,
                         n_hidden_layers: int = 2, method: str = "rk4",
                         gradient: str = "adjoint",
                         steps_per_interval: int = 1) -> DigitalTwin:
    """Lorenz96-style twin: dy/dt = MLP(y) (no external stimulation)."""
    sizes = (state_dim,) + (hidden,) * n_hidden_layers + (state_dim,)
    field = MLPVectorField(sizes=sizes, drive=None)
    node = NeuralODE(field=field, method=method, gradient=gradient,
                     steps_per_interval=steps_per_interval)
    return DigitalTwin(field=field, node=node, state_dim=state_dim)


def reference_trajectory(f: Callable, y0: jax.Array, ts: jax.Array, *args,
                         steps_per_interval: int = 16) -> jax.Array:
    """High-accuracy ground-truth solve (dense RK4) for data generation."""
    return odeint(f, y0, ts, *args, method="rk4",
                  steps_per_interval=steps_per_interval)
