"""Digital-twin façade: driven and autonomous continuous-time twins.

A twin = (vector field, integrator, gradient mode) + a pluggable
execution backend (digital jnp / analogue crossbars / fused Pallas — see
:mod:`repro.core.backends`).  This is the public API the examples and
benchmarks use; ``TwinFleet`` scales it to N independent twins in one
device program.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.analogue import AnalogueSpec, program_mlp
from repro.core.backends import (AnalogueBackend, Backend, DigitalBackend,
                                 FusedPallasBackend, resolve_backend)
from repro.core.node import MLPVectorField, NeuralODE
from repro.core.ode import odeint

Pytree = Any


@dataclasses.dataclass(frozen=True)
class DigitalTwin:
    """Continuous-time digital twin of a physical asset."""
    field: Any                       # f(t, y, params)
    node: NeuralODE
    state_dim: int

    @property
    def backend(self) -> Backend:
        return resolve_backend(self.node.backend)

    def init(self, key: jax.Array) -> Pytree:
        return self.field.init(key)

    def with_backend(self, backend) -> "DigitalTwin":
        """Return the same twin executing on another substrate.

        ``backend``: a Backend instance or registry name ('digital',
        'analogue', 'fused_pallas').  The weights stay wherever the
        caller keeps them — ``simulate(params, ...)`` programs them onto
        the substrate at solve time.
        """
        backend = resolve_backend(backend)
        return dataclasses.replace(
            self, node=dataclasses.replace(self.node, backend=backend))

    def simulate(self, params: Pytree, y0: jax.Array, ts: jax.Array):
        return self.node.trajectory(params, y0, ts)

    def simulate_batch(self, params: Pytree, y0s: jax.Array, ts: jax.Array,
                       *, drive_family: Optional[Callable] = None,
                       drive_params: Optional[jax.Array] = None,
                       mesh=None):
        """Batched fleet rollout: (N, D) initial conditions -> (N, T+1, D),
        equal to stacking N single-trajectory solves but executed as one
        device program (vmap, or one Pallas grid for the fused backend).

        ``mesh``: optional ``jax.sharding.Mesh`` with a ``"twins"`` axis
        — shards the fleet dimension across devices (weights replicated,
        uneven N padded, padding dropped); ``None`` stays single-device.
        """
        return self.node.trajectory_batch(params, y0s, ts,
                                          drive_family=drive_family,
                                          drive_params=drive_params,
                                          mesh=mesh)

    def deploy_analogue(self, key: jax.Array, params: Pytree,
                        spec: AnalogueSpec,
                        read_key: Optional[jax.Array] = None) -> "DigitalTwin":
        """Deprecated: use ``twin.with_backend(AnalogueBackend(spec=spec,
        prog_key=key, read_key=read_key))`` and keep passing ``params``.

        Kept as a thin shim: programs the crossbars eagerly so the legacy
        ``simulate(None, y0, ts)`` call pattern still works.
        """
        warnings.warn(
            "DigitalTwin.deploy_analogue is deprecated; use "
            "twin.with_backend(AnalogueBackend(...)) instead",
            DeprecationWarning, stacklevel=2)
        progs = tuple(program_mlp(key, params, spec))
        return self.with_backend(
            AnalogueBackend(spec=spec, read_key=read_key, progs=progs))


# ---------------------------------------------------------------------------
# Fleets of twins — many assets, one device program
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwinFleet:
    """N independent instances of one trained twin (one per physical
    asset), rolled out in a single device program.

    ``drive_family(t, theta) -> u`` is a parametric stimulus family;
    each fleet member i gets ``drive_params[i]`` (e.g. its own sensed
    amp/freq).  Autonomous fleets leave both None.

    Execution follows the underlying twin's backend: digital/analogue
    fleets vmap, the fused-Pallas fleet batch-tiles the kernel grid so
    all N trajectories run weights-stationary in one ``pallas_call``.
    """
    twin: DigitalTwin
    drive_family: Optional[Callable] = None

    @property
    def backend(self) -> Backend:
        return self.twin.backend

    def with_backend(self, backend) -> "TwinFleet":
        return dataclasses.replace(self, twin=self.twin.with_backend(backend))

    def simulate(self, params: Pytree, y0s: jax.Array, ts: jax.Array,
                 drive_params: Optional[jax.Array] = None) -> jax.Array:
        return self.rollout_batch(params, y0s, ts, drive_params)

    def rollout_batch(self, params: Pytree, y0s: jax.Array, ts: jax.Array,
                      drive_params: Optional[jax.Array] = None, *,
                      mesh=None) -> jax.Array:
        """Fleet rollout, optionally sharded over a multi-device mesh.

        ``mesh=None``: the whole fleet runs as one program on the current
        device (vmap / one Pallas grid).  ``mesh``: a ``jax.sharding.Mesh``
        with a ``"twins"`` axis — the fleet dimension of ``y0s`` and
        ``drive_params`` is split across devices with ``shard_map``
        (weights replicated, uneven N padded, padded rows dropped from
        the result), each device
        executing this fleet's backend on its slice.  Both paths return
        the same (N, T+1, D) trajectories; see
        :mod:`repro.launch.fleet_serving` for the serving pipeline on top.
        """
        if (drive_params is None) != (self.drive_family is None):
            raise ValueError(
                "drive_params and drive_family must be given together")
        return self.twin.simulate_batch(params, y0s, ts,
                                        drive_family=self.drive_family,
                                        drive_params=drive_params,
                                        mesh=mesh)

    def rollout_batch_resumed(self, params: Pytree, ys: jax.Array, *,
                              dt: float, num_steps: int, t0: float = 0.0,
                              start_steps=None,
                              drive_params: Optional[jax.Array] = None,
                              **kw) -> jax.Array:
        """Resume-from-state fleet rollout: advance each twin
        ``num_steps`` RK4 steps from its carried state ``ys[i]`` at its
        own global step ``start_steps[i]`` on the canonical uniform grid
        ``t = t0 + dt*k`` -> (N, num_steps+1, D).

        This is the streaming-serving primitive behind
        :class:`repro.launch.fleet_serving.StreamingFleetServer`: a twin
        served over ``[0, k)`` then ``[k, T)`` through a state store
        gets bit-identical trajectories (f32 substrates) to one served
        over ``[0, T)`` in a single request — see
        :meth:`repro.core.backends.BaseBackend.rollout_batch_resumed`
        for the determinism contract.  ``start_steps`` must be concrete
        host integers (they index the canonical float64 time grid).
        """
        if (drive_params is None) != (self.drive_family is None):
            raise ValueError(
                "drive_params and drive_family must be given together")
        node = self.twin.node
        backend = resolve_backend(node.backend)
        state = backend.program(node.field, params)
        return backend.rollout_batch_resumed(
            state, ys, dt=dt, num_steps=num_steps, t0=t0,
            start_steps=start_steps, drive_family=self.drive_family,
            drive_params=drive_params, **{**node._solver_kw(), **kw})


def simulate_batch(twin: DigitalTwin, params: Pytree, y0s: jax.Array,
                   ts: jax.Array, **kw) -> jax.Array:
    """Function-style alias for :meth:`DigitalTwin.simulate_batch`."""
    return twin.simulate_batch(params, y0s, ts, **kw)


def make_driven_twin(state_dim: int, drive: Callable, hidden: int = 14,
                     n_hidden_layers: int = 2, method: str = "rk4",
                     gradient: str = "adjoint",
                     steps_per_interval: int = 1,
                     backend: Optional[Backend] = None) -> DigitalTwin:
    """HP-memristor-style twin: dy/dt = MLP([u(t), y]).

    Default sizes (2 -> 14 -> 14 -> 1) are the paper's three crossbar
    arrays (2x14, 14x14, 14x1) for state_dim=1.
    """
    sizes = (1 + state_dim,) + (hidden,) * n_hidden_layers + (state_dim,)
    field = MLPVectorField(sizes=sizes, drive=drive)
    node = NeuralODE(field=field, method=method, gradient=gradient,
                     steps_per_interval=steps_per_interval, backend=backend)
    return DigitalTwin(field=field, node=node, state_dim=state_dim)


def make_autonomous_twin(state_dim: int, hidden: int = 64,
                         n_hidden_layers: int = 2, method: str = "rk4",
                         gradient: str = "adjoint",
                         steps_per_interval: int = 1,
                         backend: Optional[Backend] = None) -> DigitalTwin:
    """Lorenz96-style twin: dy/dt = MLP(y) (no external stimulation)."""
    sizes = (state_dim,) + (hidden,) * n_hidden_layers + (state_dim,)
    field = MLPVectorField(sizes=sizes, drive=None)
    node = NeuralODE(field=field, method=method, gradient=gradient,
                     steps_per_interval=steps_per_interval, backend=backend)
    return DigitalTwin(field=field, node=node, state_dim=state_dim)


def reference_trajectory(f: Callable, y0: jax.Array, ts: jax.Array, *args,
                         steps_per_interval: int = 16) -> jax.Array:
    """High-accuracy ground-truth solve (dense RK4) for data generation."""
    return odeint(f, y0, ts, *args, method="rk4",
                  steps_per_interval=steps_per_interval)
