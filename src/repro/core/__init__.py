from repro.core.adjoint import odeint_adjoint
from repro.core.analogue import (AnalogueMLPVectorField, AnalogueSpec,
                                 analogue_matmul, analogue_mlp_apply,
                                 program_mlp, program_tensor)
from repro.core.losses import (dtw, l1, lyapunov_time,
                               max_lyapunov_exponent, mre, normalized_dtw,
                               soft_dtw, soft_dtw_batch)
from repro.core.backends import (AnalogueBackend, Backend, DigitalBackend,
                                 ExecState, FusedPallasBackend,
                                 resolve_backend)
from repro.core.node import (ContinuousDepthBlock, MLPVectorField, NeuralODE,
                             dense_linear, mlp_apply, mlp_init)
from repro.core.ode import make_odeint, odeint, odeint_dopri5, rk4_step
from repro.core.twin import (DigitalTwin, TwinFleet, make_autonomous_twin,
                             make_driven_twin, reference_trajectory,
                             simulate_batch)
