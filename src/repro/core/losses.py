"""Losses and metrics used by the paper: soft-DTW, DTW, MRE, L1, Lyapunov.

The Lorenz96 twin is trained on DTW (Methods); since hard DTW is not
differentiable we train on soft-DTW (Cuturi & Blondel 2017 — the paper's
ref. 64) and report hard DTW as the metric, alongside MRE (Eq. 5) and L1.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

BIG = 1e10


def l1(pred: jax.Array, true: jax.Array) -> jax.Array:
    return jnp.mean(jnp.abs(pred - true))


def mre(pred: jax.Array, true: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Mean relative error, paper Eq. (5)."""
    return jnp.mean(jnp.abs((pred - true) / (jnp.abs(true) + eps)))


# ---------------------------------------------------------------------------
# (soft-)DTW via anti-diagonal wavefront
# ---------------------------------------------------------------------------

def _pairwise_dist(x: jax.Array, y: jax.Array) -> jax.Array:
    """|x_i - y_j| summed over feature dim (paper Eq. 6 uses 1-D |.|)."""
    if x.ndim == 1:
        x = x[:, None]
    if y.ndim == 1:
        y = y[:, None]
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def _softmin(a, b, c, gamma):
    stacked = jnp.stack([a, b, c], axis=0)
    return -gamma * jax.nn.logsumexp(-stacked / gamma, axis=0)


def _hardmin(a, b, c, gamma):
    del gamma
    return jnp.minimum(jnp.minimum(a, b), c)


def _dtw_scan(D: jax.Array, gamma: float, minop: Callable) -> jax.Array:
    """Wavefront DP over anti-diagonals; returns accumulated cost R[n-1,m-1].

    Diagonal k holds cells (i, k-i).  Cell deps: (i-1,j) and (i,j-1) on
    diagonal k-1, (i-1,j-1) on diagonal k-2 — so a scan with a 2-diagonal
    carry runs the whole DP in n+m-1 sequential steps of n-wide vector ops
    (the same schedule the Pallas kernel uses on the VPU).
    """
    n, m = D.shape
    rows = jnp.arange(n)

    def diag_vals(k):
        j = k - rows
        valid = (j >= 0) & (j < m)
        return jnp.where(valid, D[rows, jnp.clip(j, 0, m - 1)], BIG)

    # R for diagonal 0 is just D[0,0] at i=0.
    r0 = jnp.full((n,), BIG).at[0].set(D[0, 0])
    rm1 = jnp.full((n,), BIG)  # "diagonal -1"

    def body(carry, k):
        r_prev, r_prev2 = carry  # diagonals k-1, k-2
        d_k = diag_vals(k)
        up = r_prev                       # (i, j-1): same i on diag k-1
        left = jnp.concatenate([jnp.full((1,), BIG), r_prev[:-1]])   # (i-1, j)
        diag = jnp.concatenate([jnp.full((1,), BIG), r_prev2[:-1]])  # (i-1, j-1)
        best = minop(up, left, diag, gamma)
        # boundary: cell (0, k) has no predecessor with i-1; (i, 0) handled by
        # validity masking.  Cell (0,k) should chain from (0,k-1) = `up` — ok.
        r_k = d_k + jnp.where(d_k >= BIG, 0.0, best)
        r_k = jnp.where(d_k >= BIG, BIG, r_k)
        return (r_k, r_prev), None

    (r_last, r_prev), _ = lax.scan(body, (r0, rm1),
                                   jnp.arange(1, n + m - 1))
    if n + m - 1 == 1:  # degenerate 1x1
        return r0[0]
    return r_last[n - 1]


def soft_dtw(x: jax.Array, y: jax.Array, gamma: float = 1.0) -> jax.Array:
    """Differentiable soft-DTW divergence between two (possibly multi-dim)
    time series of shapes (n, d)/(n,) and (m, d)/(m,)."""
    D = _pairwise_dist(x, y)
    return _dtw_scan(D, gamma, _softmin)


def dtw(x: jax.Array, y: jax.Array) -> jax.Array:
    """Hard DTW (paper Eq. 6-7), reported as a metric."""
    D = _pairwise_dist(x, y)
    return _dtw_scan(D, 1.0, _hardmin)


def soft_dtw_batch(x: jax.Array, y: jax.Array, gamma: float = 1.0):
    return jax.vmap(lambda a, b: soft_dtw(a, b, gamma))(x, y)


def normalized_dtw(x: jax.Array, y: jax.Array) -> jax.Array:
    """DTW / path-length upper bound — scale-comparable across lengths."""
    n = x.shape[0]
    m = y.shape[0]
    return dtw(x, y) / (n + m)


# ---------------------------------------------------------------------------
# Lyapunov analysis (paper Methods, Eq. 10)
# ---------------------------------------------------------------------------

def max_lyapunov_exponent(f: Callable, y0: jax.Array, params,
                          dt: float, num_steps: int,
                          renorm_every: int = 10,
                          eps: float = 1e-6,
                          key: jax.Array | None = None) -> jax.Array:
    """MLE via the tangent-vector rescaling method.

    Integrates the system with RK4 alongside a perturbation direction,
    renormalising every ``renorm_every`` steps and averaging log growth:
    lambda = (1/T) * sum log(|delta_k| / eps).
    """
    from repro.core.ode import rk4_step

    if key is None:
        key = jax.random.PRNGKey(0)
    v0 = jax.random.normal(key, y0.shape, y0.dtype)
    v0 = eps * v0 / (jnp.linalg.norm(v0) + 1e-30)

    num_blocks = num_steps // renorm_every

    def block(carry, _):
        y, y_pert, log_acc, t = carry

        def inner(i, s):
            y, y_pert, t = s
            y = rk4_step(f, t, y, dt, params)
            y_pert = rk4_step(f, t, y_pert, dt, params)
            return (y, y_pert, t + dt)

        y, y_pert, t = lax.fori_loop(0, renorm_every, inner, (y, y_pert, t))
        delta = y_pert - y
        norm = jnp.linalg.norm(delta) + 1e-30
        log_acc = log_acc + jnp.log(norm / eps)
        y_pert = y + delta * (eps / norm)
        return (y, y_pert, log_acc, t), None

    t0 = jnp.asarray(0.0, y0.dtype)
    (y, y_pert, log_acc, t), _ = lax.scan(
        block, (y0, y0 + v0, jnp.asarray(0.0, y0.dtype), t0),
        None, length=num_blocks)
    total_time = num_blocks * renorm_every * dt
    return log_acc / total_time


def lyapunov_time(mle: jax.Array) -> jax.Array:
    """Inverse of the maximal Lyapunov exponent (paper Methods)."""
    return 1.0 / jnp.maximum(mle, 1e-12)
