"""O(1)-memory adjoint-state gradients for the neural-ODE twin.

The paper (Methods, "Training method of continuous-time digital twin")
trains with the adjoint method of Chen et al. 2018: the gradient of the
loss w.r.t. parameters is obtained by integrating the augmented ODE

    da/dt      = -a(t)^T ∂f/∂y
    dgrad_θ/dt = -a(t)^T ∂f/∂θ

backwards in time, so no intermediate activation of the forward solve has
to be stored.  ``odeint_adjoint`` exposes the same interface as
:func:`repro.core.ode.odeint` but with a custom VJP implementing exactly
this, making the solver O(1)-memory in trajectory length.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.ode import STEP_FNS, odeint

Pytree = Any
_tree_map = jax.tree_util.tree_map


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 4, 5))
def odeint_adjoint(
    f: Callable,
    y0: Pytree,
    ts: jax.Array,
    params: Pytree,
    method: str = "rk4",
    steps_per_interval: int = 1,
) -> Pytree:
    """Like ``odeint(lambda t, y: f(t, y, params), y0, ts)`` with adjoint grads.

    ``f(t, y, params) -> dy/dt``.  Differentiable in ``y0`` and ``params``;
    ``ts`` is treated as non-differentiable observation times.
    """
    return odeint(f, y0, ts, params, method=method,
                  steps_per_interval=steps_per_interval)


def _fwd(f, y0, ts, params, method, steps_per_interval):
    ys = odeint(f, y0, ts, params, method=method,
                steps_per_interval=steps_per_interval)
    return ys, (ys, ts, params)


def _bwd(f, method, steps_per_interval, residuals, g):
    ys, ts, params = residuals
    n = ts.shape[0]
    step = STEP_FNS[method]
    sub = steps_per_interval

    def aug_dynamics(t, aug, params):
        """Augmented reverse dynamics on (y, a, grad_params)."""
        y, a, _ = aug
        dy, vjp_fn = jax.vjp(lambda y_, p_: f(t, y_, p_), y, params)
        neg_a = _tree_map(lambda x: -x, a)
        a_dot_y, a_dot_p = vjp_fn(neg_a)
        # (dy/dt, da/dt, dgrad/dt); note a_dot_* already carry the minus sign.
        return (dy, a_dot_y, a_dot_p)

    zeros_p = _tree_map(jnp.zeros_like, params)
    a_init = _tree_map(lambda x: x[-1], g)

    def interval(carry, idx):
        """Integrate the augmented system backwards over [ts[idx+1], ts[idx]]."""
        a, grad_p = carry
        t1 = ts[idx + 1]
        t0 = ts[idx]
        # Each interval re-seeds y from the STORED forward trajectory
        # rather than continuing the backward re-integration of y from
        # y(T): for an unstable/chaotic field the reverse solve diverges
        # from the forward path exponentially, corrupting the adjoint,
        # while the stored observation-time states pin it to the true
        # path at no extra cost (odeint already materialised ys).
        y1 = _tree_map(lambda x: x[idx + 1], ys)
        aug = (y1, a, grad_p)
        dt = (t0 - t1) / sub  # negative

        def substep(i, aug):
            return step(aug_dynamics, t1 + i * dt, aug, dt, params)

        _, a, grad_p = lax.fori_loop(0, sub, substep, aug)
        # pick up the cotangent injected at observation time ts[idx]
        g_i = _tree_map(lambda x: x[idx], g)
        a = _tree_map(lambda u, v: u + v, a, g_i)
        return (a, grad_p), None

    (a_final, grad_params), _ = lax.scan(
        interval, (a_init, zeros_p), jnp.arange(n - 2, -1, -1))

    return a_final, None, grad_params


odeint_adjoint.defvjp(_fwd, _bwd)
