"""Composable device-fault models for the analogue substrate.

Real memristor crossbars are not the healthy arrays the paper's headline
numbers assume: cells get stuck at G_on/G_off, conductances relax as
they are read, and programming pulses fail outright.  This module is the
single source of truth for those fault mechanisms, shared by three
consumers that must agree bitwise on *which* cells are faulty:

* program-time injection — :func:`apply_faults_to_prog` degrades a
  programmed conductance pair the way the physical array would
  (``AnalogueBackend(faults=...)``, the jnp simulator path);
* closed-loop repair — :func:`repro.core.analogue.program_with_verify`
  writes against the same simulated physics (stuck cells ignore writes,
  write attempts fail stochastically) and reports what it could not fix;
* in-kernel injection — :mod:`repro.kernels.crossbar_vmm` and
  :mod:`repro.kernels.fused_analogue` re-derive the same stuck masks
  from the counter stream *inside* the kernel
  (:func:`repro.kernels.noise.counter_uniform_at` over global cell
  ids), so serving a faulty array costs zero extra HBM traffic — the
  mask never materialises in memory.

Fault identity is counter-derived: a cell (layer l, pair p, row k,
col n) is stuck iff ``hash(seed, salt(l, p), k * N + n) < rate`` — a
pure function of coordinates, independent of tiling, replayable from
``seed`` alone.  Write failures are the one *stochastic* mechanism
(each attempt redraws), keyed by ``jax.random`` like programming noise.

Models compose through :class:`FaultModel` (any subset active) and are
constructible by name through the :data:`FAULTS` registry::

    model = make_fault_model(("stuck", dict(rate=0.01)), ("drift", {}),
                             seed=7)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.noise import (POLARITY_SALT_OFFSET, stuck_cell_masks as
                                 stuck_masks)

#: Salt space for fault masks — disjoint from the read-noise salts of the
#: fused kernels (which count up from 0 per (step, stage, layer, pair)).
FAULT_SALT_BASE = 0x0F00_0000


def fault_salt(layer: int, pair: int) -> int:
    """Salt of device array (layer, pair): pair 0 = G+, 1 = G-."""
    return FAULT_SALT_BASE + 2 * int(layer) + int(pair)


# ---------------------------------------------------------------------------
# Fault mechanisms (the registry entries)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StuckCells:
    """Hard faults: a fraction ``rate`` of cells is pinned, ``on_frac``
    of them at G_on (= g_max, forming/over-SET failures) and the rest at
    G_off (= g_min, broken filaments).  Stuck cells ignore programming
    writes — the repair loop can only compensate through the partner
    device of the differential pair."""
    rate: float = 0.01
    on_frac: float = 0.5

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"StuckCells.rate must be in [0, 1], "
                             f"got {self.rate}")
        if not 0.0 <= self.on_frac <= 1.0:
            raise ValueError(f"StuckCells.on_frac must be in [0, 1], "
                             f"got {self.on_frac}")


@dataclasses.dataclass(frozen=True)
class ConductanceDrift:
    """Read-disturb relaxation: after ``n`` reads every conductance has
    decayed to ``g * drift_factor(n)`` with the standard power law
    ``(1 + n / tau) ** -nu``.  Both halves of the differential pair
    drift together, so the realised weight scales by the same factor —
    a slow, global gain droop rather than per-cell corruption."""
    nu: float = 0.01
    tau: float = 1e4

    def __post_init__(self):
        if self.nu < 0:
            raise ValueError(f"ConductanceDrift.nu must be >= 0, "
                             f"got {self.nu}")
        if self.tau <= 0:
            raise ValueError(f"ConductanceDrift.tau must be > 0, "
                             f"got {self.tau}")


@dataclasses.dataclass(frozen=True)
class WriteFailures:
    """Stochastic programming failures: each write attempt independently
    leaves the cell at its previous value with probability ``rate``
    (pulse did not switch the device).  Redraws every attempt — this is
    exactly what bounded write–verify retries repair."""
    rate: float = 0.1

    def __post_init__(self):
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"WriteFailures.rate must be in [0, 1], "
                             f"got {self.rate}")


#: Registry of fault mechanisms by name (the composable vocabulary).
FAULTS = {
    "stuck": StuckCells,
    "drift": ConductanceDrift,
    "write_fail": WriteFailures,
}


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """A composition of fault mechanisms over one device (any subset
    active; ``seed`` keys every counter-derived mask)."""
    stuck: Optional[StuckCells] = None
    drift: Optional[ConductanceDrift] = None
    write_fail: Optional[WriteFailures] = None
    seed: int = 0

    @property
    def stuck_rate(self) -> float:
        return 0.0 if self.stuck is None else self.stuck.rate

    @property
    def write_fail_rate(self) -> float:
        return 0.0 if self.write_fail is None else self.write_fail.rate

    def kernel_args(self, n_reads: int = 0) -> dict:
        """The static scalars the Pallas kernels consume (in-kernel
        fault injection): stuck mask parameters + drift schedule."""
        return {
            "stuck_rate": self.stuck_rate,
            "stuck_on_frac": (self.stuck.on_frac if self.stuck else 0.5),
            "fault_seed": int(self.seed),
            "salt_base": FAULT_SALT_BASE,
            "drift_nu": (self.drift.nu if self.drift else 0.0),
            "drift_tau": (self.drift.tau if self.drift else 1.0),
            "drift_n0": int(n_reads),
        }


def make_fault_model(*mechanisms, seed: int = 0) -> FaultModel:
    """Compose a :class:`FaultModel` from registry names.

    ``mechanisms``: each a name from :data:`FAULTS` or a
    ``(name, kwargs)`` pair, e.g. ``make_fault_model("drift",
    ("stuck", dict(rate=0.02)), seed=3)``.
    """
    fields = {}
    for m in mechanisms:
        name, kw = (m, {}) if isinstance(m, str) else m
        if name not in FAULTS:
            raise ValueError(
                f"unknown fault mechanism {name!r}; have {sorted(FAULTS)}")
        if name in fields:
            raise ValueError(f"fault mechanism {name!r} given twice")
        fields[name] = FAULTS[name](**kw)
    return FaultModel(seed=seed, **fields)


# ---------------------------------------------------------------------------
# Counter-derived stuck masks (shared by jnp and in-kernel consumers;
# the mask primitive itself lives in kernels/noise.py — re-exported here
# as ``stuck_masks`` — so the Pallas kernels can use it without importing
# core)
# ---------------------------------------------------------------------------

def apply_stuck(g: jax.Array, seed, salt, rate: float, on_frac: float,
                g_on: float, g_off: float, *, row0=0, col0=0,
                ncols: Optional[int] = None) -> jax.Array:
    """Pin the stuck cells of one device array to their fault values.

    Works in conductance space (``g_on = spec.g_max``/``g_off =
    spec.g_min``) or in level-index space (``g_on = levels - 1``,
    ``g_off = 0``) — the caller chooses the representation.  Idempotent:
    re-applying the same model is a no-op, so a verified program and an
    in-kernel re-injection cannot double-fault.
    """
    if rate <= 0.0:
        return g
    is_stuck, stuck_on = stuck_masks(seed, salt, g.shape, rate, on_frac,
                                     row0=row0, col0=col0, ncols=ncols)
    stuck_val = jnp.where(stuck_on, jnp.float32(g_on), jnp.float32(g_off))
    return jnp.where(is_stuck, stuck_val.astype(g.dtype), g)


def drift_factor(model: Optional[FaultModel], n_reads) -> jax.Array:
    """Multiplicative conductance decay after ``n_reads`` evaluations:
    ``(1 + n / tau) ** -nu`` (1.0 when no drift mechanism is active)."""
    if model is None or model.drift is None or model.drift.nu == 0.0:
        return jnp.float32(1.0)
    n = jnp.asarray(n_reads, jnp.float32)
    return (1.0 + n / jnp.float32(model.drift.tau)) ** jnp.float32(
        -model.drift.nu)


# ---------------------------------------------------------------------------
# Program-time fault application (the jnp simulator path)
# ---------------------------------------------------------------------------

def apply_faults_to_prog(prog: dict, model: Optional[FaultModel], spec,
                         layer: int = 0, *, n_reads: int = 0) -> dict:
    """Degrade a programmed conductance pair as the physical array would.

    Stuck cells are pinned at g_max/g_min (and their uint8 level indices,
    when staged, at ``levels-1``/0 — stuck values sit exactly on the
    level grid), then the drift snapshot after ``n_reads`` evaluations
    scales both halves.  Returns a new prog dict; ``model=None`` is the
    identity.  The masks match the in-kernel injection bitwise (same
    counter stream, same :func:`fault_salt` convention).
    """
    if model is None:
        return prog
    out = dict(prog)
    if model.stuck is not None and model.stuck.rate > 0.0:
        r, f = model.stuck.rate, model.stuck.on_frac
        for pair, key_ in ((0, "gp"), (1, "gm")):
            salt = fault_salt(layer, pair)
            out[key_] = apply_stuck(out[key_], model.seed, salt, r, f,
                                    spec.g_max, spec.g_min)
            idx_key = key_ + "_idx"
            if idx_key in out:
                out[idx_key] = apply_stuck(
                    out[idx_key].astype(jnp.float32), model.seed, salt, r,
                    f, spec.levels - 1, 0).astype(jnp.uint8)
    factor = drift_factor(model, n_reads)
    if model.drift is not None and model.drift.nu > 0.0:
        out["gp"] = out["gp"] * factor
        out["gm"] = out["gm"] * factor
        if "gp_idx" in out:
            raise ValueError(
                "drift moves conductances off the 6-bit level grid; "
                "uint8-staged programs cannot carry a drift snapshot — "
                "apply drift in-kernel (FusedAnalogueBackend(faults=...)) "
                "or use float storage")
    return out


def apply_faults_to_mlp(progs, model: Optional[FaultModel], spec, *,
                        n_reads: int = 0) -> list:
    """Per-layer :func:`apply_faults_to_prog` over a programmed MLP."""
    if model is None:
        return list(progs)
    return [apply_faults_to_prog(p, model, spec, layer=i, n_reads=n_reads)
            for i, p in enumerate(progs)]
