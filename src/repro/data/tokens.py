"""Synthetic token pipeline: deterministic, stateless, shardable.

Every batch is a pure function of (seed, step) — so training resumes
exactly after preemption by replaying the step counter (no iterator state
to checkpoint), and any data shard can be regenerated on any host
(straggler/failure recovery).  Two generators:

* ``random``  — i.i.d. uniform tokens (throughput benchmarking).
* ``markov``  — a fixed random first-order Markov chain over the vocab,
  giving a learnable bigram structure so example training shows a real
  loss curve (the "dataset" for the modality-frontend stubs: codec/VQ
  token streams are exactly such discrete sequences).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    batch: int                  # per-host/global depending on caller
    seed: int = 0
    mode: str = "markov"        # markov | random
    markov_states: int = 64     # transition structure rank (<= vocab)

    def batch_at(self, step: int) -> dict:
        """Pure function of step -> {'tokens': (B,S+1) int32}."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        if self.mode == "random":
            toks = jax.random.randint(key, (self.batch, self.seq_len + 1),
                                      0, self.vocab, jnp.int32)
            return {"tokens": toks}
        # markov: cheap deterministic chain via hashed transitions
        k1, k2 = jax.random.split(key)
        m = min(self.markov_states, self.vocab)
        start = jax.random.randint(k1, (self.batch,), 0, self.vocab,
                                   jnp.int32)
        noise = jax.random.randint(k2, (self.batch, self.seq_len + 1),
                                   0, 7919, jnp.int32)

        def step_fn(tok, eps):
            # fixed pseudo-random transition: LCG hash of the current token
            nxt = (tok * 1103515245 + 12345) % m
            nxt = (nxt + (eps % 3)) % self.vocab
            return nxt.astype(jnp.int32), nxt.astype(jnp.int32)

        _, seq = jax.lax.scan(
            lambda c, e: step_fn(c, e), start, noise.swapaxes(0, 1))
        return {"tokens": seq.swapaxes(0, 1)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def split_batch(batch: dict) -> tuple[jax.Array, jax.Array]:
    """(B, S+1) tokens -> (inputs (B,S), labels (B,S))."""
    toks = batch["tokens"]
    return toks[:, :-1], toks[:, 1:]


def input_specs(cfg, shape, mesh_axes=None):
    """ShapeDtypeStructs for the dry-run (never allocated).

    train/prefill: {'tokens': (B, S+1)}; decode: single-token step inputs.
    """
    import jax
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        return {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
