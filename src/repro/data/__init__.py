from repro.data import hp_memristor, lorenz96
