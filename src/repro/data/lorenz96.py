"""Lorenz96 dynamics (paper Eq. 4) — ground truth for the autonomous twin.

    dx_i/dt = (x_{i+1} - x_{i-2}) x_{i-1} - x_i + F,  periodic in i.

Paper setup (Methods): n = 6 variables, initial condition
[-1.2061, 0.0617, 1.1632, -1.5008, -1.5944, -0.0187], 2400 points,
first 1800 interpolation (training) / remainder extrapolation (test).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.twin import reference_trajectory

PAPER_Y0 = jnp.array([-1.2061, 0.0617, 1.1632, -1.5008, -1.5944, -0.0187])


def lorenz96_field(forcing: float = 8.0):
    def f(t, x, _params=None):
        del t
        xp1 = jnp.roll(x, -1)
        xm1 = jnp.roll(x, 1)
        xm2 = jnp.roll(x, 2)
        return (xp1 - xm2) * xm1 - x + forcing
    return f


def generate(num_points: int = 2400, dt: float = 0.02,
             y0: jax.Array = PAPER_Y0, forcing: float = 8.0,
             train_points: int | None = None):
    """Returns (ts, ys, split) with ys of shape (num_points, n).

    ``train_points`` defaults to the paper's 3/4 split (1800 of 2400).
    """
    if train_points is None:
        train_points = int(num_points * 0.75)
    ts = jnp.arange(num_points) * dt
    f = lorenz96_field(forcing)
    ys = reference_trajectory(f, y0, ts, steps_per_interval=8)
    return ts, ys, train_points


def normalize(ys: jax.Array):
    """Per-dim standardisation; returns (normed, mean, std)."""
    mean = ys.mean(axis=0)
    std = ys.std(axis=0) + 1e-8
    return (ys - mean) / std, mean, std
