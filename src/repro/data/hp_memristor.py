"""HP memristor physics (Strukov et al. 2008) — ground truth for the twin.

State x = w/D in [0, 1] (normalised doped-region boundary):

    R(x)   = R_ON * x + R_OFF * (1 - x)            (paper Eq. 2)
    i(t)   = v(t) / R(x)
    dx/dt  = (mu_v * R_ON / D^2) * i * window(x)   (paper Eq. 3 + Joglekar
                                                    window to keep x in [0,1])

Waveform generators mirror the paper's four stimulation cases (sine,
triangular, rectangular, modulated sine) as *continuous* callables u(t),
matching the analogue waveform generator feeding the crossbar.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.twin import reference_trajectory


@dataclasses.dataclass(frozen=True)
class HPParams:
    r_on: float = 100.0       # ohm
    r_off: float = 16e3       # ohm
    d: float = 1e-8           # m (10 nm)
    mu_v: float = 1e-14       # m^2 / (V s)
    window_p: int = 1         # Joglekar window exponent

    @property
    def k(self) -> float:
        """mu_v * R_ON / D^2 — the Eq. 3 rate constant (1/(V s) units
        after absorbing i = v/R)."""
        return self.mu_v * self.r_on / self.d ** 2


def resistance(x: jax.Array, p: HPParams) -> jax.Array:
    return p.r_on * x + p.r_off * (1.0 - x)


def hp_field(drive: Callable, p: HPParams = HPParams()):
    """Ground-truth vector field dx/dt = f(t, x)."""

    def f(t, x, _params=None):
        v = drive(t)
        i = v / resistance(x, p)
        window = 1.0 - (2.0 * x - 1.0) ** (2 * p.window_p)
        return p.k * i * window

    return f


# ---------------------------------------------------------------------------
# Continuous drive waveforms (the paper's four stimulation cases)
# ---------------------------------------------------------------------------

def sine(amp: float = 1.0, freq: float = 2.0) -> Callable:
    return lambda t: amp * jnp.sin(2 * jnp.pi * freq * t)


def triangular(amp: float = 1.0, freq: float = 2.0) -> Callable:
    def u(t):
        phase = (t * freq) % 1.0
        return amp * (4.0 * jnp.abs(phase - 0.5) - 1.0)
    return u


def rectangular(amp: float = 1.0, freq: float = 2.0,
                sharpness: float = 80.0) -> Callable:
    """Smoothed square wave (tanh edges keep the ODE Lipschitz, mirroring
    the finite slew rate of the analogue waveform generator)."""
    def u(t):
        return amp * jnp.tanh(sharpness * jnp.sin(2 * jnp.pi * freq * t))
    return u


def modulated_sine(amp: float = 1.0, freq: float = 4.0,
                   mod_freq: float = 1.0) -> Callable:
    def u(t):
        return amp * jnp.sin(2 * jnp.pi * freq * t) * jnp.sin(
            2 * jnp.pi * mod_freq * t)
    return u


WAVEFORMS = {
    "sine": sine,
    "triangular": triangular,
    "rectangular": rectangular,
    "modulated_sine": modulated_sine,
}


# ---------------------------------------------------------------------------
# Dataset generation (paper Methods: 500 points, dt = 1e-3 s)
# ---------------------------------------------------------------------------

def generate(waveform: str = "sine", num_points: int = 500,
             dt: float = 1e-3, x0: float = 0.1,
             p: HPParams = HPParams(), amp: float = 1.0,
             freq: float = 2.0):
    """Simulate the HP memristor; returns (ts, xs, vs, currents)."""
    drive = WAVEFORMS[waveform](amp=amp, freq=freq)
    ts = jnp.arange(num_points) * dt
    f = hp_field(drive, p)
    x0a = jnp.asarray([x0])
    xs = reference_trajectory(f, x0a, ts, steps_per_interval=16)[:, 0]
    vs = jax.vmap(drive)(ts)
    cur = vs / resistance(xs, p)
    return ts, xs, vs, cur
