"""Integration tests: the digital twins reproduce the paper's claims
(reduced budgets for CI speed)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core.analogue import AnalogueSpec
from repro.core.losses import mre
from repro.train import recipes


@pytest.fixture(scope="module")
def hp_twin():
    return recipes.train_hp_twin(pretrain_steps=200, train_steps=250)


@pytest.fixture(scope="module")
def hp_resnet():
    return recipes.train_hp_resnet(train_steps=250)


def test_hp_twin_fits_training_drive(hp_twin):
    twin, params, loss = hp_twin
    assert loss < 0.01
    m = recipes.eval_hp_twin(twin, params, "sine")
    assert m["mre"] < 0.1


def test_hp_twin_extrapolates_waveforms(hp_twin):
    """Paper Fig. 3f: the twin must interpolate AND extrapolate to drives
    it never saw in training."""
    twin, params, _ = hp_twin
    for wf in ["triangular", "rectangular", "modulated_sine"]:
        m = recipes.eval_hp_twin(twin, params, wf)
        assert m["mre"] < 0.25, (wf, m["mre"])


def test_node_beats_recurrent_resnet(hp_twin, hp_resnet):
    """Paper Fig. 3j: neural ODE < recurrent ResNet on modelling error."""
    twin, params, _ = hp_twin
    resnet, rparams, _ = hp_resnet
    node_mre, res_mre = [], []
    for wf in ["sine", "triangular", "rectangular", "modulated_sine"]:
        node_mre.append(recipes.eval_hp_twin(twin, params, wf)["mre"])
        res_mre.append(recipes.eval_hp_resnet(resnet, rparams, wf)["mre"])
    assert sum(node_mre) / 4 < 0.5 * sum(res_mre) / 4


def test_analogue_deployment_close_to_digital(hp_twin):
    """6-bit quantisation alone must cost only a few % accuracy."""
    twin, params, _ = hp_twin
    m = recipes.eval_hp_twin(twin, params, "sine")
    spec = AnalogueSpec(prog_noise=0.0)   # quantisation only
    at = twin.deploy_analogue(jax.random.PRNGKey(0), params, spec)
    pred = at.simulate(None, jnp.array([m["true"][0]]), m["ts"])[:, 0]
    assert float(mre(pred, m["pred"])) < 0.08


def test_analogue_noise_degrades_gracefully(hp_twin):
    """Paper Fig. 2k/3e statistics must not break the twin."""
    twin, params, _ = hp_twin
    m = recipes.eval_hp_twin(twin, params, "sine")
    spec = AnalogueSpec(prog_noise=0.0436, read_noise=0.02)
    at = twin.deploy_analogue(jax.random.PRNGKey(0), params, spec,
                              read_key=jax.random.PRNGKey(1))
    pred = at.simulate(None, jnp.array([m["true"][0]]), m["ts"])[:, 0]
    assert float(mre(pred, m["true"])) < 0.3


@pytest.fixture(scope="module")
def l96_setup():
    data = recipes.l96_data(num_points=1200)
    twin, params = recipes.train_l96_twin(
        pretrain_steps=1500, train_steps=((60, 300, 1e-3),), data=data)
    return data, twin, params


def test_l96_twin_interpolates(l96_setup):
    data, twin, params = l96_setup
    m = recipes.eval_l96_twin(twin, params, data=data)
    assert m["interp_l1"] < 0.3


def test_l96_twin_extrapolates_short_horizon(l96_setup):
    """Within ~2 Lyapunov times the forecast must track the chaos."""
    data, twin, params = l96_setup
    ts, ys, split = data
    pred = twin.simulate(params, ys[split - 1], ts[split - 1:split + 199])
    err = float(jnp.abs(pred[1:] - ys[split:split + 199]).mean())
    assert err < 0.5


def test_l96_noise_grid_runs(l96_setup):
    data, twin, params = l96_setup
    rows = recipes.noise_robustness_grid(
        twin, params, read_noises=[0.0, 0.02], prog_noises=[0.0],
        data=data, repeats=1)
    assert len(rows) == 2
    assert all(jnp.isfinite(r["extrap_l1"]) for r in rows)


def test_energy_model_hits_paper_anchors():
    from repro.core import energy
    hp_row = energy.hp_projection()[-1]
    l96_row = energy.lorenz96_projection()[-1]
    anchors = [
        (hp_row["node_gpu_speed_gain"], 4.2),
        (hp_row["analogue_energy_uj"], 17.0),
        (hp_row["node_gpu_energy_uj"], 705.4),
        (hp_row["resnet_gpu_energy_uj"], 176.4),
        (hp_row["node_gpu_energy_gain"], 41.4),
        (hp_row["resnet_gpu_energy_gain"], 10.4),
        (l96_row["analogue_time_us"], 40.1),
        (l96_row["node_gpu_time_us"], 505.8),
        (l96_row["lstm_gpu_time_us"], 392.5),
        (l96_row["gru_gpu_time_us"], 294.9),
        (l96_row["rnn_gpu_time_us"], 98.8),
        (l96_row["node_gpu_speed_gain"], 12.6),
        (l96_row["lstm_gpu_speed_gain"], 9.8),
        (l96_row["node_gpu_energy_gain"], 189.7),
        (l96_row["lstm_gpu_energy_gain"], 147.2),
        (l96_row["gru_gpu_energy_gain"], 100.6),
        (l96_row["rnn_gpu_energy_gain"], 37.1),
    ]
    for got, want in anchors:
        assert abs(got - want) / want < 0.20, (got, want)
