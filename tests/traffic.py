"""Arrival generators + invariant layer for the streaming serving tests.

The generators themselves live in :mod:`repro.launch.traffic` (the
latency benchmark replays the same traces); this module re-exports them
for the test suite and adds the *invariant checkers* the stress tests
run after every replayed schedule:

  * no request is dropped         (every submitted seq completes or is
                                   counted failed)
  * per-twin arrival order holds  (a twin's completions carry strictly
                                   increasing seqs and consume horizons
                                   in submission order)
  * eviction never loses state    (every twin's step counter equals the
                                   steps actually served to it, its
                                   state is finite, and the store's
                                   structural audit passes)
  * stats conservation            (enqueued == served + failed + pending)
"""
from __future__ import annotations

import numpy as np

from repro.launch.traffic import (Arrival, TRACES, all_cold_trace,  # noqa: F401
                                  bursty_trace, hot_loop_trace,
                                  poisson_trace, population_of,
                                  ragged_trace)

__all__ = [
    "Arrival", "TRACES", "all_cold_trace", "bursty_trace",
    "hot_loop_trace", "poisson_trace", "population_of", "ragged_trace",
    "check_no_drops", "check_arrival_order", "check_conservation",
    "check_state_safety", "check_all",
]


def check_no_drops(server, trace, done) -> None:
    """Every arrival was served exactly once (failures must be explicit:
    this checker is for healthy schedules where nothing may fail)."""
    assert server.stats.failed == 0, \
        f"{server.stats.failed} requests failed on a healthy schedule"
    assert server.pending == 0, f"{server.pending} requests still queued"
    assert len(done) == len(trace), \
        f"{len(trace)} arrivals but {len(done)} completions"
    assert sorted(c.seq for c in done) == list(range(len(trace))), \
        "completion seqs are not exactly the submitted seqs"


def check_arrival_order(done) -> None:
    """No twin is served out of arrival order: its completions carry
    strictly increasing seqs (seqs are assigned in submission order)."""
    by_twin: dict = {}
    for c in done:
        by_twin.setdefault(c.twin_id, []).append(c.seq)
    for twin_id, seqs in by_twin.items():
        assert seqs == sorted(seqs), \
            f"twin {twin_id!r} served out of arrival order: {seqs}"


def check_conservation(server) -> None:
    """enqueued == served + failed + pending, and the per-batch step
    accounting is consistent with the padded-work counter."""
    s = server.stats
    assert s.enqueued == s.served + s.failed + server.pending, \
        f"conservation violated: {s.as_dict()}, pending={server.pending}"
    assert s.twin_steps >= 0 and s.padded_steps >= 0


def check_state_safety(server, trace, done) -> None:
    """Eviction/paging never loses un-checkpointed state: each twin's
    global step counter equals the horizons actually completed for it,
    every carried state is finite, and the store's structural audit
    (tier partition, slot bijection) passes.  Horizons are matched in
    arrival order, so a reordered or double-served window fails here
    even if the step totals happen to agree."""
    server.store.check_invariants()
    arrival_h: dict = {}
    for a in trace:
        arrival_h.setdefault(a.twin_id, []).append(a.horizon)
    served_steps: dict = {}
    for c in sorted(done, key=lambda c: c.seq):
        expect = arrival_h[c.twin_id].pop(0)
        got = c.trajectory.shape[0] - 1
        assert got == expect, \
            (f"twin {c.twin_id!r} seq {c.seq}: served {got} steps, "
             f"arrival asked {expect}")
        assert np.isfinite(c.trajectory).all(), \
            f"twin {c.twin_id!r} seq {c.seq}: non-finite trajectory"
        served_steps[c.twin_id] = served_steps.get(c.twin_id, 0) + got
    for twin_id, total in served_steps.items():
        _, step = server.store.peek(twin_id)
        assert step == total, \
            (f"twin {twin_id!r}: store says step {step}, completions "
             f"total {total} — state lost or double-advanced")


def check_all(server, trace, done) -> None:
    check_no_drops(server, trace, done)
    check_arrival_order(done)
    check_conservation(server)
    check_state_safety(server, trace, done)
