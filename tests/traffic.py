"""Arrival generators + invariant layer for the streaming serving tests.

The generators themselves live in :mod:`repro.launch.traffic` (the
latency benchmark replays the same traces); this module re-exports them
for the test suite and adds the *invariant checkers* the stress tests
run after every replayed schedule:

  * no request is dropped         (every submitted seq completes or is
                                   counted in an explicit terminal
                                   bucket: failed/shed/expired/
                                   quarantined)
  * per-twin arrival order holds  (a twin's completions carry strictly
                                   increasing seqs and consume horizons
                                   in submission order)
  * eviction never loses state    (every twin's step counter equals the
                                   steps actually served to it, its
                                   state is finite, and the store's
                                   structural audit passes)
  * stats conservation            (enqueued == served + failed + shed +
                                   expired + quarantined + pending —
                                   every seq in exactly ONE bucket)
"""
from __future__ import annotations

import numpy as np

from repro.launch.traffic import (Arrival, TRACES, all_cold_trace,  # noqa: F401
                                  bursty_trace, deadline_trace,
                                  hot_loop_trace, poisson_trace,
                                  population_of, ragged_trace)

__all__ = [
    "Arrival", "TRACES", "all_cold_trace", "bursty_trace",
    "deadline_trace", "hot_loop_trace", "poisson_trace",
    "population_of", "ragged_trace",
    "check_no_drops", "check_arrival_order", "check_conservation",
    "check_state_safety", "check_all",
]


def check_no_drops(server, trace, done) -> None:
    """Every arrival was served exactly once (losses must be explicit:
    this checker is for healthy schedules where nothing may fail, shed,
    expire or quarantine)."""
    s = server.stats().stream
    for leg in ("failed", "shed", "expired", "quarantined"):
        assert getattr(s, leg) == 0, \
            f"{getattr(s, leg)} requests {leg} on a healthy schedule"
    assert server.pending == 0, f"{server.pending} requests still queued"
    assert len(done) == len(trace), \
        f"{len(trace)} arrivals but {len(done)} completions"
    assert sorted(c.seq for c in done) == list(range(len(trace))), \
        "completion seqs are not exactly the submitted seqs"


def check_arrival_order(done) -> None:
    """No twin is served out of arrival order: its completions carry
    strictly increasing seqs (seqs are assigned in submission order)."""
    by_twin: dict = {}
    for c in done:
        by_twin.setdefault(c.twin_id, []).append(c.seq)
    for twin_id, seqs in by_twin.items():
        assert seqs == sorted(seqs), \
            f"twin {twin_id!r} served out of arrival order: {seqs}"


def check_conservation(server, done=None) -> None:
    """Every submitted request lands in exactly one terminal bucket:
    ``enqueued == served + failed + shed + expired + quarantined +
    pending``.  With ``done`` given, the completion list is tied to the
    ``served`` counter and the quarantine ledger to its counter — a
    request counted twice (e.g. expired AND served) breaks the sum."""
    s = server.stats().stream
    total = (s.served + s.failed + s.shed + s.expired + s.quarantined
             + server.pending)
    assert s.enqueued == total, \
        (f"conservation violated: enqueued={s.enqueued} != "
         f"served+failed+shed+expired+quarantined+pending={total} "
         f"({s.as_dict()}, pending={server.pending})")
    assert len(server.quarantine) == s.quarantined, \
        (f"quarantine ledger has {len(server.quarantine)} entries but "
         f"counter says {s.quarantined}")
    if done is not None:
        assert len(done) == s.served, \
            f"{len(done)} completions but served counter says {s.served}"
        seqs = [c.seq for c in done]
        assert len(set(seqs)) == len(seqs), "a seq completed twice"
        assert not set(seqs) & set(server.quarantine), \
            "a seq is both completed and quarantined"
    assert s.twin_steps >= 0 and s.padded_steps >= 0


def check_state_safety(server, trace, done) -> None:
    """Eviction/paging never loses un-checkpointed state: each twin's
    global step counter equals the horizons actually completed for it,
    every carried state is finite, and the store's structural audit
    (tier partition, slot bijection) passes.  Horizons are matched in
    arrival order over the completions each twin actually got (shed/
    expired/quarantined arrivals never advance state, so they are
    skipped in the matching), so a reordered or double-served window
    fails here even if the step totals happen to agree."""
    server.store.check_invariants()
    arrival_h: dict = {}         # per twin: [(seq, horizon), ...]
    for i, a in enumerate(trace):
        arrival_h.setdefault(a.twin_id, []).append((i, a.horizon))
    served_steps: dict = {}
    for c in sorted(done, key=lambda c: c.seq):
        pending = arrival_h[c.twin_id]
        while pending and pending[0][0] != c.seq:
            pending.pop(0)       # an arrival that shed/expired/parked
        assert pending, \
            (f"twin {c.twin_id!r} seq {c.seq}: completion with no "
             f"matching arrival (double-served?)")
        _, expect = pending.pop(0)
        got = c.trajectory.shape[0] - 1
        assert got == expect, \
            (f"twin {c.twin_id!r} seq {c.seq}: served {got} steps, "
             f"arrival asked {expect}")
        assert np.isfinite(c.trajectory).all(), \
            f"twin {c.twin_id!r} seq {c.seq}: non-finite trajectory"
        served_steps[c.twin_id] = served_steps.get(c.twin_id, 0) + got
    for twin_id, total in served_steps.items():
        _, step = server.store.peek(twin_id)
        assert step == total, \
            (f"twin {twin_id!r}: store says step {step}, completions "
             f"total {total} — state lost or double-advanced")


def check_all(server, trace, done) -> None:
    check_no_drops(server, trace, done)
    check_arrival_order(done)
    check_conservation(server, done)
    check_state_safety(server, trace, done)
