"""Streaming stateful serving: resume-parity properties, the state
store's paging invariants, and the continuous-batching server under
seeded traffic.

The load-bearing claim (docs/serving.md): serving a twin's trajectory in
pieces through :class:`TwinStateStore` — split anywhere, batched with
anything, paged to host and back — produces the SAME trajectory as one
uninterrupted rollout.  Bit-identical for f32 (and pure-bf16) substrates,
within one storage rounding for bf16_f32acc.  The hypothesis suite
samples random split points when hypothesis is installed; a seeded
parametrised subset always runs.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import traffic
from repro.core.analogue import AnalogueSpec
from repro.core.backends import (DigitalBackend, FusedAnalogueBackend,
                                 FusedPallasBackend, resolve_backend)
from repro.core.twin import TwinFleet, make_autonomous_twin, make_driven_twin
from repro.launch.fleet_serving import ServingSLO, StreamingFleetServer
from repro.launch.state_store import TwinStateStore

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

DT = 0.01
DIM = 3

BACKENDS = {
    "digital": lambda: DigitalBackend(),
    "fused_f32": lambda: FusedPallasBackend(precision="f32"),
    "fused_bf16": lambda: FusedPallasBackend(precision="bf16"),
    "fused_bf16_f32acc": lambda: FusedPallasBackend(
        precision="bf16_f32acc"),
    "analogue_fused": lambda: FusedAnalogueBackend(
        spec=AnalogueSpec(read_noise=0.02),
        prog_key=jax.random.PRNGKey(7)),
}
#: split-and-resume must be bit-identical on these (f32 arithmetic, or a
#: single rounded dtype end to end); bf16_f32acc is exact only at chunk
#: boundaries, so it gets a one-storage-rounding tolerance instead.
BITWISE = ("digital", "fused_f32", "fused_bf16", "analogue_fused")


@functools.lru_cache(maxsize=None)
def _setup(backend_key: str):
    """Programmed execution state + a small carried fleet, shared across
    parametrised cases and hypothesis examples (weights are programmed
    once, like a physical array)."""
    backend = BACKENDS[backend_key]()
    twin = make_autonomous_twin(state_dim=DIM, hidden=8, n_hidden_layers=1,
                                backend=backend)
    params = twin.init(jax.random.PRNGKey(0))
    state = backend.program(twin.node.field, params)
    ys = jnp.asarray(
        np.random.default_rng(3).normal(size=(3, DIM)) * 0.1, jnp.float32)
    return backend, state, ys


def _split_and_resume(backend_key: str, k: int, T: int):
    """Roll [0, k] then resume [k, T] THROUGH the state store; return
    (head, tail, full) trajectories."""
    backend, state, ys = _setup(backend_key)
    n = ys.shape[0]
    full = backend.rollout_batch_resumed(state, ys, dt=DT, num_steps=T)
    head = backend.rollout_batch_resumed(state, ys, dt=DT, num_steps=k)
    store = TwinStateStore(DIM, n)
    ids = list(range(n))
    for i in ids:
        store.register(i, np.asarray(ys[i]))
    store.fetch(ids)
    store.commit(ids, head[:, k], np.full(n, k))
    mid, steps, _ = store.fetch(ids)
    assert list(steps) == [k] * n
    tail = backend.rollout_batch_resumed(state, mid, dt=DT,
                                         num_steps=T - k, start_steps=steps)
    return np.asarray(head), np.asarray(tail), np.asarray(full)


@pytest.mark.parametrize("backend_key", list(BACKENDS))
@pytest.mark.parametrize("k,T", [(1, 12), (5, 12), (11, 12), (8, 24)])
def test_resume_parity_seeded(backend_key, k, T):
    head, tail, full = _split_and_resume(backend_key, k, T)
    if backend_key in BITWISE:
        np.testing.assert_array_equal(head, full[:, : k + 1])
        np.testing.assert_array_equal(tail, full[:, k:])
    else:
        # bf16_f32acc: the carry is exact at time-chunk boundaries and
        # within ONE bf16 storage rounding elsewhere; the deviation can
        # grow with the remaining horizon, so bound it loosely.
        np.testing.assert_allclose(tail, full[:, k:], rtol=0.03, atol=0.03)
        np.testing.assert_array_equal(tail[:, 0], full[:, k])


def test_resume_matches_plain_rollout_digital():
    """The stronger cross-API property (digital only): a resumed rollout
    equals the ordinary ``rollout_batch`` over the canonical window grid
    bitwise — resume is not a parallel implementation, it IS the same
    arithmetic."""
    from repro.kernels.ops import window_times
    backend, state, ys = _setup("digital")
    T = 16
    ts = window_times(0.0, DT, T)
    plain = jax.vmap(lambda y: backend.rollout(state, y, ts))(ys)
    resumed = backend.rollout_batch_resumed(state, ys, dt=DT, num_steps=T)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(resumed))


def test_resume_rejects_traced_and_negative_starts():
    backend, state, ys = _setup("digital")
    with pytest.raises(ValueError, match="concrete host"):
        jax.jit(lambda s: backend.rollout_batch_resumed(
            state, ys, dt=DT, num_steps=2, start_steps=s))(jnp.arange(3))
    with pytest.raises(ValueError, match="non-negative"):
        backend.rollout_batch_resumed(state, ys, dt=DT, num_steps=2,
                                      start_steps=np.array([0, -1, 0]))


def test_resume_mixed_phases_fused():
    """Twins at DIFFERENT global steps batch into one fused launch; each
    row must equal that twin's own homogeneous resume."""
    backend, state, ys = _setup("fused_f32")
    starts = np.array([0, 5, 11])
    mixed = backend.rollout_batch_resumed(state, ys, dt=DT, num_steps=6,
                                          start_steps=starts)
    for i, s in enumerate(starts):
        solo = backend.rollout_batch_resumed(
            state, ys[i: i + 1], dt=DT, num_steps=6,
            start_steps=np.array([s]))
        np.testing.assert_array_equal(np.asarray(mixed[i]),
                                      np.asarray(solo[0]))


if HAVE_HYPOTHESIS:
    @given(data=st.data(), T=st.integers(2, 40))
    @settings(max_examples=15, deadline=None)
    def test_resume_parity_random_split_digital(data, T):
        k = data.draw(st.integers(1, T - 1))
        head, tail, full = _split_and_resume("digital", k, T)
        np.testing.assert_array_equal(head, full[:, : k + 1])
        np.testing.assert_array_equal(tail, full[:, k:])

    @given(data=st.data(), T=st.integers(2, 40))
    @settings(max_examples=8, deadline=None)
    def test_resume_parity_random_split_fused(data, T):
        k = data.draw(st.integers(1, T - 1))
        head, tail, full = _split_and_resume("fused_f32", k, T)
        np.testing.assert_array_equal(head, full[:, : k + 1])
        np.testing.assert_array_equal(tail, full[:, k:])

    @given(data=st.data(), T=st.integers(2, 24))
    @settings(max_examples=5, deadline=None)
    def test_resume_parity_random_split_analogue(data, T):
        k = data.draw(st.integers(1, T - 1))
        head, tail, full = _split_and_resume("analogue_fused", k, T)
        np.testing.assert_array_equal(head, full[:, : k + 1])
        np.testing.assert_array_equal(tail, full[:, k:])


# ---------------------------------------------------------------------------
# TwinStateStore: paging mechanics
# ---------------------------------------------------------------------------

def test_store_lru_eviction_pages_not_drops():
    store = TwinStateStore(2, hot_capacity=2)
    for i in range(4):
        store.register(i, np.float32([i, i]))
    store.fetch([0, 1])                   # hot: 0, 1
    store.fetch([2])                      # evicts 0 (LRU)
    assert 0 not in store.hot_ids and 2 in store.hot_ids
    assert store.stats.evictions == 1
    y, step = store.peek(0)               # paged, not lost
    np.testing.assert_array_equal(y, np.float32([0, 0]))
    store.fetch([0])                      # pages 0 back in
    store.check_invariants()
    assert store.stats.page_ins == 4      # 0,1,2 cold-first + 0 again


def test_store_fetch_touches_lru_order():
    store = TwinStateStore(2, hot_capacity=2)
    for i in range(3):
        store.register(i, np.float32([i, i]))
    store.fetch([0, 1])
    store.fetch([0])                      # 0 becomes MRU -> 1 is LRU
    store.fetch([2])                      # must evict 1, not 0
    assert set(store.hot_ids) == {0, 2}
    store.check_invariants()


def test_store_commit_round_trips_state():
    store = TwinStateStore(3, hot_capacity=2)
    store.register("a", np.zeros(3, np.float32))
    store.fetch(["a"])
    store.commit(["a"], np.float32([[1, 2, 3]]), np.array([5]))
    y, step = store.peek("a")
    np.testing.assert_array_equal(y, np.float32([1, 2, 3]))
    assert step == 5
    # survives an eviction round-trip bitwise
    store.register("b", np.zeros(3, np.float32))
    store.register("c", np.zeros(3, np.float32))
    store.fetch(["b", "c"])
    y2, step2 = store.peek("a")
    np.testing.assert_array_equal(y2, y)
    assert step2 == 5


def test_store_rejects_bad_usage():
    store = TwinStateStore(2, hot_capacity=2)
    store.register(0, np.zeros(2, np.float32))
    with pytest.raises(ValueError, match="already registered"):
        store.register(0, np.zeros(2, np.float32))
    with pytest.raises(ValueError, match="shape"):
        store.register(1, np.zeros(3, np.float32))
    with pytest.raises(ValueError, match="non-finite"):
        store.register(2, np.float32([np.nan, 0.0]))
    with pytest.raises(KeyError, match="unregistered"):
        store.fetch([99])
    with pytest.raises(ValueError, match="duplicate"):
        store.register(3, np.zeros(2, np.float32)) or store.fetch([0, 0])
    store.register(4, np.zeros(2, np.float32))
    with pytest.raises(ValueError, match="exceeds hot_capacity"):
        store.fetch([0, 3, 4])
    with pytest.raises(KeyError, match="not hot"):
        store.commit([4], np.zeros((1, 2), np.float32), np.array([1]))
    with pytest.raises(ValueError, match="mixed drive"):
        store.register("t", np.zeros(2, np.float32),
                       theta=np.float32([1.0]))
        store.fetch([0, "t"])


# ---------------------------------------------------------------------------
# StreamingFleetServer: continuous batching under seeded traffic
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _fused_fleet():
    twin = make_autonomous_twin(state_dim=DIM, hidden=8, n_hidden_layers=1,
                                gradient="fused_vjp",
                                backend=FusedPallasBackend(precision="f32"))
    params = twin.init(jax.random.PRNGKey(1))
    return TwinFleet(twin=twin), params


def _serve(trace, **kw):
    fleet, params = _fused_fleet()
    cfg = dict(dt=DT, hot_capacity=8, max_batch=4, max_window=8,
               horizon_quantum=4)
    cfg.update(kw)
    server = StreamingFleetServer(fleet, params, **cfg)
    rng = np.random.default_rng(11)
    y0s = {}

    def y0_of(tid):
        if tid not in y0s:
            y0s[tid] = rng.normal(size=DIM).astype(np.float32) * 0.1
        return y0s[tid]

    done = server.serve_trace(trace, y0_of=y0_of)
    return server, done


@pytest.mark.parametrize("trace_name",
                         sorted(set(traffic.TRACES) - {"deadline"}))
def test_streaming_invariants_under_traffic(trace_name):
    """Every healthy traffic shape — memoryless, bursty, all-cold paging
    storm, single-twin serialisation, ragged horizons — must drop
    nothing, preserve per-twin order, and conserve both requests and
    state.  (The deadline trace is exercised by its own test: it is
    *designed* to expire requests, so no-drop does not apply.)"""
    gen = traffic.TRACES[trace_name]
    trace = gen(seed=5, n_requests=24, max_horizon=12)
    server, done = _serve(trace)
    traffic.check_all(server, trace, done)


def test_streaming_deadline_trace_expires_exactly_once():
    """The deadline trace's stale requests are dropped at assembly time,
    each counted ``expired`` exactly once; everything else is served and
    the conservation sum still closes after a further drain (no
    double-count on re-pump)."""
    trace = traffic.deadline_trace(seed=5, n_requests=30, population=8,
                                   max_horizon=10, tight_fraction=0.4)
    server, done = _serve(trace)
    s = server.stats().stream
    assert s.expired > 0, "the deadline trace never expired anything"
    traffic.check_conservation(server, done)
    traffic.check_arrival_order(done)
    traffic.check_state_safety(server, trace, done)
    expired_before = s.expired
    extra = server.drain(now=trace[-1].time + 1.0)   # nothing left
    assert extra == []
    assert server.stats().stream.expired == expired_before, \
        "an expired request was counted again on a later pump"
    traffic.check_conservation(server, done)


def test_streaming_paging_exercised_population_4x_hot():
    """The acceptance bar: resident population >= 4x the hot set, served
    to completion with paging actually happening and nothing dropped."""
    trace = traffic.poisson_trace(seed=9, n_requests=40, population=32,
                                  min_horizon=2, max_horizon=10)
    assert traffic.population_of(trace) >= 4 * 8 // 2  # >=16 distinct twins
    server, done = _serve(trace, hot_capacity=4, max_batch=4)
    assert traffic.population_of(trace) >= 4 * server.store.hot_capacity
    traffic.check_all(server, trace, done)
    assert server.store.stats.evictions > 0, "paging was not exercised"


def test_streaming_matches_uninterrupted_rollout():
    """Continuous batching is invisible in the numbers: each twin's
    stitched completions equal ONE uninterrupted resumed rollout of the
    same total horizon, bitwise (f32)."""
    trace = traffic.poisson_trace(seed=2, n_requests=20, population=6,
                                  min_horizon=2, max_horizon=12)
    server, done = _serve(trace)
    traffic.check_all(server, trace, done)
    fleet, params = _fused_fleet()
    backend = resolve_backend(fleet.backend)
    state = backend.program(fleet.twin.node.field, params)
    by_twin = {}
    for c in sorted(done, key=lambda c: c.seq):
        by_twin.setdefault(c.twin_id, []).append(c.trajectory)
    for tid, parts in by_twin.items():
        stitched = np.concatenate(
            [parts[0]] + [p[1:] for p in parts[1:]], axis=0)
        total = stitched.shape[0] - 1
        full = backend.rollout_batch_resumed(
            state, stitched[None, 0], dt=DT, num_steps=total)
        np.testing.assert_array_equal(stitched, np.asarray(full[0]))


def test_streaming_deterministic_replay():
    """Same trace + same seeds -> byte-identical completions (the whole
    schedule is a pure function of the trace)."""
    trace = traffic.bursty_trace(seed=4, n_requests=16, population=8,
                                 max_horizon=10)
    _, done_a = _serve(trace)
    _, done_b = _serve(trace)
    assert [c.seq for c in done_a] == [c.seq for c in done_b]
    for a, b in zip(done_a, done_b):
        assert a.twin_id == b.twin_id and a.tier == b.tier
        np.testing.assert_array_equal(a.trajectory, b.trajectory)


def test_streaming_splits_long_requests():
    """A horizon longer than max_window is served across several batches
    through the chunk-carry path — one completion, full trajectory, and
    the split counter shows it happened."""
    trace = [traffic.Arrival(0.0, 0, 21)]
    server, done = _serve(trace, max_window=8)
    traffic.check_all(server, trace, done)
    assert len(done) == 1 and done[0].trajectory.shape == (22, DIM)
    assert server.stream_stats.splits >= 2


def test_streaming_front_door_validation():
    fleet, params = _fused_fleet()
    server = StreamingFleetServer(fleet, params, dt=DT, hot_capacity=4,
                                  max_batch=2, max_window=8)
    with pytest.raises(KeyError, match="not registered"):
        server.submit("ghost", 4)
    server.register_twin(0, np.zeros(DIM, np.float32))
    with pytest.raises(ValueError, match="horizon"):
        server.submit(0, 0)
    with pytest.raises(ValueError, match="theta"):
        server.register_twin(1, np.zeros(DIM, np.float32),
                             theta=np.float32([1.0]))
    with pytest.raises(ValueError, match="max_batch"):
        StreamingFleetServer(fleet, params, dt=DT, hot_capacity=2,
                             max_batch=4)
    with pytest.raises(ValueError, match="dt"):
        StreamingFleetServer(fleet, params, dt=0.0)


def test_streaming_driven_fleet_with_slo_fallback_chain():
    """Driven analogue fleet under an armed SLO: the fallback chain is
    built, probes run, and every request is served by SOME tier with the
    conservation invariants intact."""
    drive_family = lambda t, th: th[0] * jnp.sin(th[1] * t)
    twin = make_driven_twin(state_dim=2, hidden=8, n_hidden_layers=1,
                            drive=lambda t: jnp.sin(t),
                            gradient="fused_vjp")
    params = twin.init(jax.random.PRNGKey(2))
    backend = FusedAnalogueBackend(spec=AnalogueSpec(read_noise=0.05),
                                   prog_key=jax.random.PRNGKey(3))
    fleet = TwinFleet(twin=twin.with_backend(backend),
                      drive_family=drive_family)
    server = StreamingFleetServer(
        fleet, params, dt=DT, hot_capacity=8, max_batch=4, max_window=8,
        horizon_quantum=4, slo=ServingSLO(max_rel_error=0.5))
    assert [n for n, _ in server._tiers] == \
        ["analogue_fused", "analogue_fused_clean", "digital"]
    trace = traffic.bursty_trace(seed=6, n_requests=12, population=6,
                                 max_horizon=8)
    rng = np.random.default_rng(13)
    done = server.serve_trace(
        trace,
        y0_of=lambda i: rng.normal(size=2).astype(np.float32) * 0.1,
        theta_of=lambda i: np.float32([0.5, 2.0 + 0.1 * i]))
    traffic.check_all(server, trace, done)
    assert server.serving_stats.probes > 0
    assert sum(server.serving_stats.served_by.values()) == \
        server.stream_stats.batches


def test_streaming_pathological_request_quarantined_with_diagnostic():
    """A server whose only tier produces non-finite trajectories (here: a
    corrupted weight program) must *quarantine* the request — not drop it
    silently, not raise, not retry forever — record a diagnostic naming
    the tier that rejected it, and leave carried state untouched for the
    next (possibly re-programmed) attempt."""
    fleet, params = _fused_fleet()
    bad_params = jax.tree_util.tree_map(
        lambda x: x * np.float32(np.nan), params)
    server = StreamingFleetServer(fleet, bad_params, dt=DT, hot_capacity=4,
                                  max_batch=2, max_window=8,
                                  horizon_quantum=4)
    y0 = np.float32([0.1, 0.2, 0.3])
    server.register_twin("t", y0)
    seq = server.submit("t", 4)
    done = server.drain()
    assert done == [] and server.stream_stats.quarantined == 1
    assert seq in server.quarantine
    q = server.quarantine[seq]
    assert q.twin_id == "t" and q.horizon == 4
    assert "non-finite" in q.reason and "fused" in q.reason
    traffic.check_conservation(server, done)
    y, step = server.store.peek("t")
    np.testing.assert_array_equal(y, y0)   # state untouched by poison
    assert step == 0
    server.store.check_invariants()
    # quarantine is terminal: further pumps never resurrect the seq
    assert server.drain() == []
    assert server.stream_stats.quarantined == 1


def test_streaming_drain_with_quarantined_pending_mix():
    """drain() with a mixed queue — healthy requests AND a poison twin —
    serves the healthy ones, quarantines the poison one, and terminates
    (the quarantined seq must not wedge the drain loop)."""
    fleet, params = _fused_fleet()
    server = StreamingFleetServer(fleet, params, dt=DT, hot_capacity=4,
                                  max_batch=2, max_window=8,
                                  horizon_quantum=4)
    rng = np.random.default_rng(21)
    for tid in range(4):
        server.register_twin(tid, rng.normal(size=DIM).astype(np.float32)
                             * 0.1)
    # A non-finite *initial state* cannot enter via register_twin (it
    # validates), so poison the request with a finite-but-extreme state:
    # the first matvec overflows f32 and the window goes NaN.  Four
    # healthy twins ahead of it mean the poison assembles into a batch
    # of its own (quarantine parks whole batches).
    server.register_twin("hot", np.float32([3e38, 3e38, 3e38]))
    seqs = [server.submit(tid, 4) for tid in range(4)]
    bad = server.submit("hot", 8)
    done = server.drain()
    s = server.stats().stream
    assert sorted(c.seq for c in done) == seqs
    assert s.quarantined == 1 and bad in server.quarantine
    assert server.pending == 0
    traffic.check_conservation(server, done)
    traffic.check_state_safety(
        server,
        [traffic.Arrival(0.0, tid, 4) for tid in range(4)]
        + [traffic.Arrival(0.0, "hot", 8)],
        done)


def test_streaming_backpressure_reject_new():
    """With a bounded queue and the reject_new policy, submits past the
    bound return None, count shed, and conservation still closes."""
    fleet, params = _fused_fleet()
    server = StreamingFleetServer(fleet, params, dt=DT, hot_capacity=4,
                                  max_batch=2, max_window=8,
                                  horizon_quantum=4, max_queue=2,
                                  shed_policy="reject_new")
    rng = np.random.default_rng(3)
    for tid in range(4):
        server.register_twin(tid, rng.normal(size=DIM).astype(np.float32)
                             * 0.1)
    accepted = [server.submit(tid, 4) for tid in range(2)]
    assert all(s is not None for s in accepted)
    assert server.submit(2, 4) is None and server.submit(3, 4) is None
    s = server.stats().stream
    assert s.enqueued == 4 and s.shed == 2 and server.pending == 2
    done = server.drain()
    assert sorted(c.seq for c in done) == accepted
    traffic.check_conservation(server, done)


def test_streaming_backpressure_drop_oldest_same_twin():
    """drop_oldest sheds the oldest *unstarted request of the same twin*
    to make room (fresher data supersedes stale), and falls back to
    rejecting the newcomer when no same-twin victim exists."""
    fleet, params = _fused_fleet()
    server = StreamingFleetServer(fleet, params, dt=DT, hot_capacity=4,
                                  max_batch=2, max_window=8,
                                  horizon_quantum=4, max_queue=2,
                                  shed_policy="drop_oldest")
    rng = np.random.default_rng(4)
    for tid in ("a", "b"):
        server.register_twin(tid, rng.normal(size=DIM).astype(np.float32)
                             * 0.1)
    s0 = server.submit("a", 4)
    s1 = server.submit("b", 4)
    s2 = server.submit("a", 8)          # sheds s0 (same twin, oldest)
    assert s2 is not None
    assert [r.seq for r in server._queue] == [s1, s2]
    s3 = server.submit("b", 4)          # sheds s1
    assert s3 is not None
    # queue now [s2 (a), s3 (b)]; a twin with no queued request must NOT
    # steal another twin's slot — the newcomer is rejected instead
    server.register_twin("c", np.zeros(DIM, np.float32))
    assert server.submit("c", 4) is None
    done = server.drain()
    assert sorted(c.seq for c in done) == sorted([s2, s3])
    st = server.stats().stream
    assert st.enqueued == 5 and st.shed == 3 and st.served == 2
    traffic.check_conservation(server, done)


def test_streaming_submit_validation_names_argument():
    """Front-door validation on submit: each bad argument is rejected
    with a ValueError naming it, before any counter moves."""
    fleet, params = _fused_fleet()
    server = StreamingFleetServer(fleet, params, dt=DT, hot_capacity=4,
                                  max_batch=2, max_window=8)
    server.register_twin(0, np.zeros(DIM, np.float32))
    with pytest.raises(ValueError, match="horizon"):
        server.submit(0, True)          # bool is not a step count
    with pytest.raises(ValueError, match="horizon"):
        server.submit(0, 2.5)
    with pytest.raises(ValueError, match="t_arrival"):
        server.submit(0, 4, t_arrival=float("nan"))
    with pytest.raises(ValueError, match="deadline"):
        server.submit(0, 4, t_arrival=1.0, deadline=0.5)
    with pytest.raises(ValueError, match="deadline"):
        server.submit(0, 4, deadline=float("inf"))
    assert server.stats().stream.enqueued == 0 and server.pending == 0


def test_streaming_transient_fault_retried_with_backoff():
    """An injected transient tier fault (chaos.flaky) is absorbed by the
    retry path — the request is still served on the SAME tier, the retry
    counter moves, and no fallback/quarantine is triggered."""
    from repro.launch import chaos
    fleet, params = _fused_fleet()
    server = StreamingFleetServer(fleet, params, dt=DT, hot_capacity=4,
                                  max_batch=2, max_window=8,
                                  horizon_quantum=4, transient_retries=2,
                                  backoff_base_s=0.0)
    server.register_twin(0, np.float32([0.1, 0.2, 0.3]))
    server.submit(0, 4)
    with chaos.flaky("pump:run_tier", times=2):
        done = server.drain()
    assert len(done) == 1
    assert server.serving_stats.transient_retries == 2
    assert server.stream_stats.quarantined == 0
    assert server.stream_stats.failed == 0


def test_streaming_transient_exhaustion_falls_to_next_tier():
    """More consecutive faults than the retry budget exhausts the tier;
    with a fallback chain armed the next tier serves the batch (infra
    failure is NOT poison — nothing is quarantined)."""
    from repro.launch import chaos
    drive_family = lambda t, th: th[0] * jnp.sin(th[1] * t)
    twin = make_driven_twin(state_dim=2, hidden=8, n_hidden_layers=1,
                            drive=lambda t: jnp.sin(t),
                            gradient="fused_vjp")
    params = twin.init(jax.random.PRNGKey(2))
    backend = FusedAnalogueBackend(spec=AnalogueSpec(read_noise=0.05),
                                   prog_key=jax.random.PRNGKey(3))
    fleet = TwinFleet(twin=twin.with_backend(backend),
                      drive_family=drive_family)
    server = StreamingFleetServer(
        fleet, params, dt=DT, hot_capacity=4, max_batch=2, max_window=8,
        horizon_quantum=4, slo=ServingSLO(max_rel_error=0.5),
        transient_retries=1, backoff_base_s=0.0)
    server.register_twin(0, np.float32([0.1, 0.2]),
                         theta=np.float32([0.5, 2.0]))
    server.submit(0, 4)
    # 2 faults > 1 retry: first tier exhausts, but flaky heals before the
    # *second* tier attempts, so the fallback serves it
    with chaos.flaky("pump:run_tier", times=2):
        done = server.drain()
    assert len(done) == 1
    assert done[0].tier != server._tiers[0][0]
    assert server.stream_stats.quarantined == 0
    traffic.check_conservation(server, done)


def test_streaming_stats_unified_snapshot():
    """server.stats() returns one consistent snapshot of all three stat
    families, detached from live state (mutating the server afterwards
    does not change the snapshot)."""
    trace = traffic.poisson_trace(seed=3, n_requests=8, population=4,
                                  max_horizon=8)
    server, done = _serve(trace)
    snap = server.stats()
    assert snap.stream.served == len(done)
    assert snap.store.page_ins == server.store.stats.page_ins
    assert snap.serving.served_by == server.serving_stats.served_by
    d = snap.as_dict()
    assert set(d) == {"stream", "serving", "store"}
    assert d["stream"]["served"] == len(done)
    before = snap.stream.enqueued
    server.submit(done[0].twin_id, 4)
    assert snap.stream.enqueued == before    # snapshot is a deep copy
    server.drain()


def test_streaming_store_audit_env_flag(monkeypatch):
    """REPRO_STORE_AUDIT=1 runs the store's structural audit after every
    pump — smoke that the flag wires through and a healthy run passes."""
    monkeypatch.setenv("REPRO_STORE_AUDIT", "1")
    trace = traffic.poisson_trace(seed=6, n_requests=10, population=4,
                                  max_horizon=8)
    server, done = _serve(trace)
    assert server._audit is True
    traffic.check_all(server, trace, done)


def test_streaming_theta_survives_paging():
    """Per-twin drive parameters are host metadata: they survive
    eviction round-trips and come back with fetch in batch order."""
    store = TwinStateStore(2, hot_capacity=1)
    store.register("a", np.zeros(2, np.float32), theta=np.float32([1, 2]),
                   step=3)
    store.register("b", np.zeros(2, np.float32), theta=np.float32([3, 4]))
    _, steps, thetas = store.fetch(["a"])
    assert list(steps) == [3]
    np.testing.assert_array_equal(np.asarray(thetas),
                                  np.float32([[1, 2]]))
    store.fetch(["b"])                        # evicts "a"
    np.testing.assert_array_equal(store.theta("a"), np.float32([1, 2]))
    _, _, thetas = store.fetch(["a"])         # pages back with theta
    np.testing.assert_array_equal(np.asarray(thetas),
                                  np.float32([[1, 2]]))


def test_streaming_digital_backend_serves_too():
    """The streaming loop is substrate-agnostic: a digital-backend fleet
    goes through the vmap window path and meets the same invariants."""
    twin = make_autonomous_twin(state_dim=DIM, hidden=8, n_hidden_layers=1,
                                backend=DigitalBackend())
    params = twin.init(jax.random.PRNGKey(1))
    fleet = TwinFleet(twin=twin)
    server = StreamingFleetServer(fleet, params, dt=DT, hot_capacity=4,
                                  max_batch=2, max_window=8,
                                  horizon_quantum=4)
    trace = traffic.poisson_trace(seed=8, n_requests=10, population=5,
                                  min_horizon=2, max_horizon=8)
    rng = np.random.default_rng(17)
    done = server.serve_trace(
        trace, y0_of=lambda i: rng.normal(size=DIM).astype(np.float32) * 0.1)
    traffic.check_all(server, trace, done)
