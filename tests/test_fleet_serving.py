"""Sharded fleet serving: padding/mask, mesh parity, checkpoint->serve.

The contract under test (repro.launch.fleet_serving): sharding a fleet
rollout over a twin mesh changes *placement only* — trajectories match
the single-device ``TwinFleet`` path bit-for-bit on the same backend —
and the checkpoint hand-off (``save_twin``/``load_twin``/``serve_fleet``)
serves exactly the weights that were trained in memory.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backends import FusedPallasBackend
from repro.core.twin import TwinFleet, make_autonomous_twin, make_driven_twin
from repro.launch.fleet_serving import (FleetServer, pad_fleet_inputs,
                                        padded_size, serve_fleet)
from repro.launch.mesh import make_twin_mesh, twin_shard_count
from repro.train import checkpoint as ckpt

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def l96_small():
    twin = make_autonomous_twin(4, hidden=16)
    params = twin.init(jax.random.PRNGKey(0))
    ts = jnp.linspace(0.0, 0.02, 9)
    y0s = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (12, 4))
    return twin, params, ts, y0s


# ---------------------------------------------------------------------------
# Uneven-N padding + mask
# ---------------------------------------------------------------------------

def test_padded_size():
    assert padded_size(12, 4) == 12
    assert padded_size(13, 4) == 16
    assert padded_size(1, 4) == 4
    assert padded_size(5, 1) == 5


def test_pad_fleet_inputs_uneven():
    y0s = jnp.arange(14.0).reshape(7, 2)
    thetas = jnp.arange(21.0).reshape(7, 3)
    yp, tp, mask = pad_fleet_inputs(y0s, thetas, 4)
    assert yp.shape == (8, 2) and tp.shape == (8, 3)
    assert mask.shape == (8,) and int(mask.sum()) == 7
    # real rows untouched, padding replicates the last real asset
    np.testing.assert_array_equal(np.asarray(yp[:7]), np.asarray(y0s))
    np.testing.assert_array_equal(np.asarray(yp[7]), np.asarray(y0s[6]))
    np.testing.assert_array_equal(np.asarray(tp[7]), np.asarray(thetas[6]))


def test_pad_fleet_inputs_divisible_is_noop():
    y0s = jnp.ones((8, 3))
    yp, tp, mask = pad_fleet_inputs(y0s, None, 4)
    assert yp is y0s and tp is None
    assert mask.all()


def test_pad_fleet_inputs_batch_mismatch():
    with pytest.raises(ValueError, match="drive_params batch"):
        pad_fleet_inputs(jnp.ones((5, 2)), jnp.ones((4, 2)), 2)


# ---------------------------------------------------------------------------
# Sharded == single-device (trivial mesh on this host)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", [None, FusedPallasBackend(batch_tile=4)])
def test_sharded_matches_single_device(l96_small, backend):
    twin, params, ts, y0s = l96_small
    if backend is not None:
        twin = twin.with_backend(backend)
    fleet = TwinFleet(twin)
    mesh = make_twin_mesh()
    ref = fleet.simulate(params, y0s, ts)
    out = fleet.rollout_batch(params, y0s, ts, mesh=mesh)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


def test_sharded_driven_fleet_matches(l96_small):
    twin = make_driven_twin(1, drive=None, hidden=8)
    params = twin.init(jax.random.PRNGKey(2))
    fam = lambda t, th: th[0] * jnp.sin(th[1] * t)
    fleet = TwinFleet(twin, drive_family=fam)
    ts = jnp.linspace(0.0, 0.05, 11)
    y0s = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (6, 1))
    thetas = 1.0 + jax.random.uniform(jax.random.PRNGKey(4), (6, 2))
    ref = fleet.simulate(params, y0s, ts, thetas)
    out = fleet.rollout_batch(params, y0s, ts, thetas,
                              mesh=make_twin_mesh())
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-6)


def test_fleet_server_serves_and_unpads(l96_small):
    twin, params, ts, y0s = l96_small
    server = FleetServer(TwinFleet(twin), params, ts)
    out = server.serve(y0s[:7])            # uneven N
    ref = TwinFleet(twin).simulate(params, y0s[:7], ts)
    assert out.shape == (7, ts.shape[0], 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# Checkpoint save -> load -> serve round trip
# ---------------------------------------------------------------------------

def test_twin_checkpoint_roundtrip(tmp_path, l96_small):
    twin, params, _, _ = l96_small
    ckpt.save_twin(str(tmp_path), params, step=3)
    template = twin.init(jax.random.PRNGKey(99))   # different values
    restored = ckpt.load_twin(str(tmp_path), template)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_load_twin_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.load_twin(str(tmp_path / "nowhere"), {})


def test_checkpoint_serve_matches_in_memory(tmp_path, l96_small):
    """serve_fleet from disk == FleetServer on the in-memory weights."""
    twin, params, ts, y0s = l96_small
    fleet = TwinFleet(twin)
    ckpt.save_twin(str(tmp_path), params)

    requests = [y0s[:5], y0s[5:12]]        # two uneven batches
    served = list(serve_fleet(str(tmp_path), fleet, ts, requests))
    assert [s.shape[0] for s in served] == [5, 7]

    in_mem = FleetServer(fleet, params, ts)
    for req, out in zip(requests, served):
        ref = in_mem.serve(req)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Real multi-device sharding (virtual 4-device subprocess)
# ---------------------------------------------------------------------------

def test_multi_device_uneven_fleet_subprocess():
    """On a genuine 4-shard mesh: uneven N pads, masks, and matches the
    single-device rollout exactly (digital and fused backends)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.backends import FusedPallasBackend
        from repro.core.twin import TwinFleet, make_autonomous_twin
        from repro.launch.mesh import make_twin_mesh, twin_shard_count

        mesh = make_twin_mesh()
        assert twin_shard_count(mesh) == 4
        twin = make_autonomous_twin(4, hidden=16)
        params = twin.init(jax.random.PRNGKey(0))
        ts = jnp.linspace(0.0, 0.02, 9)
        y0s = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (37, 4))

        for twin_b in [twin, twin.with_backend(FusedPallasBackend(
                batch_tile=5))]:
            fleet = TwinFleet(twin_b)
            ref = fleet.simulate(params, y0s, ts)
            out = fleet.rollout_batch(params, y0s, ts, mesh=mesh)
            assert out.shape == (37, 9, 4), out.shape
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=0, atol=1e-5)
        print("MULTIDEV_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ,
                                        "PYTHONPATH": f"{REPO}/src"})
    assert "MULTIDEV_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])
