"""Sharded fleet serving: padding/mask, mesh parity, checkpoint->serve.

The contract under test (repro.launch.fleet_serving): sharding a fleet
rollout over a twin mesh changes *placement only* — trajectories match
the single-device ``TwinFleet`` path bit-for-bit on the same backend —
and the checkpoint hand-off (``save_twin``/``load_twin``/``serve_fleet``)
serves exactly the weights that were trained in memory.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analogue import AnalogueSpec
from repro.core.backends import (AnalogueBackend, DigitalBackend,
                                 FusedAnalogueBackend, FusedPallasBackend)
from repro.core.faults import make_fault_model
from repro.core.twin import TwinFleet, make_autonomous_twin, make_driven_twin
from repro.launch.fleet_serving import (FleetServer, ServingSLO,
                                        fallback_chain, pad_fleet_inputs,
                                        padded_size, serve_fleet,
                                        shard_rollout_batch,
                                        validate_fleet_request)
from repro.launch.mesh import make_twin_mesh, twin_shard_count
from repro.train import checkpoint as ckpt

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def l96_small():
    twin = make_autonomous_twin(4, hidden=16)
    params = twin.init(jax.random.PRNGKey(0))
    ts = jnp.linspace(0.0, 0.02, 9)
    y0s = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (12, 4))
    return twin, params, ts, y0s


# ---------------------------------------------------------------------------
# Uneven-N padding + mask
# ---------------------------------------------------------------------------

def test_padded_size():
    assert padded_size(12, 4) == 12
    assert padded_size(13, 4) == 16
    assert padded_size(1, 4) == 4
    assert padded_size(5, 1) == 5


def test_pad_fleet_inputs_uneven():
    y0s = jnp.arange(14.0).reshape(7, 2)
    thetas = jnp.arange(21.0).reshape(7, 3)
    yp, tp, mask = pad_fleet_inputs(y0s, thetas, 4)
    assert yp.shape == (8, 2) and tp.shape == (8, 3)
    assert mask.shape == (8,) and int(mask.sum()) == 7
    # real rows untouched, padding replicates the last real asset
    np.testing.assert_array_equal(np.asarray(yp[:7]), np.asarray(y0s))
    np.testing.assert_array_equal(np.asarray(yp[7]), np.asarray(y0s[6]))
    np.testing.assert_array_equal(np.asarray(tp[7]), np.asarray(thetas[6]))


def test_pad_fleet_inputs_divisible_is_noop():
    y0s = jnp.ones((8, 3))
    yp, tp, mask = pad_fleet_inputs(y0s, None, 4)
    assert yp is y0s and tp is None
    assert mask.all()


def test_pad_fleet_inputs_batch_mismatch():
    with pytest.raises(ValueError, match="drive_params batch"):
        pad_fleet_inputs(jnp.ones((5, 2)), jnp.ones((4, 2)), 2)


# ---------------------------------------------------------------------------
# Sharded == single-device (trivial mesh on this host)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", [None, FusedPallasBackend(batch_tile=4)])
def test_sharded_matches_single_device(l96_small, backend):
    twin, params, ts, y0s = l96_small
    if backend is not None:
        twin = twin.with_backend(backend)
    fleet = TwinFleet(twin)
    mesh = make_twin_mesh()
    ref = fleet.simulate(params, y0s, ts)
    out = fleet.rollout_batch(params, y0s, ts, mesh=mesh)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


def test_sharded_driven_fleet_matches(l96_small):
    twin = make_driven_twin(1, drive=None, hidden=8)
    params = twin.init(jax.random.PRNGKey(2))
    fam = lambda t, th: th[0] * jnp.sin(th[1] * t)
    fleet = TwinFleet(twin, drive_family=fam)
    ts = jnp.linspace(0.0, 0.05, 11)
    y0s = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (6, 1))
    thetas = 1.0 + jax.random.uniform(jax.random.PRNGKey(4), (6, 2))
    ref = fleet.simulate(params, y0s, ts, thetas)
    out = fleet.rollout_batch(params, y0s, ts, thetas,
                              mesh=make_twin_mesh())
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-6)


def test_fleet_server_serves_and_unpads(l96_small):
    twin, params, ts, y0s = l96_small
    server = FleetServer(TwinFleet(twin), params, ts)
    out = server.serve(y0s[:7])            # uneven N
    ref = TwinFleet(twin).simulate(params, y0s[:7], ts)
    assert out.shape == (7, ts.shape[0], 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# Front-door validation: errors name the offending argument
# ---------------------------------------------------------------------------

def test_serve_rejects_nan_y0s(l96_small):
    twin, params, ts, y0s = l96_small
    server = FleetServer(TwinFleet(twin), params, ts)
    bad = y0s.at[2, 1].set(jnp.nan)
    with pytest.raises(ValueError, match="y0s.*non-finite"):
        server.serve(bad)


def test_serve_rejects_inf_drive_params():
    twin = make_driven_twin(1, drive=None, hidden=8)
    params = twin.init(jax.random.PRNGKey(2))
    fleet = TwinFleet(twin, drive_family=lambda t, th: th[0] * t)
    server = FleetServer(fleet, params, jnp.linspace(0.0, 0.05, 11))
    y0s = jnp.zeros((4, 1))
    with pytest.raises(ValueError, match="drive_params.*non-finite"):
        server.serve(y0s, jnp.full((4, 1), jnp.inf))


def test_server_rejects_non_monotone_ts(l96_small):
    twin, params, _, _ = l96_small
    with pytest.raises(ValueError, match="ts must be strictly increasing"):
        FleetServer(TwinFleet(twin), params, jnp.array([0.0, 0.2, 0.1]))
    with pytest.raises(ValueError, match="ts must be a 1-D time grid"):
        FleetServer(TwinFleet(twin), params, jnp.array([0.0]))


def test_shard_rollout_batch_validates(l96_small):
    twin, params, ts, y0s = l96_small
    fleet = TwinFleet(twin)
    bad_ts = jnp.concatenate([ts[:-1], ts[-2:-1]])   # repeated point
    with pytest.raises(ValueError, match="shard_rollout_batch.*ts"):
        fleet.rollout_batch(params, y0s, bad_ts, mesh=make_twin_mesh())
    with pytest.raises(ValueError, match="shard_rollout_batch.*y0s"):
        fleet.rollout_batch(params, y0s.at[0, 0].set(jnp.inf), ts,
                            mesh=make_twin_mesh())


def test_validate_skips_tracers():
    @jax.jit
    def f(y):
        validate_fleet_request("inner", y0s=y)   # must not concretise
        return y * 2

    out = f(jnp.array([[jnp.nan]]))              # value check skipped
    assert out.shape == (1, 1)


# ---------------------------------------------------------------------------
# SLO / graceful degradation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hp_serving():
    fam = lambda t, th: th[0] * jnp.sin(2.0 * jnp.pi * th[1] * t)
    twin = make_driven_twin(1, drive=None, hidden=14)
    params = twin.init(jax.random.PRNGKey(0))
    fleet = TwinFleet(twin, drive_family=fam)
    ts = jnp.linspace(0.0, 0.1, 101)
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    y0s = 0.3 * jax.random.normal(k1, (6, 1))
    thetas = 1.0 + jax.random.uniform(k2, (6, 2))
    return fleet, params, ts, y0s, thetas


def test_serving_slo_validation():
    with pytest.raises(ValueError, match="max_rel_error"):
        ServingSLO(max_rel_error=0.0)
    with pytest.raises(ValueError, match="probe_every"):
        ServingSLO(probe_every=0)
    with pytest.raises(ValueError, match="max_retries"):
        ServingSLO(max_retries=-1)
    with pytest.raises(ValueError, match="timeout_s"):
        ServingSLO(timeout_s=0.0)


def test_fallback_chain_shapes(hp_serving):
    fleet = hp_serving[0]
    spec = AnalogueSpec(prog_noise=0.0, read_noise=0.01)
    names = [n for n, _ in fallback_chain(
        fleet.with_backend(FusedAnalogueBackend(spec=spec)))]
    assert names == ["analogue_fused", "analogue_fused_clean", "digital"]
    names = [n for n, _ in fallback_chain(
        fleet.with_backend(AnalogueBackend(spec=spec)))]
    assert names == ["analogue", "analogue_fused_clean", "digital"]
    assert [n for n, _ in fallback_chain(
        fleet.with_backend(DigitalBackend()))] == ["digital"]
    # last tier is always digital for analogue primaries
    for be in [AnalogueBackend(), FusedAnalogueBackend()]:
        assert fallback_chain(fleet.with_backend(be))[-1][0] == "digital"


def test_healthy_array_serves_primary(hp_serving):
    fleet, params, ts, y0s, thetas = hp_serving
    healthy = fleet.with_backend(FusedAnalogueBackend(
        spec=AnalogueSpec(prog_noise=0.0436), prog_key=jax.random.PRNGKey(7)))
    srv = FleetServer(healthy, params, ts, slo=ServingSLO(
        max_rel_error=0.2, probe_every=2, probe_horizon=101, probe_fleet=2))
    for _ in range(2):
        out = srv.serve(y0s, thetas)
        assert bool(jnp.isfinite(out).all())
    assert srv.active_tier == "analogue_fused"
    assert srv.stats.served_by == {"analogue_fused": 2}
    assert srv.stats.probe_demotions == 0
    assert srv.stats.probes >= 1


def test_unrepairable_array_falls_back_to_digital(hp_serving):
    """The ISSUE acceptance gate: with an unrepairable array (30% stuck
    cells) every request is still served — via the digital tier, zero
    NaN outputs, demotion counted — and the served trajectories match
    the digital fleet exactly."""
    fleet, params, ts, y0s, thetas = hp_serving
    broken = fleet.with_backend(FusedAnalogueBackend(
        spec=AnalogueSpec(prog_noise=0.0436), prog_key=jax.random.PRNGKey(7),
        faults=make_fault_model(("stuck", dict(rate=0.3)), seed=5)))
    srv = FleetServer(broken, params, ts, slo=ServingSLO(
        max_rel_error=0.05, probe_every=1, probe_horizon=101, probe_fleet=2))
    outs = [srv.serve(y0s, thetas) for _ in range(3)]
    assert all(bool(jnp.isfinite(o).all()) for o in outs)
    assert srv.active_tier == "digital"
    assert srv.stats.probe_demotions >= 1
    assert srv.stats.served_by == {"digital": 3}
    ref = fleet.with_backend(DigitalBackend()).rollout_batch(
        params, y0s, ts, thetas)
    np.testing.assert_allclose(np.asarray(outs[-1]), np.asarray(ref),
                               rtol=0, atol=1e-5)


def test_probe_recovers_after_demotion(hp_serving):
    """Probing restarts from the primary tier, so a server that was
    demoted (here: forced) promotes back once the array meets the SLO."""
    fleet, params, ts, y0s, thetas = hp_serving
    healthy = fleet.with_backend(FusedAnalogueBackend(
        spec=AnalogueSpec(prog_noise=0.0436), prog_key=jax.random.PRNGKey(7)))
    srv = FleetServer(healthy, params, ts, slo=ServingSLO(
        max_rel_error=0.2, probe_every=1, probe_horizon=101, probe_fleet=2))
    srv._active = len(srv._tiers) - 1          # simulate a past demotion
    srv.serve(y0s, thetas)
    assert srv.active_tier == "analogue_fused"
    assert srv.stats.probe_recoveries == 1


def test_serve_without_slo_keeps_legacy_path(l96_small):
    twin, params, ts, y0s = l96_small
    srv = FleetServer(TwinFleet(twin), params, ts)
    out = srv.serve(y0s[:5])
    ref = TwinFleet(twin).simulate(params, y0s[:5], ts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-6)
    assert srv.stats.requests == 1 and srv.stats.probes == 0


# ---------------------------------------------------------------------------
# Checkpoint save -> load -> serve round trip
# ---------------------------------------------------------------------------

def test_twin_checkpoint_roundtrip(tmp_path, l96_small):
    twin, params, _, _ = l96_small
    ckpt.save_twin(str(tmp_path), params, step=3)
    template = twin.init(jax.random.PRNGKey(99))   # different values
    restored = ckpt.load_twin(str(tmp_path), template)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_load_twin_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.load_twin(str(tmp_path / "nowhere"), {})


# ---------------------------------------------------------------------------
# Checkpoint damage: every failure mode gets a descriptive error
# ---------------------------------------------------------------------------

def _save_one(tmp_path, params):
    ckpt.save_twin(str(tmp_path), params, step=1)
    return os.path.join(str(tmp_path), "step_0000000001")


def test_load_twin_corrupt_manifest(tmp_path, l96_small):
    twin, params, _, _ = l96_small
    step_dir = _save_one(tmp_path, params)
    with open(os.path.join(step_dir, "manifest.json"), "w") as f:
        f.write('{"schema": 1, "leav')           # truncated mid-write
    with pytest.raises(ValueError, match="corrupt.*invalid JSON"):
        ckpt.load_twin(str(tmp_path), params, step=1)


def test_load_twin_missing_manifest(tmp_path, l96_small):
    twin, params, _, _ = l96_small
    step_dir = _save_one(tmp_path, params)
    os.remove(os.path.join(step_dir, "manifest.json"))
    with pytest.raises(FileNotFoundError, match="no manifest.json"):
        ckpt.load_twin(str(tmp_path), params, step=1)


def test_load_twin_truncated_arrays(tmp_path, l96_small):
    twin, params, _, _ = l96_small
    step_dir = _save_one(tmp_path, params)
    os.remove(os.path.join(step_dir, "arr_00000.npy"))
    with pytest.raises(FileNotFoundError, match="truncated"):
        ckpt.load_twin(str(tmp_path), params, step=1)


def test_load_twin_corrupt_array(tmp_path, l96_small):
    twin, params, _, _ = l96_small
    step_dir = _save_one(tmp_path, params)
    with open(os.path.join(step_dir, "arr_00000.npy"), "wb") as f:
        f.write(b"\x93NUMPY\x01\x00garbage")
    with pytest.raises(ValueError, match="arr_00000.npy.*corrupt"):
        ckpt.load_twin(str(tmp_path), params, step=1)


def test_load_twin_schema_mismatch(tmp_path, l96_small):
    import json

    twin, params, _, _ = l96_small
    step_dir = _save_one(tmp_path, params)
    mpath = os.path.join(step_dir, "manifest.json")
    with open(mpath) as f:
        doc = json.load(f)
    doc["schema"] = 99
    with open(mpath, "w") as f:
        json.dump(doc, f)
    with pytest.raises(ValueError, match="schema 99.*schema 1"):
        ckpt.load_twin(str(tmp_path), params, step=1)


def test_load_twin_shape_mismatch(tmp_path, l96_small):
    twin, params, _, _ = l96_small
    _save_one(tmp_path, params)
    other = make_autonomous_twin(4, hidden=24)   # different architecture
    template = other.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="different architecture"):
        ckpt.load_twin(str(tmp_path), template, step=1)


def test_load_twin_pre_versioned_manifest_still_loads(tmp_path, l96_small):
    """Checkpoints written before the schema field existed read as v1."""
    import json

    twin, params, _, _ = l96_small
    step_dir = _save_one(tmp_path, params)
    mpath = os.path.join(step_dir, "manifest.json")
    with open(mpath) as f:
        doc = json.load(f)
    del doc["schema"]
    with open(mpath, "w") as f:
        json.dump(doc, f)
    restored = ckpt.load_twin(str(tmp_path), params, step=1)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_serve_matches_in_memory(tmp_path, l96_small):
    """serve_fleet from disk == FleetServer on the in-memory weights."""
    twin, params, ts, y0s = l96_small
    fleet = TwinFleet(twin)
    ckpt.save_twin(str(tmp_path), params)

    requests = [y0s[:5], y0s[5:12]]        # two uneven batches
    served = list(serve_fleet(str(tmp_path), fleet, ts, requests))
    assert [s.shape[0] for s in served] == [5, 7]

    in_mem = FleetServer(fleet, params, ts)
    for req, out in zip(requests, served):
        ref = in_mem.serve(req)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Real multi-device sharding (virtual 4-device subprocess)
# ---------------------------------------------------------------------------

def test_multi_device_uneven_fleet_subprocess():
    """On a genuine 4-shard mesh: uneven N pads, masks, and matches the
    single-device rollout exactly (digital and fused backends)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.backends import FusedPallasBackend
        from repro.core.twin import TwinFleet, make_autonomous_twin
        from repro.launch.mesh import make_twin_mesh, twin_shard_count

        mesh = make_twin_mesh()
        assert twin_shard_count(mesh) == 4
        twin = make_autonomous_twin(4, hidden=16)
        params = twin.init(jax.random.PRNGKey(0))
        ts = jnp.linspace(0.0, 0.02, 9)
        y0s = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (37, 4))

        for twin_b in [twin, twin.with_backend(FusedPallasBackend(
                batch_tile=5))]:
            fleet = TwinFleet(twin_b)
            ref = fleet.simulate(params, y0s, ts)
            out = fleet.rollout_batch(params, y0s, ts, mesh=mesh)
            assert out.shape == (37, 9, 4), out.shape
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=0, atol=1e-5)
        print("MULTIDEV_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ,
                                        "PYTHONPATH": f"{REPO}/src"})
    assert "MULTIDEV_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])
