"""Gradient parity: the fused-Pallas training substrate must agree with
the digital adjoint, backprop-through-the-solver, and finite differences;
the kernelised soft-DTW backward must agree with autodiff of the
reference DP.  This is the acceptance suite for train-where-you-serve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import soft_dtw as soft_dtw_jnp
from repro.core.node import mlp_init
from repro.core.twin import make_autonomous_twin, make_driven_twin
from repro.kernels import ops, ref
from repro.core.backends import FusedPallasBackend
from repro.kernels.fused_ode_mlp import DEFAULT_VMEM_BUDGET
from repro.kernels.fused_ode_mlp_bwd import fused_node_rollout_vjp

KEY = jax.random.PRNGKey(0)


def _tree_max_err(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return max(float(jnp.abs(x - y).max()) for x, y in zip(la, lb))


def _tree_max_rel(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    scale = max(float(jnp.abs(y).max()) for y in lb) + 1e-12
    return _tree_max_err(a, b) / scale


# ---------------------------------------------------------------------------
# fused VJP vs autodiff of the jnp reference (exact same discretisation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes,drive_dim,T,chunk,bt", [
    ((2, 14, 14, 1), 1, 11, 3, 4),    # HP shape, chunk-straddling
    ((6, 32, 32, 6), 0, 21, 4, 8),    # autonomous, partial tail chunk
    ((3, 8, 2), 1, 5, 8, 8),          # single chunk > T
])
def test_fused_vjp_matches_ref_autodiff(sizes, drive_dim, T, chunk, bt):
    """Grads of a random-weighted trajectory functional: the reverse-time
    kernel must reproduce backprop-through-the-unrolled-RK4 to float32
    rounding, across time-chunk boundaries."""
    D = sizes[-1]
    k1, k2, k3 = jax.random.split(jax.random.fold_in(KEY, sizes[1] + T), 3)
    params = mlp_init(k1, sizes)
    ws = [p["w"] for p in params]
    bs = [p["b"] for p in params]
    B = 8
    ts = jnp.linspace(0.0, 0.5, T + 1)
    dt = float(ts[1] - ts[0])
    if drive_dim:
        uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    else:
        uh = jnp.zeros((2 * T + 1, 0))
    y0 = 0.3 * jax.random.normal(k2, (B, D))
    gw = jax.random.normal(k3, (T + 1, B, D))

    gk = jax.grad(lambda y, w, b: jnp.sum(
        fused_node_rollout_vjp(y, uh, w, b, dt, bt, chunk, None,
                               DEFAULT_VMEM_BUDGET, "f32") * gw),
        argnums=(0, 1, 2))(y0, ws, bs)
    gr = jax.grad(lambda y, w, b: jnp.sum(
        ref.fused_node_rollout_ref(y, uh, w, b, dt) * gw),
        argnums=(0, 1, 2))(y0, ws, bs)
    assert _tree_max_rel(gk, gr) < 1e-5


def test_fused_vjp_per_tile_drives():
    """Per-twin drive slabs (fleet training): gradients must flow through
    the (tile, chunk)-sliced drive path too."""
    params = mlp_init(KEY, (2, 14, 14, 1))
    ws = [p["w"] for p in params]
    bs = [p["b"] for p in params]
    B, T = 8, 11
    ts = jnp.linspace(0.0, 0.5, T + 1)
    amps = 0.5 + jnp.arange(B, dtype=jnp.float32) / B
    uh = jnp.stack([ops.half_step_drive(lambda t, a=a: a * jnp.sin(4 * t), ts)
                    for a in amps])
    y0 = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 5), (B, 1))
    gw = jax.random.normal(jax.random.fold_in(KEY, 6), (T + 1, B, 1))
    dt = float(ts[1] - ts[0])
    gk = jax.grad(lambda y, w, b: jnp.sum(
        fused_node_rollout_vjp(y, uh, w, b, dt, 4, 3, None,
                               DEFAULT_VMEM_BUDGET, "f32") * gw),
        argnums=(0, 1, 2))(y0, ws, bs)
    gr = jax.grad(lambda y, w, b: jnp.sum(
        ref.fused_node_rollout_ref(y, uh, w, b, dt) * gw),
        argnums=(0, 1, 2))(y0, ws, bs)
    assert _tree_max_rel(gk, gr) < 1e-5


def test_fused_vjp_drive_gets_zero_cotangent():
    """The drive is data, not a parameter: its cotangent is defined zero."""
    params = mlp_init(KEY, (2, 8, 1))
    ws = [p["w"] for p in params]
    bs = [p["b"] for p in params]
    T = 6
    ts = jnp.linspace(0.0, 0.3, T + 1)
    uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    y0 = jnp.full((4, 1), 0.2)
    g = jax.grad(lambda u: jnp.sum(
        fused_node_rollout_vjp(y0, u, ws, bs, float(ts[1] - ts[0]),
                               4, None, None) ** 2))(uh)
    assert g.shape == uh.shape
    assert float(jnp.abs(g).max()) == 0.0


# ---------------------------------------------------------------------------
# fused VJP vs the digital adjoint (twin level) and finite differences
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hp_grad_setup():
    twin = make_driven_twin(1, lambda t: jnp.sin(4.0 * t))
    params = twin.init(KEY)
    # 23 steps with time_chunk=5 -> the loss horizon straddles 5 chunks
    ts = jnp.linspace(0.0, 0.23, 24)
    y0 = jnp.array([0.2])
    return twin, params, y0, ts


def test_fused_vjp_matches_digital_adjoint(hp_grad_setup):
    """Same loss, same weights: continuous-adjoint grads (digital) and
    discretise-then-optimise grads (fused) agree to <=1e-3 rel."""
    from repro.core.backends import FusedPallasBackend
    twin, params, y0, ts = hp_grad_setup
    fused = twin.with_backend(
        FusedPallasBackend(batch_tile=1, time_chunk=5, precision="f32"))

    def loss(t):
        return lambda p: jnp.mean(t.simulate(p, y0, ts) ** 2)

    g_dig = jax.grad(loss(twin))(params)          # adjoint (O(1) memory)
    g_fus = jax.grad(loss(fused))(params)         # reverse-time kernel
    assert _tree_max_rel(g_fus, g_dig) < 1e-3


def test_fused_vjp_matches_finite_differences(hp_grad_setup):
    """Directional derivative vs central differences, <=1e-3 rel, on a
    chunk-straddling horizon (the ISSUE acceptance gate)."""
    from repro.core.backends import FusedPallasBackend
    twin, params, y0, ts = hp_grad_setup
    fused = twin.with_backend(
        FusedPallasBackend(batch_tile=1, time_chunk=5, precision="f32"))

    def loss(p, y):
        return jnp.mean(fused.node.trajectory(p, y, ts) ** 2)

    gp, gy = jax.grad(loss, argnums=(0, 1))(params, y0)

    # params: directional derivative along the gradient itself (a random
    # direction suffers g.v cancellation that amplifies float32 FD noise
    # past the gate); then fd ~= |g| and the check is well conditioned
    norm = jnp.sqrt(sum(jnp.sum(x ** 2)
                        for x in jax.tree_util.tree_leaves(gp)))
    v = jax.tree_util.tree_map(lambda x: x / norm, gp)
    eps = 3e-3   # truncation ~eps^2 stays below the 1e-3 gate; float32
                 # rounding noise in the central difference stays ~1e-5
    shift = lambda s: jax.tree_util.tree_map(lambda p_, v_: p_ + s * v_,
                                             params, v)
    fd = (loss(shift(eps), y0) - loss(shift(-eps), y0)) / (2 * eps)
    assert abs(float(fd) - float(norm)) / (abs(float(fd)) + 1e-12) < 1e-3

    # y0 direction
    fd_y = (loss(params, y0 + eps) - loss(params, y0 - eps)) / (2 * eps)
    assert abs(float(fd_y - gy[0])) / (abs(float(fd_y)) + 1e-12) < 1e-3


def test_fused_fleet_batch_gradients(hp_grad_setup):
    """Gradients through rollout_batch_local, including the fleet padding
    path (B=5 prime, batch_tile=4 -> one padded tile); padded rows must
    contribute exactly nothing."""
    from repro.core.backends import FusedPallasBackend
    twin, params, _, ts = hp_grad_setup
    y0s = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 11), (5, 1))
    fused = twin.with_backend(
        FusedPallasBackend(batch_tile=4, precision="f32"))

    def loss_f(p):
        return jnp.mean(fused.simulate_batch(p, y0s, ts) ** 2)

    def loss_d(p):
        return jnp.mean(twin.simulate_batch(p, y0s, ts) ** 2)

    gf = jax.grad(loss_f)(params)
    gd = jax.grad(loss_d)(params)
    assert _tree_max_rel(gf, gd) < 1e-3


def test_fused_stopgrad_detaches(hp_grad_setup):
    """gradient='stopgrad' pins the substrate to inference: zero grads
    instead of an autodiff error through the raw pallas_call."""
    twin, params, y0, ts = hp_grad_setup
    from repro.core.backends import FusedPallasBackend
    import dataclasses
    node = dataclasses.replace(twin.node, gradient="stopgrad",
                               backend=FusedPallasBackend(batch_tile=1))
    g = jax.grad(lambda p: jnp.mean(node.trajectory(p, y0, ts) ** 2))(params)
    assert all(float(jnp.abs(x).max()) == 0.0
               for x in jax.tree_util.tree_leaves(g))


# ---------------------------------------------------------------------------
# end-to-end: fit() on the fused substrate tracks the digital adjoint
# ---------------------------------------------------------------------------

def test_fit_fused_backend_matches_digital_loss_trajectory():
    """The ISSUE acceptance: fit() trains the HP twin with
    backend='fused_pallas' and the loss trajectory matches the
    digital-adjoint run to <=1e-3 rel."""
    from repro.data import hp_memristor as hp
    from repro.train import trainer
    from repro.train.optimizer import adam

    ts, xs, _, _ = hp.generate("sine", num_points=500, dt=1e-3,
                               amp=2.0, freq=2.0)
    ys = xs[:, None]
    twin = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=2.0, freq=2.0),
                            hidden=14)
    params = twin.init(jax.random.PRNGKey(42))
    steps = 40
    _, h_dig = trainer.train_twin(
        twin, params, ts, ys, optimizer=adam(1e-3), num_steps=steps,
        segment_len=50, loss="l1", noise_std=0.002,
        key=jax.random.PRNGKey(1))
    _, h_fus = trainer.train_twin(
        twin, params, ts, ys, optimizer=adam(1e-3), num_steps=steps,
        segment_len=50, loss="l1", noise_std=0.002,
        key=jax.random.PRNGKey(1),
        backend=FusedPallasBackend(precision="f32"))
    rel = jnp.abs(h_fus - h_dig) / (jnp.abs(h_dig) + 1e-12)
    assert float(rel.max()) < 1e-3


def test_fit_fused_backend_softdtw_loss():
    """The kernelised soft-DTW objective (wavefront forward + E-matrix
    backward) trains on the fused substrate and tracks the digital run."""
    from repro.data import hp_memristor as hp
    from repro.train import trainer
    from repro.train.optimizer import adam

    ts, xs, _, _ = hp.generate("sine", num_points=200, dt=1e-3,
                               amp=2.0, freq=2.0)
    ys = xs[:, None]
    twin = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=2.0, freq=2.0),
                            hidden=14)
    params = twin.init(jax.random.PRNGKey(42))
    _, h_dig = trainer.train_twin(
        twin, params, ts, ys, optimizer=adam(1e-3), num_steps=6,
        segment_len=40, loss="l1+softdtw", gamma=0.1,
        key=jax.random.PRNGKey(1))
    _, h_fus = trainer.train_twin(
        twin, params, ts, ys, optimizer=adam(1e-3), num_steps=6,
        segment_len=40, loss="l1+softdtw", gamma=0.1,
        key=jax.random.PRNGKey(1),
        backend=FusedPallasBackend(precision="f32"))
    rel = jnp.abs(h_fus - h_dig) / (jnp.abs(h_dig) + 1e-12)
    assert float(rel.max()) < 1e-3


def test_fit_fused_backend_honours_solver_config():
    """The fused training loss must respect the twin's solver config:
    steps_per_interval densifies the segment grid (parity vs digital),
    and a non-RK4 method raises instead of silently coarsening."""
    from repro.data import hp_memristor as hp
    from repro.train import trainer
    from repro.train.optimizer import adam

    ts, xs, _, _ = hp.generate("sine", num_points=150, dt=1e-3,
                               amp=2.0, freq=2.0)
    ys = xs[:, None]
    twin = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=2.0, freq=2.0),
                            hidden=14, steps_per_interval=3)
    params = twin.init(jax.random.PRNGKey(42))
    _, h_dig = trainer.train_twin(
        twin, params, ts, ys, optimizer=adam(1e-3), num_steps=5,
        segment_len=30, loss="l1", key=jax.random.PRNGKey(1))
    _, h_fus = trainer.train_twin(
        twin, params, ts, ys, optimizer=adam(1e-3), num_steps=5,
        segment_len=30, loss="l1", key=jax.random.PRNGKey(1),
        backend=FusedPallasBackend(precision="f32"))
    rel = jnp.abs(h_fus - h_dig) / (jnp.abs(h_dig) + 1e-12)
    assert float(rel.max()) < 1e-3

    twin5 = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=2.0, freq=2.0),
                             hidden=14, method="dopri5")
    with pytest.raises(ValueError, match="RK4"):
        trainer.train_twin(twin5, params, ts, ys, optimizer=adam(1e-3),
                           num_steps=1, segment_len=30,
                           backend="fused_pallas")


# ---------------------------------------------------------------------------
# mixed precision: reduced-storage substrate still trains
# ---------------------------------------------------------------------------

def test_fused_vjp_bf16_matches_f32_gradients():
    """bf16_f32acc gradients: bf16 slabs + f32 accumulators must land
    within ~bf16 rounding of the f32-substrate gradients, and come back
    as f32 arrays (the accumulators never round on the way out)."""
    params = mlp_init(KEY, (2, 14, 14, 1))
    T, B = 23, 8
    ts = jnp.linspace(0.0, 0.23, T + 1)
    uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    y0 = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 21), (B, 1))
    dt = float(ts[1] - ts[0])

    def loss(p, prec):
        traj = ops.fused_node_rollout(p, y0, uh, dt, batch_tile=4,
                                      time_chunk=5, precision=prec)
        return jnp.mean(traj.astype(jnp.float32) ** 2)

    g32 = jax.grad(lambda p: loss(p, "f32"))(params)
    gbf = jax.grad(lambda p: loss(p, "bf16_f32acc"))(params)
    assert all(x.dtype == jnp.float32
               for x in jax.tree_util.tree_leaves(gbf))
    assert _tree_max_rel(gbf, g32) < 2e-2


def test_fused_vjp_bf16_matches_finite_differences():
    """The ISSUE gate: bf16_f32acc fused-VJP directional derivative vs
    central differences OF THE SAME reduced-precision loss."""
    params = mlp_init(KEY, (2, 14, 14, 1))
    T, B = 23, 4
    ts = jnp.linspace(0.0, 0.23, T + 1)
    uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    y0 = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 22), (B, 1))
    dt = float(ts[1] - ts[0])

    def loss(p):
        traj = ops.fused_node_rollout(p, y0, uh, dt, batch_tile=4,
                                      time_chunk=5,
                                      precision="bf16_f32acc")
        return jnp.mean(traj.astype(jnp.float32) ** 2)

    gp = jax.grad(loss)(params)
    norm = jnp.sqrt(sum(jnp.sum(x ** 2)
                        for x in jax.tree_util.tree_leaves(gp)))
    v = jax.tree_util.tree_map(lambda x: x / norm, gp)
    # eps larger than the f32 test: the bf16-stored loss is itself only
    # ~3 decimal digits deep, so the FD noise floor sits higher
    eps = 3e-2
    shift = lambda s: jax.tree_util.tree_map(lambda p_, v_: p_ + s * v_,
                                             params, v)
    fd = (loss(shift(eps)) - loss(shift(-eps))) / (2 * eps)
    assert abs(float(fd) - float(norm)) / (abs(float(fd)) + 1e-12) < 3e-2


def test_fit_bf16_tracks_f32_loss_trajectory():
    """The ISSUE acceptance: fit on the bf16_f32acc substrate tracks the
    f32-substrate loss trajectory within 5e-2 rel (measured ~1.4e-2) and
    genuinely descends."""
    from repro.core.backends import FusedPallasBackend
    from repro.data import hp_memristor as hp
    from repro.train import trainer
    from repro.train.optimizer import adam

    ts, xs, _, _ = hp.generate("sine", num_points=500, dt=1e-3,
                               amp=2.0, freq=2.0)
    ys = xs[:, None]
    twin = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=2.0, freq=2.0),
                            hidden=14)
    params = twin.init(jax.random.PRNGKey(42))
    steps = 40
    _, h32 = trainer.train_twin(
        twin, params, ts, ys, optimizer=adam(1e-3), num_steps=steps,
        segment_len=50, loss="l1", noise_std=0.002,
        key=jax.random.PRNGKey(1),
        backend=FusedPallasBackend(precision="f32"))
    _, hbf = trainer.train_twin(
        twin, params, ts, ys, optimizer=adam(1e-3), num_steps=steps,
        segment_len=50, loss="l1", noise_std=0.002,
        key=jax.random.PRNGKey(1),
        backend=FusedPallasBackend(precision="bf16_f32acc"))
    rel = jnp.abs(hbf - h32) / (jnp.abs(h32) + 1e-12)
    assert float(rel.max()) < 5e-2
    assert float(hbf[-1]) < 0.5 * float(hbf[0])


def test_fit_bf16_softdtw_objective_descends():
    """End-to-end reduced precision incl. the kernelised soft-DTW loss
    (bf16 cost slab, f32 E-matrix carries): the objective must descend
    and stay finite."""
    from repro.core.backends import FusedPallasBackend
    from repro.data import hp_memristor as hp
    from repro.train import trainer
    from repro.train.optimizer import adam

    ts, xs, _, _ = hp.generate("sine", num_points=200, dt=1e-3,
                               amp=2.0, freq=2.0)
    ys = xs[:, None]
    twin = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=2.0, freq=2.0),
                            hidden=14)
    params = twin.init(jax.random.PRNGKey(42))
    _, h = trainer.train_twin(
        twin, params, ts, ys, optimizer=adam(1e-3), num_steps=12,
        segment_len=40, loss="l1+softdtw", gamma=0.1,
        key=jax.random.PRNGKey(1),
        backend=FusedPallasBackend(precision="bf16_f32acc"))
    assert bool(jnp.isfinite(h).all())
    assert float(h[-1]) < float(h[0])


# ---------------------------------------------------------------------------
# soft-DTW: kernelised E-matrix backward vs autodiff of the reference DP
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,d,gamma", [
    (1, 1, 1, 1.0),
    (5, 5, 1, 0.5),
    (40, 60, 2, 0.5),
    (300, 200, 1, 1.0),       # multi-chunk reverse sweep (n+m-1 > 256)
])
def test_softdtw_kernel_backward_matches_ref_autodiff(n, m, d, gamma):
    kx, ky = jax.random.split(jax.random.fold_in(KEY, n * m + d))
    x = jax.random.normal(kx, (2, n, d))
    y = jax.random.normal(ky, (2, m, d))

    def k_loss(a, b):
        return ops.soft_dtw(a, b, gamma, True, "f32").sum()

    def r_loss(a, b):
        return jax.vmap(lambda p, q: soft_dtw_jnp(p, q, gamma))(a, b).sum()

    gkx, gky = jax.grad(k_loss, argnums=(0, 1))(x, y)
    grx, gry = jax.grad(r_loss, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gkx, grx, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gky, gry, rtol=1e-3, atol=1e-4)


def test_softdtw_e_matrix_matches_numpy_oracle():
    """The wavefront E-matrix kernel vs the float64 numpy reverse DP."""
    from repro.core.losses import _pairwise_dist
    from repro.kernels.ops import (_diag_layout_batch, _sdtw_chunk,
                                   _undiag_batch)
    from repro.kernels.softdtw import softdtw_bwd_pallas, softdtw_pallas
    n, m, gamma = 17, 23, 0.7
    x = jax.random.normal(KEY, (1, n, 2))
    y = jax.random.normal(jax.random.fold_in(KEY, 3), (1, m, 2))
    D = jax.vmap(_pairwise_dist)(x, y)
    chunk = _sdtw_chunk(n, m)
    dd = _diag_layout_batch(D, chunk)
    _, rd = softdtw_pallas(dd, n, m, gamma=gamma, chunk=chunk, return_r=True)
    e_dd = softdtw_bwd_pallas(dd, rd, n, m, gamma=gamma, chunk=chunk)
    E = _undiag_batch(e_dd, n, m)[0]
    E_ref = ref.softdtw_grad_ref(D[0], gamma)
    np.testing.assert_allclose(np.asarray(E), E_ref, rtol=1e-4, atol=1e-5)


def test_softdtw_e_matrix_rows_sum_like_alignment():
    """E is a soft alignment: entries are non-negative and the total mass
    is at least 1 path's worth (monotone-path property of soft-DTW)."""
    from repro.core.losses import _pairwise_dist
    from repro.kernels.ops import (_diag_layout_batch, _sdtw_chunk,
                                   _undiag_batch)
    from repro.kernels.softdtw import softdtw_bwd_pallas, softdtw_pallas
    n, m = 24, 31
    x = jax.random.normal(KEY, (1, n, 2))
    y = jax.random.normal(jax.random.fold_in(KEY, 1), (1, m, 2))
    D = jax.vmap(_pairwise_dist)(x, y)
    chunk = _sdtw_chunk(n, m)
    dd = _diag_layout_batch(D, chunk)
    _, rd = softdtw_pallas(dd, n, m, gamma=0.5, chunk=chunk,
                           return_r=True)
    e_dd = softdtw_bwd_pallas(dd, rd, n, m, gamma=0.5, chunk=chunk)
    E = _undiag_batch(e_dd, n, m)[0]
    assert float(E.min()) >= 0.0
    assert float(E[-1, -1]) == pytest.approx(1.0, abs=1e-5)
    # every anti-diagonal of a (soft) monotone alignment carries mass >= 1
    # wherever the path must cross; check the corners chain up
    assert float(E[0, 0]) > 0.9
