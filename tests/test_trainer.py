"""Training-engine equivalence: the scan-compiled fit() must reproduce
the per-step reference loop step for step (same seeds -> same params)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.twin import make_driven_twin
from repro.data import hp_memristor as hp
from repro.train import trainer
from repro.train.optimizer import adam, sgd, warmup_cosine_schedule

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def hp_losses():
    """The HP-twin recipe's two loss phases (pretrain + trajectory)."""
    ts, xs, _, _ = hp.generate("sine", num_points=500, dt=1e-3,
                               amp=2.0, freq=2.0)
    ys = xs[:, None]
    twin = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=2.0, freq=2.0),
                            hidden=14)
    params = twin.init(jax.random.PRNGKey(42))
    tsm, ysm, dys = trainer.finite_difference_derivatives(ts, ys)
    pre_loss = trainer.derivative_matching_loss(twin.field, tsm, ysm, dys)
    ts_seg, ys_seg = trainer.make_segments(ts, ys, 50)
    traj_loss = trainer.segment_loss_fn(twin, ts_seg, ys_seg, "l1",
                                        noise_std=0.002)
    return params, pre_loss, traj_loss


def _assert_trees_close(a, b, rtol=1e-4, atol=1e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("scan_chunk", [None, 1, 37, 200])
def test_fit_equals_per_step_reference(hp_losses, scan_chunk):
    """Same seeds -> same final params, for any chunking of the scan
    (including a partial final chunk: 200 steps, chunk 37)."""
    params, pre_loss, _ = hp_losses
    steps = 200
    p_scan, h_scan = trainer.fit(pre_loss, params, adam(1e-2), steps,
                                 jax.random.PRNGKey(1),
                                 scan_chunk=scan_chunk)
    p_ref, h_ref = trainer.fit_per_step(pre_loss, params, adam(1e-2), steps,
                                        jax.random.PRNGKey(1))
    _assert_trees_close(p_scan, p_ref)
    assert h_scan.shape == h_ref.shape == (steps,)
    np.testing.assert_allclose(h_scan, h_ref, rtol=1e-4, atol=1e-6)


def test_fit_equals_per_step_on_trajectory_loss(hp_losses):
    """The noise-regularised multiple-shooting phase: the PRNG key must be
    split in exactly the same order inside the scan as in the loop."""
    params, _, traj_loss = hp_losses
    steps = 20
    p_scan, _ = trainer.fit(traj_loss, params, adam(1e-3), steps,
                            jax.random.PRNGKey(2), scan_chunk=7)
    p_ref, _ = trainer.fit_per_step(traj_loss, params, adam(1e-3), steps,
                                    jax.random.PRNGKey(2))
    _assert_trees_close(p_scan, p_ref)


@pytest.mark.parametrize("scan_chunk", [None, 1, 7])
def test_fit_input_noise_reproducible_across_chunkings(hp_losses,
                                                       scan_chunk):
    """The ``noise_std > 0`` y0-jitter draws its per-step subkey INSIDE
    the scan body, so the noise sequence is a function of (seed, step)
    only: any chunking — including chunk=1 — reproduces the per-step
    reference loop to float32 rounding (the jitter draws are identical;
    scan and per-step compile to different programs, so the loss
    reduction may fuse differently by ~1 ulp)."""
    params, _, traj_loss = hp_losses
    steps = 15
    _, h = trainer.fit(traj_loss, params, adam(1e-3), steps,
                       jax.random.PRNGKey(3), scan_chunk=scan_chunk)
    _, h_ref = trainer.fit_per_step(traj_loss, params, adam(1e-3), steps,
                                    jax.random.PRNGKey(3))
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-6, atol=1e-8)


def test_fit_input_noise_same_seed_bitwise_repeatable(hp_losses):
    """Same seed, same chunking, run twice: bitwise-identical loss
    history and final params (the noise path adds no hidden state)."""
    params, _, traj_loss = hp_losses
    runs = [trainer.fit(traj_loss, params, adam(1e-3), 10,
                        jax.random.PRNGKey(4), scan_chunk=4)
            for _ in range(2)]
    (p1, h1), (p2, h2) = runs
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    _assert_trees_close(p1, p2, rtol=0, atol=0)
    # and a different seed actually changes the noise draws
    _, h3 = trainer.fit(traj_loss, params, adam(1e-3), 10,
                        jax.random.PRNGKey(5), scan_chunk=4)
    assert not np.array_equal(np.asarray(h1), np.asarray(h3))


def test_fit_keyless_and_schedule(hp_losses):
    """key=None path (no PRNG in the carry) + a stateful LR schedule."""
    params, pre_loss, _ = hp_losses
    opt = lambda: adam(warmup_cosine_schedule(1e-2, 10, 60))
    p_scan, h_scan = trainer.fit(pre_loss, params, opt(), 60, None,
                                 scan_chunk=25)
    p_ref, h_ref = trainer.fit_per_step(pre_loss, params, opt(), 60, None)
    _assert_trees_close(p_scan, p_ref)
    np.testing.assert_allclose(h_scan, h_ref, rtol=1e-4, atol=1e-6)


def test_fit_sgd_momentum_state_carried(hp_losses):
    """Non-NamedTuple optimizer state (sgd's (step, vel) tuple) must
    survive the scan carry."""
    params, pre_loss, _ = hp_losses
    p_scan, _ = trainer.fit(pre_loss, params, sgd(1e-3, momentum=0.9), 30,
                            None, scan_chunk=8)
    p_ref, _ = trainer.fit_per_step(pre_loss, params,
                                    sgd(1e-3, momentum=0.9), 30, None)
    _assert_trees_close(p_scan, p_ref)


def test_fit_zero_steps(hp_losses):
    params, pre_loss, _ = hp_losses
    p, hist = trainer.fit(pre_loss, params, adam(1e-2), 0)
    assert hist.shape == (0,)
    _assert_trees_close(p, params, rtol=0, atol=0)


def test_fit_logging_syncs_only_at_chunk_boundaries(hp_losses, capsys):
    """Logging comes from the chunk's stacked loss array (no per-step
    float(loss) sync) and still prints the same step lines."""
    params, pre_loss, _ = hp_losses
    trainer.fit(pre_loss, params, adam(1e-2), 45, None, log_every=20,
                scan_chunk=30)
    out = capsys.readouterr().out
    for step in (0, 20, 40, 44):
        assert f"step {step:5d}" in out


def test_fit_does_not_invalidate_caller_params(hp_losses):
    """fit() copies before donating: the caller's params stay usable."""
    params, pre_loss, _ = hp_losses
    before = jax.tree_util.tree_map(np.asarray, params)
    trainer.fit(pre_loss, params, adam(1e-2), 5)
    _assert_trees_close(params, before, rtol=0, atol=0)
