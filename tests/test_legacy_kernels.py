"""Parity tests for the LEGACY LM-era kernels (repro.kernels.legacy).

These kernels are technique references only — nothing in the
twin/fleet/analogue pipeline uses them; see the legacy package
docstring.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


# ---------------------------------------------------------------------------
# state-resident SSM scan (Mamba recurrence)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bsz,s,di,n,d_tile", [
    (1, 8, 16, 4, 16), (2, 32, 64, 16, 32), (1, 64, 128, 16, 128),
])
def test_ssm_scan_matches_ref(bsz, s, di, n, d_tile):
    from repro.kernels.legacy.ssm_scan import ssm_scan, ssm_scan_ref
    key = jax.random.PRNGKey(di + s)
    ks = jax.random.split(key, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (bsz, s, di))) * 0.1
    b = jax.random.normal(ks[1], (bsz, s, n))
    c = jax.random.normal(ks[2], (bsz, s, n))
    x = jax.random.normal(ks[3], (bsz, s, di))
    a = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.3)
    yk, hk = ssm_scan(dt, b, c, x, a, d_tile=d_tile)
    yr, hr = ssm_scan_ref(dt, b, c, x, a)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), rtol=1e-5,
                               atol=1e-5)


def test_ssm_scan_matches_mamba_prefill_core():
    """The kernel must agree with the model's chunked-scan mamba path."""
    from repro.kernels.legacy.ssm_scan import ssm_scan
    from repro.models.mamba import MambaConfig, mamba_init, mamba_prefill
    cfg = MambaConfig(d_model=32, d_state=4, d_conv=4, expand=2, chunk=8)
    params = mamba_init(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out_model, state = mamba_prefill(params, cfg, u)
    # recompute y via the kernel on the same intermediate quantities
    import repro.models.mamba as M
    xz = u @ params["in_proj"]
    x_, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(M._causal_conv(params, cfg, x_))
    dt, b_, c_ = M._dbc(params, cfg, xc)
    a = -jnp.exp(params["A_log"])
    yk, hk = ssm_scan(dt, b_, c_, xc.astype(jnp.float32), a, d_tile=64)
    y = yk + params["D"] * xc.astype(jnp.float32)
    y = y.astype(u.dtype) * jax.nn.silu(z)
    out_kernel = y @ params["out_proj"]
    np.testing.assert_allclose(np.asarray(out_kernel),
                               np.asarray(out_model), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(state["ssm"]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused causal flash attention (VMEM-resident accumulator)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,s,d,bq,bk", [
    (1, 2, 2, 32, 16, 16, 16),
    (2, 4, 2, 64, 32, 32, 16),   # GQA group 2
    (1, 8, 2, 128, 64, 64, 64),  # GQA group 4
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_matches_ref(b, h, hkv, s, d, bq, bk, dtype):
    from repro.kernels.legacy.flash_attention import (
        flash_attention_pallas, flash_attention_pallas_ref)
    ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = flash_attention_pallas(q, k, v, bq=bq, bk=bk)
    ref = flash_attention_pallas_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_pallas_matches_model_flash():
    """Kernel vs the XLA flash schedule used by the models."""
    from repro.kernels.legacy.flash_attention import flash_attention_pallas
    from repro.models.flash import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, h, hkv, s, d = 1, 4, 2, 64, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    xla_out = flash_attention([q], [k], v, scale=d ** -0.5,
                              q_chunk=16, kv_chunk=16)
    kern_out = flash_attention_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                      v.swapaxes(1, 2), bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(kern_out.swapaxes(1, 2)),
                               np.asarray(xla_out), rtol=2e-5, atol=2e-5)
