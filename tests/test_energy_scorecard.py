"""Energy scorecard: paper anchors (CI-gated) + HLO-count plumbing."""
import pytest

from repro.core import energy, scorecard


# ---------------------------------------------------------------------------
# anchor gates — the paper's four headline ratios within 20%
# ---------------------------------------------------------------------------

def test_anchor_rows_within_tolerance():
    rows = scorecard.assert_anchors()          # raises on drift
    assert len(rows) == 4
    names = {(r["workload"], r["name"]) for r in rows}
    assert names == {
        ("hp", "speedup_vs_node_gpu"),
        ("hp", "energy_gain_vs_node_gpu"),
        ("lorenz96", "speed_gain_vs_node_gpu"),
        ("lorenz96", "energy_gain_vs_node_gpu"),
    }
    for r in rows:
        assert r["within_tol"] and r["rel_err"] <= scorecard.ANCHOR_TOL


def test_assert_anchors_raises_on_drift():
    rows = scorecard.anchor_rows()
    rows[0] = dict(rows[0], within_tol=False, rel_err=0.5)
    with pytest.raises(AssertionError, match="out of tolerance"):
        scorecard.assert_anchors(rows)


def test_project_from_macs_matches_project():
    """The factored digital projection must reproduce project() when fed
    the analytic MAC count."""
    for system, hidden in [("node_gpu", 64), ("resnet_gpu", 64),
                           ("lstm_gpu", 512)]:
        t_ref, e_ref = energy.project(system, hidden, in_dim=2, out_dim=1,
                                      n_layers=3, n_steps=500)
        sizes = [2, hidden, hidden, 1]
        if system == "node_gpu":
            macs = sum(a * b for a, b in zip(sizes[:-1], sizes[1:])) * 4 * 500
        elif system == "resnet_gpu":
            macs = sum(a * b for a, b in zip(sizes[:-1], sizes[1:])) * 500
        else:
            macs = 4.0 * hidden * (hidden + 2) * 500
        t, e = energy.project_from_macs(system, macs, hidden, 500)
        assert t == pytest.approx(t_ref)
        assert e == pytest.approx(e_ref)


def test_project_from_macs_rejects_analogue():
    with pytest.raises(ValueError, match="digital"):
        energy.project_from_macs("analogue_node", 1e6, 64, 500)


# ---------------------------------------------------------------------------
# HLO plumbing — small sizes, all four backends
# ---------------------------------------------------------------------------

def test_backend_rows_small_plumbing():
    """Compile + parse every registered substrate at plumbing size; the
    digital backend's measured MACs must equal the analytic count
    exactly, and the analogue simulator's must show the differential
    pair's ~2x."""
    rows = scorecard.backend_rows(workloads=[scorecard.HP], hidden=16,
                                  n_steps=10)
    by_name = {r["backend"]: r for r in rows}
    assert set(by_name) == {"digital", "analogue", "fused_pallas",
                            "analogue_fused"}
    dig = by_name["digital"]
    assert dig["hlo"]["macs"] == pytest.approx(dig["model_macs"])
    ana = by_name["analogue"]
    assert ana["hlo"]["macs"] > 1.5 * ana["model_macs"]
    for r in rows:
        assert r["projected"]["time_us"] > 0
        assert r["projected"]["energy_uj"] > 0
        assert r["substrate"] == scorecard.BACKEND_SUBSTRATE[r["backend"]]
    # analogue substrates project from array physics -> identical rows
    assert (by_name["analogue"]["projected"]
            == by_name["analogue_fused"]["projected"])


def test_scorecard_shape_without_measurement():
    sc = scorecard.scorecard(measure=False)
    assert len(sc["anchors"]) == 4
    assert len(sc["backends"]) == 2 * len(scorecard.BACKEND_SUBSTRATE)
    for r in sc["backends"]:
        assert "hlo" not in r and "projected" in r


def test_workload_definitions_match_paper():
    assert scorecard.HP.mlp_sizes() == (2, 64, 64, 1)
    assert scorecard.HP.n_steps == 500
    assert scorecard.LORENZ96.mlp_sizes() == (6, 512, 512, 6)
    assert scorecard.LORENZ96.n_steps == 1800
