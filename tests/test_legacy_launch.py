"""Quarantined LM-era launch modules (``repro.launch.legacy``): pipeline
parallelism and the LM train driver.  Kept runnable — same contract as
``tests/test_legacy_kernels.py`` for the PR-6 kernel quarantine — but the
twin-serving stack no longer imports them."""
import os
import subprocess
import sys
import textwrap

from repro.train import checkpoint as ckpt

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Pipeline parallelism
# ---------------------------------------------------------------------------

def test_pipeline_matches_sequential_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.legacy.pipeline import make_pipeline_forward

        n_stages, n_micro, mb, d = 4, 8, 2, 16
        mesh = jax.make_mesh((4,), ("pipe",))
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) / d ** 0.5

        def block(w, x):
            return jnp.tanh(x @ w)

        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
        fwd = make_pipeline_forward(block, n_stages, n_micro, mesh)
        y_pipe = fwd(ws, x)

        y_ref = x
        for s in range(n_stages):
            y_ref = block(ws[s], y_ref)
        np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)

        # gradients flow through ppermute
        g = jax.grad(lambda w: fwd(w, x).sum())(ws)
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree_util.tree_leaves(g))
        print("PIPELINE_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ,
                                        "PYTHONPATH": f"{REPO}/src"})
    assert "PIPELINE_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])


# ---------------------------------------------------------------------------
# Train driver end-to-end (resume-after-preemption semantics)
# ---------------------------------------------------------------------------

def test_train_driver_runs_and_resumes(tmp_path):
    from repro.launch.legacy.train import main as train_main
    args = ["--arch", "qwen3-1.7b", "--smoke", "--steps", "6",
            "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "3", "--log-every", "100"]
    losses1 = train_main(args)
    assert ckpt.latest_step(str(tmp_path)) == 6
    # resume: should continue from step 6 (no steps left -> quick exit)
    losses2 = train_main([*args[:-6], "--ckpt-dir", str(tmp_path),
                          "--ckpt-every", "3", "--log-every", "100"])
    assert len(losses1) == 6
