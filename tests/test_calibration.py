"""Hardware-in-the-loop calibration hooks: a measured device-constants
JSON round-trips into AnalogueSpec / ConductanceDrift / EnergyConstants,
and every validation error names the offending field."""
import dataclasses
import json

import pytest

from repro.core import energy
from repro.core.analogue import (AnalogueSpec, drift_from_calibration,
                                 load_calibration, spec_from_calibration)

GOOD = {
    "schema": 1,
    "source": "bench-top characterisation of array A7",
    "device": {
        "g_off_S": 18e-6,
        "g_on_S": 95e-6,
        "levels": 32,
        "prog_noise_sigma": 0.05,
        "read_noise_sigma": 0.013,
        "v_clamp": None,
    },
    "drift": {"nu": 0.02, "tau": 500.0},
    "energy": {"t_settle_us": 6.0e-3, "p_base_w": 1.2},
}


@pytest.fixture()
def cal_file(tmp_path):
    p = tmp_path / "device.json"
    p.write_text(json.dumps(GOOD))
    return str(p)


def test_calibration_roundtrip_spec(cal_file):
    spec = spec_from_calibration(cal_file)
    assert spec == AnalogueSpec(g_min=18e-6, g_max=95e-6, levels=32,
                                prog_noise=0.05, read_noise=0.013,
                                v_clamp=None)
    # overrides apply after the measured values
    spec2 = spec_from_calibration(cal_file, read_noise=0.0)
    assert spec2.read_noise == 0.0 and spec2.levels == 32


def test_calibration_roundtrip_drift(cal_file):
    drift = drift_from_calibration(cal_file)
    assert drift is not None
    assert (drift.nu, drift.tau) == (0.02, 500.0)
    no_drift = dict(GOOD)
    no_drift.pop("drift")
    assert drift_from_calibration(no_drift) is None


def test_calibration_roundtrip_energy(cal_file):
    c = energy.constants_from_calibration(cal_file)
    # measured fields land, missing ones keep the paper-calibrated values
    assert c.t_settle_us == 6.0e-3 and c.p_base_w == 1.2
    assert c.v_read == energy.DEFAULT_CONSTANTS.v_read
    t_cal, e_cal = energy.project("analogue_node", 64, constants=c)
    t_def, e_def = energy.project("analogue_node", 64)
    # the measured (slower, cheaper-peripheral) device moves the projection
    assert t_cal == pytest.approx(t_def * 6.0e-3 / energy.T_SETTLE_US)
    assert e_cal != e_def
    # digital systems ignore the analogue constants
    assert (energy.project("node_gpu", 64, constants=c)
            == energy.project("node_gpu", 64))


def test_paper_device_file_matches_defaults():
    """The committed reference file IS the paper's device: same spec as
    the AnalogueSpec defaults (modulo the read-noise sweep point) and the
    same energy constants as the calibrated module defaults."""
    spec = spec_from_calibration("calibration/paper_device.json")
    assert dataclasses.replace(spec, read_noise=0.0) == AnalogueSpec()
    assert spec.read_noise == 0.02   # top of the paper's Fig. 4j sweep
    c = energy.constants_from_calibration("calibration/paper_device.json")
    assert c == energy.DEFAULT_CONSTANTS


@pytest.mark.parametrize("mutate, needle", [
    (lambda c: c.update(schema=2), "schema"),
    (lambda c: c.pop("device"), "'device'"),
    (lambda c: c["device"].pop("g_on_S"), "device.g_on_S"),
    (lambda c: c["device"].update(g_on_S=1e-6), "device.g_on_S"),
    (lambda c: c["device"].update(g_off_S=-2e-6), "device.g_off_S"),
    (lambda c: c["device"].update(levels=63.5), "device.levels"),
    (lambda c: c["device"].update(levels=1), "device.levels"),
    (lambda c: c["device"].update(prog_noise_sigma=-0.1),
     "device.prog_noise_sigma"),
    (lambda c: c["device"].update(read_noise_sigma="high"),
     "device.read_noise_sigma"),
    (lambda c: c["device"].update(g_onS=1e-4), "device.g_onS"),
    (lambda c: c["drift"].pop("tau"), "drift.tau"),
    (lambda c: c["drift"].update(tau=0.0), "drift.tau"),
    (lambda c: c["energy"].update(p_base_w=0), "energy.p_base_w"),
    (lambda c: c.update(extras={}), "extras"),
])
def test_calibration_errors_name_offending_field(mutate, needle):
    cal = json.loads(json.dumps(GOOD))   # deep copy
    mutate(cal)
    with pytest.raises(ValueError, match="calibration") as ei:
        load_calibration(cal)
    assert needle in str(ei.value)


def test_calibration_invalid_json_names_file(tmp_path):
    p = tmp_path / "broken.json"
    p.write_text("{not json")
    with pytest.raises(ValueError, match="invalid JSON"):
        load_calibration(str(p))


def test_energy_constants_validate_fields():
    with pytest.raises(ValueError, match="EnergyConstants.v_read"):
        energy.EnergyConstants(v_read=0.0)
