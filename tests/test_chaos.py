"""Crash-safety: the journal/snapshot recovery path under injected
process deaths at every kill point, on every substrate tier.

The load-bearing claim (docs/robustness.md): kill the serving process at
ANY instrumented point — mid-pump, mid-scatter, mid-eviction,
mid-snapshot publish, mid-journal-append — and
``StreamingFleetServer.recover`` + re-feeding the unsubmitted trace
suffix produces carried states and completion sets **bitwise equal**
(f32) to a run that never crashed.  The determinism contract makes this
provable: every time value and every analogue read-noise draw is keyed
by the twin's *global* step, so replayed windows recompute the crash-free
arithmetic exactly regardless of how batches re-form after recovery.

The kill-point x tier matrix tests carry "matrix" in their names so the
CI chaos-smoke step can select them (``-k "matrix and fused_f32"``).
"""
import functools
import os
import struct

import jax
import numpy as np
import pytest

import traffic
from repro.core.analogue import AnalogueSpec
from repro.core.backends import (DigitalBackend, FusedAnalogueBackend,
                                 FusedPallasBackend)
from repro.core.twin import TwinFleet, make_autonomous_twin
from repro.launch import chaos
from repro.launch import journal as journal_lib
from repro.launch.fleet_serving import StreamingFleetServer

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

DT = 0.01
DIM = 3

TIERS = {
    "digital": lambda: DigitalBackend(),
    "fused_f32": lambda: FusedPallasBackend(precision="f32"),
    "analogue_fused": lambda: FusedAnalogueBackend(
        spec=AnalogueSpec(read_noise=0.02),
        prog_key=jax.random.PRNGKey(7)),
}


@functools.lru_cache(maxsize=None)
def _fleet(tier: str):
    twin = make_autonomous_twin(state_dim=DIM, hidden=8, n_hidden_layers=1,
                                backend=TIERS[tier]())
    params = twin.init(jax.random.PRNGKey(0))
    return TwinFleet(twin=twin), params


def _y0_of(tid):
    return (np.random.default_rng(100 + tid).normal(size=DIM)
            .astype(np.float32) * 0.1)


_KW = dict(dt=DT, hot_capacity=4, max_batch=4, max_window=8,
           horizon_quantum=4)


def _trace(seed=0, n=16):
    return traffic.poisson_trace(seed, n, population=6, max_horizon=10)


@functools.lru_cache(maxsize=None)
def _reference(tier: str, seed: int = 0, n: int = 16):
    """Crash-free run: per-twin (state, step) + completion seq set."""
    fleet, params = _fleet(tier)
    server = StreamingFleetServer(fleet, params, **_KW)
    done = server.serve_trace(_trace(seed, n), y0_of=_y0_of)
    ids, _, _, _ = server.store.export_state()
    states = {tid: server.store.peek(tid) for tid in ids}
    return server, done, states


def _crash_recover_cycle(tier, kill, hit, tmp_path, seed=0, n=16,
                         snapshot_every=3):
    """Run the trace with ``kill`` armed; on crash, recover + resume.
    Returns (recovered_server, all_completions) — or (None, None) if the
    kill point never fired on this schedule (caller decides if that's
    acceptable)."""
    fleet, params = _fleet(tier)
    trace = _trace(seed, n)
    d = str(tmp_path)
    live = StreamingFleetServer(fleet, params, durability_dir=d,
                                snapshot_every=snapshot_every, **_KW)
    delivered = []           # completions the "client" received pre-crash
    fired = False
    try:
        with chaos.crash_at(kill, hit=hit):
            live.serve_trace(trace, y0_of=_y0_of, sink=delivered)
    except chaos.SimulatedCrash:
        fired = True
    if not fired:
        return None, None
    rec, redelivered = StreamingFleetServer.recover(d, fleet, params)
    resumed = rec.serve_trace(trace, y0_of=_y0_of,
                              start=rec.stream_stats.enqueued)
    # at-least-once delivery: redelivered may overlap what the client
    # already saw (commits after the last snapshot, before the crash)
    return rec, delivered + list(redelivered) + list(resumed)


def _assert_parity(tier, rec, got, seed=0, n=16):
    _, ref_done, ref_states = _reference(tier, seed, n)
    assert {c.seq for c in got} == {c.seq for c in ref_done}, \
        "completion sets differ after recovery"
    for tid, (y_ref, s_ref) in ref_states.items():
        y_rec, s_rec = rec.store.peek(tid)
        assert s_rec == s_ref, \
            f"twin {tid}: step {s_rec} != crash-free {s_ref}"
        np.testing.assert_array_equal(
            y_rec, y_ref,
            err_msg=f"twin {tid}: state not bitwise-equal after recovery")
    ref_traj = {c.seq: c.trajectory
                for c in sorted(ref_done, key=lambda c: c.seq)}
    for c in got:
        np.testing.assert_array_equal(
            c.trajectory, ref_traj[c.seq],
            err_msg=f"seq {c.seq}: redelivered trajectory differs")
    traffic.check_conservation(rec)


# ---------------------------------------------------------------------------
# The kill-point x tier matrix (CI selects these via -k "matrix")
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kill,hit", [
    ("pump:pre_commit", 2),
    ("pump:post_commit", 2),
    ("store:evict", 1),
    ("snapshot:pre_rename", 1),
    ("journal:torn_append", 5),
])
@pytest.mark.parametrize("tier", sorted(TIERS))
def test_chaos_matrix_recovery_parity(tier, kill, hit, tmp_path):
    """Crash at every kill point on every substrate tier: recovery +
    resume must be bitwise-equal (f32) to the crash-free run — states,
    steps, trajectories, and the exact completion set."""
    rec, got = _crash_recover_cycle(tier, kill, hit, tmp_path)
    assert rec is not None, \
        f"kill point {kill!r} (hit={hit}) never fired on this schedule"
    _assert_parity(tier, rec, got)


def test_chaos_matrix_seeded_random_points(tmp_path):
    """Seeded pseudo-random (kill, hit, trace-seed) draws — the
    always-run stand-in for the hypothesis property below."""
    rng = np.random.default_rng(42)
    kills = ["pump:pre_commit", "pump:post_commit", "journal:torn_append"]
    for i in range(4):
        kill = kills[int(rng.integers(len(kills)))]
        hit = int(rng.integers(1, 6))
        seed = int(rng.integers(100))
        d = tmp_path / f"case{i}"
        rec, got = _crash_recover_cycle("fused_f32", kill, hit, d,
                                        seed=seed)
        if rec is None:
            continue                 # hit too deep for this schedule
        _assert_parity("fused_f32", rec, got, seed=seed)


if HAVE_HYPOTHESIS:
    @given(data=st.data())
    @settings(max_examples=8, deadline=None)
    def test_chaos_property_any_crash_recovers(data, tmp_path_factory):
        kill = data.draw(st.sampled_from(list(chaos.KILL_POINTS)))
        hit = data.draw(st.integers(1, 8))
        seed = data.draw(st.integers(0, 50))
        d = tmp_path_factory.mktemp("chaos")
        rec, got = _crash_recover_cycle("fused_f32", kill, hit, d,
                                        seed=seed)
        if rec is None:
            return                   # kill never fired: vacuously safe
        _assert_parity("fused_f32", rec, got, seed=seed)


# ---------------------------------------------------------------------------
# Journal mechanics
# ---------------------------------------------------------------------------

def test_journal_round_trip(tmp_path):
    p = tmp_path / "journal.wal"
    j = journal_lib.Journal(p)
    recs = [{"t": "submit", "seq": i, "id": i % 3, "h": 4,
             "ta": 0.1 * i, "dl": None} for i in range(7)]
    for r in recs:
        j.append(r)
    j.close()
    back, valid, torn = journal_lib.read_journal(p)
    assert back == recs and torn == 0
    assert valid == os.path.getsize(p)


def test_journal_torn_tail_truncated_on_reopen(tmp_path):
    """A partial trailing frame (mid-write death) is invisible to the
    reader and physically truncated on reopen; appends then continue."""
    p = tmp_path / "journal.wal"
    j = journal_lib.Journal(p)
    j.append({"t": "submit", "seq": 0})
    j.append({"t": "commit", "seqs": [0]})
    j.close()
    whole = os.path.getsize(p)
    with open(p, "ab") as f:                # torn half-frame on the tail
        f.write(struct.pack("<II", 999, 12345) + b'{"t":"sub')
    back, valid, torn = journal_lib.read_journal(p)
    assert len(back) == 2 and valid == whole and torn > 0
    j2 = journal_lib.Journal(p)
    assert j2.torn_bytes_dropped == torn
    assert os.path.getsize(p) == whole      # tail physically removed
    j2.append({"t": "submit", "seq": 1})
    j2.close()
    back2, _, torn2 = journal_lib.read_journal(p)
    assert [r["t"] for r in back2] == ["submit", "commit", "submit"]
    assert torn2 == 0


def test_journal_crc_stops_at_corruption(tmp_path):
    """A flipped byte mid-file fails that frame's CRC: every record
    before it is served, everything after is dropped (the suffix cannot
    be trusted once framing is lost)."""
    p = tmp_path / "journal.wal"
    j = journal_lib.Journal(p)
    for i in range(5):
        j.append({"t": "submit", "seq": i})
    j.close()
    # find the byte offset of record 2 and flip one payload byte
    _, _, _ = journal_lib.read_journal(p)
    raw = bytearray(p.read_bytes())
    off = 0
    for _ in range(2):
        ln = struct.unpack_from("<I", raw, off)[0]
        off += 8 + ln
    raw[off + 8] ^= 0xFF
    p.write_bytes(bytes(raw))
    back, valid, torn = journal_lib.read_journal(p)
    assert [r["seq"] for r in back] == [0, 1]
    assert valid == off and torn == len(raw) - off


def test_journal_config_header_written_once(tmp_path):
    fleet, params = _fleet("fused_f32")
    d = str(tmp_path)
    server = StreamingFleetServer(fleet, params, durability_dir=d, **_KW)
    server.register_twin(0, np.zeros(DIM, np.float32))
    server._journal.close()
    recs, _, _ = journal_lib.read_journal(journal_lib.journal_path(d))
    assert recs[0]["t"] == "config" and recs[0]["schema"] == 1
    assert recs[0]["cfg"]["max_batch"] == _KW["max_batch"]
    assert recs[1]["t"] == "register"


def test_recover_refuses_fresh_server_on_history(tmp_path):
    """Constructing a FRESH server on a directory with journal history
    would fork that history — it must refuse and point at recover()."""
    fleet, params = _fleet("fused_f32")
    d = str(tmp_path)
    server = StreamingFleetServer(fleet, params, durability_dir=d, **_KW)
    server.register_twin(0, np.zeros(DIM, np.float32))
    server.submit(0, 4)
    server.drain()
    with pytest.raises(ValueError, match="recover"):
        StreamingFleetServer(fleet, params, durability_dir=d, **_KW)


# ---------------------------------------------------------------------------
# Snapshot atomicity
# ---------------------------------------------------------------------------

def test_snapshot_crash_before_rename_publishes_nothing(tmp_path):
    """A death after the snapshot tmp dir is fully written but before
    the atomic rename leaves NO published snapshot — recovery falls back
    to pure journal replay and still reaches parity."""
    fleet, params = _fleet("fused_f32")
    d = str(tmp_path)
    trace = _trace()
    live = StreamingFleetServer(fleet, params, durability_dir=d,
                                snapshot_every=3, **_KW)
    with pytest.raises(chaos.SimulatedCrash):
        with chaos.crash_at("snapshot:pre_rename"):
            live.serve_trace(trace, y0_of=_y0_of)
    assert journal_lib.load_latest_snapshot(d) is None
    rec, redelivered = StreamingFleetServer.recover(d, fleet, params)
    resumed = rec.serve_trace(trace, y0_of=_y0_of,
                              start=rec.stream_stats.enqueued)
    _assert_parity("fused_f32", rec, list(redelivered) + list(resumed))


def test_snapshot_damaged_newest_falls_back_to_older(tmp_path):
    """A corrupted newest snapshot is skipped: recovery loads the older
    valid one, replays the longer journal suffix, and still reaches
    bitwise parity."""
    fleet, params = _fleet("fused_f32")
    d = str(tmp_path)
    trace = _trace()
    live = StreamingFleetServer(fleet, params, durability_dir=d,
                                snapshot_every=2, **_KW)
    done = live.serve_trace(trace, y0_of=_y0_of)
    snap_root = os.path.join(d, journal_lib.SNAPSHOT_DIR)
    steps = sorted(int(s.split("_")[1]) for s in os.listdir(snap_root)
                   if s.startswith("step_") and ".tmp" not in s)
    assert len(steps) >= 2, "schedule produced fewer than 2 snapshots"
    newest = os.path.join(snap_root, f"step_{steps[-1]:010d}")
    arrs = [f for f in os.listdir(newest) if f.endswith(".npy")]
    with open(os.path.join(newest, arrs[0]), "r+b") as f:
        f.write(b"\x00" * 64)                       # corrupt arrays blob
    lsn, _, _ = journal_lib.load_latest_snapshot(d)
    assert lsn == steps[-2], "damaged newest snapshot was not skipped"
    rec, redelivered = StreamingFleetServer.recover(d, fleet, params)
    _assert_parity("fused_f32", rec, done + list(redelivered))


def test_recover_after_clean_run_is_parity(tmp_path):
    """Recovery is not crash-only: recovering a cleanly-finished
    directory reproduces the final state exactly and a further drain
    serves nothing."""
    fleet, params = _fleet("fused_f32")
    d = str(tmp_path)
    trace = _trace()
    live = StreamingFleetServer(fleet, params, durability_dir=d,
                                snapshot_every=4, **_KW)
    done = live.serve_trace(trace, y0_of=_y0_of)
    rec, redelivered = StreamingFleetServer.recover(d, fleet, params)
    _assert_parity("fused_f32", rec, done + list(redelivered))
    assert rec.drain() == [] and rec.pending == 0


# ---------------------------------------------------------------------------
# Chaos harness hygiene
# ---------------------------------------------------------------------------

def test_chaos_unknown_kill_point_rejected():
    with pytest.raises(ValueError, match="unknown kill point"):
        with chaos.crash_at("pump:typo"):
            pass
    with pytest.raises(ValueError, match="hit"):
        with chaos.crash_at("pump:pre_commit", hit=0):
            pass
    with pytest.raises(ValueError, match="times"):
        with chaos.flaky("x", times=0):
            pass


def test_chaos_disarms_after_fire_and_on_exit():
    fired = []
    try:
        with chaos.crash_at("store:evict"):
            chaos.kill_point("store:evict")
    except chaos.SimulatedCrash:
        fired.append(True)
    assert fired
    chaos.kill_point("store:evict")          # disarmed: must not raise
    with chaos.crash_at("store:evict", hit=3):
        chaos.kill_point("store:evict")
        chaos.kill_point("store:evict")      # hits 1, 2: survive
    chaos.kill_point("store:evict")          # exited: disarmed
    assert chaos.SimulatedCrash.__bases__ == (BaseException,)
