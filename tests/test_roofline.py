"""Unit tests for the loop-aware HLO analyzer and roofline terms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.analysis import Roofline, model_flops
from repro.roofline.hlo_parse import analyze


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_counted():
    a = jnp.zeros((128, 256))
    b = jnp.zeros((256, 64))
    txt = _compile_text(lambda x, y: x @ y, a, b)
    r = analyze(txt)
    assert abs(r["flops"] - 2 * 128 * 256 * 64) / (2 * 128 * 256 * 64) < 0.05


def test_while_trip_scaling():
    """A scanned matmul must count trip x body flops."""
    w = jnp.zeros((64, 64))

    def fn(w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, jnp.ones((8, 64)), None, length=20)
        return out

    txt = _compile_text(fn, w)
    r = analyze(txt)
    expect = 20 * 2 * 8 * 64 * 64
    assert abs(r["flops"] - expect) / expect < 0.05
    assert r["n_while"] >= 1


def test_roofline_terms_and_bottleneck():
    rl = Roofline(arch="x", shape="train_4k", mesh="16x16", chips=256,
                  hlo_flops_per_chip=197e12,     # exactly 1s of compute
                  hlo_bytes_per_chip=819e9 * 2,  # 2s of memory
                  coll_bytes_per_chip=50e9 * 0.5,
                  model_flops_global=197e12 * 256 * 0.5,
                  coll_breakdown={})
    assert abs(rl.t_compute - 1.0) < 1e-9
    assert abs(rl.t_memory - 2.0) < 1e-9
    assert rl.bottleneck == "memory"
    assert abs(rl.useful_flops_ratio - 0.5) < 1e-9
    assert abs(rl.roofline_fraction - 0.25) < 1e-6


def test_model_flops_by_kind():
    from repro.configs import SHAPES, get_config
    cfg = get_config("llama3-8b")
    t = model_flops(cfg, SHAPES["train_4k"])
    p = model_flops(cfg, SHAPES["prefill_32k"])
    d = model_flops(cfg, SHAPES["decode_32k"])
    assert t / p == (6 * 256 * 4096) / (2 * 32 * 32768)
    assert d < p < t


def test_moe_active_flops_below_full():
    from repro.configs import SHAPES, get_config
    from repro.configs.base import active_param_count, param_count
    cfg = get_config("deepseek-v2-236b")
    assert active_param_count(cfg) < 0.15 * param_count(cfg)
    # ~236B total / ~21B active per the paper's config family
    assert 1.5e11 < param_count(cfg) < 3.2e11
    assert 1.0e10 < active_param_count(cfg) < 3.5e10
