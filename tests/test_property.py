"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.analogue import AnalogueSpec, conductance_pair, \
    program_tensor, quantize_conductance
from repro.core.losses import dtw, mre, soft_dtw
from repro.core.ode import odeint
from repro.models.moe import MoEConfig, capacity, moe_apply, moe_init

SET = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# ODE integrator invariants
# ---------------------------------------------------------------------------

@given(lam=st.floats(-3.0, -0.1), y0=st.floats(-2.0, 2.0),
       n=st.integers(4, 32))
@settings(**SET)
def test_linear_ode_matches_exponential(lam, y0, n):
    f = lambda t, y, p: lam * y
    ts = jnp.linspace(0.0, 1.0, n + 1)
    ys = odeint(f, jnp.array([y0]), ts, None, method="rk4",
                steps_per_interval=4)
    expected = y0 * np.exp(lam * np.asarray(ts))
    np.testing.assert_allclose(np.asarray(ys[:, 0]), expected, rtol=1e-4,
                               atol=1e-5)


@given(n=st.integers(2, 6))
@settings(**SET)
def test_rk4_order_beats_euler(n):
    """Halving dt must shrink RK4 error super-linearly (4th order) —
    checked above the float32 noise floor."""
    f = lambda t, y, p: -y + jnp.sin(3 * t)
    ts = jnp.linspace(0.0, 2.0, n + 1)
    fine = odeint(f, jnp.array([1.0]), ts, None, method="rk4",
                  steps_per_interval=64)

    def err(method, spi):
        ys = odeint(f, jnp.array([1.0]), ts, None, method=method,
                    steps_per_interval=spi)
        return float(jnp.abs(ys - fine).max())

    e_rk4_1, e_rk4_2 = err("rk4", 1), err("rk4", 2)
    assert e_rk4_2 <= e_rk4_1 / 4 + 1e-6     # comfortably super-linear


# ---------------------------------------------------------------------------
# (soft-)DTW invariants
# ---------------------------------------------------------------------------

@given(data=st.data(), n=st.integers(2, 30), m=st.integers(2, 30))
@settings(**SET)
def test_dtw_nonneg_and_identity(data, n, m):
    key = jax.random.PRNGKey(data.draw(st.integers(0, 2 ** 30)))
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (n, 2))
    y = jax.random.normal(k2, (m, 2))
    d = float(dtw(x, y))
    assert d >= -1e-6
    assert float(dtw(x, x)) < 1e-6
    assert abs(float(dtw(x, y)) - float(dtw(y, x))) < 1e-4  # symmetric dist


@given(seed=st.integers(0, 2 ** 30), gamma=st.floats(0.05, 2.0))
@settings(**SET)
def test_softdtw_lower_bounds_dtw(seed, gamma):
    """soft-min <= min pointwise => soft-DTW <= DTW."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (12, 1))
    y = jax.random.normal(k2, (15, 1))
    assert float(soft_dtw(x, y, gamma)) <= float(dtw(x, y)) + 1e-5


# ---------------------------------------------------------------------------
# Analogue mapping invariants
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2 ** 30), rows=st.integers(1, 16),
       cols=st.integers(1, 16))
@settings(**SET)
def test_differential_pair_exact_before_quant(seed, rows, cols):
    spec = AnalogueSpec(quantize=False, prog_noise=0.0)
    w = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols))
    gp, gm, scale = conductance_pair(w, spec)
    np.testing.assert_allclose(np.asarray((gp - gm) / scale), np.asarray(w),
                               rtol=1e-5, atol=1e-7)
    # conductances always within the physical device range
    assert float(gp.min()) >= spec.g_min - 1e-12
    assert float(gp.max()) <= spec.g_max + 1e-9


@given(seed=st.integers(0, 2 ** 30))
@settings(**SET)
def test_quantization_error_within_half_level(seed):
    spec = AnalogueSpec(prog_noise=0.0)
    g = jax.random.uniform(jax.random.PRNGKey(seed), (32,),
                           minval=spec.g_min, maxval=spec.g_max)
    q = quantize_conductance(g, spec)
    step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    assert float(jnp.abs(q - g).max()) <= step / 2 + 1e-12


@given(seed=st.integers(0, 2 ** 30))
@settings(max_examples=10, deadline=None)
def test_programming_noise_statistics(seed):
    """Programmed conductance must be unbiased with ~the configured sigma."""
    spec = AnalogueSpec(prog_noise=0.0436, quantize=False)
    w = jnp.ones((64, 64))
    prog = program_tensor(jax.random.PRNGKey(seed), w, spec)
    rel = (prog["gp"] - spec.g_max) / spec.g_max   # w=1 -> gp at g_max
    assert abs(float(rel.mean())) < 0.02
    assert 0.02 < float(rel.std()) < 0.07


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2 ** 30), topk=st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_moe_capacity_bounds_and_conservation(seed, topk):
    cfg = MoEConfig(n_experts=4, top_k=topk, d_ff=8, capacity_factor=8.0)
    params = moe_init(jax.random.PRNGKey(seed), cfg, 16)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 6, 16))
    y, aux = moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0
    c = capacity(12, cfg)
    assert c % 4 == 0 and c >= 4


@given(seed=st.integers(0, 2 ** 30))
@settings(max_examples=5, deadline=None)
def test_moe_drop_monotone_in_capacity(seed):
    """Higher capacity factor can only keep more tokens (|y| not smaller
    in aggregate when no drops occur)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 32, 16))
    outs = []
    for cf in [0.25, 8.0]:
        cfg = MoEConfig(n_experts=4, top_k=2, d_ff=8, capacity_factor=cf)
        params = moe_init(jax.random.PRNGKey(0), cfg, 16)
        y, _ = moe_apply(params, cfg, x)
        outs.append(float(jnp.abs(y).sum()))
    assert outs[1] >= outs[0] - 1e-4


# ---------------------------------------------------------------------------
# Data pipeline determinism (exact-resume contract)
# ---------------------------------------------------------------------------

@given(step=st.integers(0, 10000), seed=st.integers(0, 100))
@settings(**SET)
def test_pipeline_pure_function_of_step(step, seed):
    from repro.data.tokens import TokenPipeline
    p1 = TokenPipeline(vocab=128, seq_len=16, batch=2, seed=seed)
    p2 = TokenPipeline(vocab=128, seq_len=16, batch=2, seed=seed)
    np.testing.assert_array_equal(np.asarray(p1.batch_at(step)["tokens"]),
                                  np.asarray(p2.batch_at(step)["tokens"]))
    assert int(p1.batch_at(step)["tokens"].max()) < 128
