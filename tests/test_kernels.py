"""Per-kernel shape/dtype sweeps asserting allclose vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analogue import AnalogueSpec, program_mlp
from repro.core.losses import dtw as dtw_jnp, soft_dtw as soft_dtw_jnp
from repro.core.node import mlp_init
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# fused ODE MLP
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes,drive_dim,state_dim", [
    ((2, 14, 14, 1), 1, 1),      # paper's HP-twin arrays (2x14, 14x14, 14x1)
    ((6, 64, 64, 6), 0, 6),      # paper's Lorenz96 twin
    ((3, 8, 2), 1, 2),           # 2-layer variant
    ((4, 32, 32, 32, 4), 0, 4),  # 4-layer variant
])
@pytest.mark.parametrize("batch,T", [(8, 16), (16, 50)])
def test_fused_node_matches_ref(sizes, drive_dim, state_dim, batch, T):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, sizes[1]))
    params = mlp_init(k1, sizes)
    y0 = 0.3 * jax.random.normal(k2, (batch, state_dim))
    ts = jnp.linspace(0.0, 0.5, T + 1)
    dt = float(ts[1] - ts[0])
    if drive_dim:
        uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    else:
        uh = jnp.zeros((2 * T + 1, 0))
    out_k = ops.fused_node_rollout(params, y0, uh, dt, batch_tile=8,
                                   precision="f32")
    out_r = ops.fused_node_rollout_ref(params, y0, uh, dt)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)
    assert out_k.shape == (T + 1, batch, state_dim)


@pytest.mark.parametrize("T,chunk", [
    (5, 8),     # single partial chunk (chunk > T)
    (8, 4),     # exactly two chunks
    (5, 4),     # two chunks, T not divisible by the chunk
    (20, 4),    # many chunks
    (21, 4),    # many chunks + partial tail
])
def test_fused_node_time_chunks_match_ref(T, chunk):
    """The time-chunked grid must carry the state across chunk boundaries
    exactly — parity vs the jnp reference straddling one/two/many chunks,
    including T not divisible by the chunk size."""
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 31 * T + chunk))
    params = mlp_init(k1, (2, 14, 14, 1))
    y0 = 0.3 * jax.random.normal(k2, (8, 1))
    ts = jnp.linspace(0.0, 0.5, T + 1)
    uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    out_k = ops.fused_node_rollout(params, y0, uh, float(ts[1] - ts[0]),
                                   batch_tile=4, time_chunk=chunk,
                                   precision="f32")
    out_r = ops.fused_node_rollout_ref(params, y0, uh, float(ts[1] - ts[0]))
    assert out_k.shape == (T + 1, 8, 1)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)


def test_fused_node_time_chunks_per_tile_drive():
    """Per-twin drives must be sliced per (tile, chunk) cell correctly."""
    params = mlp_init(KEY, (2, 14, 14, 1))
    T, B = 11, 8
    ts = jnp.linspace(0.0, 0.5, T + 1)
    amps = 0.5 + jnp.arange(B, dtype=jnp.float32) / B
    uh = jnp.stack([ops.half_step_drive(lambda t, a=a: a * jnp.sin(4 * t), ts)
                    for a in amps])                       # (B, 2T+1, 1)
    y0 = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 5), (B, 1))
    out_k = ops.fused_node_rollout(params, y0, uh, float(ts[1] - ts[0]),
                                   batch_tile=4, time_chunk=3,
                                   precision="f32")
    out_r = ops.fused_node_rollout_ref(params, y0, uh, float(ts[1] - ts[0]))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)


def test_fused_node_long_horizon_no_vmem_error():
    """The old hard VMEM guard is gone: the exact shape that used to raise
    'needs ~X MiB VMEM' now auto-chunks over time and matches the
    reference at T=10,000 (acceptance: max abs err <= 1e-4)."""
    from repro.kernels.fused_ode_mlp import (DEFAULT_VMEM_BUDGET,
                                             plan_time_chunk)
    params = mlp_init(KEY, (6, 64, 64, 6))
    w = [p["w"].astype(jnp.float32) for p in params]
    b = [p["b"].astype(jnp.float32) for p in params]
    T = 10000
    plan = plan_time_chunk(T, 64, 6, 0, False, w, b, DEFAULT_VMEM_BUDGET)
    assert plan.num_chunks > 1                # genuinely exceeds one chunk
    assert plan.vmem_bytes <= DEFAULT_VMEM_BUDGET
    y0 = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 9), (64, 6))
    uh = jnp.zeros((2 * T + 1, 0))
    out_k = ops.fused_node_rollout(params, y0, uh, 1e-4,
                                   precision="f32")   # no ValueError
    out_r = ops.fused_node_rollout_ref(params, y0, uh, 1e-4)
    assert out_k.shape == (T + 1, 64, 6)
    assert float(jnp.abs(out_k - out_r).max()) <= 1e-4


# ---------------------------------------------------------------------------
# mixed precision (the bf16 streaming policies)
# ---------------------------------------------------------------------------

# documented per-policy tolerances for the HP-shaped rollout (see
# docs/kernels.md "Precision policy"): bf16 storage rounds each stored
# row to ~2^-8 relative, and the chunk-boundary carry re-rounds once per
# chunk; f32 accumulation keeps the in-chunk integration exact.
PRECISION_REL_TOL = {"f32": 1e-5, "bf16_f32acc": 1e-2, "bf16": 4e-2}


@pytest.mark.parametrize("precision", ["f32", "bf16_f32acc", "bf16"])
def test_fused_node_precision_parity(precision):
    """Reduced-precision rollouts track the f32 reference within the
    documented per-policy tolerance (HP-twin config, chunk-straddling)."""
    params = mlp_init(KEY, (2, 14, 14, 1))
    y0 = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 77), (8, 1))
    T = 50
    ts = jnp.linspace(0.0, 0.5, T + 1)
    uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    dt = float(ts[1] - ts[0])
    out_k = ops.fused_node_rollout(params, y0, uh, dt, batch_tile=4,
                                   time_chunk=7, precision=precision)
    out_r = ops.fused_node_rollout_ref(params, y0, uh, dt)
    if precision == "f32":
        assert out_k.dtype == jnp.float32
    else:
        assert out_k.dtype == jnp.bfloat16   # half the HBM bytes
    rel = float(jnp.abs(out_k.astype(jnp.float32) - out_r).max()
                / jnp.abs(out_r).max())
    assert rel <= PRECISION_REL_TOL[precision]


def test_plan_time_chunk_bf16_doubles_chunk():
    """The ISSUE acceptance: dtype-aware planning must give bf16 >= 1.8x
    the f32 time chunk at the default VMEM budget (and the plan must
    actually fit it)."""
    from repro.kernels.fused_ode_mlp import (DEFAULT_VMEM_BUDGET,
                                             plan_time_chunk)
    params = mlp_init(KEY, (6, 64, 64, 6))
    w = [p["w"].astype(jnp.float32) for p in params]
    b = [p["b"].astype(jnp.float32) for p in params]
    T = 10 ** 9                       # never clamp C at the horizon
    p32 = plan_time_chunk(T, 64, 6, 0, False, w, b, DEFAULT_VMEM_BUDGET)
    for bf in ["bf16", "bf16_f32acc"]:
        pbf = plan_time_chunk(T, 64, 6, 0, False, w, b,
                              DEFAULT_VMEM_BUDGET, precision=bf)
        assert pbf.time_chunk >= 1.8 * p32.time_chunk
        assert pbf.vmem_bytes <= DEFAULT_VMEM_BUDGET
    # the weights-must-fit threshold moves too: a budget that rejects
    # f32 weights can still fit the bf16-stored ones
    big = mlp_init(jax.random.fold_in(KEY, 1), (64, 256, 256, 64))
    wb = [p["w"].astype(jnp.float32) for p in big]
    bb = [p["b"].astype(jnp.float32) for p in big]
    budget = 300 * 1024
    with pytest.raises(ValueError, match="VMEM"):
        plan_time_chunk(100, 8, 64, 0, False, wb, bb, budget)
    plan = plan_time_chunk(100, 8, 64, 0, False, wb, bb, budget,
                           precision="bf16_f32acc")
    assert plan.time_chunk >= 1


def test_fused_node_rejects_non_float_inputs():
    """Clear ValueError naming the offending input instead of an opaque
    Mosaic lowering failure (ISSUE satellite)."""
    params = mlp_init(KEY, (2, 8, 1))
    y0 = jnp.zeros((4, 1))
    uh = jnp.zeros((11, 1))
    with pytest.raises(ValueError, match="y0"):
        ops.fused_node_rollout(params, y0.astype(jnp.int32), uh, 1e-2)
    with pytest.raises(ValueError, match="u_half"):
        ops.fused_node_rollout(params, y0, uh.astype(jnp.int32), 1e-2)
    bad = [dict(p) for p in params]
    bad[1]["w"] = bad[1]["w"].astype(jnp.int8)
    with pytest.raises(ValueError, match=r"params\[1\]\['w'\]"):
        ops.fused_node_rollout(bad, y0, uh, 1e-2)


def test_force_interpret_env_override(monkeypatch):
    """REPRO_FORCE_INTERPRET pins the lowering mode for BOTH kernel
    modules without monkeypatching jax (ISSUE satellite)."""
    from repro.kernels import fused_ode_mlp, fused_ode_mlp_bwd
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert fused_ode_mlp._default_interpret() is True
    assert fused_ode_mlp_bwd._default_interpret() is True
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    assert fused_ode_mlp._default_interpret() is False
    assert fused_ode_mlp_bwd._default_interpret() is False
    # common boolean-env spellings work; garbage names the variable
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "true")
    assert fused_ode_mlp._default_interpret() is True
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "off")
    assert fused_ode_mlp._default_interpret() is False
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "maybe")
    with pytest.raises(ValueError, match="REPRO_FORCE_INTERPRET"):
        fused_ode_mlp._default_interpret()
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "")
    assert (fused_ode_mlp._default_interpret()
            is (jax.default_backend() != "tpu"))


@pytest.mark.parametrize("precision", ["bf16_f32acc", "bf16"])
def test_softdtw_bf16_cost_matrix(precision):
    """The wavefront kernels accept a bf16 cost slab; f32 R/E carries
    keep the DP well-conditioned (forward AND E-matrix backward)."""
    x = jax.random.normal(KEY, (2, 60, 2))
    y = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 80, 2))
    sk = ops.soft_dtw(x, y, 0.5, True, precision)
    sr = jax.vmap(lambda a, b: soft_dtw_jnp(a, b, 0.5))(x, y)
    assert sk.dtype == jnp.float32            # answer stays full precision
    np.testing.assert_allclose(sk, sr, rtol=2e-3, atol=1e-3)
    gk = jax.grad(lambda a: ops.soft_dtw(a, y, 0.5, True, precision).sum())(x)
    gr = jax.grad(
        lambda a: jax.vmap(lambda p, q: soft_dtw_jnp(p, q, 0.5))(a, y).sum())(x)
    np.testing.assert_allclose(gk, gr, rtol=3e-2, atol=2e-2)


def test_fused_node_vmem_guard_only_when_weights_dont_fit():
    """ValueError survives only for the genuinely impossible cases: the
    weights plus a single RK4 step exceed the budget, or an explicit
    time_chunk is oversized for it."""
    params = mlp_init(KEY, (6, 64, 64, 6))
    y0 = jnp.zeros((64, 6))
    uh = jnp.zeros((2 * 100 + 1, 0))
    with pytest.raises(ValueError, match="VMEM"):
        ops.fused_node_rollout(params, y0, uh, 1e-3,
                               vmem_budget_bytes=16 * 1024)
    with pytest.raises(ValueError, match="time_chunk"):
        ops.fused_node_rollout(params, y0, uh, 1e-3, time_chunk=100,
                               vmem_budget_bytes=128 * 1024)


def test_fused_node_matches_odeint():
    """The kernel must agree with the framework's own RK4 odeint."""
    from repro.core.node import MLPVectorField
    from repro.core.ode import odeint

    field = MLPVectorField(sizes=(2, 14, 14, 1),
                           drive=lambda t: jnp.sin(4 * t))
    params = field.init(KEY)
    T = 32
    ts = jnp.linspace(0.0, 0.25, T + 1)
    y0 = jnp.array([[0.2]])
    ys = odeint(field, y0[0], ts, params, method="rk4")
    uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    out = ops.fused_node_rollout(params, y0, uh, float(ts[1] - ts[0]),
                                 batch_tile=1, precision="f32")
    np.testing.assert_allclose(out[:, 0, :], ys, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# crossbar VMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (1, 3, 15), (8, 65, 14), (37, 129, 100), (130, 256, 257), (256, 512, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_crossbar_shapes(m, k, n, dtype):
    spec = AnalogueSpec(prog_noise=0.02)
    kx, kw = jax.random.split(jax.random.fold_in(KEY, m * k + n))
    x = jax.random.normal(kx, (m, k), dtype)
    from repro.core.analogue import program_tensor
    w = jax.random.normal(kw, (k, n))
    prog = program_tensor(kw, w, spec)
    yk = ops.crossbar_vmm(prog, x, spec)
    yr = ref.crossbar_matmul_ref(x, prog["gp"], prog["gm"], 1.0,
                                 spec.v_clamp) / prog["scale"]
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(16, 64, 32), (33, 200, 129)])
def test_crossbar_quantized(m, k, n):
    spec = AnalogueSpec()
    kx, kw = jax.random.split(jax.random.fold_in(KEY, m + k + n))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    gpq, gmq, scale = ops.quantize_to_levels(w, spec)
    assert gpq.dtype == jnp.uint8
    yq = ops.crossbar_vmm_quantized(x, gpq, gmq, spec, scale)
    g_step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    yr = ref.crossbar_matmul_q_ref(x, gpq, gmq, g_step, 1.0,
                                   spec.v_clamp) / scale
    np.testing.assert_allclose(np.asarray(yq), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)
    # quantisation itself must stay within half a level of the ideal weight
    ideal = x @ w
    lvl_err = jnp.abs(yq - ideal).max() / (jnp.abs(w).max() * k)
    assert float(lvl_err) < 1.0 / spec.levels


def test_crossbar_quantized_matches_digital_coarsely():
    """6-bit differential storage should approximate the digital matmul."""
    spec = AnalogueSpec()
    w = jax.random.normal(KEY, (64, 64)) * 0.1
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (16, 64))
    gpq, gmq, scale = ops.quantize_to_levels(w, spec)
    y = ops.crossbar_vmm_quantized(x, gpq, gmq, spec, scale)
    rel = jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w)
    assert float(rel) < 0.05


# ---------------------------------------------------------------------------
# soft-DTW wavefront
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,d", [
    (1, 1, 1), (5, 5, 1), (50, 70, 2), (128, 128, 3), (300, 200, 1),
    (257, 513, 2),
])
def test_softdtw_shapes(n, m, d):
    kx, ky = jax.random.split(jax.random.fold_in(KEY, n * m))
    x = jax.random.normal(kx, (2, n, d))
    y = jax.random.normal(ky, (2, m, d))
    sk = ops.soft_dtw(x, y, 0.7, True, "f32")
    sr = jax.vmap(lambda a, b: soft_dtw_jnp(a, b, 0.7))(x, y)
    np.testing.assert_allclose(sk, sr, rtol=1e-4, atol=1e-4)
    hk = ops.dtw_distance(x, y)
    hr = jax.vmap(dtw_jnp)(x, y)
    np.testing.assert_allclose(hk, hr, rtol=1e-5, atol=1e-5)


def test_softdtw_grad_matches_ref():
    x = jax.random.normal(KEY, (2, 40, 2))
    y = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 60, 2))
    gk = jax.grad(lambda a: ops.soft_dtw(a, y, 0.5, True, "f32").sum())(x)
    gr = jax.grad(
        lambda a: jax.vmap(lambda p, q: soft_dtw_jnp(p, q, 0.5))(a, y).sum())(x)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)


def test_dtw_identity_is_zero():
    x = jax.random.normal(KEY, (1, 64, 2))
    assert float(ops.dtw_distance(x, x)[0]) == pytest.approx(0.0, abs=1e-6)


def test_dtw_shift_invariance_property():
    """DTW of a time-warped copy must be far below an unrelated series."""
    t = jnp.linspace(0, 6.28, 100)
    a = jnp.sin(t)[None, :, None]
    warped = jnp.sin(t ** 1.08 / t[-1] ** 0.08)[None, :, None]
    noise = jax.random.normal(KEY, (1, 100, 1))
    d_w = float(ops.dtw_distance(a, warped)[0])
    d_n = float(ops.dtw_distance(a, noise)[0])
    assert d_w < 0.2 * d_n


# ---------------------------------------------------------------------------
# state-resident SSM scan (Mamba recurrence)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bsz,s,di,n,d_tile", [
    (1, 8, 16, 4, 16), (2, 32, 64, 16, 32), (1, 64, 128, 16, 128),
])
def test_ssm_scan_matches_ref(bsz, s, di, n, d_tile):
    from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref
    key = jax.random.PRNGKey(di + s)
    ks = jax.random.split(key, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (bsz, s, di))) * 0.1
    b = jax.random.normal(ks[1], (bsz, s, n))
    c = jax.random.normal(ks[2], (bsz, s, n))
    x = jax.random.normal(ks[3], (bsz, s, di))
    a = -jnp.exp(jax.random.normal(ks[4], (di, n)) * 0.3)
    yk, hk = ssm_scan(dt, b, c, x, a, d_tile=d_tile)
    yr, hr = ssm_scan_ref(dt, b, c, x, a)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), rtol=1e-5,
                               atol=1e-5)


def test_ssm_scan_matches_mamba_prefill_core():
    """The kernel must agree with the model's chunked-scan mamba path."""
    from repro.kernels.ssm_scan import ssm_scan
    from repro.models.mamba import MambaConfig, mamba_init, mamba_prefill
    cfg = MambaConfig(d_model=32, d_state=4, d_conv=4, expand=2, chunk=8)
    params = mamba_init(jax.random.PRNGKey(0), cfg)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out_model, state = mamba_prefill(params, cfg, u)
    # recompute y via the kernel on the same intermediate quantities
    import repro.models.mamba as M
    xz = u @ params["in_proj"]
    x_, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(M._causal_conv(params, cfg, x_))
    dt, b_, c_ = M._dbc(params, cfg, xc)
    a = -jnp.exp(params["A_log"])
    yk, hk = ssm_scan(dt, b_, c_, xc.astype(jnp.float32), a, d_tile=64)
    y = yk + params["D"] * xc.astype(jnp.float32)
    y = y.astype(u.dtype) * jax.nn.silu(z)
    out_kernel = y @ params["out_proj"]
    np.testing.assert_allclose(np.asarray(out_kernel),
                               np.asarray(out_model), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(state["ssm"]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused causal flash attention (VMEM-resident accumulator)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,s,d,bq,bk", [
    (1, 2, 2, 32, 16, 16, 16),
    (2, 4, 2, 64, 32, 32, 16),   # GQA group 2
    (1, 8, 2, 128, 64, 64, 64),  # GQA group 4
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_matches_ref(b, h, hkv, s, d, bq, bk, dtype):
    from repro.kernels.flash_attention import (flash_attention_pallas,
                                               flash_attention_pallas_ref)
    ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = flash_attention_pallas(q, k, v, bq=bq, bk=bk)
    ref = flash_attention_pallas_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_pallas_matches_model_flash():
    """Kernel vs the XLA flash schedule used by the models."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.flash import flash_attention
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, h, hkv, s, d = 1, 4, 2, 64, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    xla_out = flash_attention([q], [k], v, scale=d ** -0.5,
                              q_chunk=16, kv_chunk=16)
    kern_out = flash_attention_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                      v.swapaxes(1, 2), bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(kern_out.swapaxes(1, 2)),
                               np.asarray(xla_out), rtol=2e-5, atol=2e-5)
