"""Per-kernel shape/dtype sweeps asserting allclose vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analogue import AnalogueSpec, program_mlp
from repro.core.losses import dtw as dtw_jnp, soft_dtw as soft_dtw_jnp
from repro.core.node import mlp_init
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# fused ODE MLP
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes,drive_dim,state_dim", [
    ((2, 14, 14, 1), 1, 1),      # paper's HP-twin arrays (2x14, 14x14, 14x1)
    ((6, 64, 64, 6), 0, 6),      # paper's Lorenz96 twin
    ((3, 8, 2), 1, 2),           # 2-layer variant
    ((4, 32, 32, 32, 4), 0, 4),  # 4-layer variant
])
@pytest.mark.parametrize("batch,T", [(8, 16), (16, 50)])
def test_fused_node_matches_ref(sizes, drive_dim, state_dim, batch, T):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, sizes[1]))
    params = mlp_init(k1, sizes)
    y0 = 0.3 * jax.random.normal(k2, (batch, state_dim))
    ts = jnp.linspace(0.0, 0.5, T + 1)
    dt = float(ts[1] - ts[0])
    if drive_dim:
        uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    else:
        uh = jnp.zeros((2 * T + 1, 0))
    out_k = ops.fused_node_rollout(params, y0, uh, dt, batch_tile=8,
                                   precision="f32")
    out_r = ops.fused_node_rollout_ref(params, y0, uh, dt)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)
    assert out_k.shape == (T + 1, batch, state_dim)


@pytest.mark.parametrize("T,chunk", [
    (5, 8),     # single partial chunk (chunk > T)
    (8, 4),     # exactly two chunks
    (5, 4),     # two chunks, T not divisible by the chunk
    (20, 4),    # many chunks
    (21, 4),    # many chunks + partial tail
])
def test_fused_node_time_chunks_match_ref(T, chunk):
    """The time-chunked grid must carry the state across chunk boundaries
    exactly — parity vs the jnp reference straddling one/two/many chunks,
    including T not divisible by the chunk size."""
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, 31 * T + chunk))
    params = mlp_init(k1, (2, 14, 14, 1))
    y0 = 0.3 * jax.random.normal(k2, (8, 1))
    ts = jnp.linspace(0.0, 0.5, T + 1)
    uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    out_k = ops.fused_node_rollout(params, y0, uh, float(ts[1] - ts[0]),
                                   batch_tile=4, time_chunk=chunk,
                                   precision="f32")
    out_r = ops.fused_node_rollout_ref(params, y0, uh, float(ts[1] - ts[0]))
    assert out_k.shape == (T + 1, 8, 1)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)


def test_fused_node_time_chunks_per_tile_drive():
    """Per-twin drives must be sliced per (tile, chunk) cell correctly."""
    params = mlp_init(KEY, (2, 14, 14, 1))
    T, B = 11, 8
    ts = jnp.linspace(0.0, 0.5, T + 1)
    amps = 0.5 + jnp.arange(B, dtype=jnp.float32) / B
    uh = jnp.stack([ops.half_step_drive(lambda t, a=a: a * jnp.sin(4 * t), ts)
                    for a in amps])                       # (B, 2T+1, 1)
    y0 = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 5), (B, 1))
    out_k = ops.fused_node_rollout(params, y0, uh, float(ts[1] - ts[0]),
                                   batch_tile=4, time_chunk=3,
                                   precision="f32")
    out_r = ops.fused_node_rollout_ref(params, y0, uh, float(ts[1] - ts[0]))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)


def test_fused_node_long_horizon_no_vmem_error():
    """The old hard VMEM guard is gone: the exact shape that used to raise
    'needs ~X MiB VMEM' now auto-chunks over time and matches the
    reference at T=10,000 (acceptance: max abs err <= 1e-4)."""
    from repro.kernels.fused_ode_mlp import (DEFAULT_VMEM_BUDGET,
                                             plan_time_chunk)
    params = mlp_init(KEY, (6, 64, 64, 6))
    w = [p["w"].astype(jnp.float32) for p in params]
    b = [p["b"].astype(jnp.float32) for p in params]
    T = 10000
    plan = plan_time_chunk(T, 64, 6, 0, False, w, b, DEFAULT_VMEM_BUDGET)
    assert plan.num_chunks > 1                # genuinely exceeds one chunk
    assert plan.vmem_bytes <= DEFAULT_VMEM_BUDGET
    y0 = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 9), (64, 6))
    uh = jnp.zeros((2 * T + 1, 0))
    out_k = ops.fused_node_rollout(params, y0, uh, 1e-4,
                                   precision="f32")   # no ValueError
    out_r = ops.fused_node_rollout_ref(params, y0, uh, 1e-4)
    assert out_k.shape == (T + 1, 64, 6)
    assert float(jnp.abs(out_k - out_r).max()) <= 1e-4


# ---------------------------------------------------------------------------
# mixed precision (the bf16 streaming policies)
# ---------------------------------------------------------------------------

# documented per-policy tolerances for the HP-shaped rollout (see
# docs/kernels.md "Precision policy"): bf16 storage rounds each stored
# row to ~2^-8 relative, and the chunk-boundary carry re-rounds once per
# chunk; f32 accumulation keeps the in-chunk integration exact.
PRECISION_REL_TOL = {"f32": 1e-5, "bf16_f32acc": 1e-2, "bf16": 4e-2}


@pytest.mark.parametrize("precision", ["f32", "bf16_f32acc", "bf16"])
def test_fused_node_precision_parity(precision):
    """Reduced-precision rollouts track the f32 reference within the
    documented per-policy tolerance (HP-twin config, chunk-straddling)."""
    params = mlp_init(KEY, (2, 14, 14, 1))
    y0 = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 77), (8, 1))
    T = 50
    ts = jnp.linspace(0.0, 0.5, T + 1)
    uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    dt = float(ts[1] - ts[0])
    out_k = ops.fused_node_rollout(params, y0, uh, dt, batch_tile=4,
                                   time_chunk=7, precision=precision)
    out_r = ops.fused_node_rollout_ref(params, y0, uh, dt)
    if precision == "f32":
        assert out_k.dtype == jnp.float32
    else:
        assert out_k.dtype == jnp.bfloat16   # half the HBM bytes
    rel = float(jnp.abs(out_k.astype(jnp.float32) - out_r).max()
                / jnp.abs(out_r).max())
    assert rel <= PRECISION_REL_TOL[precision]


def test_plan_time_chunk_bf16_doubles_chunk():
    """The ISSUE acceptance: dtype-aware planning must give bf16 >= 1.8x
    the f32 time chunk at the default VMEM budget (and the plan must
    actually fit it)."""
    from repro.kernels.fused_ode_mlp import (DEFAULT_VMEM_BUDGET,
                                             plan_time_chunk)
    params = mlp_init(KEY, (6, 64, 64, 6))
    w = [p["w"].astype(jnp.float32) for p in params]
    b = [p["b"].astype(jnp.float32) for p in params]
    T = 10 ** 9                       # never clamp C at the horizon
    p32 = plan_time_chunk(T, 64, 6, 0, False, w, b, DEFAULT_VMEM_BUDGET)
    for bf in ["bf16", "bf16_f32acc"]:
        pbf = plan_time_chunk(T, 64, 6, 0, False, w, b,
                              DEFAULT_VMEM_BUDGET, precision=bf)
        assert pbf.time_chunk >= 1.8 * p32.time_chunk
        assert pbf.vmem_bytes <= DEFAULT_VMEM_BUDGET
    # the weights-must-fit threshold moves too: a budget that rejects
    # f32 weights can still fit the bf16-stored ones
    big = mlp_init(jax.random.fold_in(KEY, 1), (64, 256, 256, 64))
    wb = [p["w"].astype(jnp.float32) for p in big]
    bb = [p["b"].astype(jnp.float32) for p in big]
    budget = 300 * 1024
    with pytest.raises(ValueError, match="VMEM"):
        plan_time_chunk(100, 8, 64, 0, False, wb, bb, budget)
    plan = plan_time_chunk(100, 8, 64, 0, False, wb, bb, budget,
                           precision="bf16_f32acc")
    assert plan.time_chunk >= 1


def test_fused_node_rejects_non_float_inputs():
    """Clear ValueError naming the offending input instead of an opaque
    Mosaic lowering failure (ISSUE satellite)."""
    params = mlp_init(KEY, (2, 8, 1))
    y0 = jnp.zeros((4, 1))
    uh = jnp.zeros((11, 1))
    with pytest.raises(ValueError, match="y0"):
        ops.fused_node_rollout(params, y0.astype(jnp.int32), uh, 1e-2)
    with pytest.raises(ValueError, match="u_half"):
        ops.fused_node_rollout(params, y0, uh.astype(jnp.int32), 1e-2)
    bad = [dict(p) for p in params]
    bad[1]["w"] = bad[1]["w"].astype(jnp.int8)
    with pytest.raises(ValueError, match=r"params\[1\]\['w'\]"):
        ops.fused_node_rollout(bad, y0, uh, 1e-2)


def test_force_interpret_env_override(monkeypatch):
    """REPRO_FORCE_INTERPRET pins the lowering mode for BOTH kernel
    modules without monkeypatching jax (ISSUE satellite)."""
    from repro.kernels import fused_ode_mlp, fused_ode_mlp_bwd
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    assert fused_ode_mlp._default_interpret() is True
    assert fused_ode_mlp_bwd._default_interpret() is True
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "0")
    assert fused_ode_mlp._default_interpret() is False
    assert fused_ode_mlp_bwd._default_interpret() is False
    # common boolean-env spellings work; garbage names the variable
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "true")
    assert fused_ode_mlp._default_interpret() is True
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "off")
    assert fused_ode_mlp._default_interpret() is False
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "maybe")
    with pytest.raises(ValueError, match="REPRO_FORCE_INTERPRET"):
        fused_ode_mlp._default_interpret()
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "")
    assert (fused_ode_mlp._default_interpret()
            is (jax.default_backend() != "tpu"))


@pytest.mark.parametrize("precision", ["bf16_f32acc", "bf16"])
def test_softdtw_bf16_cost_matrix(precision):
    """The wavefront kernels accept a bf16 cost slab; f32 R/E carries
    keep the DP well-conditioned (forward AND E-matrix backward)."""
    x = jax.random.normal(KEY, (2, 60, 2))
    y = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 80, 2))
    sk = ops.soft_dtw(x, y, 0.5, True, precision)
    sr = jax.vmap(lambda a, b: soft_dtw_jnp(a, b, 0.5))(x, y)
    assert sk.dtype == jnp.float32            # answer stays full precision
    np.testing.assert_allclose(sk, sr, rtol=2e-3, atol=1e-3)
    gk = jax.grad(lambda a: ops.soft_dtw(a, y, 0.5, True, precision).sum())(x)
    gr = jax.grad(
        lambda a: jax.vmap(lambda p, q: soft_dtw_jnp(p, q, 0.5))(a, y).sum())(x)
    np.testing.assert_allclose(gk, gr, rtol=3e-2, atol=2e-2)


def test_fused_node_vmem_guard_only_when_weights_dont_fit():
    """ValueError survives only for the genuinely impossible cases: the
    weights plus a single RK4 step exceed the budget, or an explicit
    time_chunk is oversized for it."""
    params = mlp_init(KEY, (6, 64, 64, 6))
    y0 = jnp.zeros((64, 6))
    uh = jnp.zeros((2 * 100 + 1, 0))
    with pytest.raises(ValueError, match="VMEM"):
        ops.fused_node_rollout(params, y0, uh, 1e-3,
                               vmem_budget_bytes=16 * 1024)
    with pytest.raises(ValueError, match="time_chunk"):
        ops.fused_node_rollout(params, y0, uh, 1e-3, time_chunk=100,
                               vmem_budget_bytes=128 * 1024)


def test_fused_node_matches_odeint():
    """The kernel must agree with the framework's own RK4 odeint."""
    from repro.core.node import MLPVectorField
    from repro.core.ode import odeint

    field = MLPVectorField(sizes=(2, 14, 14, 1),
                           drive=lambda t: jnp.sin(4 * t))
    params = field.init(KEY)
    T = 32
    ts = jnp.linspace(0.0, 0.25, T + 1)
    y0 = jnp.array([[0.2]])
    ys = odeint(field, y0[0], ts, params, method="rk4")
    uh = ops.half_step_drive(lambda t: jnp.sin(4 * t), ts)
    out = ops.fused_node_rollout(params, y0, uh, float(ts[1] - ts[0]),
                                 batch_tile=1, precision="f32")
    np.testing.assert_allclose(out[:, 0, :], ys, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# crossbar VMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (1, 3, 15), (8, 65, 14), (37, 129, 100), (130, 256, 257), (256, 512, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_crossbar_shapes(m, k, n, dtype):
    spec = AnalogueSpec(prog_noise=0.02)
    kx, kw = jax.random.split(jax.random.fold_in(KEY, m * k + n))
    x = jax.random.normal(kx, (m, k), dtype)
    from repro.core.analogue import program_tensor
    w = jax.random.normal(kw, (k, n))
    prog = program_tensor(kw, w, spec)
    yk = ops.crossbar_vmm(prog, x, spec)
    yr = ref.crossbar_matmul_ref(x, prog["gp"], prog["gm"], 1.0,
                                 spec.v_clamp) / prog["scale"]
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(16, 64, 32), (33, 200, 129)])
def test_crossbar_quantized(m, k, n):
    spec = AnalogueSpec()
    kx, kw = jax.random.split(jax.random.fold_in(KEY, m + k + n))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    gpq, gmq, scale = ops.quantize_to_levels(w, spec)
    assert gpq.dtype == jnp.uint8
    yq = ops.crossbar_vmm_quantized(x, gpq, gmq, spec, scale)
    g_step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    yr = ref.crossbar_matmul_q_ref(x, gpq, gmq, g_step, 1.0,
                                   spec.v_clamp) / scale
    np.testing.assert_allclose(np.asarray(yq), np.asarray(yr), rtol=1e-5,
                               atol=1e-5)
    # quantisation itself must stay within half a level of the ideal weight
    ideal = x @ w
    lvl_err = jnp.abs(yq - ideal).max() / (jnp.abs(w).max() * k)
    assert float(lvl_err) < 1.0 / spec.levels


def test_crossbar_quantized_matches_digital_coarsely():
    """6-bit differential storage should approximate the digital matmul."""
    spec = AnalogueSpec()
    w = jax.random.normal(KEY, (64, 64)) * 0.1
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (16, 64))
    gpq, gmq, scale = ops.quantize_to_levels(w, spec)
    y = ops.crossbar_vmm_quantized(x, gpq, gmq, spec, scale)
    rel = jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w)
    assert float(rel) < 0.05


# ---------------------------------------------------------------------------
# soft-DTW wavefront
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,d", [
    (1, 1, 1), (5, 5, 1), (50, 70, 2), (128, 128, 3), (300, 200, 1),
    (257, 513, 2),
])
def test_softdtw_shapes(n, m, d):
    kx, ky = jax.random.split(jax.random.fold_in(KEY, n * m))
    x = jax.random.normal(kx, (2, n, d))
    y = jax.random.normal(ky, (2, m, d))
    sk = ops.soft_dtw(x, y, 0.7, True, "f32")
    sr = jax.vmap(lambda a, b: soft_dtw_jnp(a, b, 0.7))(x, y)
    np.testing.assert_allclose(sk, sr, rtol=1e-4, atol=1e-4)
    hk = ops.dtw_distance(x, y)
    hr = jax.vmap(dtw_jnp)(x, y)
    np.testing.assert_allclose(hk, hr, rtol=1e-5, atol=1e-5)


def test_softdtw_grad_matches_ref():
    x = jax.random.normal(KEY, (2, 40, 2))
    y = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 60, 2))
    gk = jax.grad(lambda a: ops.soft_dtw(a, y, 0.5, True, "f32").sum())(x)
    gr = jax.grad(
        lambda a: jax.vmap(lambda p, q: soft_dtw_jnp(p, q, 0.5))(a, y).sum())(x)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-5)


def test_dtw_identity_is_zero():
    x = jax.random.normal(KEY, (1, 64, 2))
    assert float(ops.dtw_distance(x, x)[0]) == pytest.approx(0.0, abs=1e-6)


def test_dtw_shift_invariance_property():
    """DTW of a time-warped copy must be far below an unrelated series."""
    t = jnp.linspace(0, 6.28, 100)
    a = jnp.sin(t)[None, :, None]
    warped = jnp.sin(t ** 1.08 / t[-1] ** 0.08)[None, :, None]
    noise = jax.random.normal(KEY, (1, 100, 1))
    d_w = float(ops.dtw_distance(a, warped)[0])
    d_n = float(ops.dtw_distance(a, noise)[0])
    assert d_w < 0.2 * d_n


# ---------------------------------------------------------------------------
# crossbar VMM: kernel-level clamp, read noise, masked padding
# ---------------------------------------------------------------------------

def _toy_pair(k, n, seed=0, quantize=True):
    """A programmed (gp, gm, scale) triple plus the raw weights."""
    from repro.core.analogue import program_tensor
    spec = AnalogueSpec(prog_noise=0.0, quantize=quantize)
    kx, kw = jax.random.split(jax.random.fold_in(KEY, seed + k * n))
    x = jax.random.normal(kx, (11, k))
    w = jax.random.normal(kw, (k, n))
    prog = program_tensor(kw, w, spec)
    return spec, x, w, prog


@pytest.mark.parametrize("m,k,n", [(1, 3, 15), (37, 129, 100), (13, 200, 7)])
@pytest.mark.parametrize("quantized", [False, True])
def test_crossbar_float_vs_quantized_parity_odd_dims(m, k, n, quantized):
    """Float and uint8 storage agree with the jnp reference on odd
    (non-tile-multiple) M/K/N — the accumulator-neutral padding at work."""
    spec = AnalogueSpec(prog_noise=0.0)
    kx, kw = jax.random.split(jax.random.fold_in(KEY, 7 * m + k + n))
    x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (k, n))
    if quantized:
        gpq, gmq, scale = ops.quantize_to_levels(w, spec)
        got = ops.crossbar_vmm_quantized(x, gpq, gmq, spec, scale)
        g_step = (spec.g_max - spec.g_min) / (spec.levels - 1)
        want = ref.crossbar_matmul_q_ref(x, gpq, gmq, g_step, 1.0,
                                         spec.v_clamp) / scale
    else:
        from repro.core.analogue import program_tensor
        prog = program_tensor(kw, w, spec)
        got = ops.crossbar_vmm(prog, x, spec)
        want = ref.crossbar_matmul_ref(x, prog["gp"], prog["gm"], 1.0,
                                       spec.v_clamp) / prog["scale"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("clamp", [None, 0.5])
def test_crossbar_kernel_clamp(clamp):
    """The in-kernel clamp epilogue (applied after the true inv_scale)
    must match clip(x @ (gp - gm) * inv_scale)."""
    from repro.kernels.crossbar_vmm import crossbar_matmul
    _, x, w, prog = _toy_pair(130, 150, seed=1)
    inv_scale = 1.0 / float(prog["scale"])
    got = crossbar_matmul(x, prog["gp"], prog["gm"], inv_scale=inv_scale,
                          clamp=clamp)
    want = (x @ (prog["gp"] - prog["gm"])) * inv_scale
    if clamp is not None:
        want = jnp.clip(want, -clamp, clamp)
        assert float(jnp.abs(got).max()) <= clamp + 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("quantized", [False, True])
def test_crossbar_read_noise_deterministic(quantized):
    """Same noise_seed => bitwise-identical read; different seed =>
    different read; noise magnitude tracks read_noise."""
    spec, x, w, prog = _toy_pair(130, 150, seed=2)
    kw = dict(read_noise=0.02)
    if quantized:
        gpq, gmq, scale = ops.quantize_to_levels(w, spec)
        run = lambda s: ops.crossbar_vmm_quantized(x, gpq, gmq, spec, scale,
                                                   noise_seed=s, **kw)
        clean = ops.crossbar_vmm_quantized(x, gpq, gmq, spec, scale)
    else:
        run = lambda s: ops.crossbar_vmm(prog, x, spec, noise_seed=s, **kw)
        clean = ops.crossbar_vmm(prog, x, spec)
    a, b, c = run(5), run(5), run(6)
    assert jnp.array_equal(a, b)
    assert not jnp.array_equal(a, c)
    rel = float(jnp.linalg.norm(a - clean) / jnp.linalg.norm(clean))
    assert 0.0 < rel < 0.5


def test_crossbar_noisy_quantized_pad_rows_are_neutral():
    """Masked-padding discipline: in noisy quantised mode the pads
    reconstruct to ~g_min and their noise would NOT cancel — the kernel
    must mask them out.  Parity vs a jnp oracle that perturbs the
    reconstructed conductances with the same counter-derived stream
    catches any pad leakage (K=130, N=150 are not tile multiples)."""
    from repro.kernels.noise import counter_normal
    spec, x, w, _ = _toy_pair(130, 150, seed=3)
    gpq, gmq, scale = ops.quantize_to_levels(w, spec)
    got = ops.crossbar_vmm_quantized(x, gpq, gmq, spec, scale,
                                     read_noise=0.02, noise_seed=9)
    # jnp oracle with the kernel's exact stream: tiles are 128-wide, so
    # (k, n) < (130, 150) spans k-tiles {0,1} x n-tiles {0,1}; rebuild
    # each tile's noise block and crop
    g_step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    gp = spec.g_min + gpq.astype(jnp.float32) * g_step
    gm = spec.g_min + gmq.astype(jnp.float32) * g_step

    def stream(pair_off):
        rows = []
        for kt in range(2):
            row = []
            for nt in range(2):
                salt = kt * (2 * 65536) + nt * 2 + pair_off
                row.append(counter_normal(9, salt, (128, 128)))
            rows.append(jnp.concatenate(row, axis=1))
        return jnp.concatenate(rows, axis=0)[:130, :150]

    gp_n = gp * (1.0 + 0.02 * stream(0))
    gm_n = gm * (1.0 + 0.02 * stream(1))
    want = (x @ gp_n - x @ gm_n) / scale
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_crossbar_noisy_quantized_requires_g_min():
    from repro.kernels.crossbar_vmm import crossbar_matmul
    spec, x, w, _ = _toy_pair(64, 32, seed=4)
    gpq, gmq, _ = ops.quantize_to_levels(w, spec)
    with pytest.raises(ValueError, match="g_min"):
        crossbar_matmul(x, gpq, gmq, inv_scale=1.0, g_step=1e-6,
                        read_noise=0.01)


def test_counter_normal_stats_and_determinism():
    from repro.kernels.noise import counter_normal
    z1 = counter_normal(3, 7, (256, 256))
    z2 = counter_normal(3, 7, (256, 256))
    z3 = counter_normal(3, 8, (256, 256))
    assert jnp.array_equal(z1, z2)
    assert not jnp.array_equal(z1, z3)
    assert abs(float(z1.mean())) < 0.02
    assert abs(float(z1.std()) - 1.0) < 0.02
    assert bool(jnp.isfinite(z1).all())


def test_crossbar_vmm_validates_inputs():
    spec, x, w, prog = _toy_pair(64, 32, seed=5)
    with pytest.raises(ValueError, match="x"):
        ops.crossbar_vmm(prog, x[0], spec)           # 1-D input
    with pytest.raises(ValueError, match="non-floating"):
        ops.crossbar_vmm(prog, x.astype(jnp.int32), spec)
