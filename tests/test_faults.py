"""Device faults, write–verify programming, and the robustness gates.

The contracts under test (repro.core.faults + the write–verify loop in
repro.core.analogue + in-kernel injection in the Pallas kernels):

* fault identity is counter-derived — the same (seed, salt, cell) is
  stuck everywhere: jnp program-time baking and in-kernel re-injection
  agree bitwise, independent of kernel tiling;
* ``program_with_verify`` converges on healthy arrays, repairs stuck
  cells through the differential-pair partner, and reports what it
  cannot fix;
* the ISSUE acceptance gate: at 1% stuck cells, write–verify keeps the
  HP rollout error within 2x the fault-free analogue margin;
* extreme-but-legal ``AnalogueSpec``s (degenerate g_on ~ g_off, all-zero
  weights) program without NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analogue as an
from repro.core.analogue import AnalogueSpec, VerifyConfig
from repro.core.backends import (AnalogueBackend, DigitalBackend,
                                 FusedAnalogueBackend)
from repro.core.faults import (FAULT_SALT_BASE, ConductanceDrift, FaultModel,
                               StuckCells, WriteFailures, apply_faults_to_prog,
                               apply_stuck, drift_factor, fault_salt,
                               make_fault_model)
from repro.core.twin import TwinFleet, make_driven_twin
from repro.kernels.noise import stuck_cell_masks

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Registry + validation
# ---------------------------------------------------------------------------

def test_make_fault_model_composes():
    m = make_fault_model(("stuck", dict(rate=0.02)), "drift",
                         ("write_fail", dict(rate=0.3)), seed=7)
    assert m.stuck == StuckCells(rate=0.02)
    assert m.drift == ConductanceDrift()
    assert m.write_fail == WriteFailures(rate=0.3)
    assert m.seed == 7 and m.stuck_rate == 0.02 and m.write_fail_rate == 0.3


def test_make_fault_model_rejects_unknown_and_duplicates():
    with pytest.raises(ValueError, match="unknown fault mechanism"):
        make_fault_model("cosmic_rays")
    with pytest.raises(ValueError, match="given twice"):
        make_fault_model("stuck", ("stuck", dict(rate=0.1)))


@pytest.mark.parametrize("cls,kw", [
    (StuckCells, dict(rate=1.5)),
    (StuckCells, dict(on_frac=-0.1)),
    (ConductanceDrift, dict(nu=-1.0)),
    (ConductanceDrift, dict(tau=0.0)),
    (WriteFailures, dict(rate=2.0)),
])
def test_mechanism_validation(cls, kw):
    with pytest.raises(ValueError):
        cls(**kw)


@pytest.mark.parametrize("kw", [dict(tol=0.0), dict(max_retries=-1),
                                dict(backoff=0.0), dict(backoff=1.5)])
def test_verify_config_validation(kw):
    with pytest.raises(ValueError):
        VerifyConfig(**kw)


def test_kernel_args_schema():
    m = make_fault_model(("stuck", dict(rate=0.05, on_frac=0.25)),
                         ("drift", dict(nu=0.02, tau=500.0)), seed=3)
    ka = m.kernel_args(n_reads=40)
    assert ka == {"stuck_rate": 0.05, "stuck_on_frac": 0.25, "fault_seed": 3,
                  "salt_base": FAULT_SALT_BASE, "drift_nu": 0.02,
                  "drift_tau": 500.0, "drift_n0": 40}


# ---------------------------------------------------------------------------
# Counter-derived stuck masks: determinism, tiling independence
# ---------------------------------------------------------------------------

def test_stuck_masks_deterministic_and_rate():
    is_stuck, stuck_on = stuck_cell_masks(3, fault_salt(0, 0), (64, 64),
                                          0.1, 0.5)
    is_stuck2, _ = stuck_cell_masks(3, fault_salt(0, 0), (64, 64), 0.1, 0.5)
    np.testing.assert_array_equal(np.asarray(is_stuck), np.asarray(is_stuck2))
    frac = float(jnp.mean(is_stuck))
    assert 0.05 < frac < 0.16                  # ~Binomial(4096, 0.1)
    on = float(jnp.mean(stuck_on[is_stuck]))
    assert 0.3 < on < 0.7
    # different salts draw independent masks
    other, _ = stuck_cell_masks(3, fault_salt(0, 1), (64, 64), 0.1, 0.5)
    assert bool(jnp.any(is_stuck != other))


def test_stuck_masks_tiling_independent():
    """A (row0, col0) block of the mask equals the slice of the full
    mask — the property that makes the blocked kernel agree with the
    unblocked jnp baking."""
    full, full_on = stuck_cell_masks(9, 17, (32, 48), 0.2, 0.4)
    blk, blk_on = stuck_cell_masks(9, 17, (8, 16), 0.2, 0.4,
                                   row0=16, col0=32, ncols=48)
    np.testing.assert_array_equal(np.asarray(full[16:24, 32:48]),
                                  np.asarray(blk))
    np.testing.assert_array_equal(np.asarray(full_on[16:24, 32:48]),
                                  np.asarray(blk_on))


def test_apply_stuck_idempotent():
    g = jnp.linspace(20e-6, 100e-6, 64).reshape(8, 8)
    once = apply_stuck(g, 1, 5, 0.3, 0.5, 100e-6, 20e-6)
    twice = apply_stuck(once, 1, 5, 0.3, 0.5, 100e-6, 20e-6)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))
    assert bool(jnp.any(once != g))


def test_drift_factor_power_law():
    m = make_fault_model(("drift", dict(nu=0.05, tau=100.0)))
    np.testing.assert_allclose(float(drift_factor(m, 300)),
                               (1 + 300 / 100.0) ** -0.05, rtol=1e-6)
    assert float(drift_factor(None, 1000)) == 1.0
    assert float(drift_factor(make_fault_model("stuck"), 1000)) == 1.0


# ---------------------------------------------------------------------------
# Write–verify programming
# ---------------------------------------------------------------------------

def test_program_with_verify_converges_fault_free():
    w = jax.random.normal(jax.random.PRNGKey(1), (14, 14))
    spec = AnalogueSpec(prog_noise=0.0436)
    prog, rep = an.program_with_verify(KEY, w, spec)
    assert rep.n_unrepairable == 0
    assert rep.max_error <= rep.tol
    assert rep.attempts <= 1 + VerifyConfig().max_retries
    # realised weights match the target well within one quantisation step
    got = (prog["gp"] - prog["gm"]) / prog["scale"]
    assert float(jnp.abs(got - w).max()) <= rep.tol * float(
        jnp.abs(w).max()) * 1.5


def test_verify_beats_naive_under_write_failures():
    w = jax.random.normal(jax.random.PRNGKey(2), (14, 14))
    spec = AnalogueSpec(prog_noise=0.0436)
    fm = make_fault_model(("write_fail", dict(rate=0.4)), seed=11)
    _, rep_naive = an.program_with_verify(
        KEY, w, spec, faults=fm, verify=VerifyConfig(max_retries=0))
    _, rep_ver = an.program_with_verify(KEY, w, spec, faults=fm)
    assert rep_ver.max_error < rep_naive.max_error
    assert rep_ver.projected_rollout_error < rep_naive.projected_rollout_error


def test_verify_repairs_stuck_cells_via_partner():
    """Stuck cells ignore writes; the loop retargets the partner device
    so the differential weight still comes out right wherever the range
    allows — naive programming carries the full fault."""
    w = jax.random.normal(jax.random.PRNGKey(3), (14, 14))
    spec = AnalogueSpec(prog_noise=0.0)
    fm = make_fault_model(("stuck", dict(rate=0.05)), seed=5)
    _, rep_naive = an.program_with_verify(
        KEY, w, spec, faults=fm, verify=VerifyConfig(max_retries=0))
    _, rep_ver = an.program_with_verify(KEY, w, spec, faults=fm)
    assert rep_ver.mean_error < rep_naive.mean_error
    assert rep_ver.n_unrepairable < int(rep_naive.unrepairable.sum())


def test_unrepairable_cells_reported():
    """A G_on-stuck cell whose partner would need to exceed g_max to
    compensate is unrepairable — the report must say so rather than
    pretend convergence."""
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 32))
    spec = AnalogueSpec(prog_noise=0.0)
    fm = make_fault_model(("stuck", dict(rate=0.5, on_frac=1.0)), seed=2)
    _, rep = an.program_with_verify(KEY, w, spec, faults=fm)
    assert rep.n_unrepairable > 0
    assert rep.unrepairable.shape == w.shape
    s = rep.summary()
    assert s["n_unrepairable"] == rep.n_unrepairable
    assert 0 < s["projected_rollout_error"]


def test_program_with_verify_jit_safe():
    w = jax.random.normal(jax.random.PRNGKey(5), (8, 8))
    spec = AnalogueSpec(prog_noise=0.0436)

    @jax.jit
    def run(w):
        prog, rep = an.program_with_verify(KEY, w, spec)
        return prog["gp"], rep.max_error

    gp, err = run(w)
    prog_e, rep_e = an.program_with_verify(KEY, w, spec)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(prog_e["gp"]),
                               rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Program-time baking == in-kernel injection
# ---------------------------------------------------------------------------

def test_backend_parity_jnp_vs_fused_under_faults():
    """AnalogueBackend bakes the stuck cells into the conductances;
    FusedAnalogueBackend re-derives the same masks inside the kernel —
    trajectories must agree to float32 rounding."""
    drive = lambda t: jnp.sin(4 * t)
    twin = make_driven_twin(1, drive)
    params = twin.init(KEY)
    ts = jnp.linspace(0.0, 0.1, 21)
    y0 = jnp.array([0.2])
    spec = AnalogueSpec(prog_noise=0.0)
    fm = make_fault_model(("stuck", dict(rate=0.1)), seed=13)
    outs = {}
    for name, be in [
        ("jnp", AnalogueBackend(spec=spec, prog_key=KEY, faults=fm)),
        ("fused", FusedAnalogueBackend(spec=spec, prog_key=KEY, faults=fm)),
    ]:
        st = be.program(twin.node.field, params)
        outs[name] = be.rollout(st, y0, ts)
    np.testing.assert_allclose(np.asarray(outs["jnp"]),
                               np.asarray(outs["fused"]),
                               rtol=0, atol=2e-6)
    # faults actually moved the trajectory
    clean = AnalogueBackend(spec=spec, prog_key=KEY)
    st = clean.program(twin.node.field, params)
    assert float(jnp.abs(clean.rollout(st, y0, ts) - outs["jnp"]).max()) > 1e-4


def test_backend_drift_snapshot_matches_factor():
    """AnalogueBackend's drift snapshot scales the whole differential,
    so the realised vector field scales by drift_factor(n_reads)."""
    drive = lambda t: jnp.sin(4 * t)
    twin = make_driven_twin(1, drive)
    params = twin.init(KEY)
    spec = AnalogueSpec(prog_noise=0.0, quantize=False)
    fm = make_fault_model(("drift", dict(nu=0.05, tau=100.0)), seed=0)
    be0 = AnalogueBackend(spec=spec, prog_key=KEY)
    be1 = AnalogueBackend(spec=spec, prog_key=KEY, faults=fm, n_reads=400)
    st0 = be0.program(twin.node.field, params)
    st1 = be1.program(twin.node.field, params)
    x = jnp.array([0.3])
    f0 = be0.apply(st0, 0.1, x)
    f1 = be1.apply(st1, 0.1, x)
    fac = float(drift_factor(fm, 400))
    # layered nonlinearity means the output is not exactly fac * f0, but
    # the first-layer preactivation is — check via a linear probe: both
    # must differ, and re-scaling the conductances back must recover f0
    st_rescaled = be0.program(twin.node.field, params)
    assert float(jnp.abs(f1 - f0).max()) > 0
    progs1 = st1.field.progs
    progs0 = st0.field.progs
    for p0, p1 in zip(progs0, progs1):
        np.testing.assert_allclose(np.asarray(p1["gp"]),
                                   np.asarray(p0["gp"]) * fac, rtol=1e-6)


def test_uint8_storage_rejects_drift():
    twin = make_driven_twin(1, lambda t: jnp.sin(t))
    params = twin.init(KEY)
    fm = make_fault_model("drift")
    be = AnalogueBackend(spec=AnalogueSpec(prog_noise=0.0), storage="uint8",
                         faults=fm)
    with pytest.raises(ValueError, match="drift"):
        be.program(twin.node.field, params)


def test_apply_faults_to_prog_uint8_stuck_on_grid():
    spec = AnalogueSpec(prog_noise=0.0)
    w = jax.random.normal(jax.random.PRNGKey(6), (16, 16))
    prog = an.program_tensor(KEY, w, spec)
    staged = an.stage_uint8(prog, spec)
    fm = make_fault_model(("stuck", dict(rate=0.2)), seed=4)
    out = apply_faults_to_prog(staged, fm, spec, layer=0)
    # float view and uint8 view stay consistent (stuck levels are the
    # grid endpoints)
    step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    recon = spec.g_min + out["gp_idx"].astype(jnp.float32) * step
    np.testing.assert_allclose(np.asarray(recon), np.asarray(out["gp"]),
                               rtol=0, atol=1e-9)


# ---------------------------------------------------------------------------
# The ISSUE acceptance gate: 1% stuck + write–verify within 2x fault-free
# ---------------------------------------------------------------------------

def test_hp_rollout_error_within_2x_margin_at_1pct_stuck():
    fam = lambda t, th: th[0] * jnp.sin(2.0 * jnp.pi * th[1] * t)
    twin = make_driven_twin(1, drive=None, hidden=14)
    params = twin.init(KEY)
    fleet = TwinFleet(twin, drive_family=fam)
    ts = jnp.linspace(0.0, 0.1, 101)
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    y0s = 0.3 * jax.random.normal(k1, (8, 1))
    thetas = 1.0 + jax.random.uniform(k2, (8, 2))
    ref = fleet.rollout_batch(params, y0s, ts, thetas)
    refn = float(jnp.linalg.norm(ref))
    spec = AnalogueSpec(prog_noise=0.0436)
    pk = jax.random.PRNGKey(17)

    def err(be):
        out = fleet.with_backend(be).rollout_batch(params, y0s, ts, thetas)
        return float(jnp.linalg.norm(out - ref)) / refn

    margin = err(FusedAnalogueBackend(spec=spec, prog_key=pk))
    fm = make_fault_model(("stuck", dict(rate=0.01)), seed=3)
    e_verify = err(FusedAnalogueBackend(spec=spec, prog_key=pk, faults=fm,
                                        verify=VerifyConfig()))
    assert e_verify <= 2.0 * margin, (e_verify, margin)


def test_repair_reports_surface_through_backend():
    twin = make_driven_twin(1, lambda t: jnp.sin(t))
    params = twin.init(KEY)
    fm = make_fault_model(("stuck", dict(rate=0.02)), seed=1)
    for be in [AnalogueBackend(faults=fm, verify=VerifyConfig()),
               FusedAnalogueBackend(faults=fm, verify=VerifyConfig())]:
        st = be.program(twin.node.field, params)
        reps = (st.extra.get("repair_reports") if isinstance(st.extra, dict)
                else None)
        assert reps is not None and len(reps) == len(params)
        assert all(r.attempts >= 1 for r in reps)


# ---------------------------------------------------------------------------
# Extreme-but-legal specs (satellite: programming_error / stage_uint8)
# ---------------------------------------------------------------------------

def test_programming_error_zero_weights():
    spec = AnalogueSpec(prog_noise=0.0)
    w = jnp.zeros((6, 5))
    prog = an.program_tensor(KEY, w, spec)
    e = an.programming_error(prog, w, spec)
    assert bool(jnp.isfinite(e).all()) and float(e.max()) == 0.0
    staged = an.stage_uint8(prog, spec)
    assert int(staged["gp_idx"].max()) == 0  # all cells parked at g_min


def test_programming_error_degenerate_range():
    """g_on ~ g_off (worn array): the mapping degrades gracefully —
    finite errors, uint8 staging round-trips."""
    spec = AnalogueSpec(g_min=50e-6, g_max=50.0001e-6, prog_noise=0.0)
    w = jax.random.normal(jax.random.PRNGKey(8), (8, 8))
    prog = an.program_tensor(KEY, w, spec)
    e = an.programming_error(prog, w, spec)
    assert bool(jnp.isfinite(e).all())
    staged = an.stage_uint8(prog, spec)
    step = (spec.g_max - spec.g_min) / (spec.levels - 1)
    recon = spec.g_min + staged["gp_idx"].astype(jnp.float32) * step
    np.testing.assert_allclose(np.asarray(recon), np.asarray(prog["gp"]),
                               rtol=0, atol=step)


def test_analogue_spec_rejects_inverted_range():
    with pytest.raises(ValueError, match="g_max"):
        AnalogueSpec(g_min=100e-6, g_max=20e-6)
    with pytest.raises(ValueError, match="levels"):
        AnalogueSpec(levels=1)
    with pytest.raises(ValueError, match="sigmas"):
        AnalogueSpec(prog_noise=-0.1)
