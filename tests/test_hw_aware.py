"""Hardware-aware training: STE semantics, the step-keyed determinism
contract of ``fit(hw_aware=...)``, and the differentiable training mode
of the fused analogue backend."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analogue import AnalogueSpec, spec_from_calibration
from repro.core.backends import FusedAnalogueBackend
from repro.core.faults import make_fault_model
from repro.data import hp_memristor as hp
from repro.core.twin import make_driven_twin
from repro.train import trainer
from repro.train.hw_aware import (HwAwareConfig, expectation_over_draws,
                                  hw_aware_params)
from repro.train.optimizer import adam

SPEC = spec_from_calibration("calibration/paper_device.json")


@pytest.fixture(scope="module")
def hp_setup():
    ts, xs, _, _ = hp.generate("sine", num_points=500, dt=1e-3,
                               amp=2.0, freq=2.0)
    ys = xs[:, None]
    twin = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=2.0, freq=2.0),
                            hidden=14)
    params = twin.init(jax.random.PRNGKey(42))
    ts_seg, ys_seg = trainer.make_segments(ts, ys, 50)
    return twin, params, ts_seg, ys_seg


def _leaves(tree):
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _trees_equal(a, b):
    return all(np.array_equal(x, y) for x, y in zip(_leaves(a), _leaves(b)))


# ---------------------------------------------------------------------------
# The write-path transform
# ---------------------------------------------------------------------------

def test_transform_is_deterministic_per_seed_step_draw(hp_setup):
    _, params, _, _ = hp_setup
    cfg = HwAwareConfig(spec=SPEC, k_draws=3, noise_seed=7)
    a = hw_aware_params(params, cfg, 11, 1)
    b = hw_aware_params(params, cfg, 11, 1)
    assert _trees_equal(a, b)
    # every key component changes the realisation
    assert not _trees_equal(a, hw_aware_params(params, cfg, 12, 1))
    assert not _trees_equal(a, hw_aware_params(params, cfg, 11, 2))
    cfg2 = dataclasses.replace(cfg, noise_seed=8)
    assert not _trees_equal(a, hw_aware_params(params, cfg2, 11, 1))
    # under jit the traced step is deterministic too, and matches the
    # eager realisation to float32 rounding (same counter-derived noise
    # bits; only the fused float arithmetic differs between programs)
    f = jax.jit(lambda s: hw_aware_params(params, cfg, s, 1))
    c1, c2 = f(jnp.asarray(11, jnp.int32)), f(jnp.asarray(11, jnp.int32))
    assert _trees_equal(c1, c2)
    for x, y in zip(_leaves(a), _leaves(c1)):
        np.testing.assert_allclose(x, y, rtol=2e-6, atol=1e-7)


def test_transform_gradient_is_identity(hp_setup):
    """The STE: d/dw sum(transform(w)) == 1 exactly, through quantise,
    noise, stuck cells and drift."""
    _, params, _, _ = hp_setup
    fm = make_fault_model(("stuck", dict(rate=0.05)), "drift", seed=3)
    cfg = HwAwareConfig(spec=SPEC, k_draws=2, noise_seed=0, faults=fm,
                        fault_ensemble=True, drift_reads=1000)

    def total(p):
        eff = hw_aware_params(p, cfg, 4, 1)
        return sum(jnp.sum(l["w"]) + jnp.sum(l["b"]) for l in eff)

    g = jax.grad(total)(params)
    for leaf in _leaves(g):
        np.testing.assert_array_equal(leaf, np.ones_like(leaf))


def test_transform_forward_matches_quantised_write(hp_setup):
    """With all noise off, the forward value is exactly the post-hoc
    deployment: a rollout with the transformed params on the fused
    digital kernel matches the analogue_fused substrate."""
    twin, params, _, _ = hp_setup
    spec0 = dataclasses.replace(SPEC, prog_noise=0.0, read_noise=0.0)
    cfg = HwAwareConfig(spec=spec0, k_draws=1)
    eff = jax.tree_util.tree_map(np.asarray,
                                 hw_aware_params(params, cfg, 0, 0))

    ts = np.linspace(0.0, 0.05, 51).astype(np.float32)
    y0 = jnp.asarray([[0.1]], jnp.float32)
    be_a = FusedAnalogueBackend(spec=spec0, batch_tile=8)
    st_a = be_a.program(twin.field, params)
    out_a = be_a.rollout_batch_local(st_a, y0, jnp.asarray(ts))
    from repro.core.backends import FusedPallasBackend
    be_d = FusedPallasBackend(batch_tile=8)
    st_d = be_d.program(twin.field, eff)
    out_d = be_d.rollout_batch_local(st_d, y0, jnp.asarray(ts))
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_d),
                               rtol=2e-4, atol=2e-6)


def test_expectation_over_draws_averages():
    cfg = HwAwareConfig(k_draws=4)
    val = expectation_over_draws(lambda d: jnp.float32(d), cfg)
    assert float(val) == pytest.approx(1.5)


def test_config_validation_names_field():
    with pytest.raises(ValueError, match="k_draws"):
        HwAwareConfig(k_draws=0)
    with pytest.raises(ValueError, match="read_sigma"):
        HwAwareConfig(read_sigma=-0.1)
    with pytest.raises(ValueError, match="fault_ensemble"):
        HwAwareConfig(fault_ensemble=True)


# ---------------------------------------------------------------------------
# fit(hw_aware=...): one jitted scan, step-keyed, bitwise-reproducible
# ---------------------------------------------------------------------------

def test_fit_hw_aware_bitwise_reproducible(hp_setup):
    """The acceptance contract: same seed => bitwise-identical loss
    history run to run, and the same history for any chunking of the
    scan (the noise draws are keyed by the ABSOLUTE step carried through
    the scan, not the chunk layout) and for the per-step reference
    engine — to float32 rounding across those distinct compiled
    programs."""
    twin, params, ts_seg, ys_seg = hp_setup
    cfg = HwAwareConfig(spec=SPEC, k_draws=2, noise_seed=1)
    loss_fn = trainer.segment_loss_fn(twin, ts_seg, ys_seg, "l1",
                                      noise_std=0.002, hw_aware=cfg)
    assert loss_fn.wants_step
    steps = 9
    runs = {}
    for chunk in (None, 1, 4):
        _, hist = trainer.fit(loss_fn, params, adam(1e-3), steps,
                              jax.random.PRNGKey(6), scan_chunk=chunk)
        runs[chunk] = np.asarray(hist)
    _, h_ref = trainer.fit_per_step(loss_fn, params, adam(1e-3), steps,
                                    jax.random.PRNGKey(6))
    np.testing.assert_allclose(runs[None], runs[1], rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(runs[None], runs[4], rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(runs[None], np.asarray(h_ref),
                               rtol=1e-6, atol=1e-8)
    # run-to-run bitwise repeatability (no hidden state anywhere) — THE
    # acceptance gate: same seed, same chunking => identical history
    _, again = trainer.fit(loss_fn, params, adam(1e-3), steps,
                           jax.random.PRNGKey(6), scan_chunk=4)
    np.testing.assert_array_equal(runs[4], np.asarray(again))


def test_fit_hw_aware_step_keying_matters(hp_setup):
    """Different noise_seed => different loss history (the device draws
    are live, not constant-folded away)."""
    twin, params, ts_seg, ys_seg = hp_setup
    hists = []
    for seed in (1, 2):
        cfg = HwAwareConfig(spec=SPEC, k_draws=2, noise_seed=seed)
        loss_fn = trainer.segment_loss_fn(twin, ts_seg, ys_seg, "l1",
                                          hw_aware=cfg)
        _, h = trainer.fit(loss_fn, params, adam(1e-3), 5,
                           jax.random.PRNGKey(6))
        hists.append(np.asarray(h))
    assert not np.array_equal(hists[0], hists[1])


def test_fused_substrate_hw_aware_loss(hp_setup):
    """hw_aware composes with the fused-Pallas training path (the STE is
    upstream of the kernel, so the reverse-time VJP needs no changes)."""
    from repro.core.backends import FusedPallasBackend
    twin, params, ts_seg, ys_seg = hp_setup
    cfg = HwAwareConfig(spec=SPEC, k_draws=2, noise_seed=1)
    be = FusedPallasBackend(batch_tile=8)
    loss_fn = trainer.segment_loss_fn(twin, ts_seg, ys_seg, "l1",
                                      backend=be, hw_aware=cfg)
    assert loss_fn.wants_step
    _, h1 = trainer.fit(loss_fn, params, adam(1e-3), 4,
                        jax.random.PRNGKey(0), scan_chunk=2)
    _, h2 = trainer.fit(loss_fn, params, adam(1e-3), 4,
                        jax.random.PRNGKey(0), scan_chunk=None)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    assert np.all(np.isfinite(np.asarray(h1)))


def test_analogue_fused_backend_training_auto_hw_aware(hp_setup):
    """Training on the analogue_fused substrate implies hardware-aware
    mode: the loss is step-keyed and sees the backend's own device model
    (previously this silently trained on the clean digital kernel)."""
    twin, params, ts_seg, ys_seg = hp_setup
    be = FusedAnalogueBackend(spec=SPEC, batch_tile=8)
    loss_fn = trainer.segment_loss_fn(twin, ts_seg, ys_seg, "l1",
                                      backend=be)
    assert getattr(loss_fn, "wants_step", False)
    clean_fn = trainer.segment_loss_fn(twin, ts_seg, ys_seg, "l1",
                                       backend="fused_pallas")
    l_hw = float(loss_fn(params, None, jnp.int32(0)))
    l_clean = float(clean_fn(params, None))
    assert np.isfinite(l_hw) and l_hw != l_clean


@pytest.mark.slow
def test_noise_aware_training_beats_clean_2x():
    """The headline acceptance gate (ISSUE / ``BENCH_robustness.json``):
    at the paper-level operating point (6-bit quantisation, calibrated
    programming + read noise), noise-aware-trained weights deployed on
    the noisy ``analogue_fused`` substrate cut the trajectory error by
    >= 2x vs clean-trained post-hoc-quantised weights, and land within
    the acceptable margin (2x the clean weights' noise-free analogue
    error — the same convention as the fault-tolerance gates).

    Full paper training budget; k_draws=2 keeps it ~2 min (measured
    improvement ~4.7x, so the 2x gate has wide headroom)."""
    from repro.train import recipes
    from repro.train.hw_aware import HwAwareConfig

    twin, p_clean, _ = recipes.train_hp_twin(seed=42)
    cfg = HwAwareConfig(spec=SPEC, k_draws=2, noise_seed=0)
    _, p_hw, _ = recipes.train_hp_twin(seed=42, hw_aware=cfg)

    def an_mre(params, spec, seeds=(0, 1)):
        errs = []
        for rs in seeds:
            be = FusedAnalogueBackend(spec=spec,
                                      prog_key=jax.random.PRNGKey(100),
                                      read_seed=rs)
            errs.append(recipes.eval_hp_twin(twin, params, "sine",
                                             backend=be)["mre"])
        return float(np.mean(errs))

    spec_nf = dataclasses.replace(SPEC, read_noise=0.0)
    margin = 2.0 * an_mre(p_clean, spec_nf, seeds=(0,))
    e_clean = an_mre(p_clean, SPEC)
    e_hw = an_mre(p_hw, SPEC)
    assert e_hw <= margin, (
        f"hw-aware weights outside the deployment margin: "
        f"mre {e_hw:.4f} > {margin:.4f}")
    assert e_clean / e_hw >= 2.0, (
        f"noise-aware training below the 2x gate: clean {e_clean:.4f} "
        f"vs hw-aware {e_hw:.4f} (x{e_clean / e_hw:.2f})")


def test_trainable_backend_solve_is_differentiable(hp_setup):
    """FusedAnalogueBackend(trainable=True): gradients flow through the
    write path to the f32 masters; trainable=False stays detached."""
    twin, params, _, _ = hp_setup
    ts = jnp.linspace(0.0, 0.05, 51)
    y0 = jnp.asarray([0.1], jnp.float32)

    def loss_through(be):
        state = be.program(twin.field, params)
        if be.trainable:
            masters = [{"w": w, "b": b}
                       for w, b in zip(state.extra["weights"],
                                       state.extra["biases"])]

            def f(ms):
                st = dataclasses.replace(be, trainable=True).program(
                    twin.field, ms)
                return jnp.sum(be.rollout(st, y0, ts))
            return jax.grad(f)(masters)
        return None

    be = FusedAnalogueBackend(spec=SPEC, batch_tile=8, trainable=True)
    grads = loss_through(be)
    leaves = _leaves(grads)
    assert all(np.all(np.isfinite(l)) for l in leaves)
    assert any(np.any(l != 0) for l in leaves)

    # non-trainable stays detached whatever gradient mode is requested
    be0 = FusedAnalogueBackend(spec=SPEC, batch_tile=8)
    st0 = be0.program(twin.field, params)
    out = be0.rollout(st0, y0, ts, gradient="fused_vjp")
    assert np.all(np.isfinite(np.asarray(out)))
