"""Checkpointing, compression, sharding rules."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train.compression import compressed, topk_sparsify
from repro.train.optimizer import adam, apply_updates

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 16)),
            "nested": {"b": jax.random.normal(k2, (4,)),
                       "step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 10, tree)
    assert ckpt.latest_step(str(tmp_path)) == 10
    restored = ckpt.restore(str(tmp_path), 10, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_atomicity(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(str(tmp_path), s, tree, keep=2)
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_0000000004", "step_0000000005"]
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_async(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    ckpt.save(str(tmp_path), 3, tree, blocking=False)
    ckpt.wait_for_async()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_checkpoint_elastic_reshard_subprocess(tmp_path):
    """Save on a 4-device mesh, restore onto an 8-device mesh (elastic)."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt
        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh4 = jax.make_mesh((4,), ("model",),
                              devices=jax.devices()[:4])
        sh4 = {{"w": NamedSharding(mesh4, P("model", None))}}
        placed = jax.device_put(tree["w"], sh4["w"])
        ckpt.save(r"{tmp_path}", 1, {{"w": placed}})
        mesh8 = jax.make_mesh((8,), ("model",))
        sh8 = {{"w": NamedSharding(mesh8, P(None, "model"))}}
        out = ckpt.restore(r"{tmp_path}", 1, tree, shardings=sh8)
        assert out["w"].sharding == sh8["w"], out["w"].sharding
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))
        print("ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ,
                                        "PYTHONPATH": f"{REPO}/src"})
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_compression_error_feedback_preserves_convergence():
    """Quadratic bowl: int8+EF must reach (near) the same optimum."""
    target = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p - target) ** 2)

    for opt in [adam(0.05), compressed(adam(0.05), bits=8)]:
        p = jnp.zeros(3)
        s = opt.init(p)
        for _ in range(300):
            g = jax.grad(loss)(p)
            u, s = opt.update(g, s, p)
            p = apply_updates(p, u)
        assert float(loss(p)) < 1e-3


def test_topk_sparsify_residual():
    g = jnp.arange(-5.0, 5.0)
    kept, resid = topk_sparsify(g, 0.2)
    assert float(jnp.count_nonzero(kept)) <= 3
    np.testing.assert_allclose(np.asarray(kept + resid), np.asarray(g))


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_sharding_rules_divisibility_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.launch.mesh import make_production_mesh
        from repro.launch.sharding import param_shardings
        from repro.models.model import init_params

        mesh = make_production_mesh()
        # qwen1.5: 40 heads not divisible by 16 -> attention TP replicated
        # (Megatron-canonical rules: NO head_dim fallback, see §Perf iter 2)
        cfg = get_config("qwen1.5-32b")
        sds = jax.eval_shape(lambda k: init_params(cfg, k),
                             jax.random.PRNGKey(0))
        sh = param_shardings(mesh, sds)
        wq = sh["stack"]["b0"]["mixer"]["wq"].spec
        assert wq == P(None, "data", None, None), wq
        # ... and the optimised variant pads heads to 48 -> TP restored
        from repro.configs.optimized import get_optimized
        cfg = get_optimized("qwen1.5-32b")
        sds = jax.eval_shape(lambda k: init_params(cfg, k),
                             jax.random.PRNGKey(0))
        sh = param_shardings(mesh, sds)
        wq = sh["stack"]["b0"]["mixer"]["wq"].spec
        assert wq == P(None, "data", "model", None), wq
        # llama3: 32 heads divisible -> heads sharded
        cfg = get_config("llama3-8b")
        sds = jax.eval_shape(lambda k: init_params(cfg, k),
                             jax.random.PRNGKey(0))
        sh = param_shardings(mesh, sds)
        wq = sh["stack"]["b0"]["mixer"]["wq"].spec
        assert wq == P(None, "data", "model", None), wq
        # MoE experts on the model axis (EP)
        cfg = get_config("deepseek-v2-lite-16b")
        sds = jax.eval_shape(lambda k: init_params(cfg, k),
                             jax.random.PRNGKey(0))
        sh = param_shardings(mesh, sds)
        wup = sh["stack"]["b0"]["ffn"]["w_up"].spec
        assert wup[1] == "model", wup
        print("SHARDING_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ,
                                        "PYTHONPATH": f"{REPO}/src"})
    assert "SHARDING_OK" in r.stdout, (r.stdout[-500:], r.stderr[-2000:])


# Pipeline parallelism and the LM train driver moved to
# repro.launch.legacy; their tests live in tests/test_legacy_launch.py.
