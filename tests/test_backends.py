"""Backend parity: the same weights must produce the same trajectory on
every execution substrate (paper's portability claim, Fig. 3-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.analogue import AnalogueSpec
from repro.core.backends import (AnalogueBackend, DigitalBackend,
                                 FusedPallasBackend, resolve_backend)
from repro.core.ode import odeint
from repro.core.twin import TwinFleet, make_autonomous_twin, make_driven_twin

KEY = jax.random.PRNGKey(0)
DRIVE = lambda t: jnp.sin(4.0 * t)

NOISE_FREE = AnalogueSpec(prog_noise=0.0, read_noise=0.0, quantize=False)


@pytest.fixture(scope="module")
def hp_setup():
    """Paper's HP-twin shape (2->14->14->1), driven."""
    twin = make_driven_twin(1, DRIVE)
    params = twin.init(KEY)
    ts = jnp.linspace(0.0, 0.25, 51)
    y0 = jnp.array([0.2])
    return twin, params, y0, ts


@pytest.fixture(scope="module")
def l96_setup():
    """Paper's Lorenz96-twin shape (6->64->64->6), autonomous."""
    twin = make_autonomous_twin(6)
    params = twin.init(jax.random.fold_in(KEY, 1))
    ts = jnp.linspace(0.0, 0.125, 51)
    y0 = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 2), (6,))
    return twin, params, y0, ts


# ---------------------------------------------------------------------------
# (a) digital backend == odeint, exactly
# ---------------------------------------------------------------------------

def test_digital_backend_equals_odeint(hp_setup):
    twin, params, y0, ts = hp_setup
    got = twin.with_backend(DigitalBackend()).simulate(params, y0, ts)
    want = odeint(twin.field, y0, ts, params, method="rk4")
    assert jnp.array_equal(got, want)


def test_default_backend_is_digital(hp_setup):
    twin, params, y0, ts = hp_setup
    default = twin.simulate(params, y0, ts)
    explicit = twin.with_backend("digital").simulate(params, y0, ts)
    assert jnp.array_equal(default, explicit)


def test_resolve_backend_names():
    assert isinstance(resolve_backend("digital"), DigitalBackend)
    assert isinstance(resolve_backend("analogue"), AnalogueBackend)
    assert isinstance(resolve_backend("fused_pallas"), FusedPallasBackend)
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("quantum")


# ---------------------------------------------------------------------------
# (b) fused Pallas == digital within 1e-4
# ---------------------------------------------------------------------------

def test_fused_matches_digital_hp_driven(hp_setup):
    twin, params, y0, ts = hp_setup
    dig = twin.simulate(params, y0, ts)
    fus = twin.with_backend(
        FusedPallasBackend(batch_tile=1, precision="f32")).simulate(
            params, y0, ts)
    np.testing.assert_allclose(fus, dig, atol=1e-4, rtol=1e-4)


def test_fused_matches_digital_l96_autonomous(l96_setup):
    twin, params, y0, ts = l96_setup
    dig = twin.simulate(params, y0, ts)
    fus = twin.with_backend(
        FusedPallasBackend(batch_tile=1, precision="f32")).simulate(
            params, y0, ts)
    np.testing.assert_allclose(fus, dig, atol=1e-4, rtol=1e-4)


def test_fused_honours_steps_per_interval(hp_setup):
    twin, params, y0, ts = hp_setup
    twin_s = make_driven_twin(1, DRIVE, steps_per_interval=4)
    dig = twin_s.simulate(params, y0, ts)
    fus = twin_s.with_backend(
        FusedPallasBackend(precision="f32")).simulate(params, y0, ts)
    assert fus.shape == dig.shape
    np.testing.assert_allclose(fus, dig, atol=1e-4, rtol=1e-4)


def test_fused_rejects_non_uniform_grid(hp_setup):
    twin, params, y0, _ = hp_setup
    bad_ts = jnp.array([0.0, 0.1, 0.15, 0.4])
    with pytest.raises(ValueError, match="uniform"):
        twin.with_backend(FusedPallasBackend()).simulate(params, y0, bad_ts)


def test_fused_rejects_non_rk4(hp_setup):
    twin, params, y0, ts = hp_setup
    import dataclasses
    node = dataclasses.replace(twin.node, method="euler",
                               backend=FusedPallasBackend())
    with pytest.raises(ValueError, match="RK4"):
        node.trajectory(params, y0, ts)


def test_interpret_autodetect_off_tpu():
    from repro.kernels.fused_ode_mlp import _default_interpret
    if jax.default_backend() == "tpu":
        assert _default_interpret() is False
    else:
        # CPU/GPU hosts must fall back to the Pallas interpreter
        assert _default_interpret() is True


# ---------------------------------------------------------------------------
# (b') mixed precision: bf16 substrate == f32 digital within the
#      documented per-policy tolerance (docs/kernels.md)
# ---------------------------------------------------------------------------

# ISSUE acceptance: <= 1e-2 rel on the HP-twin config for bf16_f32acc;
# pure-bf16 carries compound one rounding per step, so its gate is wider
PRECISION_REL_TOL = {"f32": 1e-4, "bf16_f32acc": 1e-2, "bf16": 4e-2}


@pytest.mark.parametrize("precision", ["f32", "bf16_f32acc", "bf16"])
def test_fused_precision_matches_digital_hp(hp_setup, precision):
    twin, params, y0, ts = hp_setup
    dig = twin.simulate(params, y0, ts)
    fus = twin.with_backend(
        FusedPallasBackend(batch_tile=1, precision=precision)).simulate(
            params, y0, ts)
    scale = float(jnp.abs(dig).max())
    rel = float(jnp.abs(fus.astype(jnp.float32) - dig).max()) / scale
    assert rel <= PRECISION_REL_TOL[precision]


@pytest.mark.parametrize("precision,tol", [
    # pure bf16 re-rounds the carried state EVERY step, so on the wider
    # chaotic L96 twin the per-step eps (~4e-3) compounds with the flow's
    # Lipschitz growth; f32 accumulation keeps the drift ~30x smaller
    ("bf16_f32acc", 1e-2),
    ("bf16", 2e-1),
])
def test_fused_precision_matches_digital_l96(l96_setup, precision, tol):
    twin, params, y0, ts = l96_setup
    dig = twin.simulate(params, y0, ts)
    fus = twin.with_backend(
        FusedPallasBackend(batch_tile=1, precision=precision)).simulate(
            params, y0, ts)
    scale = float(jnp.abs(dig).max())
    rel = float(jnp.abs(fus.astype(jnp.float32) - dig).max()) / scale
    assert rel <= tol


def test_fused_precision_storage_dtype(hp_setup):
    """The bf16 policies actually store the trajectory at half width —
    the byte win is real, not cosmetic — while the STAGED weights stay
    f32 masters (so a per-call precision override never sees
    pre-rounded operands)."""
    twin, params, y0, ts = hp_setup
    be = FusedPallasBackend(batch_tile=1, precision="bf16_f32acc")
    fus = twin.with_backend(be).simulate(params, y0, ts)
    assert fus.dtype == jnp.bfloat16
    state = be.program(twin.field, params)
    assert all(w.dtype == jnp.float32 for w in state.extra["weights"])
    # f32 policy stays f32; an f32 per-call override on the bf16 backend
    # must match the f32 backend exactly (no double rounding)
    be32 = FusedPallasBackend(batch_tile=1, precision="f32")
    f32_traj = twin.with_backend(be32).simulate(params, y0, ts)
    assert f32_traj.dtype == jnp.float32
    over = be.rollout(be.program(twin.field, params), y0, ts,
                      precision="f32")
    np.testing.assert_array_equal(np.asarray(over), np.asarray(f32_traj))


def test_fused_precision_fleet_and_per_call_override(hp_setup):
    """precision threads through TwinFleet batching AND the per-call
    rollout_batch override used by sharded serving's solver_kw."""
    twin, params, _, ts = hp_setup

    def family(t, theta):
        return theta[0] * jnp.sin(theta[1] * t)

    y0s = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 60), (5, 1))
    thetas = 1.0 + jax.random.uniform(jax.random.fold_in(KEY, 61), (5, 2))
    fleet = TwinFleet(twin, drive_family=family)
    dig = fleet.simulate(params, y0s, ts, thetas)
    bf = fleet.with_backend(
        FusedPallasBackend(batch_tile=4, precision="bf16_f32acc")).simulate(
            params, y0s, ts, thetas)
    assert bf.dtype == jnp.bfloat16
    rel = float(jnp.abs(bf.astype(jnp.float32) - dig).max()
                / jnp.abs(dig).max())
    assert rel <= PRECISION_REL_TOL["bf16_f32acc"]
    # per-call override beats the backend attribute: an f32 backend asked
    # for bf16_f32acc must produce the identical bf16 trajectory
    be32 = FusedPallasBackend(batch_tile=4)
    state = be32.program(twin.field, params)
    over = be32.rollout_batch(state, y0s, ts, drive_family=family,
                              drive_params=thetas,
                              precision="bf16_f32acc")
    np.testing.assert_array_equal(np.asarray(over, np.float32),
                                  np.asarray(bf, np.float32))


def test_fused_precision_sharded_serving_matches_local(hp_setup):
    """The bf16 policy survives shard_map: sharded == single-device on
    the trivial mesh, still at storage dtype."""
    from repro.launch.mesh import make_twin_mesh
    twin, params, _, ts = hp_setup
    y0s = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 62), (6, 1))
    fleet = TwinFleet(twin).with_backend(
        FusedPallasBackend(batch_tile=2, precision="bf16_f32acc"))
    local = fleet.rollout_batch(params, y0s, ts)
    sharded = fleet.rollout_batch(params, y0s, ts, mesh=make_twin_mesh())
    assert sharded.dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(sharded, np.float32),
                                  np.asarray(local, np.float32))


# ---------------------------------------------------------------------------
# (c) noise-free analogue == digital within quantisation-free tolerance
# ---------------------------------------------------------------------------

def test_analogue_noise_free_matches_digital(hp_setup):
    twin, params, y0, ts = hp_setup
    dig = twin.simulate(params, y0, ts)
    ana = twin.with_backend(
        AnalogueBackend(spec=NOISE_FREE, prog_key=KEY)).simulate(
            params, y0, ts)
    np.testing.assert_allclose(ana, dig, atol=5e-4, rtol=1e-4)


def test_analogue_backend_supports_dopri5(hp_setup):
    """Adaptive dopri5 twins must still deploy to the analogue substrate
    (regression: the default rollout used to reject 'dopri5')."""
    twin, params, y0, ts = hp_setup
    twin5 = make_driven_twin(1, DRIVE, method="dopri5")
    dig = twin5.simulate(params, y0, ts)
    ana = twin5.with_backend(
        AnalogueBackend(spec=NOISE_FREE, prog_key=KEY)).simulate(
            params, y0, ts)
    np.testing.assert_allclose(ana, dig, atol=5e-4, rtol=1e-4)


def test_analogue_needs_params_or_progs(hp_setup):
    twin, params, y0, ts = hp_setup
    at = twin.with_backend(AnalogueBackend(spec=NOISE_FREE))
    with pytest.raises(ValueError, match="program the crossbars"):
        at.simulate(None, y0, ts)


def test_deploy_analogue_shim_still_works(hp_setup):
    """Legacy path: deprecation warning, pre-programmed crossbars, and
    the old ``simulate(None, ...)`` call pattern."""
    twin, params, y0, ts = hp_setup
    with pytest.warns(DeprecationWarning):
        at = twin.deploy_analogue(KEY, params, NOISE_FREE)
    old = at.simulate(None, y0, ts)
    new = twin.with_backend(
        AnalogueBackend(spec=NOISE_FREE, prog_key=KEY)).simulate(
            params, y0, ts)
    np.testing.assert_allclose(old, new, atol=1e-6)


# ---------------------------------------------------------------------------
# (d) batched fleet == stacked single-trajectory solves
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", [
    None,
    FusedPallasBackend(batch_tile=2, precision="f32"),
    AnalogueBackend(spec=NOISE_FREE, prog_key=KEY),
])
def test_simulate_batch_equals_stacked_singles(hp_setup, backend):
    twin, params, y0, ts = hp_setup
    if backend is not None:
        twin = twin.with_backend(backend)
    y0s = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 3), (4, 1))
    batched = twin.simulate_batch(params, y0s, ts)
    stacked = jnp.stack([twin.simulate(params, y, ts) for y in y0s])
    assert batched.shape == stacked.shape == (4, ts.shape[0], 1)
    np.testing.assert_allclose(batched, stacked, atol=1e-5, rtol=1e-5)


def test_fleet_per_twin_drives_match_across_backends(hp_setup):
    """Per-twin drive parameters: the fused grid-tiled path must agree
    with the digital vmap path."""
    twin, params, _, ts = hp_setup

    def family(t, theta):
        return theta[0] * jnp.sin(theta[1] * t)

    y0s = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 4), (4, 1))
    thetas = jnp.array([[1.0, 4.0], [0.5, 8.0], [2.0, 2.0], [1.5, 6.0]])
    fleet = TwinFleet(twin, drive_family=family)
    dig = fleet.simulate(params, y0s, ts, thetas)
    fus = fleet.with_backend(
        FusedPallasBackend(batch_tile=2, precision="f32")).simulate(
            params, y0s, ts, thetas)
    ana = fleet.with_backend(
        AnalogueBackend(spec=NOISE_FREE, prog_key=KEY)).simulate(
            params, y0s, ts, thetas)
    np.testing.assert_allclose(fus, dig, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(ana, dig, atol=5e-4, rtol=1e-4)


def test_fleet_drive_params_contract(hp_setup):
    twin, params, _, ts = hp_setup
    y0s = jnp.zeros((2, 1))
    fleet = TwinFleet(twin, drive_family=lambda t, th: th * jnp.sin(t))
    with pytest.raises(ValueError, match="together"):
        fleet.simulate(params, y0s, ts)


def test_fleet_autonomous_batch(l96_setup):
    twin, params, _, ts = l96_setup
    y0s = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 5), (8, 6))
    dig = TwinFleet(twin).simulate(params, y0s, ts)
    fus = TwinFleet(twin).with_backend(
        FusedPallasBackend(batch_tile=4, precision="f32")).simulate(
            params, y0s, ts)
    np.testing.assert_allclose(fus, dig, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("n", [5, 7, 13])
def test_fused_fleet_prime_sizes_pad_to_tile(hp_setup, n):
    """Prime fleet sizes must PAD up to the batch tile (one extra tile)
    instead of degenerating to bt=1 grid cells — and the padded rows must
    not leak into the result (parity vs the digital vmap path)."""
    twin, params, _, ts = hp_setup
    y0s = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 40 + n), (n, 1))
    dig = twin.simulate_batch(params, y0s, ts)
    fus = twin.with_backend(
        FusedPallasBackend(batch_tile=4, precision="f32")).simulate_batch(
            params, y0s, ts)
    assert fus.shape == dig.shape == (n, ts.shape[0], 1)
    np.testing.assert_allclose(fus, dig, atol=1e-4, rtol=1e-4)


def test_fused_fleet_prime_sizes_pad_per_twin_drives(hp_setup):
    """The padding path must also replicate per-twin drive slabs."""
    twin, params, _, ts = hp_setup

    def family(t, theta):
        return theta[0] * jnp.sin(theta[1] * t)

    n = 5
    y0s = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 50), (n, 1))
    thetas = 1.0 + jax.random.uniform(jax.random.fold_in(KEY, 51), (n, 2))
    fleet = TwinFleet(twin, drive_family=family)
    dig = fleet.simulate(params, y0s, ts, thetas)
    fus = fleet.with_backend(
        FusedPallasBackend(batch_tile=4, precision="f32")).simulate(
            params, y0s, ts, thetas)
    np.testing.assert_allclose(fus, dig, atol=1e-4, rtol=1e-4)


def test_fused_time_chunk_threads_through_backend(hp_setup):
    """An explicit time_chunk forcing many chunks must not change the
    trajectory the backend serves."""
    twin, params, y0, ts = hp_setup
    one = twin.with_backend(
        FusedPallasBackend(batch_tile=1, precision="f32")).simulate(
            params, y0, ts)
    many = twin.with_backend(
        FusedPallasBackend(batch_tile=1, time_chunk=7,
                           precision="f32")).simulate(
            params, y0, ts)
    np.testing.assert_allclose(many, one, atol=1e-6, rtol=1e-6)


def test_fused_fleet_long_horizon_rollout(l96_setup):
    """T=10,000-step fleet serving through the fused backend — the shape
    that used to die on the VMEM guard now streams in time chunks and
    matches the jnp reference kernel within 1e-4."""
    twin, params, _, _ = l96_setup
    from repro.kernels import ops
    T = 10000
    ts = jnp.linspace(0.0, T * 1e-4, T + 1)
    y0s = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 6), (64, 6))
    fleet = TwinFleet(twin).with_backend(
        FusedPallasBackend(batch_tile=64, precision="f32"))
    got = fleet.simulate(params, y0s, ts)
    assert got.shape == (64, T + 1, 6)
    uh = jnp.zeros((2 * T + 1, 0))
    want = jnp.transpose(
        ops.fused_node_rollout_ref(params, y0s, uh, float(ts[1] - ts[0])),
        (1, 0, 2))
    assert float(jnp.abs(got - want).max()) <= 1e-4


# ---------------------------------------------------------------------------
# training still differentiates through the digital backend
# ---------------------------------------------------------------------------

def test_digital_backend_adjoint_gradients(hp_setup):
    twin, params, y0, ts = hp_setup

    def loss(p):
        ys = twin.simulate(p, y0, ts[:9])
        return jnp.mean(ys ** 2)

    grads = jax.grad(loss)(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


# ---------------------------------------------------------------------------
# (g) fused-analogue backend: crossbar semantics on the fused kernel
# ---------------------------------------------------------------------------

QUANT_CLEAN = AnalogueSpec(prog_noise=0.0)   # quantised, no noise


def test_resolve_analogue_fused():
    from repro.core.backends import BACKENDS, FusedAnalogueBackend
    assert "analogue_fused" in BACKENDS
    assert isinstance(resolve_backend("analogue_fused"),
                      FusedAnalogueBackend)


def _analogue_pair(twin, params, spec, **fused_kw):
    """(jnp-sim state+backend, fused state+backend) with the SAME
    programming key — bitwise-identical crossbar programs."""
    from repro.core.backends import FusedAnalogueBackend
    sim = AnalogueBackend(spec=spec, prog_key=KEY)
    fused = FusedAnalogueBackend(spec=spec, prog_key=KEY, **fused_kw)
    return (sim, sim.program(twin.node.field, params),
            fused, fused.program(twin.node.field, params))


def test_analogue_fused_matches_sim_hp(hp_setup):
    """Noise-free fused rollout == jnp crossbar simulator (<=1e-5 rel)."""
    twin, params, y0, ts = hp_setup
    sim, st_s, fused, st_f = _analogue_pair(twin, params, QUANT_CLEAN)
    want = sim.rollout(st_s, y0, ts)
    got = fused.rollout(st_f, y0, ts)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel <= 1e-5


def test_analogue_fused_matches_sim_l96(l96_setup):
    twin, params, y0, ts = l96_setup
    sim, st_s, fused, st_f = _analogue_pair(twin, params, QUANT_CLEAN)
    want = sim.rollout(st_s, y0, ts)
    got = fused.rollout(st_f, y0, ts)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel <= 1e-5


def test_analogue_fused_uint8_matches_float(hp_setup):
    """Noise-free conductances sit exactly ON the 6-bit level grid, so
    the uint8 level-index deployment represents the float program
    exactly; the rollouts agree to float32 rounding (the dequant
    computes (i - j) * step where float mode subtracts the absolute
    conductances — one ulp apart)."""
    twin, params, y0, ts = hp_setup
    _, _, f_float, st_float = _analogue_pair(twin, params, QUANT_CLEAN)
    _, _, f_u8, st_u8 = _analogue_pair(twin, params, QUANT_CLEAN,
                                       storage="uint8")
    assert st_u8.extra["gps"][0].dtype == jnp.uint8
    a = f_float.rollout(st_float, y0, ts)
    b = f_u8.rollout(st_u8, y0, ts)
    rel = float(jnp.abs(a - b).max() / jnp.abs(a).max())
    assert rel <= 1e-6


def test_analogue_fused_fleet_per_twin_drives(hp_setup):
    """Fleet tiling + per-twin drives on the fused-analogue grid must
    match the jnp simulator's vmap path."""
    from repro.core.backends import FusedAnalogueBackend
    twin, params, _, ts = hp_setup

    def family(t, theta):
        return theta[0] * jnp.sin(theta[1] * t)

    y0s = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 11), (4, 1))
    thetas = jnp.array([[1.0, 4.0], [0.5, 8.0], [2.0, 2.0], [1.5, 6.0]])
    fleet = TwinFleet(twin, drive_family=family)
    sim = fleet.with_backend(
        AnalogueBackend(spec=QUANT_CLEAN, prog_key=KEY)).simulate(
            params, y0s, ts, thetas)
    fused = fleet.with_backend(
        FusedAnalogueBackend(spec=QUANT_CLEAN, prog_key=KEY,
                             batch_tile=2)).simulate(
            params, y0s, ts, thetas)
    np.testing.assert_allclose(fused, sim, atol=1e-5, rtol=1e-5)


def test_analogue_fused_read_noise_deterministic(hp_setup):
    """Counter-derived read noise: same seed => bitwise-identical
    rollout; different seed => different trajectory; noise visibly
    perturbs vs the clean solve."""
    import dataclasses
    from repro.core.backends import FusedAnalogueBackend
    twin, params, y0, ts = hp_setup
    spec = AnalogueSpec(prog_noise=0.0, read_noise=0.01)
    be = FusedAnalogueBackend(spec=spec, prog_key=KEY, read_seed=42)
    st = be.program(twin.node.field, params)
    o1 = be.rollout(st, y0, ts)
    o2 = be.rollout(st, y0, ts)
    assert jnp.array_equal(o1, o2)
    be2 = dataclasses.replace(be, read_seed=43)
    o3 = be2.rollout(be2.program(twin.node.field, params), y0, ts)
    assert not jnp.array_equal(o1, o3)
    clean = FusedAnalogueBackend(spec=QUANT_CLEAN, prog_key=KEY)
    o_clean = clean.rollout(clean.program(twin.node.field, params), y0, ts)
    assert float(jnp.abs(o1 - o_clean).max()) > 0.0


def test_analogue_fused_is_detached(hp_setup):
    """The analogue substrate is inference-only: gradients through the
    fused rollout are zero, never an error."""
    from repro.core.backends import FusedAnalogueBackend
    twin, params, y0, ts = hp_setup
    be = FusedAnalogueBackend(spec=QUANT_CLEAN, prog_key=KEY)
    st = be.program(twin.node.field, params)

    g = jax.grad(lambda y: jnp.sum(be.rollout(st, y, ts) ** 2))(y0)
    assert float(jnp.abs(g).max()) == 0.0


@pytest.mark.parametrize("bad,match", [
    (jnp.array([[1, 2], [3, 4]]), "non-floating"),
    (jnp.array([[jnp.nan, 1.0], [0.0, 2.0]]), "NaN"),
])
def test_analogue_programming_validation(bad, match):
    """Programming rejects unprogrammable weights with an error naming
    the offending input."""
    from repro.core.analogue import program_tensor
    with pytest.raises(ValueError, match=match):
        program_tensor(KEY, bad, QUANT_CLEAN, name="w_bad")
    try:
        program_tensor(KEY, bad, QUANT_CLEAN, name="w_bad")
    except ValueError as e:
        assert "w_bad" in str(e)


def test_analogue_fused_storage_validation(hp_setup):
    from repro.core.backends import FusedAnalogueBackend
    twin, params, _, _ = hp_setup
    be = FusedAnalogueBackend(spec=QUANT_CLEAN, storage="int4")
    with pytest.raises(ValueError, match="storage"):
        be.program(twin.node.field, params)
