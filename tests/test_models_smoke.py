"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step + a short decode on CPU; asserts shapes and
finiteness (the FULL configs are exercised compile-only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke
from repro.models.model import decode_step, forward, init_cache, init_params
from repro.train.lm_trainer import lm_loss, make_train_step
from repro.train.optimizer import adam

BATCH, SEQ = 2, 16


@pytest.fixture(scope="module")
def smoke_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_smoke(name)
            params = init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_finite(name, smoke_state):
    cfg, params = smoke_state(name)
    toks = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                              cfg.vocab, jnp.int32)
    logits, aux, _ = forward(params, cfg, toks)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_reduces_loss(name, smoke_state):
    cfg, params = smoke_state(name)
    opt = adam(3e-3, grad_clip=1.0)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    toks = jax.random.randint(jax.random.PRNGKey(2), (BATCH, SEQ + 1), 0,
                              cfg.vocab, jnp.int32)
    batch = {"tokens": toks}
    p, s = params, opt_state
    losses = []
    for _ in range(8):
        p, s, m = step(p, s, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]   # memorising one batch must work


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_prefill(name, smoke_state):
    """Token-by-token decode must agree with the parallel forward."""
    cfg, params = smoke_state(name)
    toks = jax.random.randint(jax.random.PRNGKey(3), (BATCH, 8), 0,
                              cfg.vocab, jnp.int32)
    logits_all, _, _ = forward(params, cfg, toks)
    cache = init_cache(cfg, BATCH, 16)
    dec = []
    for i in range(8):
        lg, cache = decode_step(params, cfg, toks[:, i:i + 1],
                                jnp.asarray(i, jnp.int32), cache)
        dec.append(lg[:, 0, :])
    dec = jnp.stack(dec, axis=1)
    # mixers with train-time chunking or conv-history simplifications may
    # deviate slightly; attention paths must agree tightly.
    tol = {"hybrid": 2e-2, "ssm": 1e30}.get(cfg.family, 2e-3)
    if cfg.family == "ssm":
        assert bool(jnp.all(jnp.isfinite(dec)))   # mLSTM decode drops conv
    else:
        np.testing.assert_allclose(np.asarray(dec),
                                   np.asarray(logits_all), rtol=tol,
                                   atol=tol * 10)


@pytest.mark.parametrize("name", ["llama3-8b", "qwen3-1.7b"])
def test_ode_depth_mode(name, smoke_state):
    """The paper's continuous-depth execution as an LM feature."""
    import dataclasses
    cfg, _ = smoke_state(name)
    cfg_ode = dataclasses.replace(cfg, ode_depth=2)
    params = init_params(cfg_ode, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (BATCH, SEQ), 0,
                              cfg_ode.vocab, jnp.int32)
    logits, _, _ = forward(params, cfg_ode, toks)
    assert logits.shape == (BATCH, SEQ, cfg_ode.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # fewer params than the discrete stack (weight-tied)
    n_ode = sum(x.size for x in jax.tree_util.tree_leaves(params))
    full = init_params(cfg, jax.random.PRNGKey(0))
    n_full = sum(x.size for x in jax.tree_util.tree_leaves(full))
    assert n_ode < n_full


def test_param_count_analytic_matches_actual():
    from repro.configs.base import param_count
    for name in ["llama3-8b", "qwen3-1.7b", "musicgen-medium"]:
        cfg = get_smoke(name)
        params = init_params(cfg, jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
        analytic = param_count(cfg)
        assert abs(actual - analytic) / actual < 0.02, (name, actual,
                                                        analytic)
