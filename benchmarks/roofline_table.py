"""Render the EXPERIMENTS.md roofline tables from dry-run artifacts.

Usage: PYTHONPATH=src python -m benchmarks.roofline_table [--dir runs/dryrun]
"""
import argparse
import glob
import json
import os


def load(dir_):
    rows = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def fmt_ms(s):
    return f"{s*1e3:.1f}"


def table(rows, mesh):
    rows = [r for r in rows if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | compute ms | memory ms | collective ms | "
           "bottleneck | MODEL_FLOPS | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(r['t_compute_s'])} | "
            f"{fmt_ms(r['t_memory_s'])} | {fmt_ms(r['t_collective_s'])} | "
            f"{r['bottleneck']} | {r['model_flops']:.2e} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']*100:.2f}% |")
    return "\n".join(out)


def skips():
    from repro.configs import ARCH_NAMES, get_config
    out = []
    for a in ARCH_NAMES:
        if not get_config(a).sub_quadratic:
            out.append(f"| {a} | long_500k | SKIP — pure O(L^2) attention "
                       f"(policy in DESIGN.md §Arch-applicability) |")
    return "\n".join(["| arch | shape | status |", "|---|---|---|"] + out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.dir)
    print(f"## Roofline — mesh {args.mesh} ({len(rows)} artifacts)\n")
    print(table(rows, args.mesh))
    print("\n### Skipped cells\n")
    print(skips())


if __name__ == "__main__":
    main()
