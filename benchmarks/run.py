"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract), a
readable table per benchmark, and writes one machine-readable
``BENCH_<module>.json`` artifact per module (rows + environment
metadata) — the repo's measured perf trajectory that future PRs regress
against.  Modules:

  fig3j_hp_errors      — HP twin: NODE vs recurrent ResNet across waveforms
  fig3kl_hp_energy     — projected speed/energy scalability (HP twin)
  fig4g_l96_errors     — Lorenz96: NODE vs LSTM/GRU/RNN interp/extrap
  fig4hi_l96_energy    — projected time/energy scalability (Lorenz96)
  fig4j_noise          — read/programming-noise robustness grid
  kernels              — Pallas kernel vs jnp-reference checks + ref timing
                         (incl. the fused-ODE reverse-time backward and
                         the soft-DTW E-matrix backward), plus fused
                         fwd+bwd rows per precision policy (f32 vs bf16)
                         with the modelled bytes-moved / achieved GB/s
  fleet_backends       — digital vs fused-Pallas vs analogue (jnp sim) vs
                         fused-analogue fleet rollout throughput at fleet
                         sizes {1, 64, 1024}, plus a long-horizon (T=10k)
                         time-chunked fused rollout
  energy_projection    — the paper's energy scorecard: the four headline
                         anchor ratios (CI-gated within 20%) plus
                         per-backend rows projecting time/energy from
                         HLO-measured op counts (digital substrates) or
                         array physics (analogue substrates)
  fleet_sharded        — multi-device fleet serving via launch.fleet_serving:
                         single-device baseline vs sharded rollout on the
                         trivial mesh, plus per-device scaling rows from a
                         virtual multi-device subprocess
  train_throughput     — scan-compiled fit() engine vs per-step baseline,
                         plus digital-adjoint vs fused-VJP training steps
                         and the bf16_f32acc training substrate rows
                         (bytes-moved per step)
  fault_tolerance      — device-fault robustness: stuck-rate sweep of
                         naive vs write–verify programming (recovery
                         rows gate the 2x fault-free margin) and the
                         SLO-armed FleetServer serving an unrepairable
                         array through the digital fallback tier
  robustness           — the hardware-robustness scorecard: clean-trained
                         vs noise-aware-trained (fit(hw_aware=...))
                         weights on the fused analogue substrate, swept
                         over read-noise sigma x quantisation levels x
                         stuck-cell rate; the comparison/paper_point row
                         gates the >= 2x improvement + deployment margin
  serving_latency      — streaming stateful serving: per-request p50/p99
                         latency and sustained twin-steps/s of the
                         StreamingFleetServer replaying a seeded Poisson
                         trace with a 4x-oversubscribed (paging) resident
                         population
  roofline             — per-(arch x shape) roofline table from the dry-run

Usage:  PYTHONPATH=src python -m benchmarks.run [--only kernels
        --only fleet_backends] [--artifact-dir DIR]
        FAST=1 to cut training budgets ~4x.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

FAST = bool(int(os.environ.get("FAST", "0")))
ROWS: list[tuple] = []

BENCH_SCHEMA = 1


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"CSV,{name},{us_per_call:.3f},{derived}")


def _timeit(fn, *args, repeats=3, best=False):
    """Wall-time per call in us (mean, or fastest repeat with ``best``).

    The warm-up call is blocked on BEFORE t0 so no async warm-up work
    leaks into the measured window, and every repeat is synced so
    single-repeat timings (the n>=1024 fleet cases) measure a completed
    call, not a dispatch.  ``best=True`` reports the fastest repeat —
    the standard noise floor for ratio-gated microbenchmarks.
    """
    import jax
    jax.block_until_ready(fn(*args))  # warm — fully retired before t0
    times = []
    for _ in range(repeats):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        times.append(time.time() - t0)
    return (min(times) if best else sum(times) / repeats) * 1e6


def _walltime(fn, *, repeats: int = 1):
    """Host wall-time per call in us for serving-path work that
    ``_timeit`` cannot see (state-store paging, queue pumping, crossbar
    programming — host control flow around device calls, not one jitted
    fn).  Callers pass a closure that already blocks on its device work;
    returns ``(us_per_call, last_result)`` so one-shot measurements keep
    their product.  The warm-up/best-of discipline stays in ``_timeit``;
    this helper is for end-to-end loops where every iteration is real
    work (a served batch, a programmed array) and averaging is the
    honest statistic.
    """
    out = None
    t0 = time.time()
    for _ in range(repeats):
        out = fn()
    return (time.time() - t0) * 1e6 / repeats, out


def _env_metadata() -> dict:
    import jax
    devs = jax.devices()
    return {
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device": devs[0].device_kind if devs else "unknown",
        "device_count": len(devs),
        "platform": platform.platform(),
        "fast": FAST,
    }


def write_artifact(module: str, rows: list[tuple], outdir: str) -> str:
    """Write BENCH_<module>.json: the machine-readable perf contract."""
    path = os.path.join(outdir, f"BENCH_{module}.json")
    doc = {
        "schema": BENCH_SCHEMA,
        "module": module,
        "created_unix": int(time.time()),
        "env": _env_metadata(),
        "rows": [{"name": n, "us_per_call": round(us, 3), "derived": d}
                 for n, us, d in rows],
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"  wrote {path} ({len(doc['rows'])} rows)")
    return path


# ---------------------------------------------------------------------------

def bench_fig3j_hp_errors():
    import jax
    from repro.train import recipes
    scale = 0.4 if FAST else 1.0
    twin, params, _ = recipes.train_hp_twin(
        pretrain_steps=int(400 * scale), train_steps=int(600 * scale))
    resnet, rparams, _ = recipes.train_hp_resnet(train_steps=int(800 * scale))
    node_m = res_m = 0.0
    for wf in ["sine", "triangular", "rectangular", "modulated_sine"]:
        mn = recipes.eval_hp_twin(twin, params, wf)
        mr = recipes.eval_hp_resnet(resnet, rparams, wf)
        node_m += mn["mre"] / 4
        res_m += mr["mre"] / 4
        emit(f"fig3j/{wf}/node_mre", 0.0, f"{mn['mre']:.4f}")
        emit(f"fig3j/{wf}/resnet_mre", 0.0, f"{mr['mre']:.4f}")
    # paper: NODE 0.17 vs ResNet 0.61
    emit("fig3j/mean/node_mre", 0.0, f"{node_m:.4f} (paper 0.17)")
    emit("fig3j/mean/resnet_mre", 0.0, f"{res_m:.4f} (paper 0.61)")

    # inference timing of the twin step (digital, CPU wall-time)
    import jax.numpy as jnp
    ts = jnp.linspace(0, 0.5, 501)
    sim = jax.jit(lambda p: twin.simulate(p, jnp.array([0.1]), ts))
    emit("fig3j/node_rollout_500steps", _timeit(sim, params), "wall_us")


def bench_fig3kl_hp_energy():
    from repro.core import energy
    for row in energy.hp_projection():
        h = row["hidden"]
        emit(f"fig3kl/h{h}/analogue_energy_uj", row["analogue_time_us"],
             f"{row['analogue_energy_uj']:.2f}")
        emit(f"fig3kl/h{h}/node_gpu_speed_gain", row["node_gpu_time_us"],
             f"{row['node_gpu_speed_gain']:.2f}")
        emit(f"fig3kl/h{h}/node_gpu_energy_gain", 0.0,
             f"{row['node_gpu_energy_gain']:.2f}")
    r = energy.hp_projection()[-1]
    emit("fig3kl/h64/check_vs_paper", 0.0,
         f"speed {r['node_gpu_speed_gain']:.1f} (4.2) energy "
         f"{r['node_gpu_energy_gain']:.1f} (41.4)")


def bench_fig4g_l96_errors():
    from repro.train import recipes
    scale = 0.3 if FAST else 1.0
    data = recipes.l96_data()
    twin, params = recipes.train_l96_twin(
        pretrain_steps=int(5000 * scale),
        train_steps=((60, int(600 * scale), 1e-3),
                     (200, int(600 * scale), 4e-4)), data=data)
    m = recipes.eval_l96_twin(twin, params, data=data)
    emit("fig4g/node/interp_l1", 0.0,
         f"{m['interp_l1']:.3f} (paper 0.512)")
    emit("fig4g/node/extrap_l1", 0.0,
         f"{m['extrap_l1']:.3f} (paper 0.321)")
    for cell in ["lstm", "gru", "rnn"]:
        b = recipes.eval_l96_baseline(cell, train_steps=int(2500 * scale),
                                      data=data)
        emit(f"fig4g/{cell}/interp_l1", 0.0, f"{b['interp_l1']:.3f}")
        emit(f"fig4g/{cell}/extrap_l1", 0.0, f"{b['extrap_l1']:.3f}")
    return twin, params, data


def bench_fig4hi_l96_energy():
    from repro.core import energy
    for row in energy.lorenz96_projection():
        h = row["hidden"]
        for sysname in ["node_gpu", "lstm_gpu", "gru_gpu", "rnn_gpu"]:
            emit(f"fig4hi/h{h}/{sysname}", row[f"{sysname}_time_us"],
                 f"speed x{row[f'{sysname}_speed_gain']:.1f} energy "
                 f"x{row[f'{sysname}_energy_gain']:.1f}")
    r = energy.lorenz96_projection()[-1]
    emit("fig4hi/h512/check_vs_paper", r["analogue_time_us"],
         f"analogue {r['analogue_time_us']:.1f}us (40.1) node speed "
         f"x{r['node_gpu_speed_gain']:.1f} (12.6)")


def bench_fig4j_noise(l96_state=None):
    from repro.train import recipes
    if l96_state is None:
        scale = 0.3 if FAST else 0.6
        data = recipes.l96_data()
        twin, params = recipes.train_l96_twin(
            pretrain_steps=int(5000 * scale),
            train_steps=((60, int(600 * scale), 1e-3),), data=data)
    else:
        twin, params, data = l96_state
    rows = recipes.noise_robustness_grid(
        twin, params, read_noises=[0.0, 0.01, 0.02],
        prog_noises=[0.0, 0.01], data=data, repeats=1 if FAST else 3)
    for r in rows:
        emit(f"fig4j/prog{r['prog_noise']:.2f}/read{r['read_noise']:.2f}",
             0.0, f"extrap_l1 {r['extrap_l1']:.3f}")


def _fused_hbm_bytes(T, B, D, du, wsize, precision, bwd=False):
    """Modelled HBM bytes of one fused rollout (VJP adds the reverse
    sweep): y0 in (always f32) + drive slab + weights in + trajectory
    slab out, every slab at the policy's storage width; the backward
    additionally streams the cotangent slab in and flushes the f32
    dW/db accumulators + dy0 (boundary rows are re-read from the primal
    trajectory, already counted).  This is the quantity the bf16
    policies halve — the achieved-bandwidth column divides it by the
    measured wall time."""
    sb = 2 if precision != "f32" else 4
    uh = (2 * T + 1) * max(du, 1)
    n = B * D * 4 + uh * sb + wsize * sb + T * B * D * sb
    if bwd:
        n += T * B * D * sb + wsize * 4 + B * D * 4
    return n


def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.core.analogue import AnalogueSpec, program_tensor
    from repro.core.node import mlp_init
    from repro.kernels import ops, ref

    params = mlp_init(jax.random.PRNGKey(0), (2, 64, 64, 1))
    y0 = jnp.zeros((64, 1))
    T = 100
    ts = jnp.linspace(0, 0.1, T + 1)
    uh = ops.half_step_drive(lambda t: jnp.sin(20 * t), ts)
    dt = float(ts[1] - ts[0])
    out_k = ops.fused_node_rollout(params, y0, uh, dt, precision="f32")
    out_r = ops.fused_node_rollout_ref(params, y0, uh, dt)
    err = float(jnp.abs(out_k - out_r).max())
    ref_fn = jax.jit(lambda: ops.fused_node_rollout_ref(params, y0, uh, dt))
    emit("kernels/fused_node_mlp", _timeit(lambda: ref_fn()),
         f"interpret_max_err {err:.2e}")

    # --- precision rows: the kernel itself (compiled on TPU, interpreter
    # elsewhere) per policy, with the modelled bytes-moved and achieved
    # bandwidth.  bf16 storage halves the slab traffic; the derived field
    # carries the error vs the f32 reference (the documented error model).
    wsize = sum(p["w"].size + p["b"].size for p in params)
    B, D, du = y0.shape[0], y0.shape[1], uh.shape[-1]
    scale = float(jnp.abs(out_r).max())
    for prec in ["f32", "bf16"]:
        pol = "bf16_f32acc" if prec == "bf16" else "f32"
        fn = jax.jit(lambda pol=pol: ops.fused_node_rollout(
            params, y0, uh, dt, gradient="stopgrad", precision=pol))
        out_p = fn()
        rel = float(jnp.abs(out_p.astype(jnp.float32) - out_r).max()) / scale
        us = _timeit(fn, best=True)
        nbytes = _fused_hbm_bytes(T, B, D, du, wsize, pol)
        emit(f"kernels/fused_node_mlp/{prec}", us,
             f"rel_err_vs_f32ref {rel:.2e} bytes_moved {nbytes} "
             f"({nbytes / (us * 1e-6) / 1e9:.3f} GB/s)")

    spec = AnalogueSpec(prog_noise=0.0436)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    prog = program_tensor(jax.random.PRNGKey(2), w, spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (128, 256))
    yk = ops.crossbar_vmm(prog, x, spec)
    yr = ref.crossbar_matmul_ref(x, prog["gp"], prog["gm"], 1.0,
                                 spec.v_clamp) / prog["scale"]
    err = float(jnp.abs(yk - yr).max())
    ref_fn = jax.jit(lambda: ref.crossbar_matmul_ref(
        x, prog["gp"], prog["gm"], 1.0, spec.v_clamp))
    emit("kernels/crossbar_vmm", _timeit(lambda: ref_fn()),
         f"interpret_max_err {err:.2e}")

    a = jax.random.normal(jax.random.PRNGKey(4), (2, 128, 2))
    b = jax.random.normal(jax.random.PRNGKey(5), (2, 160, 2))
    # precision pinned to f32: these rows gate EXACT kernel parity (the
    # reduced policies have their own rel-err rows above)
    sk = ops.soft_dtw(a, b, 0.5, True, "f32")
    from repro.core.losses import soft_dtw as sj
    sr = jax.vmap(lambda p, q: sj(p, q, 0.5))(a, b)
    err = float(jnp.abs(sk - sr).max())
    ref_fn = jax.jit(lambda: jax.vmap(lambda p, q: sj(p, q, 0.5))(a, b))
    emit("kernels/softdtw", _timeit(lambda: ref_fn()),
         f"interpret_max_err {err:.2e}")

    # soft-DTW backward: the closed-form E-matrix wavefront kernel vs
    # autodiff of the reference DP (which the op no longer uses)
    gk = jax.grad(lambda x: ops.soft_dtw(x, b, 0.5, True, "f32").sum())(a)
    gr = jax.grad(
        lambda x: jax.vmap(lambda p, q: sj(p, q, 0.5))(x, b).sum())(a)
    err = float(jnp.abs(gk - gr).max())
    bwd_ref = jax.jit(jax.grad(
        lambda x: jax.vmap(lambda p, q: sj(p, q, 0.5))(x, b).sum()))
    emit("kernels/softdtw_bwd", _timeit(bwd_ref, a),
         f"e_matrix_max_err {err:.2e}")

    # fused neural-ODE backward: reverse-time checkpoint/replay kernel vs
    # backprop through the unrolled reference
    def loss_k(p):
        return jnp.sum(ops.fused_node_rollout(p, y0, uh, dt,
                                              precision="f32") ** 2)

    def loss_r(p):
        return jnp.sum(ops.fused_node_rollout_ref(p, y0, uh, dt) ** 2)

    gk = jax.grad(loss_k)(params)
    gr = jax.grad(loss_r)(params)
    err = max(float(jnp.abs(x - y).max()) for x, y in zip(
        jax.tree_util.tree_leaves(gk), jax.tree_util.tree_leaves(gr)))
    bwd_ref = jax.jit(jax.grad(loss_r))
    emit("kernels/fused_node_mlp_bwd", _timeit(lambda: bwd_ref(params)),
         f"interpret_max_err {err:.2e}")

    # --- backward precision rows: fwd+bwd through the reverse-time
    # kernel per policy, bytes-moved model incl. the cotangent slab and
    # the f32 gradient flush
    g_scale = max(float(jnp.abs(x).max())
                  for x in jax.tree_util.tree_leaves(gr))
    for prec in ["f32", "bf16"]:
        pol = "bf16_f32acc" if prec == "bf16" else "f32"

        def loss_p(p, pol=pol):
            traj = ops.fused_node_rollout(p, y0, uh, dt, precision=pol)
            return jnp.sum(traj.astype(jnp.float32) ** 2)

        bwd_fn = jax.jit(jax.grad(loss_p))
        gp = bwd_fn(params)
        rel = max(float(jnp.abs(x - y).max()) for x, y in zip(
            jax.tree_util.tree_leaves(gp),
            jax.tree_util.tree_leaves(gr))) / g_scale
        us = _timeit(lambda: bwd_fn(params), best=True)
        nbytes = _fused_hbm_bytes(T, B, D, du, wsize, pol, bwd=True)
        emit(f"kernels/fused_node_mlp_bwd/{prec}", us,
             f"grad_rel_err_vs_f32ref {rel:.2e} bytes_moved {nbytes} "
             f"({nbytes / (us * 1e-6) / 1e9:.3f} GB/s)")


def bench_fleet_backends():
    """Fleet-of-twins serving throughput across execution backends.

    One HP-shaped twin (2->14->14->1), shared weights, N independent
    initial conditions + per-twin drive parameters, one device program
    per rollout.  Uses untrained weights — this measures substrate
    throughput, not accuracy.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.analogue import AnalogueSpec
    from repro.core.backends import (AnalogueBackend, FusedAnalogueBackend,
                                     FusedPallasBackend)
    from repro.core.twin import TwinFleet, make_driven_twin

    T = 50 if FAST else 100
    ts = jnp.linspace(0.0, T * 1e-3, T + 1)

    def family(t, theta):
        return theta[0] * jnp.sin(2.0 * jnp.pi * theta[1] * t)

    twin = make_driven_twin(1, drive=None, hidden=14)
    params = twin.init(jax.random.PRNGKey(0))
    fleet = TwinFleet(twin, drive_family=family)
    spec = AnalogueSpec(prog_noise=0.0)

    analogue_us = {}
    for n in [1, 64, 1024]:
        kf = jax.random.fold_in(jax.random.PRNGKey(1), n)
        k1, k2 = jax.random.split(kf)
        y0s = 0.3 * jax.random.normal(k1, (n, 1))
        thetas = 1.0 + jax.random.uniform(k2, (n, 2))
        backends = {
            "digital": fleet,
            "fused_pallas": fleet.with_backend(
                FusedPallasBackend(batch_tile=min(64, n))),
            "analogue": fleet.with_backend(
                AnalogueBackend(spec=spec, prog_key=jax.random.PRNGKey(7))),
        }
        for name, fl in backends.items():
            fn = jax.jit(lambda p, y, th, fl=fl: fl.simulate(p, y, ts, th))
            us = _timeit(fn, params, y0s, thetas,
                         repeats=1 if n >= 1024 else 3)
            steps_per_s = n * T / (us * 1e-6)
            if name == "analogue":
                analogue_us[n] = us
            emit(f"fleet_backends/{name}/n{n}", us,
                 f"{steps_per_s:.0f} twin-steps/s")

        # Fused-analogue: program ONCE outside the timed jit — analogue
        # deployment is one-time (a physical array holds concrete, frozen
        # conductances; serving closes over them).  This also lets XLA
        # fold the conductances as constants, which is what a stationary
        # array is.  Same prog_key as the analogue rows above, so the
        # substrates execute bitwise-identical crossbar programs.
        be_af = FusedAnalogueBackend(spec=spec,
                                     prog_key=jax.random.PRNGKey(7),
                                     batch_tile=min(256, n))
        st_af = be_af.program(twin.node.field, params)
        fn = jax.jit(lambda y, th: be_af.rollout_batch_local(
            st_af, y, ts, drive_family=family, drive_params=th))
        us = _timeit(fn, y0s, thetas, repeats=1 if n >= 1024 else 3)
        speedup = (f" {analogue_us[n] / us:.2f}x vs analogue"
                   if n in analogue_us else "")
        emit(f"fleet_backends/analogue_fused/n{n}", us,
             f"{n * T / (us * 1e-6):.0f} twin-steps/s{speedup}")

    # Long-horizon serving: the (T+1, bt, D) trajectory no longer has to
    # fit VMEM — the fused kernel streams it in time chunks (this exact
    # shape used to raise a VMEM ValueError).
    from repro.core.twin import make_autonomous_twin
    from repro.kernels.fused_ode_mlp import (DEFAULT_VMEM_BUDGET,
                                             plan_time_chunk)
    T_long = 2000 if FAST else 10000
    n_long = 64
    twin6 = make_autonomous_twin(6, hidden=64)
    params6 = twin6.init(jax.random.PRNGKey(2))
    fleet6 = TwinFleet(twin6).with_backend(FusedPallasBackend(batch_tile=64))
    ts_l = jnp.linspace(0.0, T_long * 1e-4, T_long + 1)
    y06 = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (n_long, 6))
    w = [p["w"].astype(jnp.float32) for p in params6]
    b = [p["b"].astype(jnp.float32) for p in params6]
    plan = plan_time_chunk(T_long, 64, 6, 0, False, w, b,
                           DEFAULT_VMEM_BUDGET)
    fn = jax.jit(lambda p, y: fleet6.simulate(p, y, ts_l))
    us = _timeit(fn, params6, y06, repeats=1)
    emit(f"fleet_backends/fused_pallas/n{n_long}_T{T_long}", us,
         f"{n_long * T_long / (us * 1e-6):.0f} twin-steps/s "
         f"chunk {plan.time_chunk} x{plan.num_chunks}")


def bench_fleet_sharded():
    """Multi-device fleet serving (repro.launch.fleet_serving).

    In-process rows compare the single-device ``TwinFleet`` rollout with
    the sharded path on the trivial mesh of this host — same program,
    plus the shard_map wrapper, so the delta is pure sharding overhead
    (and the derived field carries the parity error, which must be 0).
    The per-device scaling rows run in a subprocess with virtual host
    devices (``--xla_force_host_platform_device_count``): on CPU the
    virtual devices share the same cores, so these rows validate the
    scaling *mechanism* and become real speedups on multi-chip hosts.
    """
    import subprocess
    import textwrap

    import jax
    import jax.numpy as jnp
    from repro.launch.mesh import make_twin_mesh
    from repro.train import recipes

    n = 64 if FAST else 256
    horizon = 50 if FAST else 100
    fleet = recipes.make_l96_fleet()
    params = fleet.twin.init(jax.random.PRNGKey(0))
    ts = recipes.l96_fleet_ts(horizon=horizon)
    y0s = next(recipes.l96_fleet_requests(fleet_size=n))
    mesh = make_twin_mesh()

    single = jax.jit(lambda p, y: fleet.simulate(p, y, ts))
    sharded = jax.jit(
        lambda p, y: fleet.rollout_batch(p, y, ts, mesh=mesh))
    # parity from the compile-time outputs — these calls double as the
    # JIT warm-up, so timing below adds no redundant rollouts
    ref = jax.block_until_ready(single(params, y0s))
    out = jax.block_until_ready(sharded(params, y0s))
    gap = float(jnp.abs(out - ref).max())
    us_single = _timeit(single, params, y0s)
    us_sharded = _timeit(sharded, params, y0s)
    emit(f"fleet_sharded/fused/single_device/n{n}", us_single,
         f"{n * horizon / (us_single * 1e-6):.0f} twin-steps/s")
    emit(f"fleet_sharded/fused/sharded_1dev/n{n}", us_sharded,
         f"{n * horizon / (us_sharded * 1e-6):.0f} twin-steps/s "
         f"parity_max_err {gap:.1e}")

    # per-device scaling: virtual 4-device mesh in a subprocess (XLA_FLAGS
    # must be set before jax initialises)
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import time
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_twin_mesh
        from repro.train import recipes
        fleet = recipes.make_l96_fleet(backend="digital")
        params = fleet.twin.init(jax.random.PRNGKey(0))
        ts = recipes.l96_fleet_ts(horizon={horizon})
        y0s = next(recipes.l96_fleet_requests(fleet_size={n}))
        for shards in [1, 2, 4]:
            mesh = make_twin_mesh(shards)
            fn = jax.jit(lambda p, y: fleet.rollout_batch(p, y, ts,
                                                          mesh=mesh))
            jax.block_until_ready(fn(params, y0s))
            times = []
            for _ in range(3):
                t0 = time.time()
                jax.block_until_ready(fn(params, y0s))
                times.append(time.time() - t0)
            us = min(times) * 1e6
            rate = {n} * {horizon} / (us * 1e-6)
            print(f"RESULT,fleet_sharded/digital/shards{{shards}}/"
                  f"n{n},{{us:.3f}},{{rate:.0f}} twin-steps/s")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True,
                       env={**os.environ,
                            "PYTHONPATH": os.path.join(
                                os.path.dirname(__file__), "..", "src")})
    ok = False
    for line in r.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, name, us, derived = line.split(",", 3)
            emit(name, float(us), derived)
            ok = True
    if not ok:
        print(f"  (virtual multi-device subprocess failed)\n"
              f"{r.stderr[-2000:]}")


def bench_train_throughput():
    """Scan-compiled training engine vs the per-step dispatch loop.

    Both engines run the derivative-matching pretrain loss of the HP twin
    recipe (2->14->14->1, 500 observations, keyless — exactly how
    ``recipes.train_hp_twin`` invokes ``pretrain_derivatives``) — the
    phase whose thousands of steps were dominated by host round-trips.
    Steady-state steps/s (compile excluded for both sides, fastest of 3
    repeats); the `speedup` row is the acceptance gate for the scan
    engine (>= 3x on CPU).
    """
    import jax
    from repro.core.twin import make_driven_twin
    from repro.data import hp_memristor as hp
    from repro.train import trainer
    from repro.train.optimizer import adam

    ts, xs, _, _ = hp.generate("sine", num_points=500, dt=1e-3,
                               amp=2.0, freq=2.0)
    ys = xs[:, None]
    twin = make_driven_twin(1, hp.WAVEFORMS["sine"](amp=2.0, freq=2.0),
                            hidden=14)
    params = twin.init(jax.random.PRNGKey(42))
    tsm, ysm, dys = trainer.finite_difference_derivatives(ts, ys)
    loss_fn = trainer.derivative_matching_loss(twin.field, tsm, ysm, dys)
    opt = adam(1e-2)
    opt_state = opt.init(params)
    key = None                       # pretrain_derivatives passes no key
    steps = 200 if FAST else 400

    engine = trainer.make_scan_engine(loss_fn, opt, False, donate=False)
    step = trainer.make_step_fn(loss_fn, opt, False)

    def run_scan():
        return engine(params, opt_state, key, steps)

    def run_loop():
        p, o, k = params, opt_state, key
        for _ in range(steps):
            p, o, k, loss = step(p, o, k)
        return p, loss

    us_scan = _timeit(run_scan, repeats=5, best=True)
    us_loop = _timeit(run_loop, repeats=5, best=True)
    sps_scan = steps / (us_scan * 1e-6)
    sps_loop = steps / (us_loop * 1e-6)
    emit("train_throughput/scan_fit", us_scan / steps,
         f"{sps_scan:.0f} steps/s")
    emit("train_throughput/per_step_fit", us_loop / steps,
         f"{sps_loop:.0f} steps/s")
    emit("train_throughput/speedup", 0.0,
         f"{sps_scan / sps_loop:.2f}x scan over per-step")

    # --- train where you serve: the multiple-shooting trajectory phase,
    # digital adjoint vs the fused-Pallas substrate (weights-stationary
    # forward + reverse-time checkpoint/replay backward).  On CPU hosts
    # the fused kernels run in INTERPRET mode, so this ratio understates
    # the substrate — the row exists to track the gap per platform (it
    # becomes a genuine speedup on TPU, where the digital path re-reads
    # the weights from HBM every f-eval in both directions).
    from repro.core.backends import FusedPallasBackend
    ts_seg, ys_seg = trainer.make_segments(ts, ys, 50)
    loss_dig = trainer.segment_loss_fn(twin, ts_seg, ys_seg, "l1")
    loss_fus = trainer.segment_loss_fn(twin, ts_seg, ys_seg, "l1",
                                       backend=FusedPallasBackend())
    steps_t = 4 if FAST else 10
    eng_d = trainer.make_scan_engine(loss_dig, opt, False, donate=False)
    eng_f = trainer.make_scan_engine(loss_fus, opt, False, donate=False)
    us_d = _timeit(lambda: eng_d(params, opt_state, None, steps_t),
                   repeats=3, best=True)
    us_f = _timeit(lambda: eng_f(params, opt_state, None, steps_t),
                   repeats=3, best=True)
    sps_d = steps_t / (us_d * 1e-6)
    sps_f = steps_t / (us_f * 1e-6)
    emit("train_throughput/digital_adjoint_step", us_d / steps_t,
         f"{sps_d:.1f} steps/s (trajectory phase)")
    emit("train_throughput/fused_vjp_step", us_f / steps_t,
         f"{sps_f:.1f} steps/s (trajectory phase)")
    emit("train_throughput/fused_vs_digital", 0.0,
         f"{sps_f / sps_d:.2f}x fused-VJP over digital-adjoint "
         f"({jax.default_backend()})")

    # --- reduced-precision training substrate: same shooting loss, bf16
    # slabs + f32 accumulation, with the per-step bytes-moved model (the
    # quantity the policy halves; bandwidth becomes meaningful on TPU —
    # on CPU hosts the kernels run interpreted and the ratio just tracks
    # the interpreter overhead per platform).
    loss_fb = trainer.segment_loss_fn(
        twin, ts_seg, ys_seg, "l1",
        backend=FusedPallasBackend(precision="bf16_f32acc"))
    eng_fb = trainer.make_scan_engine(loss_fb, opt, False, donate=False)
    us_fb = _timeit(lambda: eng_fb(params, opt_state, None, steps_t),
                    repeats=3, best=True)
    sps_fb = steps_t / (us_fb * 1e-6)
    S, Lp1 = ts_seg.shape
    wsize = sum(p["w"].size + p["b"].size for p in params)
    for prec, us_row in [("f32", us_f / steps_t), ("bf16", us_fb / steps_t)]:
        pol = "bf16_f32acc" if prec == "bf16" else "f32"
        nbytes = _fused_hbm_bytes(Lp1 - 1, S, ys.shape[1], 1, wsize, pol,
                                  bwd=True)
        sps = sps_fb if prec == "bf16" else sps_f
        emit(f"train_throughput/fused_vjp_step/{prec}", us_row,
             f"{sps:.1f} steps/s bytes_moved {nbytes} "
             f"({nbytes / (us_row * 1e-6) / 1e9:.3f} GB/s)")
    emit("train_throughput/fused_bf16_vs_f32", 0.0,
         f"{sps_fb / sps_f:.2f}x bf16_f32acc over f32 fused "
         f"({jax.default_backend()})")


def bench_energy_projection():
    """The paper's energy scorecard (``repro.core.scorecard``).

    Anchor rows recompute the four headline ratios (HP: 4.2x speed,
    41.4x energy vs the GPU neural-ODE; Lorenz96: 12.6x / 189.7x) from
    the calibrated model and carry the paper value + relative error —
    CI asserts each stays within the 20% tolerance.  Backend rows
    compile one rollout per registered substrate at the paper's
    workload sizes, parse the optimised HLO loop-aware into MAC counts,
    and project per-trajectory time/energy: digital substrates from the
    measured MACs, analogue substrates from array physics (settling
    time x stages + peripheral/array power — an array settles, it does
    not execute MACs; its simulator's HLO MACs are still reported, and
    show the differential pair's 2x).
    """
    from repro.core import scorecard

    for r in scorecard.anchor_rows():
        emit(f"energy_projection/anchors/{r['workload']}/{r['name']}",
             r["model"],
             f"paper {r['paper']} rel_err {r['rel_err']:.3f} "
             f"within_tol {r['within_tol']}")

    for r in scorecard.backend_rows():
        hlo = r["hlo"]
        emit(f"energy_projection/{r['workload']}/{r['backend']}",
             r["projected"]["time_us"],
             f"energy_uj {r['projected']['energy_uj']:.3f} substrate "
             f"{r['substrate']} hlo_macs {hlo['macs']:.3e} model_macs "
             f"{r['model_macs']:.3e} traffic_mb "
             f"{hlo['traffic_bytes'] / 1e6:.1f}")


def bench_fault_tolerance():
    """Device faults, write–verify repair, and serving fallback
    (``docs/robustness.md``).

    HP-shaped twin on the fused-analogue substrate.  The ``stuck*``
    rows sweep hard-fault rates and compare naive one-shot programming
    against closed-loop write–verify (same write physics, zero vs
    bounded retries); each rate's ``recovery`` row carries the error
    reduction and whether the repaired array stays within 2x the
    fault-free analogue margin (the acceptance gate at 1%).  The
    ``serving`` rows then break the array outright (30% stuck —
    unrepairable) and show the SLO-armed :class:`FleetServer` serving
    every request via the digital fallback tier with zero NaN outputs.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.analogue import AnalogueSpec, VerifyConfig
    from repro.core.backends import FusedAnalogueBackend
    from repro.core.faults import make_fault_model
    from repro.core.twin import TwinFleet, make_driven_twin
    from repro.launch.fleet_serving import FleetServer, ServingSLO

    T = 100 if FAST else 200
    ts = jnp.linspace(0.0, T * 1e-3, T + 1)

    def family(t, theta):
        return theta[0] * jnp.sin(2.0 * jnp.pi * theta[1] * t)

    twin = make_driven_twin(1, drive=None, hidden=14)
    params = twin.init(jax.random.PRNGKey(0))
    fleet = TwinFleet(twin, drive_family=family)
    n = 16
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    y0s = 0.3 * jax.random.normal(k1, (n, 1))
    thetas = 1.0 + jax.random.uniform(k2, (n, 2))
    ref = fleet.rollout_batch(params, y0s, ts, thetas)
    refn = float(jnp.linalg.norm(ref))
    spec = AnalogueSpec(prog_noise=0.0436)
    pk = jax.random.PRNGKey(7)

    def rollout_err(backend):
        out = fleet.with_backend(backend).rollout_batch(params, y0s, ts,
                                                        thetas)
        return float(jnp.linalg.norm(out - ref)) / refn

    margin = rollout_err(FusedAnalogueBackend(spec=spec, prog_key=pk))
    emit("fault_tolerance/hp/fault_free/rollout_err", 0.0,
         f"{margin:.4f} (prog_noise 4.36%, the repair target x2)")

    for rate in ([0.01] if FAST else [0.005, 0.01, 0.02]):
        fm = make_fault_model(("stuck", dict(rate=rate)),
                              ("write_fail", dict(rate=0.1)), seed=3)
        e_naive = rollout_err(FusedAnalogueBackend(spec=spec, prog_key=pk,
                                                   faults=fm))
        be_v = FusedAnalogueBackend(spec=spec, prog_key=pk, faults=fm,
                                    verify=VerifyConfig())
        us_prog, st = _walltime(
            lambda: be_v.program(twin.node.field, params))
        e_verify = rollout_err(be_v)
        rep = st.extra["repair_reports"]
        unrep = sum(r.n_unrepairable for r in rep)
        emit(f"fault_tolerance/hp/stuck{rate:g}/naive", 0.0,
             f"rollout_err {e_naive:.4f}")
        emit(f"fault_tolerance/hp/stuck{rate:g}/verify", us_prog,
             f"rollout_err {e_verify:.4f} unrepairable_cells {unrep}")
        emit(f"fault_tolerance/hp/stuck{rate:g}/recovery", 0.0,
             f"x{e_naive / max(e_verify, 1e-12):.2f} err reduction "
             f"within_2x_margin {e_verify <= 2.0 * margin}")

    # Unrepairable array: 30% stuck cells cannot be remapped through the
    # differential pairs — the SLO probe demotes to the digital tier and
    # every request is still served finite (degrade energy, not
    # correctness).
    # full-horizon golden probe: stuck-fault deviation accumulates along
    # the trajectory, so a short probe under-reads the serving error
    slo = ServingSLO(max_rel_error=0.05, probe_every=2, probe_horizon=T + 1,
                     probe_fleet=2)
    healthy = fleet.with_backend(FusedAnalogueBackend(spec=spec, prog_key=pk))
    srv_h = FleetServer(healthy, params, ts, slo=slo)
    broken = fleet.with_backend(FusedAnalogueBackend(
        spec=spec, prog_key=pk,
        faults=make_fault_model(("stuck", dict(rate=0.3)), seed=5)))
    srv_b = FleetServer(broken, params, ts, slo=slo)
    batches = 2 if FAST else 4
    nans = {"h": 0, "b": 0}

    def serve_once(srv, key):
        out = srv.serve(y0s, thetas)
        nans[key] += int(jnp.sum(~jnp.isfinite(out)))
        return out

    us_h, _ = _walltime(lambda: serve_once(srv_h, "h"), repeats=batches)
    us_b, _ = _walltime(lambda: serve_once(srv_b, "b"), repeats=batches)
    nan_h, nan_b = nans["h"], nans["b"]
    emit("fault_tolerance/serving/healthy", us_h,
         f"tier {srv_h.active_tier} served_by {srv_h.stats.served_by} "
         f"nan_outputs {nan_h}")
    emit("fault_tolerance/serving/fallback_recovery", us_b,
         f"tier {srv_b.active_tier} served_by {srv_b.stats.served_by} "
         f"nan_outputs {nan_b} demotions {srv_b.stats.probe_demotions} "
         f"probe_err {srv_b.stats.probe_errors.get('analogue_fused', -1):.3f}")


def bench_robustness():
    """The hardware-robustness scorecard: clean-trained vs
    noise-aware-trained weights on the analogue_fused substrate
    (``docs/robustness.md`` — Noise-aware training).

    Both weight sets come from the SAME recipe (HP twin, same seeds);
    the only difference is ``hw_aware=``: the noise-aware run trains
    through the analogue write path (STE 6-bit quantise + programming
    noise + read-noise draws at the calibrated sigma,
    ``calibration/paper_device.json``).  The scorecard then evaluates
    both on the fused analogue substrate across read-noise sigma x
    quantisation levels x stuck-cell severity, averaging the MRE over
    read seeds.

    Gates (asserted in CI and in ``tests/test_hw_aware.py``):
    ``comparison/paper_point`` — at the paper-level operating point
    (6-bit, calibrated read sigma) noise-aware weights must cut the
    trajectory error >= 2x vs clean weights AND land within the
    acceptable margin (2x the clean weights' noise-free analogue error,
    the same margin convention as ``fault_tolerance``).
    """
    import dataclasses as dc

    import jax
    import numpy as np
    from repro.core.analogue import spec_from_calibration
    from repro.core.backends import FusedAnalogueBackend
    from repro.core.faults import make_fault_model
    from repro.train import recipes
    from repro.train.hw_aware import HwAwareConfig

    cal = "calibration/paper_device.json"
    spec = spec_from_calibration(cal)          # 6-bit, sigma_read 0.02
    # FAST keeps the FULL training budgets: the clean model's deployment
    # error is non-monotone in training steps (half-budget runs land in
    # flat minima that deploy well and flip the gate), so only the draw
    # count and the evaluation sweeps are reduced.
    pre, steps = 400, 600
    k_draws = 2 if FAST else 4
    read_seeds = (0, 1) if FAST else (0, 1, 2)

    us_clean, (twin, p_clean, l_clean) = _walltime(
        lambda: recipes.train_hp_twin(seed=42, pretrain_steps=pre,
                                      train_steps=steps))
    cfg = HwAwareConfig(spec=spec, k_draws=k_draws, noise_seed=0)
    us_hw, (_, p_hw, l_hw) = _walltime(
        lambda: recipes.train_hp_twin(seed=42, pretrain_steps=pre,
                                      train_steps=steps, hw_aware=cfg))
    emit("robustness/hp/train/clean", us_clean, f"final_loss {l_clean:.5f}")
    emit("robustness/hp/train/hw_aware", us_hw,
         f"final_loss {l_hw:.5f} k_draws {k_draws} "
         f"overhead x{us_hw / max(us_clean, 1e-9):.2f}")

    def an_mre(params, sp, faults=None, seeds=read_seeds):
        errs = []
        for rs in seeds:
            be = FusedAnalogueBackend(spec=sp, faults=faults,
                                      prog_key=jax.random.PRNGKey(100),
                                      read_seed=rs)
            errs.append(recipes.eval_hp_twin(twin, params, "sine",
                                             backend=be)["mre"])
        return float(np.mean(errs))

    # the acceptable margin: 2x the clean weights' error on the paper's
    # demonstrated deployment (6-bit + programming noise, nominal read)
    spec_nf = dc.replace(spec, read_noise=0.0)
    base = an_mre(p_clean, spec_nf, seeds=(0,))
    margin = 2.0 * base
    emit("robustness/hp/margin", 0.0,
         f"noise_free_mre {base:.4f} margin {margin:.4f} (2x convention)")

    # sigma x levels sweep (both weight sets, same arrays)
    sigmas = [0.02] if FAST else [0.005, 0.01, 0.02]
    levels = [64] if FAST else [64, 16]
    results = {}
    for lv in levels:
        for sg in sigmas:
            sp = dc.replace(spec, levels=lv, read_noise=sg)
            e_c = an_mre(p_clean, sp)
            e_h = an_mre(p_hw, sp)
            results[(lv, sg)] = (e_c, e_h)
            emit(f"robustness/hp/levels{lv}/sigma{sg:g}/clean", 0.0,
                 f"mre {e_c:.4f}")
            emit(f"robustness/hp/levels{lv}/sigma{sg:g}/hw_aware", 0.0,
                 f"mre {e_h:.4f} improvement "
                 f"x{e_c / max(e_h, 1e-12):.2f} "
                 f"within_margin {e_h <= margin}")

    # fault severity (stuck cells on top of the paper point)
    for rate in ([0.01] if FAST else [0.005, 0.01]):
        fm = make_fault_model(("stuck", dict(rate=rate)), seed=3)
        e_c = an_mre(p_clean, spec, faults=fm)
        e_h = an_mre(p_hw, spec, faults=fm)
        emit(f"robustness/hp/stuck{rate:g}/clean", 0.0, f"mre {e_c:.4f}")
        emit(f"robustness/hp/stuck{rate:g}/hw_aware", 0.0,
             f"mre {e_h:.4f} improvement x{e_c / max(e_h, 1e-12):.2f}")

    # the CI-gated acceptance row: paper-level operating point
    e_c, e_h = results[(64, 0.02)]
    improvement = e_c / max(e_h, 1e-12)
    emit("robustness/hp/comparison/paper_point", 0.0,
         f"clean_mre {e_c:.4f} hw_aware_mre {e_h:.4f} "
         f"improvement x{improvement:.2f} within_margin {e_h <= margin} "
         f"gate_2x {improvement >= 2.0}")


def bench_serving_latency():
    """Streaming stateful serving under Poisson load
    (``docs/serving.md``).

    One :class:`StreamingFleetServer` on the fused substrate, resident
    population 4x the hot set (every request risks a page-in), replaying
    a seeded Poisson arrival trace.  Rows:

      ``request_latency``  per-request wall latency submit -> completion
                           (p50/p99 ms) under continuous batching;
      ``throughput``       sustained twin-steps/s over a full closed-loop
                           trace replay, plus the ragged-horizon padding
                           overhead the batcher paid;
      ``paging``           state-store counters proving the hot slab
                           actually paged (evictions > 0) with zero
                           dropped requests.
    """
    import jax
    import numpy as np
    from repro.core.backends import FusedPallasBackend
    from repro.core.twin import TwinFleet, make_autonomous_twin
    from repro.launch import traffic
    from repro.launch.fleet_serving import StreamingFleetServer, StreamStats

    n_req = 60 if FAST else 200
    population = 32 if FAST else 128
    hot = population // 4            # 4x oversubscription: paging is real
    twin = make_autonomous_twin(
        state_dim=8, hidden=16, n_hidden_layers=1, gradient="fused_vjp",
        backend=FusedPallasBackend(precision="f32"))
    params = twin.init(jax.random.PRNGKey(0))
    server = StreamingFleetServer(
        TwinFleet(twin=twin), params, dt=1e-2, hot_capacity=hot,
        max_batch=min(16, hot), max_window=32, horizon_quantum=8)
    trace = traffic.poisson_trace(0, n_req, rate_hz=500.0,
                                  population=population, min_horizon=4,
                                  max_horizon=48)
    rng = np.random.default_rng(1)
    y0s = {a.twin_id: rng.normal(size=8).astype(np.float32) * 0.1
           for a in trace}

    # pass 1 (unmeasured): compiles every (tier, window) program and
    # registers the population, so the measured passes see the steady
    # state a resident server actually serves from
    server.serve_trace(trace, y0_of=y0s.__getitem__)

    # pass 2: per-request wall latency under continuous batching
    server.stream_stats = StreamStats()
    t_submit, lat = {}, []
    for a in trace:
        seq = server.submit(a.twin_id, a.horizon, t_arrival=a.time)
        t_submit[seq] = time.time()
        if server.pending >= server.max_batch:
            for c in server.pump():
                lat.append(time.time() - t_submit.pop(c.seq))
    while server.pending:
        for c in server.pump():
            lat.append(time.time() - t_submit.pop(c.seq))
    assert server.stream_stats.failed == 0 and not t_submit, \
        "dropped requests"
    p50, p99 = np.percentile(np.asarray(lat) * 1e3, [50, 99])
    emit("serving_latency/poisson/request_latency",
         float(np.mean(lat)) * 1e6,
         f"p50_ms {p50:.2f} p99_ms {p99:.2f} n_requests {len(lat)} "
         f"batches {server.stream_stats.batches}")

    # pass 3: sustained throughput over a whole closed-loop replay
    server.stream_stats = StreamStats()
    us_replay, done = _walltime(
        lambda: server.serve_trace(trace, y0_of=y0s.__getitem__))
    s = server.stream_stats
    rate = s.twin_steps / (us_replay * 1e-6)
    overhead = s.padded_steps / max(s.twin_steps + s.padded_steps, 1)
    emit("serving_latency/poisson/throughput", us_replay,
         f"twin_steps_per_s {rate:.0f} served {s.served} "
         f"splits {s.splits} padded_frac {overhead:.2f}")

    st = server.store.stats
    emit("serving_latency/poisson/paging", 0.0,
         f"population {population} hot_capacity {hot} "
         f"evictions {st.evictions} page_ins {st.page_ins} "
         f"hot_hits {st.hot_hits} dropped 0")


def bench_recovery():
    """Crash-safe serving: journal overhead and recovery cost
    (``docs/robustness.md``).

    Rows:

      ``journal_overhead``  per-request latency of the SAME Poisson
                            workload with the fsync'd journal off vs on;
                            derived carries p50/p99 both ways and the
                            p99 ratio (the CI bench-smoke gate:
                            ratio <= 1.2);
      ``replay/interval_K`` crash mid-trace with snapshots every K
                            pumps, then time ``recover()`` (snapshot
                            load + journal replay) — the
                            replay-time-vs-snapshot-cadence trade;
      ``parity``            the zero-loss row: after every crash above,
                            recovered state is bitwise-equal (f32) to
                            the crash-free run and no completion is
                            lost or invented.
    """
    import shutil
    import tempfile

    import jax
    import numpy as np
    from repro.core.backends import FusedPallasBackend
    from repro.core.twin import TwinFleet, make_autonomous_twin
    from repro.launch import chaos, traffic
    from repro.launch.fleet_serving import StreamingFleetServer

    n_req = 40 if FAST else 120
    population = 16 if FAST else 48
    hot = population // 2
    twin = make_autonomous_twin(
        state_dim=8, hidden=16, n_hidden_layers=1, gradient="fused_vjp",
        backend=FusedPallasBackend(precision="f32"))
    params = twin.init(jax.random.PRNGKey(0))
    fleet = TwinFleet(twin=twin)
    kw = dict(dt=1e-2, hot_capacity=hot, max_batch=min(8, hot),
              max_window=16, horizon_quantum=8)
    trace = traffic.poisson_trace(0, n_req, rate_hz=500.0,
                                  population=population, min_horizon=4,
                                  max_horizon=24)
    rng = np.random.default_rng(1)
    y0s = {a.twin_id: rng.normal(size=8).astype(np.float32) * 0.1
           for a in trace}
    y0_of = y0s.__getitem__

    def lat_pass(server):
        """Per-request submit->completion wall latency (ms array)."""
        t_submit, lat = {}, []
        for a in trace:
            if a.twin_id not in server.store:
                server.register_twin(a.twin_id, y0_of(a.twin_id))
            seq = server.submit(a.twin_id, a.horizon, t_arrival=a.time)
            t_submit[seq] = time.time()
            if server.pending >= server.max_batch:
                for c in server.pump(now=a.time):
                    lat.append(time.time() - t_submit.pop(c.seq))
        for c in server.drain(now=trace[-1].time):
            lat.append(time.time() - t_submit.pop(c.seq))
        assert not t_submit, "dropped requests"
        return np.asarray(lat) * 1e3

    # compile pass (unmeasured), then journal-off vs journal-on
    StreamingFleetServer(fleet, params, **kw).serve_trace(trace,
                                                          y0_of=y0_of)
    lat_off = lat_pass(StreamingFleetServer(fleet, params, **kw))
    tmp = tempfile.mkdtemp(prefix="bench_recovery_")
    try:
        lat_on = lat_pass(StreamingFleetServer(
            fleet, params, durability_dir=os.path.join(tmp, "lat"),
            snapshot_every=16, **kw))
        p50_off, p99_off = np.percentile(lat_off, [50, 99])
        p50_on, p99_on = np.percentile(lat_on, [50, 99])
        ratio = p99_on / max(p99_off, 1e-9)
        emit("recovery/journal_overhead", float(np.mean(lat_on)) * 1e3,
             f"p50_off_ms {p50_off:.3f} p99_off_ms {p99_off:.3f} "
             f"p50_on_ms {p50_on:.3f} p99_on_ms {p99_on:.3f} "
             f"p99_ratio {ratio:.3f}")

        # crash-free reference for the parity row
        ref = StreamingFleetServer(fleet, params, **kw)
        ref_done = ref.serve_trace(trace, y0_of=y0_of)
        ref_ids, _, _, _ = ref.store.export_state()

        lost = phantom = diverged = 0
        for interval in (4, 16, 64):
            d = os.path.join(tmp, f"replay_{interval}")
            live = StreamingFleetServer(fleet, params, durability_dir=d,
                                        snapshot_every=interval, **kw)
            delivered = []
            try:
                with chaos.crash_at("pump:post_commit", hit=n_req // 8):
                    live.serve_trace(trace, y0_of=y0_of, sink=delivered)
            except chaos.SimulatedCrash:
                pass
            jbytes = live._journal.nbytes
            t0 = time.time()
            rec, redelivered = StreamingFleetServer.recover(d, fleet,
                                                            params)
            recover_ms = (time.time() - t0) * 1e3
            resumed = rec.serve_trace(trace, y0_of=y0_of,
                                      start=rec.stream_stats.enqueued)
            got = {c.seq for c in delivered} | \
                  {c.seq for c in redelivered} | {c.seq for c in resumed}
            ref_seqs = {c.seq for c in ref_done}
            lost += len(ref_seqs - got)
            phantom += len(got - ref_seqs)
            for tid in ref_ids:
                y_ref, s_ref = ref.store.peek(tid)
                y_rec, s_rec = rec.store.peek(tid)
                if s_ref != s_rec or not np.array_equal(y_ref, y_rec):
                    diverged += 1
            emit(f"recovery/replay/interval_{interval}",
                 recover_ms * 1e3,
                 f"recover_ms {recover_ms:.1f} journal_bytes {jbytes} "
                 f"replayed {len(redelivered)} "
                 f"resumed {len(resumed)}")
        emit("recovery/parity", 0.0,
             f"lost {lost} phantom {phantom} diverged_twins {diverged} "
             f"bitwise {'true' if not (lost or phantom or diverged) else 'FALSE'}")
        assert not (lost or phantom or diverged), \
            "recovery parity violated (see recovery/parity row)"
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def bench_roofline():
    import glob
    import json
    files = sorted(glob.glob("runs/dryrun/*.json"))
    if not files:
        print("  (no dry-run artifacts found; run repro.launch.dryrun)")
        return
    for f in files:
        d = json.load(open(f))
        name = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}"
        t_step = max(d["t_compute_s"], d["t_memory_s"], d["t_collective_s"])
        emit(name, t_step * 1e6,
             f"{d['bottleneck']}-bound frac {d['roofline_fraction']:.4f}")


BENCHES = {
    "fig3j_hp_errors": bench_fig3j_hp_errors,
    "fig3kl_hp_energy": bench_fig3kl_hp_energy,
    "fig4g_l96_errors": None,   # chained with fig4j below
    "fig4hi_l96_energy": bench_fig4hi_l96_energy,
    "fig4j_noise": None,
    "kernels": bench_kernels,
    "fleet_backends": bench_fleet_backends,
    "energy_projection": bench_energy_projection,
    "fleet_sharded": bench_fleet_sharded,
    "train_throughput": bench_train_throughput,
    "fault_tolerance": bench_fault_tolerance,
    "robustness": bench_robustness,
    "serving_latency": bench_serving_latency,
    "recovery": bench_recovery,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    help="module to run (repeatable); default: all")
    ap.add_argument("--artifact-dir", default=".",
                    help="where BENCH_<module>.json artifacts are written")
    args = ap.parse_args()
    t0 = time.time()
    names = args.only if args.only else list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown module(s) {unknown}; have {sorted(BENCHES)}")
    l96_state = None
    for name in names:
        print(f"\n=== {name} ===")
        start = len(ROWS)
        if name == "fig4g_l96_errors":
            l96_state = bench_fig4g_l96_errors()
        elif name == "fig4j_noise":
            bench_fig4j_noise(l96_state)
        else:
            BENCHES[name]()
        if len(ROWS) > start:
            write_artifact(name, ROWS[start:], args.artifact_dir)
    print(f"\nname,us_per_call,derived  ({len(ROWS)} rows, "
          f"{time.time()-t0:.0f}s total)")
    for r in ROWS:
        print(f"{r[0]},{r[1]:.3f},{r[2]}")


if __name__ == "__main__":
    main()
